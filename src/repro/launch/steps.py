"""Step functions lowered by the dry-run and used by the real drivers.

  * fsvrg_round_step — the paper's technique: one federated round
    (full-grad all-reduce + local VR epochs + scaled aggregation).
    This is the `train` entry in the roofline table.
  * adamw_train_step — standard centralized training step (baseline
    substrate; also what the FSVRGR/centralized comparisons use).
  * serve_prefill / serve_decode_step — inference entries.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.core.neural import FedNeuralConfig, make_fsvrg_round
from repro.models.model import Model
from repro.optim import Optimizer


def make_fsvrg_step(model: Model, fed_cfg: FedNeuralConfig) -> Callable:
    round_fn = make_fsvrg_round(model, fed_cfg)

    def step(params, client_batches):
        return round_fn(params, client_batches)

    return step


def make_adamw_step(model: Model, opt: Optimizer) -> Callable:
    def step(params, opt_state, opt_step, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        params, opt_state = opt.update(params, grads, opt_state, opt_step)
        return params, opt_state, opt_step + 1, loss, metrics

    return step


def make_prefill_step(model: Model) -> Callable:
    def step(params, batch):
        return model.prefill(params, batch)

    return step


def make_decode_step(model: Model) -> Callable:
    def step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return step
