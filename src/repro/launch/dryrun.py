import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes and emit memory / cost / collective analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]

The XLA_FLAGS line above MUST precede any jax import (jax locks the device
count at first init).  This module is the only place that flag is set —
smoke tests and benchmarks see the single real CPU device.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ArchConfig, InputShape
from repro.core.neural import FedNeuralConfig
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_decode_step,
                                make_fsvrg_step, make_prefill_step)
from repro.models import build_model
from repro.sharding import (batch_shardings, cache_shardings,
                            params_shardings, replicated)
from repro.utils import roofline as RL


def combo_supported(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 524k KV decode skipped (DESIGN.md)"
    return True, ""


def lower_combo(arch_id: str, shape_name: str, *, multi_pod: bool = False,
                dtype=jnp.bfloat16, fed_cfg: FedNeuralConfig | None = None,
                step_override=None, verbose: bool = True):
    """Returns (Roofline, dict) or raises on lowering/compile failure."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, why = combo_supported(cfg, shape)
    if not ok:
        return None, {"arch": arch_id, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    model = build_model(cfg, dtype)

    t0 = time.time()
    with jax.set_mesh(mesh):
        if shape.kind == "train":
            step = step_override or make_fsvrg_step(
                model, fed_cfg or FedNeuralConfig(local_steps=S.FED_LOCAL_STEPS))
            p_specs, b_specs = S.input_specs(cfg, shape, model, dtype)
            in_sh = (params_shardings(p_specs, mesh),
                     batch_shardings(b_specs, mesh, client_axis=True))
            out_sh = (params_shardings(p_specs, mesh), replicated(mesh))
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(
                p_specs, b_specs)
        elif shape.kind == "prefill":
            step = step_override or make_prefill_step(model)
            p_specs, b_specs = S.input_specs(cfg, shape, model, dtype)
            cache_out = jax.eval_shape(step, p_specs, b_specs)[1]
            in_sh = (params_shardings(p_specs, mesh),
                     batch_shardings(b_specs, mesh))
            out_sh = (replicated(mesh), cache_shardings(cache_out, mesh))
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(
                p_specs, b_specs)
        else:  # decode
            step = step_override or make_decode_step(model)
            p_specs, t_specs, c_specs = S.input_specs(cfg, shape, model, dtype)
            c_sh = cache_shardings(c_specs, mesh)
            in_sh = (params_shardings(p_specs, mesh),
                     batch_shardings(t_specs, mesh), c_sh)
            out_sh = (replicated(mesh), c_sh)
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(
                p_specs, t_specs, c_specs)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    hlo = compiled.as_text()
    rl = RL.analyze(arch_id, shape_name, mesh_name, chips, compiled, hlo,
                    RL.model_flops_for(cfg, shape))
    mem = compiled.memory_analysis()
    info = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "hlo_flops": rl.hlo_flops, "hlo_bytes": rl.hlo_bytes,
        "coll_bytes": rl.coll_bytes, "coll_breakdown": rl.coll_breakdown,
        "t_compute_ms": rl.t_compute * 1e3, "t_memory_ms": rl.t_memory * 1e3,
        "t_collective_ms": rl.t_collective * 1e3,
        "bottleneck": rl.bottleneck,
        "model_flops": rl.model_flops,
        "useful_flops_ratio": rl.useful_flops_ratio,
        "bytes_per_chip": {
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "args": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
        },
    }
    if verbose:
        print(f"[dryrun] {rl.row()}  (lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"         memory_analysis: temp={info['bytes_per_chip']['temp']} "
              f"args={info['bytes_per_chip']['args']} out={info['bytes_per_chip']['output']}")
    return rl, info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="input shape (default: all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    failures = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                try:
                    rl, info = lower_combo(a, s, multi_pod=mp)
                    results.append(info)
                    if rl is None:
                        print(f"[dryrun] SKIP {a} {s}: {info['skipped']}")
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures.append((a, s, mp, repr(e)[:500]))
                    print(f"[dryrun] FAIL {a} {s} multi_pod={mp}: {repr(e)[:300]}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"results": results,
                       "failures": [list(f_) for f_ in failures]}, f, indent=1)
    print(f"\n[dryrun] done: {len(results)} lowered/skipped, {len(failures)} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
