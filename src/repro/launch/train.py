"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --mode fsvrg \
        --rounds 100 [--reduced] [--checkpoint-dir ckpts/]

Modes:
  fsvrg  — the paper's federated rounds (core/neural.py)
  fedavg — local-SGD baseline rounds
  adamw  — centralized training step (the FSVRGR/centralized reference)

On this container run with --reduced (CPU).  On a real TPU slice the same
driver runs the full config under the production mesh: params/batches get
their rule-engine shardings and the step is jit-compiled once.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save
from repro.configs import ARCH_IDS, get_config
from repro.core import neural
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.sharding import params_shardings


def synthetic_batch(rng, cfg, num_clients, local_steps, batch_per_client, seq):
    toks = rng.integers(0, cfg.vocab_size,
                        size=(num_clients, local_steps, batch_per_client, seq + 1))
    batch = {
        "tokens": jnp.asarray(toks[..., :-1], jnp.int32),
        "labels": jnp.asarray(toks[..., 1:], jnp.int32),
        "mask": jnp.ones(toks[..., 1:].shape, jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((num_clients, local_steps, batch_per_client,
                                 cfg.frontend_tokens, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec_audio":
        batch["frame_embeds"] = jnp.asarray(
            rng.standard_normal((num_clients, local_steps, batch_per_client,
                                 cfg.frontend_tokens, cfg.d_model)), jnp.float32)
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--mode", default="fsvrg", choices=["fsvrg", "fedavg", "adamw"])
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch-per-client", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--stepsize", type=float, default=0.5)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.float32 if args.reduced else jnp.bfloat16
    model = build_model(cfg, dtype)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()

    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"[train] {cfg.name} mode={args.mode} params={n_params/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    with jax.set_mesh(mesh):
        p_sh = params_shardings(params, mesh)
        params = jax.device_put(params, p_sh)

        if args.mode in ("fsvrg", "fedavg"):
            fed = neural.FedNeuralConfig(stepsize=args.stepsize,
                                         local_steps=args.local_steps,
                                         algorithm=args.mode)
            step = jax.jit(neural.make_fsvrg_round(model, fed),
                           in_shardings=(p_sh, None), out_shardings=(p_sh, None))
            t0 = time.time()
            for r in range(args.rounds):
                batch = synthetic_batch(rng, cfg, args.clients, args.local_steps,
                                        args.batch_per_client, args.seq)
                params, metrics = step(params, batch)
                if (r + 1) % args.log_every == 0 or r == 0:
                    flat = jax.tree.map(lambda x: x[0, 0], batch)
                    loss = float(model.loss(params, flat)[0])
                    print(f"round {r+1:4d}: loss={loss:.4f} "
                          f"|∇f|={float(metrics['full_grad_norm']):.4f} "
                          f"({time.time()-t0:.0f}s)")
        else:  # adamw
            opt = adamw(args.lr)
            opt_state = opt.init(params)
            opt_step = jnp.zeros((), jnp.int32)

            @jax.jit
            def train_step(params, opt_state, opt_step, batch):
                (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
                    params, batch)
                params, opt_state = opt.update(params, grads, opt_state, opt_step)
                return params, opt_state, opt_step + 1, loss

            t0 = time.time()
            for r in range(args.rounds):
                b = synthetic_batch(rng, cfg, 1, 1,
                                    args.clients * args.batch_per_client, args.seq)
                flat = jax.tree.map(lambda x: x[0, 0], b)
                params, opt_state, opt_step, loss = train_step(
                    params, opt_state, opt_step, flat)
                if (r + 1) % args.log_every == 0 or r == 0:
                    print(f"step {r+1:4d}: loss={float(loss):.4f} "
                          f"({time.time()-t0:.0f}s)")

    if args.checkpoint_dir:
        save(args.checkpoint_dir, params, step=args.rounds,
             metadata={"arch": cfg.name, "mode": args.mode})
        print(f"[train] checkpoint -> {args.checkpoint_dir}")


if __name__ == "__main__":
    main()
