"""ShapeDtypeStruct stand-ins for every model input — the dry-run never
allocates real arrays (shannon/kernels pattern: weak-type-correct,
shardable, no device memory).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape

# federated round layout for train shapes: C waves × T local steps
FED_WAVES = 4
FED_LOCAL_STEPS = 1


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape,
                      dtype=jnp.bfloat16, *, federated: bool = True) -> Dict:
    """Batch ShapeDtypeStructs.

    federated=True: client layout (C, T, B_c, ...) for the FSVRG round.
    federated=False: flat (B, ...) for the centralized AdamW step.
    """
    B, S = shape.global_batch, shape.seq_len
    if federated:
        C, T = FED_WAVES, FED_LOCAL_STEPS
        Bc = B // (C * T)
        lead = (C, T, Bc)
    else:
        lead = (B,)

    def tok(*tail):
        return sds(lead + tuple(tail), jnp.int32)

    def f32(*tail):
        return sds(lead + tuple(tail), jnp.float32)

    def emb(*tail):
        return sds(lead + tuple(tail), dtype)

    if cfg.family == "vlm":
        P = cfg.frontend_tokens
        return {"tokens": tok(S - P), "labels": tok(S - P), "mask": f32(S - P),
                "patch_embeds": emb(P, cfg.d_model)}
    if cfg.family == "encdec_audio":
        F = cfg.frontend_tokens
        return {"tokens": tok(S), "labels": tok(S), "mask": f32(S),
                "frame_embeds": emb(F, cfg.d_model)}
    return {"tokens": tok(S), "labels": tok(S), "mask": f32(S)}


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape, dtype=jnp.bfloat16) -> Dict:
    return train_batch_specs(cfg, shape, dtype, federated=False)


def decode_token_specs(shape: InputShape) -> jax.ShapeDtypeStruct:
    return sds((shape.global_batch, 1), jnp.int32)


def params_specs(model) -> Dict:
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def cache_specs(model, shape: InputShape):
    if model.cfg.family == "encdec_audio":
        return jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                     model.cfg.frontend_tokens))
    return jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))


def input_specs(cfg: ArchConfig, shape: InputShape, model, dtype=jnp.bfloat16):
    """All positional input specs for the step that `shape.kind` selects."""
    if shape.kind == "train":
        return (params_specs(model), train_batch_specs(cfg, shape, dtype))
    if shape.kind == "prefill":
        return (params_specs(model), prefill_batch_specs(cfg, shape, dtype))
    if shape.kind == "decode":
        return (params_specs(model), decode_token_specs(shape), cache_specs(model, shape))
    raise ValueError(shape.kind)
