"""Serving driver: batched prefill + decode loop with request batching.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --requests 8

Runs the reduced config on CPU; the same step functions are what the
dry-run lowers for the production mesh (decode_32k / long_500k shapes).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.models import build_model, make_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))

    batch = make_batch(cfg, InputShape("serve", args.prompt_len, args.requests,
                                       "prefill"), dtype=jnp.float32)
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch)
    t_prefill = time.time() - t0
    step = jax.jit(model.decode_step)

    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.time()
    n_done = 0
    for _ in range(args.max_new - 1):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None]
        n_done += 1
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[serve] {cfg.name}: prefill {args.requests}x{args.prompt_len} "
          f"in {t_prefill:.2f}s; {n_done} decode steps in {dt:.2f}s "
          f"({args.requests * n_done / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
