"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16×16 = 256 v5e chips, axes
('data','model').  Multi-pod: 2×16×16 = 512 chips, axes
('pod','data','model') — the pod axis is pure DP (and, in federated mode,
the client-group axis).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1×1 mesh over the single CPU device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
