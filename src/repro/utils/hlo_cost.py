"""Trip-count-aware structural cost analysis of optimized (SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits every while-loop body
ONCE, so anything under a `lax.scan` (layer stacks, client waves, flash
blocks) under-reports FLOPs, bytes and — via HLO-text parsing — collective
traffic by its trip count.  XLA's optimized HLO carries
``backend_config={"known_trip_count":{"n":...}}`` on while ops, which lets
us do the accounting properly:

  cost(computation) = Σ op costs,  with
  cost(while)  = trip × (cost(body) + cost(cond))
  cost(fusion) = operand+output bytes, FLOPs of the fused computation
  cost(call)   = cost(callee);  cost(conditional) = max(branch costs)

FLOPs: dots = 2·prod(out)·prod(contracted dims); elementwise/reduce ≈ one
flop per output (or input for reduce) element.  Bytes: operands + outputs of
top-level compute ops (fusion internals excluded — matches post-fusion
"bytes accessed" semantics).  Collectives: operand bytes × loop multiplier,
per collective kind.

All values are per-chip (the HLO is the per-partition SPMD module).
Validated against cost_analysis() on loop-free graphs in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s2": 1, "u2": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "exponential", "log", "tanh", "negate", "abs", "sign", "rsqrt", "sqrt",
    "compare", "select", "and", "or", "not", "xor", "convert", "floor",
    "ceil", "round-nearest-afz", "clamp", "atan2", "expm1", "log1p",
    "logistic", "cosine", "sine", "remainder", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "is-finite",
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]
    parts: Optional[List["Shape"]] = None      # tuple shapes

    @property
    def elements(self) -> int:
        if self.parts is not None:
            return sum(p.elements for p in self.parts)
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        if self.parts is not None:
            return sum(p.bytes for p in self.parts)
        return self.elements * _DTYPE_BYTES.get(self.dtype, 4)


def _parse_shape(text: str) -> Shape:
    text = text.strip()
    if text.startswith("("):
        depth, parts, cur = 0, [], []
        for ch in text[1:-1]:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            if ch == "," and depth == 0:
                parts.append("".join(cur))
                cur = []
            else:
                cur.append(ch)
        if cur:
            parts.append("".join(cur))
        return Shape("tuple", (), [_parse_shape(p) for p in parts if p.strip()])
    m = _SHAPE_RE.match(text)
    if not m:
        return Shape("opaque", ())
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return Shape(m.group(1), dims)


@dataclasses.dataclass
class Op:
    name: str
    shape: Shape
    opcode: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Optional[Dict[str, float]] = None
    transcendentals: float = 0.0

    def __post_init__(self):
        if self.coll is None:
            self.coll = {k: 0.0 for k in _COLLECTIVES}

    def __add__(self, o: "Cost") -> "Cost":
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    {k: self.coll[k] + o.coll[k] for k in self.coll},
                    self.transcendentals + o.transcendentals)

    def __mul__(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()},
                    self.transcendentals * f)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_line(stripped: str):
    """'%x = <shape> opcode(...)' -> (name, shape_str, opcode, rest) or None.

    Handles tuple shapes with embedded /*index=N*/ comments (which defeat
    naive regexes) via balanced-paren scanning.
    """
    s = stripped.lstrip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape_s = _COMMENT_RE.sub("", rest[: end + 1])
        tail = rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape_s = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    par = tail.find("(")
    if par <= 0:
        return None
    opcode = tail[:par].strip()
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return name, shape_s, opcode, tail[par:]
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._cost_cache: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            stripped = line.rstrip()
            if not stripped:
                continue
            mc = _COMP_RE.match(stripped)
            if mc and stripped.endswith("{"):
                cur = mc.group(1)
                self.computations[cur] = []
                if stripped.startswith("ENTRY"):
                    self.entry = cur
                continue
            if stripped.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            parsed = _parse_line(stripped)
            if parsed is None:
                continue
            name, shape_s, opcode, rest = parsed
            self.computations[cur].append(
                Op(name=name, shape=_parse_shape(shape_s), opcode=opcode,
                   operands=[], raw=stripped))

    # ------------------------------------------------------------- #
    def _symbols(self, comp: str) -> Dict[str, Shape]:
        out = {}
        for op in self.computations[comp]:
            out[op.name] = op.shape
        return out

    def _dot_flops(self, op: Op, syms: Dict[str, Shape]) -> float:
        # operands: first two %refs in the args portion of the line
        args = op.raw.split("(", 1)[1]
        refs = _OPERAND_RE.findall(args)
        contract = 1
        mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.raw)
        if mcd and refs:
            lhs = syms.get(refs[0])
            if lhs is not None and lhs.dims:
                for d in mcd.group(1).split(","):
                    if d:
                        di = int(d)
                        if di < len(lhs.dims):
                            contract *= lhs.dims[di]
        return 2.0 * op.shape.elements * contract

    def _op_cost(self, op: Op, comp: str, syms: Dict[str, Shape],
                 *, top_level: bool) -> Cost:
        oc = op.opcode
        c = Cost()
        if oc == "while":
            trip = 1
            mt = _TRIP_RE.search(op.raw)
            if mt:
                trip = int(mt.group(1))
            body = re.search(r"body=%?([\w.\-]+)", op.raw)
            cond = re.search(r"condition=%?([\w.\-]+)", op.raw)
            sub = Cost()
            if body:
                sub = sub + self.computation_cost(body.group(1))
            if cond:
                sub = sub + self.computation_cost(cond.group(1))
            return sub * trip
        if oc in ("call", "async-start"):
            m = re.search(r"to_apply=%?([\w.\-]+)", op.raw)
            if m:
                return self.computation_cost(m.group(1))
            return c
        if oc == "conditional":
            m = re.search(r"branch_computations=\{([^}]*)\}", op.raw)
            if m:
                branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                costs = [self.computation_cost(b) for b in branches if b in self.computations]
                if costs:
                    best = max(costs, key=lambda x: x.flops + x.bytes)
                    return best
            return c
        if oc == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.raw)
            inner = Cost()
            if m and m.group(1) in self.computations:
                inner = self.computation_cost(m.group(1), fused=True)
            c.flops = inner.flops
            c.transcendentals = inner.transcendentals
            c.coll = dict(inner.coll)
            if top_level:
                c.bytes = self._io_bytes(op, syms)
            return c
        if oc in _COLLECTIVES:
            b = op.shape.bytes  # result size ≈ shard traffic proxy
            c.coll[oc] = float(b)
            c.bytes = self._io_bytes(op, syms) if top_level else 0.0
            return c
        if oc == "dot":
            c.flops = self._dot_flops(op, syms)
            if top_level:
                c.bytes = self._io_bytes(op, syms)
            return c
        if oc == "convolution":
            # rough: 2 * out_elements * (kernel spatial * in_features)
            c.flops = 2.0 * op.shape.elements * 128.0
            if top_level:
                c.bytes = self._io_bytes(op, syms)
            return c
        if oc == "reduce" or oc == "reduce-window":
            refs = _OPERAND_RE.findall(op.raw.split("(", 1)[1])
            in_el = syms.get(refs[0], op.shape).elements if refs else op.shape.elements
            c.flops = float(in_el)
            if top_level:
                c.bytes = self._io_bytes(op, syms)
            return c
        if oc in _ELEMENTWISE or oc in ("scatter", "gather", "iota", "broadcast",
                                        "transpose", "reshape", "concatenate",
                                        "slice", "dynamic-slice",
                                        "dynamic-update-slice", "pad", "copy",
                                        "reverse", "sort", "exponential-minus-one"):
            if oc in _ELEMENTWISE:
                c.flops = float(op.shape.elements)
                if oc in ("exponential", "log", "tanh", "logistic", "power",
                          "cosine", "sine", "rsqrt", "sqrt", "expm1", "log1p"):
                    c.transcendentals = float(op.shape.elements)
            if top_level and oc not in ("reshape", "bitcast"):
                c.bytes = self._io_bytes(op, syms)
            return c
        return c

    def _io_bytes(self, op: Op, syms: Dict[str, Shape]) -> float:
        args = op.raw.split("(", 1)[1]
        # cut metadata/backed_config tails to avoid matching comp names
        args = args.split("metadata=")[0].split("backend_config=")[0]
        total = float(op.shape.bytes)
        for ref in _OPERAND_RE.findall(args):
            s = syms.get(ref)
            if s is not None:
                total += s.bytes
        return total

    def computation_cost(self, comp: str, fused: bool = False) -> Cost:
        key = f"{comp}|{fused}"
        if key in self._cost_cache:
            return self._cost_cache[key]
        if comp not in self.computations:
            return Cost()
        syms = self._symbols(comp)
        total = Cost()
        for op in self.computations[comp]:
            total = total + self._op_cost(op, comp, syms, top_level=not fused)
        self._cost_cache[key] = total
        return total

    def entry_cost(self) -> Cost:
        if self.entry is None:
            # fall back: the computation with the greatest cost
            best = Cost()
            for comp in self.computations:
                c = self.computation_cost(comp)
                if c.flops + c.bytes > best.flops + best.bytes:
                    best = c
            return best
        return self.computation_cost(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
