"""Runtime flags (env-var driven, read at trace time).

REPRO_DRYRUN_UNROLL=1 — unroll the matmul-dominated scans (layer stack,
client waves, loss chunks) so ``compiled.cost_analysis()`` counts their
FLOPs/bytes correctly: XLA's HloCostAnalysis visits a while-loop body ONCE,
so scanned structures under-report by their trip count.  Token-level
recurrent scans (flash-attention blocks, Mamba/RWKV time steps) stay rolled —
their FLOPs are <1% of the matmul total for every assigned arch (see
EXPERIMENTS.md §Roofline methodology).

Only ``repro.launch.dryrun`` sets this; training/serving/tests keep compact
scanned HLO.
"""
from __future__ import annotations

import os


def scan_unroll():
    """Value for lax.scan(unroll=...) on matmul-dominated scans."""
    return True if os.environ.get("REPRO_DRYRUN_UNROLL") == "1" else 1
