"""Roofline analysis from a compiled dry-run artifact (no hardware needed).

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs          / PEAK_FLOPS
  memory     = HLO_bytes          / HBM_BW
  collective = Σ collective-bytes / LINK_BW

``compiled.cost_analysis()`` on an SPMD executable reports *per-partition*
(per-chip) FLOPs and bytes, so no further division by chip count is applied
(this matches the formula compute = HLO_FLOPs_total / (chips × peak) since
HLO_FLOPs_total = chips × per-chip).  Collective bytes are NOT in
cost_analysis: we parse the optimized HLO text and sum operand sizes of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (per-chip shapes, so the sum is per-chip traffic).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[4,1024,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?\s(" + "|".join(_COLLECTIVES) + r")\(",
)
# tuple-result collectives:  = (f32[...], f32[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(((?:[a-z0-9]+\[[\d,]*\][^,()]*,?\s*)+)\)\s*(" + "|".join(_COLLECTIVES) + r")\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    total = b
    if dims.strip():
        for d in dims.split(","):
            total *= int(d)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind byte totals from (S)HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        out[kind] += _shape_bytes(dtype, dims)
    for m in _TUPLE_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        for sm in _SHAPE_RE.finditer(shapes):
            out[kind] += _shape_bytes(sm.group(1), sm.group(2))
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float           # 6·N·D (dense) or 6·N_active·D
    bytes_per_chip: Optional[float] = None   # from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS      # hlo_flops is per-chip

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW          # hlo_bytes is per-chip

    @property
    def t_collective(self) -> float:
        # coll_bytes parsed from SPMD HLO is already per-chip traffic
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips × per-chip HLO_FLOPs)."""
        total = self.chips * self.hlo_flops
        return self.model_flops / total if total else 0.0

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:9s} "
                f"comp={self.t_compute*1e3:9.2f}ms mem={self.t_memory*1e3:9.2f}ms "
                f"coll={self.t_collective*1e3:9.2f}ms -> {self.bottleneck:10s} "
                f"useful={self.useful_flops_ratio:6.3f}")


# effective traffic multiplier per collective kind (ring algorithms):
# all-reduce moves ~2× its payload; gather/scatter/permute ~1×.
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def analyze(arch, shape, mesh_name, chips, compiled, lowered_text,
            model_flops) -> Roofline:
    """Structural, trip-count-aware cost analysis (see utils/hlo_cost.py).

    ``compiled.cost_analysis()`` counts while bodies once, so every scanned
    structure (layer stacks, client waves) under-reports by its trip count;
    the structural analyzer multiplies loop bodies by
    backend_config.known_trip_count.  cost_analysis values are kept in the
    JSON dump as a cross-check.
    """
    from repro.utils import hlo_cost

    cost = hlo_cost.analyze_text(lowered_text)
    coll = {k: v * _COLL_FACTOR[k] for k, v in cost.coll.items()}
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=cost.flops, hlo_bytes=cost.bytes,
                    coll_bytes=float(sum(coll.values())), coll_breakdown=coll,
                    model_flops=model_flops, bytes_per_chip=mem)


def model_flops_for(cfg, shape, *, federated_waves: int = 4,
                    local_steps: int = 1) -> float:
    """MODEL_FLOPS = 6·N·D with D = tokens processed by the step.

    For the FSVRG round the step runs (1 full-grad + 2 per local step)
    gradient passes over the global batch; a gradient pass ≈ 3× forward, and
    6·N·D already counts fwd+bwd, so the round's useful FLOPs are
    (1 + 2·local_steps) × 6·N·D_batch... conservatively we report the
    single-pass 6·N·D and let `useful_flops_ratio` expose the multiplier.
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        passes = 1 + 2 * local_steps     # full grad + (new,old) grads per step
        return 6.0 * n_active * tokens * passes
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
