from repro.data.synthetic import (FederatedDataset, VirtualDataset, generate,
                                  make_client_batch, train_split_sizes,
                                  virtual_dataset)

__all__ = ["FederatedDataset", "VirtualDataset", "generate",
           "make_client_batch", "train_split_sizes", "virtual_dataset"]
