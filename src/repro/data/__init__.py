from repro.data.synthetic import FederatedDataset, generate

__all__ = ["FederatedDataset", "generate"]
