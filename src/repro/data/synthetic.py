"""Synthetic federated sparse-logreg data matching the paper's §4 statistics.

The original Google+ dataset cannot be released (paper footnote 8).  We
generate a synthetic substitute reproducing every property the experiment
depends on:

  * massively distributed: K clients (paper: 10,000)
  * unbalanced: n_k power-law in [min_client_examples, max_client_examples]
    (paper: 75..9,000, mean ~216)
  * non-IID: each client has a private "vocabulary" — a Dirichlet-weighted
    subset of features — plus globally common features (bias, unknown-word),
    giving the Fig.-1 feature-vs-node occupancy profile
  * sparse: fixed nnz bag-of-words rows
  * per-client label bias so "predict the per-author majority" beats the
    global model (the paper's 17.14% vs 26.27% observation)
  * chronological 75/25 train/test split per client
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class FederatedDataset:
    """Sparse design matrix in fixed-nnz row format, partitioned by client."""

    idx: np.ndarray        # (n, nnz) int32 feature indices (val==0 -> padding)
    val: np.ndarray        # (n, nnz) float32
    y: np.ndarray          # (n,) float32 in {-1, +1}
    client_of: np.ndarray  # (n,) int32
    client_sizes: np.ndarray  # (K,) int32
    num_features: int

    # test split (same format)
    test_idx: np.ndarray
    test_val: np.ndarray
    test_y: np.ndarray
    test_client_of: np.ndarray

    @property
    def num_clients(self) -> int:
        return len(self.client_sizes)

    @property
    def num_examples(self) -> int:
        return len(self.y)

    def client_slices(self) -> List[slice]:
        """Examples are stored client-contiguous."""
        out, start = [], 0
        for nk in self.client_sizes:
            out.append(slice(start, start + int(nk)))
            start += int(nk)
        return out


def _power_law_sizes(rng, K, n_total, n_min, n_max, alpha=1.6):
    raw = (rng.pareto(alpha, size=K) + 1.0) * n_min
    raw = np.clip(raw, n_min, n_max)
    sizes = np.maximum(n_min, (raw / raw.sum() * n_total)).astype(np.int64)
    sizes = np.clip(sizes, n_min, n_max)
    return sizes


def generate(cfg, seed: int = 0) -> FederatedDataset:
    """cfg: repro.configs.gplus_logreg.LogRegConfig (possibly .scaled())."""
    rng = np.random.default_rng(seed)
    K, d = cfg.num_clients, cfg.num_features
    nnz = min(cfg.nnz_per_example, d - 2)

    sizes = _power_law_sizes(rng, K, cfg.num_examples,
                             cfg.min_client_examples, cfg.max_client_examples)
    n = int(sizes.sum())

    # ground-truth weights: heavy-tailed so rare features carry signal
    w_true = rng.standard_normal(d) * (rng.random(d) < 0.3)

    # global feature popularity (zipf over non-special features)
    ranks = np.arange(2, d)
    global_pop = 1.0 / ranks ** 1.1
    global_pop /= global_pop.sum()

    vocab_size = max(8, int(0.02 * d))  # private vocabulary per client

    all_idx = np.zeros((n, nnz + 2), np.int32)
    all_val = np.zeros((n, nnz + 2), np.float32)
    all_y = np.zeros(n, np.float32)
    client_of = np.zeros(n, np.int32)

    start = 0
    for k in range(K):
        nk = int(sizes[k])
        # client vocabulary: a zipf-weighted random subset + global mass
        own = rng.choice(np.arange(2, d), size=vocab_size, replace=False,
                         p=global_pop)
        mix_w = rng.dirichlet(np.full(vocab_size, 0.3))
        # per-example features: mostly from own vocab, some global
        n_own = int(0.8 * nnz)
        own_feats = rng.choice(own, size=(nk, n_own), p=mix_w)
        glob_feats = rng.choice(np.arange(2, d), size=(nk, nnz - n_own), p=global_pop)
        feats = np.concatenate([own_feats, glob_feats], axis=1)

        rows_idx = np.concatenate(
            [np.zeros((nk, 1), np.int32),                     # bias
             np.ones((nk, 1), np.int32),                      # unknown-word
             feats.astype(np.int32)], axis=1)
        rows_val = np.ones((nk, nnz + 2), np.float32)
        # dedupe within a row: zero out repeated features (keeps fixed width)
        srt = np.sort(rows_idx, axis=1)
        dup = np.concatenate([np.zeros((nk, 1), bool), srt[:, 1:] == srt[:, :-1]], axis=1)
        order = np.argsort(rows_idx, axis=1)
        inv = np.argsort(order, axis=1)
        rows_val *= ~np.take_along_axis(dup, inv, axis=1)

        margin = (rows_val * w_true[rows_idx]).sum(axis=1)
        client_bias = rng.standard_normal() * 1.5              # non-IID label skew
        p = 1.0 / (1.0 + np.exp(-(0.7 * margin + client_bias)))
        yk = np.where(rng.random(nk) < p, 1.0, -1.0).astype(np.float32)

        sl = slice(start, start + nk)
        all_idx[sl], all_val[sl], all_y[sl] = rows_idx, rows_val, yk
        client_of[sl] = k
        start += nk

    # chronological 75/25 split per client (synthetic order = time order)
    tr_mask = np.zeros(n, bool)
    start = 0
    tr_sizes = np.zeros(K, np.int64)
    for k in range(K):
        nk = int(sizes[k])
        cut = max(1, int(0.75 * nk))
        tr_mask[start : start + cut] = True
        tr_sizes[k] = cut
        start += nk

    te_mask = ~tr_mask
    return FederatedDataset(
        idx=all_idx[tr_mask], val=all_val[tr_mask], y=all_y[tr_mask],
        client_of=client_of[tr_mask], client_sizes=tr_sizes.astype(np.int32),
        num_features=d,
        test_idx=all_idx[te_mask], test_val=all_val[te_mask],
        test_y=all_y[te_mask], test_client_of=client_of[te_mask],
    )
