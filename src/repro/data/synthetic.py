"""Synthetic federated sparse-logreg data matching the paper's §4 statistics.

The original Google+ dataset cannot be released (paper footnote 8).  We
generate a synthetic substitute reproducing every property the experiment
depends on:

  * massively distributed: K clients (paper: 10,000)
  * unbalanced: n_k power-law in [min_client_examples, max_client_examples]
    (paper: 75..9,000, mean ~216)
  * non-IID: each client has a private "vocabulary" — a Dirichlet-weighted
    subset of features — plus globally common features (bias, unknown-word),
    giving the Fig.-1 feature-vs-node occupancy profile
  * sparse: fixed nnz bag-of-words rows
  * per-client label bias so "predict the per-author majority" beats the
    global model (the paper's 17.14% vs 26.27% observation)
  * chronological 75/25 train/test split per client
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np


@dataclasses.dataclass
class FederatedDataset:
    """Sparse design matrix in fixed-nnz row format, partitioned by client."""

    idx: np.ndarray        # (n, nnz) int32 feature indices (val==0 -> padding)
    val: np.ndarray        # (n, nnz) float32
    y: np.ndarray          # (n,) float32 in {-1, +1}
    client_of: np.ndarray  # (n,) int32
    client_sizes: np.ndarray  # (K,) int32
    num_features: int

    # test split (same format)
    test_idx: np.ndarray
    test_val: np.ndarray
    test_y: np.ndarray
    test_client_of: np.ndarray

    @property
    def num_clients(self) -> int:
        return len(self.client_sizes)

    @property
    def num_examples(self) -> int:
        return len(self.y)

    def client_slices(self) -> List[slice]:
        """Examples are stored client-contiguous."""
        out, start = [], 0
        for nk in self.client_sizes:
            out.append(slice(start, start + int(nk)))
            start += int(nk)
        return out


def _power_law_sizes(rng, K, n_total, n_min, n_max, alpha=1.6):
    """Power-law client sizes with Σ n_k == clip(n_total, K·n_min, K·n_max).

    The pre-fix version clipped *after* normalizing (raw/raw.sum()·n_total,
    then clip), so whatever mass the clip removed from the tail was simply
    lost and the realized Σ n_k drifted far below the configured total.
    Here the clipped mass is redistributed over the unsaturated clients
    (iterated, since redistribution can saturate more of them) and the
    float sizes are integerized largest-remainder style, so the realized
    total is exact whenever ``K·n_min <= n_total <= K·n_max`` (and the
    nearest feasible total otherwise).
    """
    target = float(np.clip(n_total, K * n_min, K * n_max))
    raw = np.clip((rng.pareto(alpha, size=K) + 1.0) * n_min, n_min, n_max)
    sizes = np.clip(raw / raw.sum() * target, n_min, n_max)
    # Absorb the clipped mass largest-first (deficit) / smallest-first
    # (surplus) so the power-law spread — the §1.2 "unbalanced" property —
    # survives the renormalization; a proportional redistribution would
    # drag the small clients up toward the mean.
    gap = target - sizes.sum()
    order = np.argsort(-sizes if gap > 0 else sizes, kind="stable")
    for k in order:
        if abs(gap) < 0.5:
            break
        if gap > 0:
            take = min(gap, n_max - sizes[k])
        else:
            take = max(gap, n_min - sizes[k])
        sizes[k] += take
        gap -= take

    # largest-remainder integerization, respecting the [n_min, n_max] bounds
    base = np.clip(np.floor(sizes).astype(np.int64), n_min, n_max)
    rem = int(round(target)) - int(base.sum())
    frac_order = np.argsort(-(sizes - base), kind="stable")
    step = 1 if rem > 0 else -1
    while rem != 0:
        adjustable = False
        for k in frac_order:
            if rem == 0:
                break
            if n_min <= base[k] + step <= n_max:
                base[k] += step
                rem -= step
                adjustable = True
        if not adjustable:      # every client saturated: nearest feasible
            break
    return base


def generate(cfg, seed: int = 0) -> FederatedDataset:
    """cfg: repro.configs.gplus_logreg.LogRegConfig (possibly .scaled()).

    Fully vectorized over clients *and* examples — no per-client Python
    loop — so the paper-scale K = 10,000 dataset generates in seconds:
    client vocabularies are drawn with one Gumbel-top-``vocab_size`` pass
    (exactly weighted sampling without replacement), vocabulary mixtures
    with one batched gamma draw, and every example's private-vocab features
    with one offset-searchsorted inverse-CDF lookup against its client's
    mixture.

    The chronological 75/25 per-client split guarantees ≥1 train *and* ≥1
    test example for every client with n_k ≥ 2.  A client with n_k == 1
    puts its single example in train and has zero test examples.
    """
    rng = np.random.default_rng(seed)
    K, d = cfg.num_clients, cfg.num_features
    nnz = min(cfg.nnz_per_example, d - 2)

    sizes = _power_law_sizes(rng, K, cfg.num_examples,
                             cfg.min_client_examples, cfg.max_client_examples)
    n = int(sizes.sum())
    client_of = np.repeat(np.arange(K, dtype=np.int32), sizes)

    # ground-truth weights: heavy-tailed so rare features carry signal
    w_true = rng.standard_normal(d) * (rng.random(d) < 0.3)

    # global feature popularity (zipf over non-special features)
    ranks = np.arange(2, d)
    global_pop = 1.0 / ranks ** 1.1
    global_pop /= global_pop.sum()

    vocab_size = max(8, int(0.02 * d))  # private vocabulary per client

    # client vocabularies: a zipf-weighted random subset per client —
    # Gumbel-top-k over log popularity is exactly weighted sampling without
    # replacement (Plackett–Luce).  Drawn in client blocks so the dense
    # (block, d) score matrix bounds peak memory at O(block·d), not O(K·d)
    # (at the paper's real d=20k, a full (10k, 20k) f64 draw is ~1.6 GB).
    log_pop = np.log(global_pop)
    vocab = np.empty((K, vocab_size), np.int32)                 # (K, V)
    block = 2048
    for k0 in range(0, K, block):
        scores = log_pop[None, :] + rng.gumbel(size=(min(block, K - k0),
                                                     d - 2))
        vocab[k0:k0 + block] = np.argpartition(
            -scores, vocab_size - 1, axis=1)[:, :vocab_size] + 2
    # Dirichlet(0.3) mixture over each vocabulary (batched gamma-normalize)
    mix = rng.standard_gamma(0.3, size=(K, vocab_size))
    mix /= np.maximum(mix.sum(axis=1, keepdims=True), 1e-300)

    # per-example features: mostly from own vocab, some global
    n_own = int(0.8 * nnz)
    # inverse-CDF sampling of every example's own-vocab features in one
    # searchsorted: client k's CDF lives on the offset interval [k, k+1)
    cdf = np.cumsum(mix, axis=1)
    cdf[:, -1] = 1.0
    flat_cdf = (cdf + np.arange(K)[:, None]).ravel()
    u = rng.random((n, n_own))
    pos = np.searchsorted(flat_cdf, client_of[:, None] + u, side="right")
    # k + u can round up to k+1 in float64 when u -> 1 at large k; clip the
    # (measure-~0) overflow back into the client's own vocabulary
    local = np.clip(pos - client_of[:, None].astype(np.int64) * vocab_size,
                    0, vocab_size - 1)
    own_feats = vocab[client_of[:, None], local]                 # (n, n_own)
    glob_feats = rng.choice(np.arange(2, d), size=(n, nnz - n_own),
                            p=global_pop)
    feats = np.concatenate([own_feats, glob_feats], axis=1)

    all_idx = np.concatenate(
        [np.zeros((n, 1), np.int32),                             # bias
         np.ones((n, 1), np.int32),                              # unknown-word
         feats.astype(np.int32)], axis=1)
    all_val = np.ones((n, nnz + 2), np.float32)
    # dedupe within a row: zero out repeated features (keeps fixed width)
    srt = np.sort(all_idx, axis=1)
    dup = np.concatenate([np.zeros((n, 1), bool),
                          srt[:, 1:] == srt[:, :-1]], axis=1)
    order = np.argsort(all_idx, axis=1)
    inv = np.argsort(order, axis=1)
    all_val *= ~np.take_along_axis(dup, inv, axis=1)

    margin = (all_val * w_true[all_idx]).sum(axis=1)
    client_bias = rng.standard_normal(K) * 1.5                   # non-IID skew
    p = 1.0 / (1.0 + np.exp(-(0.7 * margin + client_bias[client_of])))
    all_y = np.where(rng.random(n) < p, 1.0, -1.0).astype(np.float32)

    # chronological 75/25 split per client (synthetic order = time order).
    # Every client with n_k >= 2 keeps at least one test example: the
    # train share is clamped to [1, n_k − 1] (at n_k == 1 the max(1, ·)
    # floor used to consume the whole client, emitting a zero-test
    # client).  A client with n_k == 1 still contributes its only example
    # to train and has zero test examples — there is no way to give it
    # both; callers that need test coverage everywhere must keep n_min >= 2.
    tr_sizes = np.maximum(1, (0.75 * sizes).astype(np.int64))
    tr_sizes = np.where(sizes >= 2, np.minimum(tr_sizes, sizes - 1), tr_sizes)
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    pos_in_client = np.arange(n) - starts[client_of]
    tr_mask = pos_in_client < tr_sizes[client_of]
    te_mask = ~tr_mask
    return FederatedDataset(
        idx=all_idx[tr_mask], val=all_val[tr_mask], y=all_y[tr_mask],
        client_of=client_of[tr_mask], client_sizes=tr_sizes.astype(np.int32),
        num_features=d,
        test_idx=all_idx[te_mask], test_val=all_val[te_mask],
        test_y=all_y[te_mask], test_client_of=client_of[te_mask],
    )
