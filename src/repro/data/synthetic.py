"""Synthetic federated sparse-logreg data matching the paper's §4 statistics.

The original Google+ dataset cannot be released (paper footnote 8).  We
generate a synthetic substitute reproducing every property the experiment
depends on:

  * massively distributed: K clients (paper: 10,000)
  * unbalanced: n_k power-law in [min_client_examples, max_client_examples]
    (paper: 75..9,000, mean ~216)
  * non-IID: each client has a private "vocabulary" — a heavy-tail-weighted
    subset of features — plus globally common features (bias, unknown-word),
    giving the Fig.-1 feature-vs-node occupancy profile
  * sparse: fixed nnz bag-of-words rows
  * per-client label bias so "predict the per-author majority" beats the
    global model (the paper's 17.14% vs 26.27% observation)
  * chronological 75/25 train/test split per client

The per-client seeding contract (the virtual-data foundation)
-------------------------------------------------------------

Every client's data is a pure function of ``(PRNGKey(seed), k)`` and every
row a pure function of the client key and its chronological position:

    ck        = fold_in(PRNGKey(seed), k)
    vocab/mix = f(fold_in(ck, VOCAB/MIX/BIAS tags))       # per-client params
    row p     = f(fold_in(fold_in(ck, ROWS tag), p))      # per-row draws

so any client's rows can be regenerated *on demand* without touching any
other client — :func:`make_client_batch` / :meth:`VirtualDataset.client_rows_padded`
— and :func:`generate` materializes the whole dataset through the *same*
sampler (``_client_params`` / ``_row``), just batched differently.  Both
paths therefore agree **bit-for-bit**: the sampler uses only batch-shape-
stable primitives (uniform, log/exp, sigmoid, sort, top_k, searchsorted) —
never ``normal``/``gamma``, whose erfinv / rejection internals can differ
by an ulp across batch shapes — so vmapping over rows, clients, or the
flattened dataset produces identical bits.

Only the O(K) size draw and the O(d) ground truth live outside the keyed
sampler (numpy, drawn once into the :class:`VirtualDataset` spec); the
spec is all a K=10⁶ round needs in memory.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class FederatedDataset:
    """Sparse design matrix in fixed-nnz row format, partitioned by client."""

    idx: np.ndarray        # (n, nnz) int32 feature indices (val==0 -> padding)
    val: np.ndarray        # (n, nnz) float32
    y: np.ndarray          # (n,) float32 in {-1, +1}
    client_of: np.ndarray  # (n,) int32
    client_sizes: np.ndarray  # (K,) int32
    num_features: int

    # test split (same format)
    test_idx: np.ndarray
    test_val: np.ndarray
    test_y: np.ndarray
    test_client_of: np.ndarray

    @property
    def num_clients(self) -> int:
        return len(self.client_sizes)

    @property
    def num_examples(self) -> int:
        return len(self.y)

    def client_slices(self) -> List[slice]:
        """Examples are stored client-contiguous."""
        out, start = [], 0
        for nk in self.client_sizes:
            out.append(slice(start, start + int(nk)))
            start += int(nk)
        return out


def _power_law_sizes(rng, K, n_total, n_min, n_max, alpha=1.6):
    """Power-law client sizes with Σ n_k == clip(n_total, K·n_min, K·n_max).

    The pre-fix version clipped *after* normalizing (raw/raw.sum()·n_total,
    then clip), so whatever mass the clip removed from the tail was simply
    lost and the realized Σ n_k drifted far below the configured total.
    Here the clipped mass is redistributed over the unsaturated clients
    (iterated, since redistribution can saturate more of them) and the
    float sizes are integerized largest-remainder style, so the realized
    total is exact whenever ``K·n_min <= n_total <= K·n_max`` (and the
    nearest feasible total otherwise).
    """
    target = float(np.clip(n_total, K * n_min, K * n_max))
    raw = np.clip((rng.pareto(alpha, size=K) + 1.0) * n_min, n_min, n_max)
    sizes = np.clip(raw / raw.sum() * target, n_min, n_max)
    # Absorb the clipped mass largest-first (deficit) / smallest-first
    # (surplus) so the power-law spread — the §1.2 "unbalanced" property —
    # survives the renormalization; a proportional redistribution would
    # drag the small clients up toward the mean.
    gap = target - sizes.sum()
    order = np.argsort(-sizes if gap > 0 else sizes, kind="stable")
    for k in order:
        if abs(gap) < 0.5:
            break
        if gap > 0:
            take = min(gap, n_max - sizes[k])
        else:
            take = max(gap, n_min - sizes[k])
        sizes[k] += take
        gap -= take

    # largest-remainder integerization, respecting the [n_min, n_max] bounds
    base = np.clip(np.floor(sizes).astype(np.int64), n_min, n_max)
    rem = int(round(target)) - int(base.sum())
    frac_order = np.argsort(-(sizes - base), kind="stable")
    step = 1 if rem > 0 else -1
    while rem != 0:
        adjustable = False
        for k in frac_order:
            if rem == 0:
                break
            if n_min <= base[k] + step <= n_max:
                base[k] += step
                rem -= step
                adjustable = True
        if not adjustable:      # every client saturated: nearest feasible
            break
    return base


def train_split_sizes(sizes) -> np.ndarray:
    """The chronological 75/25 split rule, shared by :func:`generate` and the
    virtual layout so the two paths cannot drift on train/test boundaries.

    Train gets ``max(1, floor(0.75 n_k))`` **capped at n_k − 1**: every
    client with n_k >= 2 keeps at least one train AND one test example (the
    pre-PR-6 ``max(1, ·)`` floor consumed n_k == 1 clients whole, emitting
    zero-test clients).  A client with n_k == 1 puts its single example in
    train and has zero test examples — there is no way to give it both;
    callers that need test coverage everywhere must keep n_min >= 2.
    """
    sizes = np.asarray(sizes, np.int64)
    tr = np.maximum(1, (0.75 * sizes).astype(np.int64))
    return np.where(sizes >= 2, np.minimum(tr, sizes - 1), tr)


# --------------------------------------------------------------------- #
# the shared per-client sampler (one code path for generate / virtual)
# --------------------------------------------------------------------- #

# fold_in tag domains off the client key ck = fold_in(base, k)
_ROWS_TAG, _VOCAB_TAG, _MIX_TAG, _BIAS_TAG = 0, 1, 2, 3
# fold_in tag domains off the row key rk = fold_in(fold_in(ck, ROWS), pos)
_OWN_TAG, _GLOB_TAG, _LABEL_TAG = 0, 1, 2

#: logistic(0, s) has std s·π/√3 — this scale gives the per-client label
#: bias std 1.5 (the non-IID skew) from a uniform draw, avoiding
#: jax.random.normal whose erfinv can differ by an ulp across batch shapes.
_BIAS_SCALE = 1.5 * math.sqrt(3.0) / math.pi


def _client_params(ck, log_pop, vocab_size: int):
    """One client's (vocab, mixture CDF, label bias) from its key.

    Gumbel-top-k over log popularity is exactly weighted sampling without
    replacement (Plackett–Luce) — the client's private vocabulary is a
    zipf-weighted random subset of the feature space.  The mixture over the
    vocabulary is a normalized Weibull(0.3) draw ``(−log u)^{1/0.3}`` —
    the same heavy-tail-dominated profile as a Dirichlet(0.3) gamma draw,
    but built from uniforms only (bit-stable across batch shapes, unlike
    ``jax.random.gamma``'s rejection loop).
    """
    g = jax.random.gumbel(jax.random.fold_in(ck, _VOCAB_TAG), log_pop.shape)
    _, top = jax.lax.top_k(log_pop + g, vocab_size)
    vocab = (top + 2).astype(jnp.int32)                      # skip bias/unk
    u = jax.random.uniform(jax.random.fold_in(ck, _MIX_TAG), (vocab_size,),
                           minval=1e-7, maxval=1.0)
    raw = (-jnp.log(u)) ** (1.0 / 0.3)
    cdf = jnp.cumsum(raw / raw.sum())
    cdf = cdf.at[-1].set(1.0)
    ub = jax.random.uniform(jax.random.fold_in(ck, _BIAS_TAG), (),
                            minval=1e-6, maxval=1.0 - 1e-6)
    bias = _BIAS_SCALE * jnp.log(ub / (1.0 - ub))
    return vocab, cdf, bias


def _row(rk, vocab, cdf, bias, w_true, global_cdf, nnz: int, n_own: int):
    """One example (idx, val, y) from its row key and its client's params.

    Features: ``n_own`` inverse-CDF draws from the client's private
    vocabulary mixture + ``nnz − n_own`` from the global zipf popularity,
    prefixed by the always-on bias (0) and unknown-word (1) features.
    Duplicate features within the row are zeroed out (fixed width kept).
    The label is Bernoulli(sigmoid(0.7·margin + client bias)).
    """
    V = vocab.shape[0]
    u_own = jax.random.uniform(jax.random.fold_in(rk, _OWN_TAG), (n_own,))
    own = vocab[jnp.clip(jnp.searchsorted(cdf, u_own, side="right"), 0, V - 1)]
    dg = global_cdf.shape[0]
    u_glob = jax.random.uniform(jax.random.fold_in(rk, _GLOB_TAG),
                                (nnz - n_own,))
    glob = (jnp.clip(jnp.searchsorted(global_cdf, u_glob, side="right"),
                     0, dg - 1) + 2).astype(jnp.int32)
    idx = jnp.concatenate([jnp.array([0, 1], jnp.int32), own, glob])
    val = jnp.ones((nnz + 2,), jnp.float32)
    # dedupe within the row: zero out repeated features (keeps fixed width)
    srt = jnp.sort(idx)
    dup = jnp.concatenate([jnp.zeros((1,), bool), srt[1:] == srt[:-1]])
    order = jnp.argsort(idx)
    inv = jnp.argsort(order)
    val = val * (~dup[inv]).astype(jnp.float32)

    margin = (val * w_true[idx]).sum()
    p = jax.nn.sigmoid(jnp.float32(0.7) * margin + bias)
    u_y = jax.random.uniform(jax.random.fold_in(rk, _LABEL_TAG), ())
    y = jnp.where(u_y < p, 1.0, -1.0).astype(jnp.float32)
    return idx, val, y


def _client_rows(ck, vocab, cdf, bias, num_rows: int, w_true, global_cdf,
                 nnz: int, n_own: int):
    """The client's first ``num_rows`` chronological rows — row p is keyed by
    ``fold_in(fold_in(ck, ROWS), p)``, independent of how many rows are
    asked for (a prefix is always a prefix)."""
    rows_key = jax.random.fold_in(ck, _ROWS_TAG)
    positions = jnp.arange(num_rows, dtype=jnp.uint32)
    return jax.vmap(
        lambda p: _row(jax.random.fold_in(rows_key, p), vocab, cdf, bias,
                       w_true, global_cdf, nnz, n_own))(positions)


@functools.partial(jax.jit,
                   static_argnames=("num_rows", "vocab_size", "nnz", "n_own"))
def _one_client_rows(base_key, client_id, w_true, log_pop, global_cdf, *,
                     num_rows: int, vocab_size: int, nnz: int, n_own: int):
    ck = jax.random.fold_in(base_key, client_id)
    vocab, cdf, bias = _client_params(ck, log_pop, vocab_size)
    return _client_rows(ck, vocab, cdf, bias, num_rows, w_true, global_cdf,
                        nnz, n_own)


# --------------------------------------------------------------------- #
# the virtual dataset: O(K + d) spec, rows regenerated on demand
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class VirtualDataset:
    """The O(K + d) spec from which any client's rows regenerate on demand.

    Holds exactly what :func:`generate` draws *outside* the keyed sampler —
    the power-law sizes, the ground-truth weights, the global popularity —
    plus the base PRNG key.  ``client_sizes`` are the per-client **train**
    sizes (:func:`train_split_sizes` of the full sizes), matching
    :class:`FederatedDataset.client_sizes`; a client's test rows are the
    chronological tail ``[client_sizes[k], full_sizes[k])``.
    """

    base_key: jax.Array        # PRNGKey(seed)
    full_sizes: np.ndarray     # (K,) int64, train+test rows per client
    client_sizes: np.ndarray   # (K,) int32, TRAIN rows per client
    w_true: jax.Array          # (d,) f32 ground-truth weights
    log_pop: jax.Array         # (d-2,) f32 log zipf popularity
    global_cdf: jax.Array      # (d-2,) f32 zipf CDF
    num_features: int
    nnz: int
    vocab_size: int
    n_own: int

    @property
    def num_clients(self) -> int:
        return len(self.client_sizes)

    @property
    def num_examples(self) -> int:
        """Train examples (matches ``FederatedDataset.num_examples``)."""
        return int(self.client_sizes.sum())

    def client_rows_padded(self, client_ids, n_k, m_pad: int
                           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Regenerate a batch of clients' rows into the engine's padded
        bucket layout: (C, m_pad, nnz) idx/val and (C, m_pad) y, with rows
        at positions >= n_k zeroed to the padding contract (idx=0, val=0,
        y=1).  Traceable — this is what runs inside the round's
        ``lax.scan`` body under ``EngineConfig.virtual_data``.
        """
        base, log_pop = self.base_key, self.log_pop
        w_true, gcdf = self.w_true, self.global_cdf
        V, nnz, n_own = self.vocab_size, self.nnz, self.n_own

        def one(cid, nk):
            ck = jax.random.fold_in(base, cid.astype(jnp.uint32))
            vocab, cdf, bias = _client_params(ck, log_pop, V)
            idx, val, y = _client_rows(ck, vocab, cdf, bias, m_pad, w_true,
                                       gcdf, nnz, n_own)
            keep = jnp.arange(m_pad) < nk
            return (jnp.where(keep[:, None], idx, 0),
                    jnp.where(keep[:, None], val, 0.0),
                    jnp.where(keep, y, 1.0))

        return jax.vmap(one)(jnp.asarray(client_ids), jnp.asarray(n_k))


def make_client_batch(vds: VirtualDataset, k: int,
                      num_rows: Optional[int] = None
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Client ``k``'s first ``num_rows`` chronological rows (default: all
    of them, train + test) regenerated from its fold_in seed — bit-for-bit
    equal to the row-slice ``k`` of :func:`generate` on the same config and
    seed (the property tests pin this for every client)."""
    if num_rows is None:
        num_rows = int(vds.full_sizes[k])
    return _one_client_rows(
        vds.base_key, jnp.uint32(k), vds.w_true, vds.log_pop, vds.global_cdf,
        num_rows=num_rows, vocab_size=vds.vocab_size, nnz=vds.nnz,
        n_own=vds.n_own)


def virtual_dataset(cfg, seed: int = 0) -> VirtualDataset:
    """The virtual twin of :func:`generate`: same cfg, same seed, same data —
    but O(K + d) memory.  Draws the numpy-stream quantities (sizes, w_true)
    in the exact order :func:`generate` historically did, so the two paths
    share sizes/weights bit-for-bit."""
    rng = np.random.default_rng(seed)
    K, d = cfg.num_clients, cfg.num_features
    nnz = min(cfg.nnz_per_example, d - 2)

    sizes = _power_law_sizes(rng, K, cfg.num_examples,
                             cfg.min_client_examples, cfg.max_client_examples)

    # ground-truth weights: heavy-tailed so rare features carry signal
    w_true = rng.standard_normal(d) * (rng.random(d) < 0.3)

    # global feature popularity (zipf over non-special features)
    ranks = np.arange(2, d)
    global_pop = 1.0 / ranks ** 1.1
    global_pop /= global_pop.sum()
    gcdf = np.cumsum(global_pop)
    gcdf[-1] = 1.0

    vocab_size = min(max(8, int(0.02 * d)), d - 2)

    return VirtualDataset(
        base_key=jax.random.PRNGKey(seed),
        full_sizes=sizes.astype(np.int64),
        client_sizes=train_split_sizes(sizes).astype(np.int32),
        w_true=jnp.asarray(w_true, jnp.float32),
        log_pop=jnp.asarray(np.log(global_pop), jnp.float32),
        global_cdf=jnp.asarray(gcdf, jnp.float32),
        num_features=d, nnz=nnz, vocab_size=vocab_size,
        n_own=int(0.8 * nnz),
    )


# --------------------------------------------------------------------- #
# materialization: generate() through the same sampler, batched
# --------------------------------------------------------------------- #

# fixed batch shapes (padded, sliced after) so repeated small generates —
# e.g. 200 property-test draws — reuse one compilation per (d, nnz) pool
_PARAM_BLOCK = 2048
_ROW_BLOCK = 4096


@functools.partial(jax.jit, static_argnames=("vocab_size",))
def _param_block(base_key, client_ids, log_pop, *, vocab_size: int):
    def one(cid):
        ck = jax.random.fold_in(base_key, cid)
        vocab, cdf, bias = _client_params(ck, log_pop, vocab_size)
        return vocab, cdf, bias, jax.random.fold_in(ck, _ROWS_TAG)
    return jax.vmap(one)(client_ids)


@functools.partial(jax.jit, static_argnames=("nnz", "n_own"))
def _row_block(rows_keys, pos, vocab, cdf, bias, w_true, global_cdf, *,
               nnz: int, n_own: int):
    def one(rkb, p, vo, cd, bi):
        return _row(jax.random.fold_in(rkb, p), vo, cd, bi, w_true,
                    global_cdf, nnz, n_own)
    return jax.vmap(one)(rows_keys, pos, vocab, cdf, bias)


def generate(cfg, seed: int = 0) -> FederatedDataset:
    """cfg: repro.configs.gplus_logreg.LogRegConfig (possibly .scaled()).

    Materializes the dataset through the *same* keyed sampler the virtual
    path uses (:func:`virtual_dataset` / :func:`make_client_batch`) —
    ``generate(cfg, seed)`` is exactly
    ``materialize_dataset(virtual_dataset(cfg, seed))``.
    """
    return materialize_dataset(virtual_dataset(cfg, seed))


def materialize_dataset(vds: VirtualDataset) -> FederatedDataset:
    """Materialize every client's rows from a virtual spec, fully
    vectorized over clients and examples: per-client params run in
    ``_PARAM_BLOCK`` client batches (the dense (block, d) Gumbel score
    matrix bounds peak memory at O(block·d), not O(K·d)), per-example rows
    in fixed ``_ROW_BLOCK`` batches.  Because every draw is keyed by
    (client, position), the batching is invisible: ``make_client_batch(k)``
    reproduces row-slice ``k`` bit-for-bit.

    The chronological 75/25 per-client split (:func:`train_split_sizes`)
    guarantees ≥1 train *and* ≥1 test example for every client with
    n_k ≥ 2.  A client with n_k == 1 puts its single example in train and
    has zero test examples.

    Taking the spec (rather than a cfg) is what makes distribution drift a
    data-layer feature: :func:`drifted_dataset` perturbs the spec and this
    function materializes the drifted epoch through the same sampler.
    """
    K, d = vds.num_clients, vds.num_features
    nnz = vds.nnz
    sizes = vds.full_sizes
    n = int(sizes.sum())
    client_of = np.repeat(np.arange(K, dtype=np.int32), sizes)

    # per-client params, client-blocked (ids padded to a full block; the
    # extra params are computed and discarded — keys make them harmless)
    vocabs = np.empty((K, vds.vocab_size), np.int32)
    cdfs = np.empty((K, vds.vocab_size), np.float32)
    biases = np.empty((K,), np.float32)
    rows_keys = np.empty((K, 2), np.uint32)
    for k0 in range(0, K, _PARAM_BLOCK):
        ids = np.arange(k0, k0 + _PARAM_BLOCK, dtype=np.uint32)
        vo, cd, bi, rk = _param_block(vds.base_key, jnp.asarray(ids),
                                      vds.log_pop,
                                      vocab_size=vds.vocab_size)
        take = min(K, k0 + _PARAM_BLOCK) - k0
        vocabs[k0:k0 + take] = np.asarray(vo)[:take]
        cdfs[k0:k0 + take] = np.asarray(cd)[:take]
        biases[k0:k0 + take] = np.asarray(bi)[:take]
        rows_keys[k0:k0 + take] = np.asarray(rk)[:take]

    # per-example rows, row-blocked at a fixed shape
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    pos = (np.arange(n) - starts[client_of]).astype(np.uint32)
    all_idx = np.empty((n, nnz + 2), np.int32)
    all_val = np.empty((n, nnz + 2), np.float32)
    all_y = np.empty((n,), np.float32)
    for i0 in range(0, n, _ROW_BLOCK):
        i1 = min(i0 + _ROW_BLOCK, n)
        m = i1 - i0
        cof = client_of[i0:i1]
        args = [rows_keys[cof], pos[i0:i1], vocabs[cof], cdfs[cof],
                biases[cof]]
        if m < _ROW_BLOCK:        # pad to the fixed block shape, slice after
            args = [np.concatenate(
                [a, np.repeat(a[-1:], _ROW_BLOCK - m, axis=0)]) for a in args]
        bi_, bv_, by_ = _row_block(*[jnp.asarray(a) for a in args],
                                   vds.w_true, vds.global_cdf,
                                   nnz=nnz, n_own=vds.n_own)
        all_idx[i0:i1] = np.asarray(bi_)[:m]
        all_val[i0:i1] = np.asarray(bv_)[:m]
        all_y[i0:i1] = np.asarray(by_)[:m]

    # chronological 75/25 split per client (synthetic order = time order),
    # via the shared train_split_sizes rule (train capped at n_k − 1)
    tr_sizes = vds.client_sizes.astype(np.int64)
    tr_mask = pos < tr_sizes[client_of]
    te_mask = ~tr_mask
    return FederatedDataset(
        idx=all_idx[tr_mask], val=all_val[tr_mask], y=all_y[tr_mask],
        client_of=client_of[tr_mask], client_sizes=vds.client_sizes,
        num_features=d,
        test_idx=all_idx[te_mask], test_val=all_val[te_mask],
        test_y=all_y[te_mask], test_client_of=client_of[te_mask],
    )


# --------------------------------------------------------------------- #
# distribution drift: epoch-indexed perturbations of the virtual spec
# --------------------------------------------------------------------- #

# folded off base_key to root drift resampling; chain depth keeps it
# disjoint from per-client keys (those are fold_in(base, k) — one level)
_DRIFT_TAG = 0xD41F7


def drifted_dataset(vds: VirtualDataset, epoch: int, *,
                    w_true_scale: float = 1.0,
                    resample_clients: bool = False) -> VirtualDataset:
    """Epoch ``epoch``'s view of the fleet's data distribution.

    Two drift modes, composable, both pure functions of
    ``(vds, epoch)`` so any epoch's data regenerates bit-for-bit in
    isolation (the campaign's resume contract):

      * ``w_true_scale`` — smooth concept drift: the ground-truth weights
        scale by ``w_true_scale**epoch``, so label noise grows (scale < 1,
        the signal washes out) or sharpens (scale > 1) across epochs while
        every client keeps its vocabulary and feature marginals.
      * ``resample_clients`` — abrupt distribution shift: the base key is
        re-rooted through the drift chain, redrawing every client's
        vocabulary / mixture / bias (fresh conditional distributions, same
        sizes, same w_true).

    ``epoch=0`` is the identity — the returned spec *is* ``vds``, so
    campaigns without drift pay nothing.  Client count, per-client sizes,
    and therefore every engine shape are invariant under drift: solvers
    keep their compiled rounds' shapes, only the regenerated rows change.
    """
    if epoch < 0:
        raise ValueError("epoch must be >= 0")
    if epoch == 0:
        return vds
    out = vds
    if w_true_scale != 1.0:
        out = dataclasses.replace(
            out, w_true=vds.w_true * jnp.float32(w_true_scale) ** epoch)
    if resample_clients:
        out = dataclasses.replace(
            out, base_key=jax.random.fold_in(
                jax.random.fold_in(vds.base_key, _DRIFT_TAG), epoch))
    return out
