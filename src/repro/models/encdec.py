"""Encoder-decoder backbone (SeamlessM4T-medium).

The audio frontend (mel-spectrogram + conv feature extractor) is a stub per
the carve-out: the encoder consumes precomputed frame embeddings of shape
(B, frames, d_model).  The decoder is a standard causal transformer with
interleaved cross-attention; decode caches self-attn KV and the
cross-attention K/V are precomputed once from the encoder output.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.utils import flags


def init_encoder(key, cfg: ArchConfig, dtype) -> Dict:
    def one(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(k1, cfg, dtype),
            "mlp": L.init_mlp(k2, cfg, dtype),
        }
    keys = jax.random.split(key, cfg.encoder_layers)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *[one(k) for k in keys])
    return {"layers": stacked, "out_norm": jnp.ones((cfg.d_model,), jnp.float32)}


def encoder_forward(params, cfg: ArchConfig, frames: jax.Array, *, remat: bool = True):
    """frames: (B, F, d) precomputed frontend embeddings -> (B, F, d)."""
    B, F, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(F), (B, F))

    def block(x, p):
        h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
        Bh, S, _ = h.shape
        H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = L.apply_rope((h @ p["attn"]["wq"]).reshape(Bh, S, H, Dh), positions, cfg.rope_theta)
        k = L.apply_rope((h @ p["attn"]["wk"]).reshape(Bh, S, Hkv, Dh), positions, cfg.rope_theta)
        v = (h @ p["attn"]["wv"]).reshape(Bh, S, Hkv, Dh)
        o = L.flash_attention(q, k, v, causal=False)          # bidirectional
        x = x + (o.reshape(Bh, S, H * Dh) @ p["attn"]["wo"])
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp_fwd(p["mlp"], h, cfg)
        return x, None

    body = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda c, p: body(c, p), frames, params["layers"],
                        unroll=flags.scan_unroll())
    return L.rms_norm(x, params["out_norm"], cfg.norm_eps)


def init_decoder(key, cfg: ArchConfig, dtype) -> Dict:
    def one(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": jnp.ones((cfg.d_model,), jnp.float32),
            "norm_x": jnp.ones((cfg.d_model,), jnp.float32),
            "norm2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": L.init_attention(k1, cfg, dtype),
            "xattn": L.init_cross_attention(k2, cfg, dtype),
            "mlp": L.init_mlp(k3, cfg, dtype),
        }
    keys = jax.random.split(key, cfg.num_layers)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[one(k) for k in keys])


def decoder_forward(params, cfg: ArchConfig, x, enc_out, positions, *, remat: bool = True):
    """x: (B,S,d) token embeddings; enc_out: (B,F,d)."""

    def block(x, p):
        h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
        o, _ = L.attention_fwd(p["attn"], h, cfg, positions)
        x = x + o
        h = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
        x = x + L.cross_attention_fwd(p["xattn"], h, enc_out, cfg)
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp_fwd(p["mlp"], h, cfg)
        return x, None

    body = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params,
                        unroll=flags.scan_unroll())
    return x


def init_decoder_cache(cfg: ArchConfig, batch: int, max_seq: int, frames: int, dtype) -> Dict:
    shp = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    xshp = (cfg.num_layers, batch, frames, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
        "xk": jnp.zeros(xshp, dtype), "xv": jnp.zeros(xshp, dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def precompute_cross_cache(params, cfg: ArchConfig, enc_out: jax.Array):
    """K/V projections of the encoder output for every decoder layer."""
    B, F, _ = enc_out.shape
    Hkv, Dh = cfg.num_kv_heads, cfg.head_dim

    def one(_, p):
        k = (enc_out @ p["xattn"]["wk"]).reshape(B, F, Hkv, Dh)
        v = (enc_out @ p["xattn"]["wv"]).reshape(B, F, Hkv, Dh)
        return None, (k, v)

    _, (xk, xv) = jax.lax.scan(one, None, params)
    return xk, xv


def decoder_decode_step(params, cfg: ArchConfig, x: jax.Array, cache: Dict):
    """One-token decode with cached self KV + precomputed cross KV."""
    cur_len = cache["len"]
    B = x.shape[0]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    def block(x, inp):
        p, ck, cv, xk, xv = inp
        h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
        pos = jnp.full((B, 1), cur_len, dtype=jnp.int32)
        q = L.apply_rope((h @ p["attn"]["wq"]).reshape(B, 1, H, Dh), pos, cfg.rope_theta)
        k = L.apply_rope((h @ p["attn"]["wk"]).reshape(B, 1, Hkv, Dh), pos, cfg.rope_theta)
        v = (h @ p["attn"]["wv"]).reshape(B, 1, Hkv, Dh)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cur_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cur_len, 0, 0))
        o = L.decode_attention(q, ck, cv, cur_len + 1)
        x = x + (o.reshape(B, 1, H * Dh) @ p["attn"]["wo"])
        # cross attention against full (static) encoder memory
        h = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
        qx = (h @ p["xattn"]["wq"]).reshape(B, 1, H, Dh)
        ox = L.decode_attention(qx, xk, xv, jnp.asarray(xk.shape[1], jnp.int32))
        x = x + (ox.reshape(B, 1, H * Dh) @ p["xattn"]["wo"])
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp_fwd(p["mlp"], h, cfg)
        return x, (ck, cv)

    x, (nk, nv) = jax.lax.scan(block, x, (params, cache["k"], cache["v"], cache["xk"], cache["xv"]),
                               unroll=flags.scan_unroll())
    new_cache = dict(cache)
    new_cache.update(k=nk, v=nv, len=cur_len + 1)
    return x, new_cache
