"""Top-level model API: ``build_model(cfg, dtype)`` -> :class:`Model`.

A :class:`Model` is a bundle of pure functions over explicit param pytrees:

    init(rng)                          -> params
    loss(params, batch)                -> (scalar, metrics)      # train
    prefill(params, batch)             -> (last_logits, cache)   # inference
    init_cache(batch, max_seq)         -> cache
    decode_step(params, tokens, cache) -> (logits, cache)        # one token

Batches (all int32 tokens, global shapes before sharding):
    dense/moe/hybrid/ssm : {tokens, labels, mask}
    vlm                  : + patch_embeds (B, P, d)  [vision-stub carve-out]
    encdec_audio         : {frame_embeds (B,F,d), tokens, labels, mask}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec as ED
from repro.models import layers as L
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    dtype: Any
    init: Callable
    loss: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable


def _init_embeddings(key, cfg: ArchConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {"embed": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
         "out_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size)) * 0.02).astype(dtype)
    return p


def _unembed(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


# --------------------------------------------------------------------- #
# decoder-only families (dense / moe / hybrid / ssm / vlm)
# --------------------------------------------------------------------- #


def _build_decoder_only(cfg: ArchConfig, dtype) -> Model:
    is_vlm = cfg.family == "vlm"

    def init(rng):
        k1, k2 = jax.random.split(rng)
        p = _init_embeddings(k1, cfg, dtype)
        p["layers"] = T.init_stack(k2, cfg, dtype)
        return p

    def _embed_inputs(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if is_vlm:
            patches = batch["patch_embeds"].astype(x.dtype)   # (B, P, d)
            x = jnp.concatenate([patches, x], axis=1)
        return x

    def loss(params, batch):
        x = _embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, aux = T.stack_forward(params["layers"], cfg, x, positions, remat=True)
        h = L.rms_norm(h, params["out_norm"], cfg.norm_eps)
        if is_vlm:  # image prefix predicts nothing
            P = batch["patch_embeds"].shape[1]
            h = h[:, P:]
        ce = L.lm_head_loss(h, _unembed(params, cfg), batch["labels"], batch["mask"])
        lb_w = cfg.moe.load_balance_weight if cfg.moe is not None else 0.0
        total = ce + lb_w * aux
        return total, {"ce": ce, "aux": aux}

    def prefill(params, batch):
        x = _embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, _, cache = T.stack_forward(params["layers"], cfg, x, positions,
                                      remat=False, collect_cache=True)
        h = L.rms_norm(h, params["out_norm"], cfg.norm_eps)
        logits = h[:, -1] @ _unembed(params, cfg)
        cache = _prefill_cache_from_entries(cfg, cache, S)
        return logits, cache

    def init_cache(batch: int, max_seq: int):
        return T.init_cache(cfg, batch, max_seq, dtype)

    def decode_step(params, tokens, cache):
        x = jnp.take(params["embed"], tokens, axis=0)          # (B,1,d)
        h, cache = T.stack_decode(params["layers"], cfg, x, cache)
        h = L.rms_norm(h, params["out_norm"], cfg.norm_eps)
        logits = h[:, -1] @ _unembed(params, cfg)
        return logits, cache

    return Model(cfg, dtype, init, loss, prefill, init_cache, decode_step)


def _prefill_cache_from_entries(cfg: ArchConfig, entries: Dict, seq_len: int) -> Dict:
    """Convert stack_forward cache entries into the decode-cache layout.

    For attention entries the full-sequence K/V become the cache prefix (or
    the last-`window` ring for SWA); recurrent entries carry final states.
    """
    smax = T.cache_max_len(cfg, seq_len)
    out: Dict = {"len": jnp.asarray(seq_len, jnp.int32)}
    for key, e in entries.items():
        if "k" in e:  # attention: (nrep, B, S, Hkv, Dh)
            k, v = e["k"], e["v"]
            if cfg.sliding_window is not None and seq_len > smax:
                k = jnp.roll(k[:, :, -smax:], shift=seq_len % smax, axis=2)
                v = jnp.roll(v[:, :, -smax:], shift=seq_len % smax, axis=2)
            out[key] = {"k": k, "v": v}
        elif "ssm" in e:
            out[key] = {"ssm": e["ssm"], "conv": e["conv"]}
        elif "wkv" in e:
            out[key] = {"wkv": e["wkv"], "shift_tm": e["shift_tm"],
                        "shift_cm": e.get("shift_cm", e["shift_tm"])}
    return out


# --------------------------------------------------------------------- #
# encoder-decoder (audio)
# --------------------------------------------------------------------- #


def _build_encdec(cfg: ArchConfig, dtype) -> Model:
    def init(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        p = _init_embeddings(k1, cfg, dtype)
        p["encoder"] = ED.init_encoder(k2, cfg, dtype)
        p["decoder"] = ED.init_decoder(k3, cfg, dtype)
        return p

    def loss(params, batch):
        enc = ED.encoder_forward(params["encoder"], cfg, batch["frame_embeds"].astype(dtype))
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = ED.decoder_forward(params["decoder"], cfg, x, enc, positions)
        h = L.rms_norm(h, params["out_norm"], cfg.norm_eps)
        ce = L.lm_head_loss(h, _unembed(params, cfg), batch["labels"], batch["mask"])
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def prefill(params, batch):
        enc = ED.encoder_forward(params["encoder"], cfg, batch["frame_embeds"].astype(dtype),
                                 remat=False)
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = ED.decoder_forward(params["decoder"], cfg, x, enc, positions, remat=False)
        h = L.rms_norm(h, params["out_norm"], cfg.norm_eps)
        logits = h[:, -1] @ _unembed(params, cfg)
        cache = ED.init_decoder_cache(cfg, B, S, enc.shape[1], dtype)
        xk, xv = ED.precompute_cross_cache(params["decoder"], cfg, enc)
        cache.update(xk=xk, xv=xv)
        return logits, cache

    def init_cache(batch: int, max_seq: int, frames: Optional[int] = None):
        return ED.init_decoder_cache(cfg, batch, max_seq, frames or cfg.frontend_tokens, dtype)

    def decode_step(params, tokens, cache):
        x = jnp.take(params["embed"], tokens, axis=0)
        h, cache = ED.decoder_decode_step(params["decoder"], cfg, x, cache)
        h = L.rms_norm(h, params["out_norm"], cfg.norm_eps)
        logits = h[:, -1] @ _unembed(params, cfg)
        return logits, cache

    return Model(cfg, dtype, init, loss, prefill, init_cache, decode_step)


# --------------------------------------------------------------------- #


def build_model(cfg: ArchConfig, dtype=jnp.bfloat16) -> Model:
    if cfg.family == "encdec_audio":
        return _build_encdec(cfg, dtype)
    return _build_decoder_only(cfg, dtype)


def make_batch(cfg: ArchConfig, shape, rng=None, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Concrete random batch for smoke tests (small shapes only)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    B, S = shape.global_batch, shape.seq_len
    k1, k2, k3 = jax.random.split(rng, 3)
    if cfg.family == "vlm":
        P = cfg.frontend_tokens
        toks = jax.random.randint(k1, (B, S - P), 0, cfg.vocab_size)
        return {"tokens": toks,
                "labels": jax.random.randint(k2, (B, S - P), 0, cfg.vocab_size),
                "mask": jnp.ones((B, S - P), jnp.float32),
                "patch_embeds": jax.random.normal(k3, (B, P, cfg.d_model), dtype)}
    if cfg.family == "encdec_audio":
        F = cfg.frontend_tokens
        toks = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks,
                "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
                "mask": jnp.ones((B, S), jnp.float32),
                "frame_embeds": jax.random.normal(k3, (B, F, cfg.d_model), dtype)}
    toks = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks,
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
            "mask": jnp.ones((B, S), jnp.float32)}
