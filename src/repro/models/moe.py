"""Mixture-of-Experts MLP with top-k routing and capacity-based dispatch.

Dispatch is gather-based (per-expert top-C token selection) so the cost is
O(T * k * cf * d_ff) — linear in tokens — rather than the quadratic
one-hot-einsum dispatch.  Experts are stacked on a leading E axis so they
shard cleanly over the `model` mesh axis (expert parallelism).

Per-client expert-occupancy statistics (which experts a federated client's
tokens actually route to) feed the paper's A-matrix aggregation scaling; see
``repro.core.scaling.expert_occupancy``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, cfg, dtype) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], (E, d, f), dtype),
        "w_up": dense_init(ks[2], (E, d, f), dtype),
        "w_down": dense_init(ks[3], (E, f, d), dtype),
    }


def route_topk(gates_logits: jax.Array, k: int):
    """gates_logits: (..., E).  Returns (..., E) combine weights (top-k softmax)."""
    E = gates_logits.shape[-1]
    probs = jax.nn.softmax(gates_logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                     # (..., k)
    mask = jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(axis=-2)  # (..., E)
    weights = probs * mask
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, probs, mask


def load_balance_loss(probs: jax.Array, mask: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss: E * <fraction routed> . <mean prob>."""
    E = probs.shape[-1]
    frac = mask.reshape(-1, E).mean(axis=0)
    mean_p = probs.reshape(-1, E).mean(axis=0)
    return E * jnp.sum(frac * mean_p)


def moe_fwd(params, x, cfg, *, capacity_factor: float = 2.0):
    """x: (B, S, d) -> (B, S, d), aux_loss scalar.

    Dispatch is *per sequence* (capacity C = cf·k·S/E tokens per expert per
    sequence): every routing/gather/scatter op is batched over B, so the
    whole MoE layer shards cleanly over the data axis with zero dispatch
    communication.  A global top-C (across the full token set) would force
    XLA to gather every shard's tokens — measured as a 12.4 TB/chip
    activation all-reduce on dbrx-132b before this change (EXPERIMENTS.md
    §Perf iter 8).
    """
    B, S, d = x.shape
    E = cfg.moe.num_experts
    k = cfg.moe.experts_per_token

    logits = x.astype(jnp.float32) @ params["router"]        # (B, S, E)
    weights, probs, mask = route_topk(logits, k)
    aux = load_balance_loss(probs, mask)

    C = max(1, min(S, int(capacity_factor * k * S / E)))
    gate_es = weights.transpose(0, 2, 1)                     # (B, E, S)
    top_w, top_idx = jax.lax.top_k(gate_es, C)               # (B, E, C)

    from repro.sharding.hints import constrain_heads

    xe = jnp.take_along_axis(
        x[:, None, :, :],                                    # (B, 1, S, d)
        top_idx[..., None], axis=2)                          # -> (B, E, C, d)
    # pin dispatch output: batch over data, experts over model — XLA's
    # gather partitioner otherwise replicates the full global batch
    xe = constrain_heads(xe, head_axis=1)

    if cfg.mlp_style == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, params["w_gate"]))
        h = h * jnp.einsum("becd,edf->becf", xe, params["w_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", xe, params["w_up"]))
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"])

    ye = ye * top_w[..., None].astype(ye.dtype)              # (B, E, C, d)
    ye = constrain_heads(ye, head_axis=1)
    bidx = jnp.arange(B)[:, None, None]
    out = jnp.zeros((B, S, d), ye.dtype).at[bidx, top_idx].add(ye)
    from repro.sharding.hints import constrain_activations
    out = constrain_activations(out)
    return out.astype(x.dtype), aux
