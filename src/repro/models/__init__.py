from repro.models.model import Model, build_model, make_batch

__all__ = ["Model", "build_model", "make_batch"]
