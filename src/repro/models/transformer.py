"""Unified decoder stack for every assigned family.

Layers are grouped into *pattern blocks*: the layer-kind pattern of an
architecture repeats with period P (P=1 for homogeneous dense/MoE/RWKV
stacks; P=8 for Jamba's 1-attention-per-8 + MoE-every-other interleave).
Parameters are stacked with a leading (num_layers // P) axis and the stack is
driven by one `lax.scan` over pattern blocks — compile time is O(P) block
traces regardless of depth, which is what keeps 40 dry-run combinations
tractable on 512 SPMD devices.

Each pattern block is rematerialized (`jax.checkpoint`) in training mode.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv as R
from repro.utils import flags


# --------------------------------------------------------------------- #
# layer-kind pattern
# --------------------------------------------------------------------- #


def pattern_period(cfg: ArchConfig) -> int:
    p = 1
    if cfg.attn_period:
        p = math.lcm(p, cfg.attn_period)
    if cfg.moe is not None and cfg.moe_period:
        p = math.lcm(p, cfg.moe_period)
    return p


def layer_kind(cfg: ArchConfig, j: int) -> Tuple[str, str]:
    """Kind of the layer at pattern position j: (mixer, mlp)."""
    if cfg.attention_free:
        return "rwkv", "rwkv_cm"
    mixer = "attn"
    if cfg.attn_period and (j % cfg.attn_period) != cfg.attn_period - 1:
        mixer = "mamba"
    mlp = "dense"
    if cfg.moe is not None and cfg.moe_period and (j % cfg.moe_period) == cfg.moe_period - 1:
        mlp = "moe"
    return mixer, mlp


# --------------------------------------------------------------------- #
# per-position init
# --------------------------------------------------------------------- #


def _init_layer(key, cfg: ArchConfig, j: int, dtype) -> Dict:
    mixer, mlp = layer_kind(cfg, j)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Dict = {"norm1": jnp.ones((cfg.d_model,), jnp.float32),
               "norm2": jnp.ones((cfg.d_model,), jnp.float32)}
    if mixer == "attn":
        p["attn"] = L.init_attention(k1, cfg, dtype)
    elif mixer == "mamba":
        p["mamba"] = M.init_mamba(k1, cfg, dtype)
    elif mixer == "rwkv":
        p["rwkv_tm"] = R.init_rwkv_time_mix(k1, cfg, dtype)
    if mlp == "dense":
        p["mlp"] = L.init_mlp(k2, cfg, dtype)
    elif mlp == "moe":
        p["moe"] = MOE.init_moe(k2, cfg, dtype)
    elif mlp == "rwkv_cm":
        p["rwkv_cm"] = R.init_rwkv_channel_mix(k2, cfg, dtype)
    return p


def init_stack(key, cfg: ArchConfig, dtype) -> Dict:
    """Stacked params: {'pos{j}': pytree with leading (L//P) axis}."""
    P = pattern_period(cfg)
    nrep = cfg.num_layers // P
    assert nrep * P == cfg.num_layers, (cfg.num_layers, P)
    out = {}
    for j in range(P):
        keys = jax.random.split(jax.random.fold_in(key, j), nrep)
        per_rep = [_init_layer(k, cfg, j, dtype) for k in keys]
        out[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
    return out


# --------------------------------------------------------------------- #
# forward (train / prefill)
# --------------------------------------------------------------------- #


def _apply_layer_fwd(p, x, cfg, j, positions, collect_cache: bool):
    """Returns (x, aux_loss, cache_entry_or_None)."""
    mixer, mlp = layer_kind(cfg, j)
    aux = jnp.zeros((), jnp.float32)
    cache_entry = None
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer == "attn":
        o, (kc, vc) = L.attention_fwd(p["attn"], h, cfg, positions)
        if collect_cache:
            cache_entry = {"k": kc, "v": vc}
        x = x + o
    elif mixer == "mamba":
        o, (ssm, conv) = M.mamba_fwd(p["mamba"], h, cfg)
        if collect_cache:
            cache_entry = {"ssm": ssm, "conv": conv}
        x = x + o
    elif mixer == "rwkv":
        o, (st, sl) = R.rwkv_time_mix(p["rwkv_tm"], h, cfg)
        if collect_cache:
            cache_entry = {"wkv": st, "shift_tm": sl}
        x = x + o
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    if mlp == "dense":
        x = x + L.mlp_fwd(p["mlp"], h, cfg)
    elif mlp == "moe":
        o, a = MOE.moe_fwd(p["moe"], h, cfg)
        x = x + o
        aux = aux + a
    elif mlp == "rwkv_cm":
        o, sl_cm = R.rwkv_channel_mix(p["rwkv_cm"], h)
        x = x + o
        if collect_cache and cache_entry is not None:
            cache_entry["shift_cm"] = sl_cm
    return x, aux, cache_entry


def stack_forward(params: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
                  *, remat: bool = True, collect_cache: bool = False):
    """x: (B,S,d) -> (hidden, aux_loss[, cache])."""
    P = pattern_period(cfg)

    def block(x, stacked):
        from repro.sharding.hints import constrain_activations, gather_fsdp
        stacked = gather_fsdp(stacked)
        aux = jnp.zeros((), jnp.float32)
        entries = {}
        x = constrain_activations(x)
        for j in range(P):
            x, a, ce = _apply_layer_fwd(stacked[f"pos{j}"], x, cfg, j, positions, collect_cache)
            aux = aux + a
            if ce is not None:
                entries[f"pos{j}"] = ce
        return constrain_activations(x), (aux, entries)

    body = jax.checkpoint(block) if remat else block
    x, (auxs, caches) = jax.lax.scan(lambda c, p: body(c, p), x, params,
                                     unroll=flags.scan_unroll())
    if collect_cache:
        return x, auxs.sum(), caches
    return x, auxs.sum()


# --------------------------------------------------------------------- #
# decode (one token, stateful caches)
# --------------------------------------------------------------------- #


def cache_max_len(cfg: ArchConfig, max_seq: int) -> int:
    if cfg.sliding_window is not None:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    P = pattern_period(cfg)
    nrep = cfg.num_layers // P
    smax = cache_max_len(cfg, max_seq)
    d = cfg.d_model
    out: Dict = {"len": jnp.zeros((), jnp.int32)}
    for j in range(P):
        mixer, _ = layer_kind(cfg, j)
        if mixer == "attn":
            shp = (nrep, batch, smax, cfg.num_kv_heads, cfg.head_dim)
            out[f"pos{j}"] = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
        elif mixer == "mamba":
            di = cfg.mamba_expand * d
            out[f"pos{j}"] = {
                "ssm": jnp.zeros((nrep, batch, di, cfg.mamba_d_state), jnp.float32),
                "conv": jnp.zeros((nrep, batch, cfg.mamba_d_conv - 1, di), dtype),
            }
        elif mixer == "rwkv":
            hn = d // cfg.rwkv_head_dim
            out[f"pos{j}"] = {
                "wkv": jnp.zeros((nrep, batch, hn, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
                "shift_tm": jnp.zeros((nrep, batch, d), dtype),
                "shift_cm": jnp.zeros((nrep, batch, d), dtype),
            }
    return out


def _apply_layer_decode(p, x, cfg, j, cache_j, cur_len, smax):
    mixer, mlp = layer_kind(cfg, j)
    new_cache = {}
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer == "attn":
        slot = cur_len % smax if cfg.sliding_window is not None else cur_len
        B = x.shape[0]
        H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        pos = jnp.full((B, 1), cur_len, dtype=jnp.int32)
        q = L.apply_rope((h @ p["attn"]["wq"]).reshape(B, 1, H, Dh), pos, cfg.rope_theta)
        k = L.apply_rope((h @ p["attn"]["wk"]).reshape(B, 1, Hkv, Dh), pos, cfg.rope_theta)
        v = (h @ p["attn"]["wv"]).reshape(B, 1, Hkv, Dh)
        ck = jax.lax.dynamic_update_slice(cache_j["k"], k.astype(cache_j["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache_j["v"], v.astype(cache_j["v"].dtype), (0, slot, 0, 0))
        n_valid = jnp.minimum(cur_len + 1, smax)
        o = L.decode_attention(q, ck, cv, n_valid, window=None)
        x = x + (o.reshape(B, 1, H * Dh) @ p["attn"]["wo"])
        new_cache = {"k": ck, "v": cv}
    elif mixer == "mamba":
        o, (ssm, conv) = M.mamba_fwd(p["mamba"], h, cfg,
                                     ssm_state=cache_j["ssm"], conv_state=cache_j["conv"])
        x = x + o
        new_cache = {"ssm": ssm, "conv": conv}
    elif mixer == "rwkv":
        o, (st, sl) = R.rwkv_time_mix(p["rwkv_tm"], h, cfg,
                                      state=cache_j["wkv"], shift_last=cache_j["shift_tm"])
        x = x + o
        new_cache = {"wkv": st, "shift_tm": sl}
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    if mlp == "dense":
        x = x + L.mlp_fwd(p["mlp"], h, cfg)
    elif mlp == "moe":
        o, _ = MOE.moe_fwd(p["moe"], h, cfg)
        x = x + o
    elif mlp == "rwkv_cm":
        o, sl_cm = R.rwkv_channel_mix(p["rwkv_cm"], h, shift_last=cache_j["shift_cm"])
        x = x + o
        new_cache["shift_cm"] = sl_cm
    return x, new_cache


def stack_decode(params: Dict, cfg: ArchConfig, x: jax.Array, cache: Dict):
    """x: (B,1,d). Returns (x, new_cache)."""
    P = pattern_period(cfg)
    cur_len = cache["len"]
    smax = None
    for j in range(P):
        if layer_kind(cfg, j)[0] == "attn":
            smax = cache[f"pos{j}"]["k"].shape[2]
    layer_caches = {k: v for k, v in cache.items() if k != "len"}

    def block(x, inp):
        stacked, cj = inp
        new_cj = {}
        for j in range(P):
            key = f"pos{j}"
            x, nc = _apply_layer_decode(stacked[key], x, cfg, j, cj[key], cur_len, smax)
            new_cj[key] = nc
        return x, new_cj

    x, new_caches = jax.lax.scan(block, x, (params, layer_caches),
                                 unroll=flags.scan_unroll())
    new_cache = dict(new_caches)
    new_cache["len"] = cur_len + 1
    return x, new_cache
