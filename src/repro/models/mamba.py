"""Mamba (S6) selective-state-space block, used by the Jamba hybrid.

Training/prefill run a chunked selective scan: `lax.scan` over sequence
chunks (rematerialized) with an inner `associative_scan` over the diagonal
recurrence h_t = a_t * h_{t-1} + b_t.  Decode is the O(1) single-step update,
which is what makes long_500k lowerable for the hybrid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mamba(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    N = cfg.mamba_d_state
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (cfg.mamba_d_conv, di), dtype, scale=1.0),
        "x_bc": dense_init(ks[2], (di, 2 * N), dtype),
        "x_dt": dense_init(ks[3], (di, 1), dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv. x: (B,S,di), w: (Kc,di)."""
    Kc = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], Kc - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, x], axis=1)                   # (B, S+Kc-1, di)
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(Kc))
    new_state = xp[:, -(Kc - 1):] if Kc > 1 else pad
    return out, new_state


def _ssm_scan_chunked(a, b, h0, chunk: int):
    """Diagonal SSM recurrence h_t = a_t*h_{t-1} + b_t over (B,S,di,N).

    Scans chunks sequentially (carrying h) and runs an associative scan
    inside each (rematerialized) chunk.
    """
    B, S, di, N = a.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    ac = a.reshape(B, n, chunk, di, N).swapaxes(0, 1)
    bc = b.reshape(B, n, chunk, di, N).swapaxes(0, 1)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    @jax.checkpoint
    def chunk_step(h, inp):
        ab, bb = inp                                          # (B, chunk, di, N)
        aa, bb2 = jax.lax.associative_scan(combine, (ab, bb), axis=1)
        hs = aa * h[:, None] + bb2                            # (B, chunk, di, N)
        return hs[:, -1], hs

    h_last, hs = jax.lax.scan(chunk_step, h0, (ac, bc))
    hs = hs.swapaxes(0, 1).reshape(B, S, di, N)
    return hs, h_last


def mamba_fwd(params, x, cfg, *, ssm_state=None, conv_state=None, chunk: int = 256):
    """x: (B,S,d) -> (B,S,d), (ssm_state, conv_state).

    Pass states for streaming decode (S==1 uses the O(1) update)."""
    B, S, d = x.shape
    di = cfg.mamba_expand * d
    N = cfg.mamba_d_state

    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                         # (B,S,di) each
    xs, new_conv = _causal_conv(xs, params["conv_w"], conv_state)
    xs = jax.nn.silu(xs)

    bc = xs @ params["x_bc"]                                  # (B,S,2N)
    Bm, Cm = jnp.split(bc.astype(jnp.float32), 2, axis=-1)    # (B,S,N)
    dt = jax.nn.softplus(
        (xs @ params["x_dt"]).astype(jnp.float32) + params["dt_bias"]
    )                                                         # (B,S,di) via (B,S,1)+(di,)
    A = -jnp.exp(params["a_log"])                             # (di,N)

    a_bar = jnp.exp(dt[..., None] * A[None, None])            # (B,S,di,N)
    b_bar = dt[..., None] * Bm[:, :, None, :] * xs.astype(jnp.float32)[..., None]

    if ssm_state is None:
        ssm_state = jnp.zeros((B, di, N), jnp.float32)

    if S == 1:
        h = a_bar[:, 0] * ssm_state + b_bar[:, 0]             # (B,di,N)
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None]    # (B,1,di)
        new_state = h
    else:
        hs, new_state = _ssm_scan_chunked(a_bar, b_bar, ssm_state, chunk)
        y = jnp.einsum("bsdn,bsn->bsd", hs, Cm)

    y = y + xs.astype(jnp.float32) * params["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"], (new_state, new_conv)
