"""Shared transformer building blocks (pure JAX, explicit param pytrees).

All attention goes through :func:`flash_attention` — a pure-JAX blocked
(online-softmax) implementation scanning over query/key blocks so the full
S x S score matrix is never materialized.  This is what makes prefill_32k
lower with a bounded working set on the production mesh.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils import flags

# ----------------------------------------------------------------------- #
# initializers / norms
# ----------------------------------------------------------------------- #


def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0]
    std = scale / (fan_in ** 0.5)
    return (jax.random.normal(key, shape) * std).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with a custom VJP that keeps the residual-stream cotangent in
    the input dtype.

    The naive autodiff of the f32 variance branch produces an f32 (B,S,d)
    cotangent; when it joins the bf16 branch the sum promotes to f32 and the
    entire backward residual stream — including every Megatron all-reduce —
    becomes f32 (measured: ~12 × 268 MB f32 ARs per layer per pass on
    llama3-8b train_4k; EXPERIMENTS.md §Perf iter 2/3).  Here the backward
    math runs in f32 *locally* and returns dx cast to x.dtype.
    """
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(x.dtype)


def _rms_norm_fwd(x, weight, eps):
    return rms_norm(x, weight, eps), (x, weight)


def _rms_norm_bwd(eps, res, dy):
    x, weight = res
    x32 = x.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    w32 = weight.astype(jnp.float32)
    d = x.shape[-1]
    s = jax.lax.rsqrt(jnp.mean(jnp.square(x32), axis=-1, keepdims=True) + eps)
    wdy = w32 * dy32
    dx = s * wdy - (s ** 3) * x32 * jnp.sum(x32 * wdy, axis=-1, keepdims=True) / d
    dw = jnp.sum((x32 * s) * dy32, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dw.astype(weight.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ----------------------------------------------------------------------- #
# RoPE
# ----------------------------------------------------------------------- #


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- #
# blocked flash attention (pure JAX)
# ----------------------------------------------------------------------- #

_NEG_INF = -1e30


def _attn_block(q, k, v, qpos, kpos, *, causal, window, scale):
    """One (q-block, kv-block) tile. q: (B,Qb,Hkv,G,Dh) k/v: (B,Kb,Hkv,Dh)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None, :, :], s, _NEG_INF)
    return s


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_block: int = 512,
    kv_block: int = 512,
    q_offset: int = 0,
) -> jax.Array:
    """Blocked online-softmax attention.

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh); H % Hkv == 0 (GQA).
    Returns (B, Sq, H, Dh).  Never materializes (Sq, Skv) scores: scans over
    query blocks, inner-scans over kv blocks with running (max, denom, acc).
    """
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    G = H // Hkv
    scale = Dh ** -0.5

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    if Sq % q_block or Skv % kv_block:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks ({q_block},{kv_block})")
    nq, nk = Sq // q_block, Skv // kv_block

    qg = q.reshape(B, nq, q_block, Hkv, G, Dh)
    kg = k.reshape(B, nk, kv_block, Hkv, Dh)
    vg = v.reshape(B, nk, kv_block, Hkv, Dh)

    def q_step(_, qi):
        qb, qidx = qi
        qpos = q_offset + qidx * q_block + jnp.arange(q_block)

        def kv_step(carry, ki):
            acc, m, l = carry
            kb, vb, kidx = ki
            kpos = kidx * kv_block + jnp.arange(kv_block)
            s = _attn_block(qb, kb, vb, qpos, kpos, causal=causal, window=window, scale=scale)
            m_new = jnp.maximum(m, s.max(axis=-1))                   # (B,Hkv,G,Qb)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, Hkv, G, q_block, Dh), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_block), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step),
            (acc0, m0, l0),
            (kg.swapaxes(0, 1), vg.swapaxes(0, 1), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B,Hkv,G,Qb,Dh) -> (B,Qb,Hkv,G,Dh)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, out = jax.lax.scan(q_step, None, (qg.swapaxes(0, 1), jnp.arange(nq)))
    # out: (nq, B, Qb, Hkv, G, Dh)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,            # (B, 1, H, Dh)
    k_cache: jax.Array,      # (B, Smax, Hkv, Dh)
    v_cache: jax.Array,
    cur_len: jax.Array,      # () int32 — number of valid cache entries
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token decode attention against a (possibly windowed) KV cache."""
    B, _, H, Dh = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache, preferred_element_type=jnp.float32)
    s = s * (Dh ** -0.5)
    pos = jnp.arange(Smax)
    mask = pos < cur_len
    if window is not None:
        mask &= pos >= cur_len - window
    s = jnp.where(mask[None, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


# ----------------------------------------------------------------------- #
# attention layer (params + apply)
# ----------------------------------------------------------------------- #


def init_attention(key, cfg, dtype) -> dict:
    d, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, H * Dh), dtype),
        "wk": dense_init(ks[1], (d, Hkv * Dh), dtype),
        "wv": dense_init(ks[2], (d, Hkv * Dh), dtype),
        "wo": dense_init(ks[3], (H * Dh, d), dtype),
    }


def attention_fwd(params, x, cfg, positions, *, window=None):
    """Full-sequence (train/prefill) attention. x: (B,S,d)."""
    from repro.sharding.hints import constrain_heads

    B, S, _ = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (x @ params["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ params["wv"]).reshape(B, S, Hkv, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # q shards on its own head count; k/v only when the KV heads divide
    # (GQA einsums treat heads as a batch dim, so mixed q-sharded /
    # kv-replicated layouts need no communication)
    q = constrain_heads(q)
    k = constrain_heads(k, kv_heads=Hkv)
    v = constrain_heads(v, kv_heads=Hkv)
    o = flash_attention(q, k, v, causal=True, window=window or cfg.sliding_window)
    return o.reshape(B, S, H * Dh) @ params["wo"], (k, v)


def attention_decode(params, x, cfg, cache_k, cache_v, cur_len):
    """One-token decode. x: (B,1,d); caches: (B,Smax,Hkv,Dh)."""
    B = x.shape[0]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = jnp.full((B, 1), cur_len, dtype=jnp.int32)
    q = apply_rope((x @ params["wq"]).reshape(B, 1, H, Dh), pos, cfg.rope_theta)
    k = apply_rope((x @ params["wk"]).reshape(B, 1, Hkv, Dh), pos, cfg.rope_theta)
    v = (x @ params["wv"]).reshape(B, 1, Hkv, Dh)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype), (0, cur_len, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype), (0, cur_len, 0, 0))
    o = decode_attention(q, cache_k, cache_v, cur_len + 1, window=cfg.sliding_window)
    return o.reshape(B, 1, H * Dh) @ params["wo"], cache_k, cache_v


# cross-attention (enc-dec): no RoPE on encoder keys, not causal.


def init_cross_attention(key, cfg, dtype) -> dict:
    return init_attention(key, cfg, dtype)


def cross_attention_fwd(params, x, enc_out, cfg):
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, H, Dh)
    k = (enc_out @ params["wk"]).reshape(B, Se, Hkv, Dh)
    v = (enc_out @ params["wv"]).reshape(B, Se, Hkv, Dh)
    o = flash_attention(q, k, v, causal=False, window=None)
    return o.reshape(B, S, H * Dh) @ params["wo"]


# ----------------------------------------------------------------------- #
# MLPs
# ----------------------------------------------------------------------- #


def init_mlp(key, cfg, dtype, d_ff=None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_style == "swiglu":
        ks = jax.random.split(key, 3)
        return {
            "w_gate": dense_init(ks[0], (d, f), dtype),
            "w_up": dense_init(ks[1], (d, f), dtype),
            "w_down": dense_init(ks[2], (f, d), dtype),
        }
    ks = jax.random.split(key, 2)
    return {"w_up": dense_init(ks[0], (d, f), dtype), "w_down": dense_init(ks[1], (f, d), dtype)}


def mlp_fwd(params, x, cfg):
    if cfg.mlp_style == "swiglu":
        return (jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]
    return jax.nn.gelu(x @ params["w_up"]) @ params["w_down"]


# ----------------------------------------------------------------------- #
# chunked LM head loss (never materializes full (tokens, vocab) logits)
# ----------------------------------------------------------------------- #


def lm_head_loss(x, emb_out, labels, mask, *, chunk: int = 2048):
    """Mean next-token cross entropy.

    x: (B,S,d) final hidden states, emb_out: (d,V), labels: (B,S) int32,
    mask: (B,S) {0,1}.  Computes softmax CE in sequence chunks under remat so
    peak logits memory is (B, chunk, V).
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fallback: odd lengths take the unchunked path
    n = S // chunk
    xc = x.reshape(B, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)
    mc = mask.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_loss(carry, inp):
        xb, lb, mb = inp
        logits = (xb @ emb_out).astype(jnp.float32)      # (B, chunk, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * mb
        return carry + ce.sum(), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (xc, lc, mc),
                            unroll=flags.scan_unroll())
    return total / jnp.maximum(mask.sum().astype(jnp.float32), 1.0)
