"""RWKV-6 (Finch) block — attention-free time mixing with data-dependent decay.

Per head h with head_dim D, the recurrence over the (D, D) state S is

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (diag(u) k_t^T v_t + S_{t-1})

where w_t = exp(-exp(decay_t)) is the *data-dependent* per-channel decay
(the defining RWKV-6 feature vs RWKV-4/5's static decay).  Training/prefill
scan sequence chunks with remat; decode is the O(1) state update — this is
why rwkv6 runs long_500k.

Simplifications vs the reference implementation (documented, tested against
our own oracle): single token-shift interpolation parameter set (no 5-way
LoRA mix), decay LoRA of rank 64.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_rwkv_time_mix(key, cfg, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    ks = jax.random.split(key, 8)
    lora = 64
    return {
        "mix_r": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_v": 0.5 * jnp.ones((d,), jnp.float32),
        "mix_w": 0.5 * jnp.ones((d,), jnp.float32),
        "wr": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "wo": dense_init(ks[3], (d, d), dtype),
        # data-dependent decay LoRA: w_t = exp(-exp(decay_base + lora(x)))
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_a": dense_init(ks[4], (d, lora), dtype),
        "decay_b": dense_init(ks[5], (lora, d), dtype),
        "bonus_u": jnp.zeros((d // hd, hd), jnp.float32),
        "ln_x_w": jnp.ones((d,), jnp.float32),
    }


def _token_shift(x, mix, last=None):
    """x: (B,S,d). shift by one step; `last` seeds position -1 for decode."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1) if x.shape[1] > 1 else last[:, None]
    return x * mix + prev * (1.0 - mix)


def _wkv_sequential(r, k, v, w, u, state):
    """Reference WKV: one step at a time (the oracle; O(1)-state decode path)."""
    B, S, Hn, D = r.shape

    def step(s, inp):
        rt, kt, vt, wt = inp                                  # (B,Hn,D)
        kv = kt[..., :, None] * vt[..., None, :]              # (B,Hn,D,D)
        out = jnp.einsum("bhd,bhde->bhe", rt, u[None, :, :, None] * kv + s)
        s = wt[..., :, None] * s + kv
        return s, out

    rs, ks_, vs, ws = (t.swapaxes(0, 1) for t in (r, k, v, w))
    state, outs = jax.lax.scan(jax.checkpoint(step), state, (rs, ks_, vs, ws))
    return outs.swapaxes(0, 1), state                         # (B,S,Hn,D)


def _wkv_chunked(r, k, v, w, u, state, chunk: int):
    """Chunk-parallel WKV (linear-attention form).

    Within a chunk of length L, with per-channel decay products
    c_t = Π_{i<=t} w_i:

        intra_t = [(r_t ⊙ c_{t-1}) (k_s / c_s)^T ⊙ M_strict] v_s
        bonus_t = (r_t · (u ⊙ k_t)) v_t
        inter_t = (r_t ⊙ c_{t-1}) S_0
        S_L     = diag(c_L) (S_0 + (k/c)^T v)

    This replaces S×(D,D)-state HBM round-trips per token with two (L,D)
    matmuls + one state update per chunk — the dominant-term fix for the
    rwkv6 train_4k roofline (EXPERIMENTS.md §Perf pair 3).  Sequential
    scanning only happens across chunks (S/L carry steps).

    Numerics: c_t can underflow for strongly-decaying channels, so chunks
    are kept short (default 32) and all chunk math is f32; validated against
    the sequential oracle in tests/test_rwkv_chunked.py.
    """
    B, S, Hn, D = r.shape
    L = min(chunk, S)
    if S % L:
        return _wkv_sequential(r, k, v, w, u, state)
    n = S // L

    def to_chunks(t):
        return t.reshape(B, n, L, Hn, D).transpose(1, 0, 3, 2, 4)  # (n,B,Hn,L,D)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    mask = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)      # strict lower

    @jax.checkpoint
    def chunk_step(s, inp):
        rb, kb, vb, wb = inp                                  # (B,Hn,L,D)
        c = jnp.cumprod(wb, axis=2)                           # c_t, (B,Hn,L,D)
        c_prev = jnp.concatenate(
            [jnp.ones_like(c[:, :, :1]), c[:, :, :-1]], axis=2)  # c_{t-1}
        r_t = rb * c_prev
        k_t = kb / jnp.maximum(c, 1e-30)
        scores = jnp.einsum("bhtd,bhsd->bhts", r_t, k_t) * mask[None, None]
        intra = jnp.einsum("bhts,bhsd->bhtd", scores, vb)
        bonus = jnp.einsum("bhtd,bhtd->bht", rb, u[None, :, None, :] * kb)[..., None] * vb
        inter = jnp.einsum("bhtd,bhde->bhte", r_t, s)
        out = intra + bonus + inter
        s_new = c[:, :, -1][..., None] * (s + jnp.einsum("bhsd,bhse->bhde", k_t, vb))
        return s_new, out

    state, outs = jax.lax.scan(chunk_step, state, (rc, kc, vc, wc))
    # (n, B, Hn, L, D) -> (B, S, Hn, D)
    outs = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, Hn, D)
    return outs, state


def rwkv_time_mix(params, x, cfg, *, state=None, shift_last=None):
    """x: (B,S,d) -> (B,S,d), (state, shift_last)."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    Hn = d // hd

    xr = _token_shift(x, params["mix_r"].astype(x.dtype), shift_last)
    xk = _token_shift(x, params["mix_k"].astype(x.dtype), shift_last)
    xv = _token_shift(x, params["mix_v"].astype(x.dtype), shift_last)
    xw = _token_shift(x, params["mix_w"].astype(x.dtype), shift_last)

    r = (xr @ params["wr"]).reshape(B, S, Hn, hd).astype(jnp.float32)
    k = (xk @ params["wk"]).reshape(B, S, Hn, hd).astype(jnp.float32)
    v = (xv @ params["wv"]).reshape(B, S, Hn, hd).astype(jnp.float32)

    decay = params["decay_base"] + (jnp.tanh(xw @ params["decay_a"]) @ params["decay_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(B, S, Hn, hd)        # in (0,1)

    if state is None:
        state = jnp.zeros((B, Hn, hd, hd), jnp.float32)
    if S == 1:
        out, state = _wkv_sequential(r, k, v, w, params["bonus_u"], state)
    else:
        out, state = _wkv_chunked(r, k, v, w, params["bonus_u"], state, chunk=32)

    out = out.reshape(B, S, d)
    # group norm over heads (ln_x in reference impl)
    out = out.reshape(B, S, Hn, hd)
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(B, S, d) * params["ln_x_w"]
    new_shift_last = x[:, -1]
    return (out.astype(x.dtype) @ params["wo"]), (state, new_shift_last)


def init_rwkv_channel_mix(key, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 2)
    return {
        "mix_k": 0.5 * jnp.ones((d,), jnp.float32),
        "wk": dense_init(ks[0], (d, f), dtype),
        "wv": dense_init(ks[1], (f, d), dtype),
    }


def rwkv_channel_mix(params, x, *, shift_last=None):
    xk = _token_shift(x, params["mix_k"].astype(x.dtype), shift_last)
    h = jnp.square(jax.nn.relu(xk @ params["wk"]))
    return h @ params["wv"], x[:, -1]
