"""Activation sharding hints, safe to call from model code.

Model code is mesh-agnostic; these helpers look up the *current* mesh (the
`jax.set_mesh` context the launcher established) and no-op when there is
none — so smoke tests and CPU runs are untouched.

`constrain_activations(x)` pins the residual-stream layout between scanned
blocks to the Megatron convention: batch over ('pod','data'), d_model
replicated.  Without the pin, XLA propagates a d-sharded layout out of the
row-parallel matmul and inserts a full f32 activation all-gather inside
every layer (measured: 63% of llama3-8b train_4k collective bytes — see
EXPERIMENTS.md §Perf iteration 1).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not mesh.axis_names:
        return None
    return mesh


def constrain_param_tree(tree):
    """Pin a parameter-shaped pytree (grad accumulators, scan carries of
    model copies) to the rule-engine parameter shardings.

    Scan carries don't inherit the in_shardings of the params they were
    derived from; without the pin the FSVRG aggregate carry materializes as
    a fully-replicated f32 param copy (32 GB/chip for llama3-8b —
    EXPERIMENTS.md §Perf iter 5).
    """
    mesh = _current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return tree
    from repro.sharding import rules

    def one(kp, leaf):
        spec = rules.spec_for_param(jax.tree_util.keystr(kp), leaf.shape, mesh)
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(one, tree)


def gather_fsdp(tree):
    """FSDP weight-gather for the current pattern block's (sliced) params.

    Inside the layer scan, weights keep their TP ('model') sharding but drop
    the FSDP ('data') axis — an explicit per-layer all-gather.  Without it
    XLA keeps contraction dims data-sharded and partial-sums *activations*
    instead: on dbrx-132b train_4k the expert matmuls all-reduced 12.4 TB of
    f32 (E,C,f) activations per chip per round (EXPERIMENTS.md §Perf
    iter 8).  Gathering the block's weights costs layer_params/TP bytes —
    ~15× less.
    """
    mesh = _current_mesh()
    if mesh is None or "model" not in mesh.axis_names or "data" not in mesh.axis_names:
        return tree
    if mesh.shape["data"] <= 1:
        return tree
    from repro.sharding import rules

    def drop_data(entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in axes if a != "data")
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    def one(kp, leaf):
        spec = rules.spec_for_param(jax.tree_util.keystr(kp), leaf.shape, mesh)
        return jax.lax.with_sharding_constraint(
            leaf, P(*[drop_data(e) for e in spec]))

    return jax.tree_util.tree_map_with_path(one, tree)


def constrain_activations(x: jax.Array) -> jax.Array:
    """(B, S, d) residual stream -> batch over ('pod','data'), rest replicated."""
    mesh = _current_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return x
    ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    size = math.prod(mesh.shape[a] for a in ax)
    if x.ndim < 1 or size <= 1 or x.shape[0] % size:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(ax, *([None] * (x.ndim - 1))))


def constrain_heads(x: jax.Array, head_axis: int = 2,
                    kv_heads: int | None = None) -> jax.Array:
    """(B, S, H, Dh) attention activations.

    Heads shard over 'model' only when *both* H and the KV-head count
    divide the axis (otherwise XLA falls back to sharding the contracted
    head_dim, turning every attention score block into a partial-sum
    all-reduce — measured 2.1 TB/chip on internvl2-1b train_4k whose 14
    heads don't divide 16; EXPERIMENTS.md §Perf iter 6).  Indivisible cases
    replicate heads and keep attention purely data-parallel.
    """
    mesh = _current_mesh()
    if mesh is None or "data" not in mesh.axis_names:
        return x
    ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = math.prod(mesh.shape[a] for a in ax)
    if x.ndim < 3 or bsize <= 1 or x.shape[0] % bsize:
        return x
    entries: list = [ax] + [None] * (x.ndim - 1)
    if "model" in mesh.axis_names:
        msize = mesh.shape["model"]
        h = x.shape[head_axis]
        kv_ok = kv_heads is None or (kv_heads % msize == 0)
        if h % msize == 0 and kv_ok:
            entries[head_axis] = "model"
    return jax.lax.with_sharding_constraint(x, P(*entries))
