"""PartitionSpec rule engine.

Parameters are matched by pytree path substring and assigned a logical spec;
every axis assignment is guarded by divisibility (a dim that doesn't divide
the mesh axis falls back to replication — e.g. seamless's 256,206-row vocab
is not 16-divisible, so its embedding replicates while llama3's 128,256 rows
shard).

Scheme (single-pod mesh ('data','model'); multi-pod prepends 'pod'):
  * TP over 'model': attention heads, MLP hidden, experts (expert-parallel),
    Mamba/RWKV channel dims, vocab rows of the embedding / vocab cols of the
    unembedding.
  * FSDP over 'data': the non-TP matrix dim of every large matrix, so
    parameter + optimizer memory scales with the full chip count.
  * 'pod' is pure data parallelism — parameters are replicated across pods;
    in federated mode the pod axis is the client-group axis.

Layer-stacked parameters carry a leading (num_layers/P) axis which is never
sharded (it is scanned over).
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# (path-regex, spec for the *trailing* dims of the param)
# None entries replicate; 'm' = model axis, 'd' = data (FSDP) axis.
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings
    (r"embed", ("m", None)),            # (V, d): vocab-parallel rows
    (r"unembed", ("d", "m")),           # (d, V): FSDP d, vocab-parallel cols
    # attention / cross-attention
    (r"(attn|xattn).*wq", ("d", "m")),
    (r"(attn|xattn).*wk", ("d", "m")),
    (r"(attn|xattn).*wv", ("d", "m")),
    (r"(attn|xattn).*wo", ("m", "d")),
    # dense MLP
    (r"mlp.*w_gate", ("d", "m")),
    (r"mlp.*w_up", ("d", "m")),
    (r"mlp.*w_down", ("m", "d")),
    # MoE: experts over 'model' (expert parallelism), FSDP inside the expert
    (r"moe.*router", (None, None)),
    (r"moe.*w_gate", ("m", "d", None)),
    (r"moe.*w_up", ("m", "d", None)),
    (r"moe.*w_down", ("m", None, "d")),
    # Mamba
    (r"mamba.*in_proj", ("d", "m")),
    (r"mamba.*conv_w", (None, "m")),
    (r"mamba.*x_bc", ("m", None)),
    (r"mamba.*x_dt", ("m", None)),
    (r"mamba.*dt_bias", ("m",)),
    (r"mamba.*a_log", ("m", None)),
    (r"mamba.*d_skip", ("m",)),
    (r"mamba.*out_proj", ("m", "d")),
    # RWKV
    (r"rwkv_tm.*w[rkv]$", ("d", "m")),
    (r"rwkv_tm.*wo", ("m", "d")),
    (r"rwkv_tm.*decay_a", ("d", None)),
    (r"rwkv_tm.*decay_b", (None, "m")),
    (r"rwkv_cm.*wk", ("d", "m")),
    (r"rwkv_cm.*wv", ("m", "d")),
)


def _axis_name(tag: Optional[str], mesh: Mesh) -> Optional[str]:
    if tag is None:
        return None
    name = {"m": "model", "d": "data"}[tag]
    return name if name in mesh.axis_names else None


def spec_for_param(path: str, shape: Tuple[int, ...], mesh: Mesh,
                   *, stacked: bool = True) -> P:
    """PartitionSpec for one parameter; leading stack axes replicate."""
    for pattern, tags in _RULES:
        if re.search(pattern, path):
            ndim_rule = len(tags)
            lead = len(shape) - ndim_rule
            if lead < 0:
                continue
            entries = [None] * lead
            for tag, dim in zip(tags, shape[lead:]):
                ax = _axis_name(tag, mesh)
                if ax is not None and dim % mesh.shape[ax] == 0:
                    entries.append(ax)
                else:
                    entries.append(None)
            return P(*entries)
    return P()  # norms, scalars, mixes: replicate


def params_shardings(params, mesh: Mesh):
    """NamedSharding pytree for a param pytree (works on ShapeDtypeStructs)."""

    def one(kp, leaf):
        path = jax.tree_util.keystr(kp)
        return NamedSharding(mesh, spec_for_param(path, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------- #
# activations / batches / caches
# --------------------------------------------------------------------- #


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that shard the batch dimension ('pod' joins 'data')."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _divides(dim: int, mesh: Mesh, axes: Tuple[str, ...]) -> bool:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size > 0 and dim % size == 0


def batch_spec(shape: Tuple[int, ...], mesh: Mesh, *, client_axis: bool = False) -> P:
    """Spec for a batch leaf.

    Default: dim0 = batch over ('pod','data').  client_axis=True marks
    federated client batches with layout (C, T, B_c, ...): the client axis C
    is scanned (never sharded), B_c (dim 2) takes the batch sharding.
    """
    ax = batch_axes(mesh)
    if client_axis:
        entries: list = [None, None]
        if len(shape) > 2 and _divides(shape[2], mesh, ax):
            entries.append(ax)
        elif len(shape) > 2:
            entries.append(None)
        entries += [None] * (len(shape) - len(entries))
        return P(*entries)
    if shape and _divides(shape[0], mesh, ax):
        return P(ax, *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(batch, mesh: Mesh, *, client_axis: bool = False):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, batch_spec(leaf.shape, mesh, client_axis=client_axis)),
        batch)


def cache_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """KV / recurrent-state cache sharding.

    Attention KV (nrep, B, S, Hkv, Dh): batch over ('pod','data'), sequence
    over 'model' — flash-decoding-style sequence parallelism.  Sharding S
    (rather than Hkv or Dh) works for every GQA config (Hkv < 16 for most
    assigned archs) and turns decode attention into per-shard partial
    softmax + a small all-reduce, instead of the involuntary full-cache
    rematerialization XLA emits for contracted-dim (Dh) sharding.
    When B doesn't divide (long_500k B=1), S additionally takes 'data'.
    Recurrent states (nrep, B, ...): batch over ('pod','data'), channel dim
    over 'model' where divisible.
    """
    ax = batch_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    entries: list = [None] * len(shape)
    if len(shape) == 0:
        return P()
    bdim = 1 if len(shape) >= 2 else 0
    is_kv = len(shape) == 5  # (nrep, B, S, Hkv, Dh)
    if _divides(shape[bdim], mesh, ax):
        entries[bdim] = ax
        if is_kv and model is not None and shape[2] % mesh.shape[model] == 0:
            entries[2] = model
    elif is_kv:
        seq_axes = tuple(a for a in (*ax, model) if a is not None)
        if _divides(shape[2], mesh, seq_axes):
            entries[2] = seq_axes            # B=1: all axes shard the sequence
    if not is_kv and model is not None and len(shape) >= 2:
        # recurrent state: shard the largest trailing channel dim over model
        dims = sorted(range(bdim + 1, len(shape)), key=lambda d: -shape[d])
        for d in dims:
            if shape[d] % mesh.shape[model] == 0 and shape[d] > 1:
                entries[d] = model
                break
    return P(*entries)


def cache_shardings(cache, mesh: Mesh):
    def one(kp, leaf):
        path = jax.tree_util.keystr(kp)
        return NamedSharding(mesh, cache_spec(path, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
