from repro.sharding.rules import (batch_shardings, batch_spec, cache_shardings,
                                  cache_spec, params_shardings, replicated,
                                  spec_for_param)

__all__ = [
    "batch_shardings", "batch_spec", "cache_shardings", "cache_spec",
    "params_shardings", "replicated", "spec_for_param",
]
