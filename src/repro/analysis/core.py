"""fedlint core: file collection, rule registry, suppressions, reports.

The engine is deliberately tiny and stdlib-only.  A *rule* is a function
``check(ctx) -> list[Finding]`` over a :class:`RepoContext` (every parsed
file in the scan), registered with the :func:`rule` decorator.  Rules see
the whole context so cross-module rules (FED003's kernel/oracle/test
triangle, FED004's engine call graph) are first-class, not bolted on.

Suppressions are per line and must carry a reason::

    u = jax.random.uniform(key, (n,))  # fedlint: disable=FED002 -- seeded once at process start

A trailing ``# fedlint: disable=...`` applies to its own line; a comment
that is the whole line applies to the next line.  A disable without a
``-- reason`` does not suppress anything and is reported as FED000 — the
point of the pass is that every exception to a contract is explained.

Baselines: ``--update-baseline`` snapshots the current findings'
fingerprints (rule + path + message, line-number free so pure code motion
doesn't churn the file) into ``fedlint_baseline.json``; later runs
subtract them, so a new rule can land with known debt grandfathered
instead of blocking the PR that introduces it.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from typing import Callable, Dict, List, Optional, Tuple

BASELINE_DEFAULT = "fedlint_baseline.json"

_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache", "node_modules"}

_SUPPRESS_RE = re.compile(
    r"#\s*fedlint:\s*disable=([A-Za-z0-9_,\s]+?)(?:\s+--\s*(\S.*?))?\s*$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to a file/line."""

    rule: str
    path: str
    line: int
    message: str

    @property
    def fingerprint(self) -> str:
        # line-free so code motion above a finding doesn't churn baselines
        return f"{self.rule}:{self.path}:{self.message}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    check: Callable[["RepoContext"], List[Finding]]


#: rule id -> Rule; populated at import time by the @rule decorator
RULES: Dict[str, Rule] = {}


def rule(rule_id: str, title: str):
    """Register ``check(ctx) -> list[Finding]`` under ``rule_id``."""

    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = Rule(rule_id, title, fn)
        return fn

    return deco


@dataclasses.dataclass
class SourceFile:
    path: str                 # normalized, '/'-separated, as given on the CLI
    source: str
    tree: Optional[ast.AST]   # None when the file does not parse
    lines: List[str]

    @property
    def is_test(self) -> bool:
        parts = self.path.split("/")
        return "tests" in parts or parts[-1].startswith("test_")


class RepoContext:
    """Every parsed file in the scan, keyed by normalized relative path."""

    def __init__(self, files: Dict[str, SourceFile]):
        self.files = files

    def matching(self, fragment: str) -> List[SourceFile]:
        """Files whose path contains ``fragment`` (posix form)."""
        return [f for p, f in sorted(self.files.items()) if fragment in p]

    def single(self, suffix: str) -> Optional[SourceFile]:
        hits = [f for p, f in sorted(self.files.items()) if p.endswith(suffix)]
        return hits[0] if hits else None


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def collect_files(paths: List[str]) -> Dict[str, SourceFile]:
    out: Dict[str, SourceFile] = {}
    for root in paths:
        if os.path.isfile(root):
            if root.endswith(".py"):
                _load(out, root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in _SKIP_DIRS and not d.startswith("."))
            for name in sorted(filenames):
                if name.endswith(".py"):
                    _load(out, os.path.join(dirpath, name))
    return out


def _load(out: Dict[str, SourceFile], path: str) -> None:
    norm = _norm(path)
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError:
        tree = None
    out[norm] = SourceFile(norm, source, tree, source.splitlines())


# ---------------------------------------------------------------------------
# suppressions


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int          # line the disable comment sits on
    codes: Tuple[str, ...]
    reason: Optional[str]
    applies_to: int    # line the suppression covers


def parse_suppressions(sf: SourceFile) -> List[Suppression]:
    """Real COMMENT tokens only — disables quoted in docstrings don't count."""
    sups: List[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(sf.source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return sups
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        codes = tuple(c.strip().upper()
                      for c in m.group(1).split(",") if c.strip())
        reason = m.group(2)
        i = tok.start[0]
        # a comment-only line shields the next line; trailing comments
        # shield their own line
        own_line = tok.start[1] > 0 and bool(sf.lines[i - 1][:tok.start[1]].strip())
        sups.append(Suppression(i, codes, reason, i if own_line else i + 1))
    return sups


def apply_suppressions(
    ctx: RepoContext, findings: List[Finding]
) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (active, suppressed); bad disables become FED000."""
    by_file: Dict[str, List[Suppression]] = {}
    extra: List[Finding] = []
    for path, sf in ctx.files.items():
        sups = parse_suppressions(sf)
        by_file[path] = sups
        for s in sups:
            if not s.reason:
                extra.append(Finding(
                    "FED000", path, s.line,
                    "suppression without a reason — use "
                    "'# fedlint: disable=FED00x -- <why this is safe>'"))
            for code in s.codes:
                if code != "FED000" and code not in RULES:
                    extra.append(Finding(
                        "FED000", path, s.line,
                        f"suppression names unknown rule {code!r}"))

    active: List[Finding] = list(extra)
    suppressed: List[Finding] = []
    for f in findings:
        sups = by_file.get(f.path, [])
        hit = any(
            s.reason and f.rule in s.codes and s.applies_to == f.line
            for s in sups
        )
        (suppressed if hit else active).append(f)
    return active, suppressed


# ---------------------------------------------------------------------------
# baseline


def load_baseline(path: str) -> set:
    if not os.path.exists(path):
        return set()
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return set(data.get("fingerprints", []))


def write_baseline(path: str, findings: List[Finding]) -> None:
    data = {
        "version": 1,
        "comment": "fedlint grandfathered findings; regenerate with "
                   "python -m repro.analysis --update-baseline",
        "fingerprints": sorted({f.fingerprint for f in findings}),
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=True)
        fh.write("\n")


# ---------------------------------------------------------------------------
# top-level run


@dataclasses.dataclass
class Report:
    active: List[Finding]
    suppressed: List[Finding]
    baselined: List[Finding]
    n_files: int

    @property
    def failed(self) -> bool:
        return bool(self.active)

    def to_json(self, paths: List[str]) -> dict:
        def enc(f: Finding, status: str) -> dict:
            return {"rule": f.rule, "path": f.path, "line": f.line,
                    "message": f.message, "status": status}

        return {
            "version": 1,
            "paths": list(paths),
            "files_scanned": self.n_files,
            "findings": (
                [enc(f, "active") for f in self.active]
                + [enc(f, "suppressed") for f in self.suppressed]
                + [enc(f, "baselined") for f in self.baselined]
            ),
            "summary": {
                "active": len(self.active),
                "suppressed": len(self.suppressed),
                "baselined": len(self.baselined),
            },
        }


def run_context(ctx: RepoContext, baseline: Optional[set] = None) -> Report:
    findings: List[Finding] = []
    for path, sf in sorted(ctx.files.items()):
        if sf.tree is None:
            findings.append(Finding("FED000", path, 1, "file does not parse"))
    for rid in sorted(RULES):
        findings.extend(RULES[rid].check(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))
    active, suppressed = apply_suppressions(ctx, findings)
    baselined: List[Finding] = []
    if baseline:
        still_active = []
        for f in active:
            (baselined if f.fingerprint in baseline else still_active).append(f)
        active = still_active
    return Report(active, suppressed, baselined, len(ctx.files))


def run_paths(paths: List[str], baseline: Optional[set] = None) -> Report:
    return run_context(RepoContext(collect_files(paths)), baseline)
