"""fedlint — repo-specific static analysis for the federated-optimization repo.

``python -m repro.analysis src benchmarks tests`` walks the given trees,
parses every ``.py`` file with the stdlib :mod:`ast` module (no runtime
dependencies beyond the standard library), and runs the FED rule registry
over them:

  ======  ==========================================================
  rule    contract it machine-checks
  ======  ==========================================================
  FED001  bit-stable RNG primitives only in data/ and fleet traces/faults
  FED002  PRNG key discipline (no reuse after consumption, no raw-key
          sampling outside the absolute-round schedule)
  FED003  every Pallas kernel has a ref.py oracle, an ops.py
          registration, and a parity test
  FED004  every EngineConfig field is threaded through all round paths
          or explicitly validated/rejected
  FED005  no tracer-leak hazards (Python control flow on traced values)
          inside jitted bodies
  ======  ==========================================================

Findings are suppressed per line with ``# fedlint: disable=FED00x -- reason``
(the reason is mandatory; a bare disable is itself a finding, FED000).
See ``docs/ARCHITECTURE.md`` ("Static contracts") for the full story.
"""
from repro.analysis.core import (  # noqa: F401  (public API re-exports)
    Finding,
    RepoContext,
    Rule,
    RULES,
    load_baseline,
    run_paths,
    rule,
)
from repro.analysis import rules as _rules  # noqa: F401  (registers FED rules)
