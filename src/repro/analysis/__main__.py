"""``python -m repro.analysis [paths...]`` — run the fedlint pass.

Exit status: 0 when no active findings, 1 when there are, 2 on usage
errors.  ``--json`` writes the machine-readable report (uploaded as a CI
artifact by tier1.yml); ``--update-baseline`` grandfathers the current
findings into ``fedlint_baseline.json`` so a new rule can land without
blocking on pre-existing debt.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List

from repro.analysis.core import (
    BASELINE_DEFAULT,
    RULES,
    load_baseline,
    run_paths,
    write_baseline,
)

DEFAULT_PATHS = ("src", "benchmarks", "tests")


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="fedlint: repo-specific static analysis (FED001-FED005)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/directories to scan "
                         f"(default: {' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--json", metavar="FILE", dest="json_out",
                    help="write the full JSON report to FILE ('-' = stdout)")
    ap.add_argument("--baseline", metavar="FILE", default=BASELINE_DEFAULT,
                    help="baseline file to read (default: %(default)s)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings to the baseline and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="list registered rules and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid].title}")
        return 0

    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.exists(p)]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"fedlint: no such path: {', '.join(missing)}", file=sys.stderr)
        return 2

    baseline = set() if (args.no_baseline or args.update_baseline) \
        else load_baseline(args.baseline)
    report = run_paths(paths, baseline)

    if args.update_baseline:
        write_baseline(args.baseline, report.active)
        print(f"fedlint: wrote {len(report.active)} fingerprint(s) to "
              f"{args.baseline}")
        return 0

    # With `--json -` stdout IS the JSON document; human-readable lines
    # move to stderr so the output stays parseable.
    json_on_stdout = args.json_out == "-"
    if args.json_out:
        payload = json.dumps(report.to_json(paths), indent=2, sort_keys=True)
        if json_on_stdout:
            print(payload)
        else:
            with open(args.json_out, "w", encoding="utf-8") as fh:
                fh.write(payload + "\n")

    human = sys.stderr if json_on_stdout else sys.stdout
    for f in report.active:
        print(f.render(), file=human)
    tail = (f"fedlint: {len(report.active)} finding(s) "
            f"({len(report.suppressed)} suppressed, "
            f"{len(report.baselined)} baselined) "
            f"across {report.n_files} file(s)")
    print(tail, file=sys.stderr if report.failed else human)
    return 1 if report.failed else 0


if __name__ == "__main__":
    sys.exit(main())
