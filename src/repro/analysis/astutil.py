"""Shared AST helpers: jax.random name resolution, jit-decorator detection.

The rules need to answer "is this Call a jax.random sampler?" robustly
across the import spellings the repo actually uses (``import jax``,
``import jax.random as jr``, ``from jax import random``,
``from jax.random import fold_in``).  :class:`RandomNames` builds the
per-module alias map once from the import statements and then classifies
call nodes.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

#: jax.random attributes that are *not* samplers (key plumbing)
KEY_PLUMBING = {
    "PRNGKey", "key", "split", "fold_in", "wrap_key_data", "key_data",
    "clone", "key_impl", "default_prng_impl",
}

#: samplers whose implementation is not bit-stable under batch reshaping:
#: erfinv-based (the normal family and everything built on it) or
#: rejection sampling (the gamma family and discrete rejection samplers).
#: Inversion samplers (uniform, gumbel, exponential, logistic, cauchy,
#: rayleigh, ...) are fine and deliberately absent — the FED001 forbidden set.
BIT_UNSTABLE = {
    # erfinv / normal-derived
    "normal", "multivariate_normal", "truncated_normal", "lognormal",
    "wald", "maxwell", "double_sided_maxwell", "generalized_normal",
    "orthogonal", "ball",
    # rejection sampling / gamma-derived
    "gamma", "loggamma", "beta", "dirichlet", "chisquare", "f", "t",
    "poisson", "binomial",
}


class RandomNames:
    """Classifies names/calls in one module against ``jax.random``."""

    def __init__(self, tree: ast.AST):
        self.module_aliases: Set[str] = set()   # names bound to jax.random
        self.jax_aliases: Set[str] = {"jax"}    # names bound to jax itself
        self.member_aliases = {}                # local name -> jax.random member
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "jax":
                        self.jax_aliases.add(a.asname or "jax")
                    elif a.name == "jax.random":
                        self.module_aliases.add(a.asname or "jax")
                        if a.asname:
                            self.module_aliases.add(a.asname)
            elif isinstance(node, ast.ImportFrom):
                if node.module == "jax":
                    for a in node.names:
                        if a.name == "random":
                            self.module_aliases.add(a.asname or "random")
                elif node.module == "jax.random":
                    for a in node.names:
                        self.member_aliases[a.asname or a.name] = a.name

    def member_of_call(self, call: ast.Call) -> Optional[str]:
        """``'uniform'`` if this call targets ``jax.random.uniform`` etc."""
        return self.member_of(call.func)

    def member_of(self, func: ast.expr) -> Optional[str]:
        if isinstance(func, ast.Name):
            return self.member_aliases.get(func.id)
        if not isinstance(func, ast.Attribute):
            return None
        val = func.value
        # jr.uniform / random.uniform
        if isinstance(val, ast.Name) and val.id in self.module_aliases:
            return func.attr
        # jax.random.uniform
        if (isinstance(val, ast.Attribute) and val.attr == "random"
                and isinstance(val.value, ast.Name)
                and val.value.id in self.jax_aliases):
            return func.attr
        return None

    def is_sampler(self, member: Optional[str]) -> bool:
        return member is not None and member not in KEY_PLUMBING


def iter_functions(tree: ast.AST) -> List[ast.AST]:
    """Every FunctionDef/AsyncFunctionDef in the tree (any nesting)."""
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _is_jit_name(node: ast.expr) -> bool:
    """``jax.jit`` / ``jit`` (imported from jax) / ``pl.when``-free check."""
    if isinstance(node, ast.Name):
        return node.id == "jit"
    return (isinstance(node, ast.Attribute) and node.attr == "jit"
            and isinstance(node.value, ast.Name) and node.value.id == "jax")


def jit_static_names(fn: ast.AST) -> Optional[Tuple[str, ...]]:
    """``None`` if ``fn`` is not jit-decorated, else its static_argnames.

    Recognized decorator spellings (all used in this repo)::

        @jax.jit
        @jit
        @functools.partial(jax.jit, static_argnames=("m", "interpret"))
        @partial(jax.jit, donate_argnums=(0,))
        @jax.jit_or_other(...)        # NOT matched
    """
    for dec in getattr(fn, "decorator_list", []):
        if _is_jit_name(dec):
            return ()
        if isinstance(dec, ast.Call):
            # functools.partial(jax.jit, ...) / partial(jax.jit, ...)
            is_partial = (
                (isinstance(dec.func, ast.Name) and dec.func.id == "partial")
                or (isinstance(dec.func, ast.Attribute)
                    and dec.func.attr == "partial"))
            if is_partial and dec.args and _is_jit_name(dec.args[0]):
                return _static_from_keywords(dec.keywords)
            # @jax.jit(static_argnames=...)
            if _is_jit_name(dec.func):
                return _static_from_keywords(dec.keywords)
    return None


def _static_from_keywords(keywords) -> Tuple[str, ...]:
    names: List[str] = []
    for kw in keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.append(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                for el in v.elts:
                    if isinstance(el, ast.Constant) and isinstance(el.value, str):
                        names.append(el.value)
    return tuple(names)


def arg_names(fn: ast.AST) -> List[str]:
    a = fn.args
    names = [x.arg for x in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names
