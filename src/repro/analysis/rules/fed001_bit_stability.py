"""FED001 — bit-unstable RNG primitives in regeneration-critical modules.

Virtual clients (PR 7) and kill-resume (PR 8) both depend on client data
being a *pure, bit-stable* function of ``(seed, client id, row)``: the
same rows must come back bit-identical whether they are generated in one
batch, per chunk, or one client at a time.  ``jax.random.uniform`` /
``gumbel`` / ``exponential`` etc. are per-element inversions and keep that
promise; ``normal`` (erfinv) and the gamma/beta/dirichlet rejection
samplers do not — their output can depend on batch shape and XLA fusion
decisions.  This rule forbids the unstable set inside the modules whose
output must regenerate bit-identically: ``repro/data/`` and the fleet's
trace/fault draw chains.

Model-parameter initializers (``repro/models/``) may use ``normal``
freely — weights are sampled once and carried in checkpoints, never
regenerated from shape.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.astutil import BIT_UNSTABLE, RandomNames
from repro.analysis.core import Finding, RepoContext, rule

#: path fragments whose files must stay on bit-stable primitives
SCOPED = ("repro/data/", "repro/fleet/traces.py", "repro/fleet/faults.py")


@rule("FED001", "bit-unstable RNG primitive in a regeneration-critical module")
def check(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    for fragment in SCOPED:
        for sf in ctx.matching(fragment):
            if sf.tree is None:
                continue
            names = RandomNames(sf.tree)
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                member = names.member_of_call(node)
                if member in BIT_UNSTABLE:
                    findings.append(Finding(
                        "FED001", sf.path, node.lineno,
                        f"jax.random.{member} is not bit-stable under batch "
                        f"reshaping (erfinv/rejection sampling); use an "
                        f"inversion sampler (uniform/gumbel/exponential) — "
                        f"this module's output must regenerate bit-identically "
                        f"for virtual clients and kill-resume"))
    # dedupe: a file can match two fragments
    return sorted(set(findings), key=lambda f: (f.path, f.line))
