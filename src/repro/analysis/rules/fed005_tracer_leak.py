"""FED005 — tracer-leak hazards inside jitted bodies.

Inside a ``jax.jit``-compiled function, Python control flow on a traced
value (``if``/``while``/``bool()``/``float()``/``int()``/``.item()``)
either raises ``TracerBoolConversionError`` at trace time or — worse,
with weak types and concrete sub-expressions — silently bakes one branch
into the compiled program.  The engine's round bodies are all jitted with
donated buffers, so a leak there is a correctness bug across every
subsequent round.

The rule runs a small taint analysis over every *lexically* jit-decorated
function (``@jax.jit``, ``@functools.partial(jax.jit, ...)``) and over
lambdas passed directly to ``jax.jit(...)``:

  * non-static parameters are tainted (they arrive as tracers);
    ``static_argnames``/``static_argnums`` parameters are not;
  * taint propagates through expressions and assignments, and into the
    parameters of functions/lambdas *defined inside* the jitted body
    (they run under the same trace);
  * sanitizers stop taint: ``x is None`` / ``is not None`` tests,
    ``isinstance``/``len`` calls, and ``.shape``/``.ndim``/``.dtype``/
    ``.size`` attribute reads — those are Python-level facts known at
    trace time, and branching on them is the repo's standard idiom.

Fired on: an ``if``/``while``/ternary test that is tainted, and
``bool()``/``float()``/``int()``/``.item()`` applied to a tainted value.
``jnp.where``/``lax.cond``/``lax.select`` are the fixes.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.astutil import arg_names, jit_static_names
from repro.analysis.core import Finding, RepoContext, rule

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "weak_type"}
_SANITIZER_CALLS = {"isinstance", "len", "type", "hasattr", "getattr"}
_HAZARD_CASTS = {"bool", "float", "int"}


def _is_jax_jit_call(node: ast.Call) -> bool:
    f = node.func
    if isinstance(f, ast.Name) and f.id == "jit":
        return True
    return (isinstance(f, ast.Attribute) and f.attr == "jit"
            and isinstance(f.value, ast.Name) and f.value.id == "jax")


class _TaintChecker:
    def __init__(self, path: str):
        self.path = path
        self.findings: Set[Finding] = set()

    # -- taint query --------------------------------------------------------

    def tainted(self, node: ast.expr, env: Set[str]) -> bool:
        """Is this expression derived from a traced value?"""
        if isinstance(node, ast.Name):
            return node.id in env
        if isinstance(node, ast.Attribute):
            if node.attr in _SHAPE_ATTRS:
                return False  # trace-time Python facts
            return self.tainted(node.value, env)
        if isinstance(node, ast.Compare):
            # `x is None` / `is not None` yields a Python bool at trace time
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.tainted(node.left, env)
                    or any(self.tainted(c, env) for c in node.comparators))
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None)
            if name in _SANITIZER_CALLS:
                return False
            # the hazard casts are checked separately; their *result* is a
            # Python scalar but producing it is already the leak
            return (any(self.tainted(a, env) for a in node.args)
                    or any(self.tainted(k.value, env) for k in node.keywords)
                    or (isinstance(fn, ast.Attribute)
                        and self.tainted(fn.value, env)))
        if isinstance(node, (ast.Constant, ast.Lambda)):
            return False
        return any(self.tainted(c, env)
                   for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    # -- hazard scan --------------------------------------------------------

    def scan_fn(self, fn: ast.AST, static: tuple) -> None:
        env: Set[str] = {a for a in arg_names(fn)
                         if a not in static and a not in ("self", "cls")}
        if isinstance(fn, ast.Lambda):
            self.scan_expr(fn.body, env)
            return
        self.scan_block(fn.body, env)

    def scan_block(self, stmts, env: Set[str]) -> None:
        for st in stmts:
            self.scan_stmt(st, env)

    def scan_stmt(self, st: ast.stmt, env: Set[str]) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs run under the same trace: their params are traced
            # whenever a tainted value can flow in — assume they are
            inner = set(env) | set(arg_names(st))
            self.scan_block(st.body, inner)
            return
        if isinstance(st, ast.Assign):
            self.scan_expr(st.value, env)
            taint = self.tainted(st.value, env)
            for t in st.targets:
                self.bind(t, taint, env)
        elif isinstance(st, ast.AnnAssign) and st.value is not None:
            self.scan_expr(st.value, env)
            self.bind(st.target, self.tainted(st.value, env), env)
        elif isinstance(st, ast.AugAssign):
            self.scan_expr(st.value, env)
            if isinstance(st.target, ast.Name):
                if self.tainted(st.value, env):
                    env.add(st.target.id)
        elif isinstance(st, ast.If):
            self.scan_expr(st.test, env)
            if self.tainted(st.test, env):
                self.report(st, "Python `if` on a traced value inside a "
                                "jitted body — use jnp.where / lax.cond")
            self.scan_block(st.body, env)
            self.scan_block(st.orelse, env)
        elif isinstance(st, ast.While):
            self.scan_expr(st.test, env)
            if self.tainted(st.test, env):
                self.report(st, "Python `while` on a traced value inside a "
                                "jitted body — use lax.while_loop")
            self.scan_block(st.body, env)
            self.scan_block(st.orelse, env)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self.scan_expr(st.iter, env)
            self.bind(st.target, self.tainted(st.iter, env), env)
            self.scan_block(st.body, env)
            self.scan_block(st.orelse, env)
        elif isinstance(st, ast.Try):
            self.scan_block(st.body, env)
            for h in st.handlers:
                self.scan_block(h.body, env)
            self.scan_block(st.orelse, env)
            self.scan_block(st.finalbody, env)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                self.scan_expr(item.context_expr, env)
            self.scan_block(st.body, env)
        else:
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self.scan_expr(child, env)

    def bind(self, target: ast.expr, taint: bool, env: Set[str]) -> None:
        if isinstance(target, ast.Name):
            (env.add if taint else env.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self.bind(elt.value if isinstance(elt, ast.Starred) else elt,
                          taint, env)

    def scan_expr(self, node: ast.expr, env: Set[str]) -> None:
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else None
            if (name in _HAZARD_CASTS and node.args
                    and self.tainted(node.args[0], env)):
                self.report(node, f"`{name}()` on a traced value inside a "
                                  f"jitted body forces a concrete value at "
                                  f"trace time")
            if (isinstance(fn, ast.Attribute) and fn.attr == "item"
                    and not node.args and self.tainted(fn.value, env)):
                self.report(node, "`.item()` on a traced value inside a "
                                  "jitted body forces a device sync at "
                                  "trace time")
        elif isinstance(node, ast.IfExp):
            if self.tainted(node.test, env):
                self.report(node, "ternary on a traced value inside a jitted "
                                  "body — use jnp.where")
        elif isinstance(node, ast.Lambda):
            inner = set(env) | set(arg_names(node))
            self.scan_expr(node.body, inner)
            return
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.scan_expr(child, env)

    def report(self, node: ast.AST, message: str) -> None:
        self.findings.add(Finding("FED005", self.path, node.lineno, message))


@rule("FED005", "tracer leak inside a jitted body")
def check(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    for path, sf in sorted(ctx.files.items()):
        if sf.tree is None:
            continue
        checker = _TaintChecker(path)
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                static = jit_static_names(node)
                if static is not None:
                    checker.scan_fn(node, static)
            elif (isinstance(node, ast.Call) and _is_jax_jit_call(node)
                    and node.args and isinstance(node.args[0], ast.Lambda)):
                checker.scan_fn(node.args[0], ())
        findings.extend(sorted(checker.findings,
                               key=lambda f: (f.line, f.message)))
    return findings
