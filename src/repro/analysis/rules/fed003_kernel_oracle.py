"""FED003 — cross-module kernel completeness.

The Pallas kernel convention (docs/ARCHITECTURE.md "Pallas kernel
conventions") is a triangle: each kernel module under ``repro/kernels/``
exports an entry point, ``kernels/ref.py`` carries a pure-``jnp``
``<entry>_ref`` oracle with identical semantics, ``kernels/ops.py``
registers a jit wrapper, and a test somewhere under ``tests/`` pins
kernel-vs-oracle parity.  A kernel missing any leg of the triangle is
unverifiable — exactly the state ``wkv6`` sat in for three PRs.  This
rule closes the loop mechanically:

  * for every public top-level function in ``repro/kernels/<mod>.py``
    (``<mod>`` not in {__init__, ops, ref}) there must exist a top-level
    ``<entry>_ref`` in ``ref.py``;
  * ``ops.py`` must mention the entry name;
  * some scanned test file must mention both the entry and its oracle
    (skipped when the scan contains no test files, e.g. a src-only run).

Helpers prefixed with ``_`` are exempt — only the public surface needs
an oracle.
"""
from __future__ import annotations

import ast
import re
from typing import List

from repro.analysis.core import Finding, RepoContext, rule

_EXEMPT_MODULES = {"__init__", "ops", "ref"}


def _top_level_functions(tree: ast.AST) -> List[ast.FunctionDef]:
    return [n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _mentions(source: str, name: str) -> bool:
    return re.search(rf"\b{re.escape(name)}\b", source) is not None


@rule("FED003", "Pallas kernel without oracle / registration / parity test")
def check(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    kernel_files = [sf for sf in ctx.matching("repro/kernels/")
                    if sf.path.rsplit("/", 1)[-1][:-3] not in _EXEMPT_MODULES
                    and sf.tree is not None]
    if not kernel_files:
        return findings
    ref = ctx.single("repro/kernels/ref.py")
    ops = ctx.single("repro/kernels/ops.py")
    ref_names = ({fn.name for fn in _top_level_functions(ref.tree)}
                 if ref is not None and ref.tree is not None else set())
    test_files = [sf for sf in ctx.files.values() if sf.is_test]

    for sf in kernel_files:
        for fn in _top_level_functions(sf.tree):
            if fn.name.startswith("_"):
                continue
            oracle = f"{fn.name}_ref"
            if oracle not in ref_names:
                findings.append(Finding(
                    "FED003", sf.path, fn.lineno,
                    f"kernel entry '{fn.name}' has no '{oracle}' oracle in "
                    f"kernels/ref.py — every Pallas kernel needs a pure-jnp "
                    f"reference implementation"))
            if ops is not None and not _mentions(ops.source, fn.name):
                findings.append(Finding(
                    "FED003", sf.path, fn.lineno,
                    f"kernel entry '{fn.name}' is not registered in "
                    f"kernels/ops.py — callers must go through the ops "
                    f"wrappers (interpret fallback off-TPU)"))
            if test_files and not any(
                    _mentions(t.source, fn.name) and _mentions(t.source, oracle)
                    for t in test_files):
                findings.append(Finding(
                    "FED003", sf.path, fn.lineno,
                    f"no test references both '{fn.name}' and '{oracle}' — "
                    f"kernel/oracle parity must be pinned by a test"))
    return findings
