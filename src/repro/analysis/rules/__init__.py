"""FED rule registry — importing this package registers every rule."""
from repro.analysis.rules import (  # noqa: F401  (imported for registration)
    fed001_bit_stability,
    fed002_key_discipline,
    fed003_kernel_oracle,
    fed004_round_paths,
    fed005_tracer_leak,
)
