"""FED004 — EngineConfig round-path completeness.

Every :class:`EngineConfig` knob must be *threaded*: read on all of the
round paths (``round`` / ``round_streamed`` / ``round_cohort`` /
``round_virtual`` and their ``_with_state`` twins, including everything
they reach through ``self.*`` calls) or explicitly validated/rejected in
``__post_init__``.  PR 8 and PR 9 each threaded new knobs by hand, and a
missed path is a *wrong-answer* bug — the knob silently no-ops on that
path — not a crash.  This rule recovers the read sets from ``engine.py``'s
AST:

  * a *read* is any ``self.cfg.<field>`` / ``cfg.<field>`` attribute load
    (local aliases of ``self.cfg`` are tracked);
  * the call graph follows ``self.<method>`` references (calls, ``vmap``
    targets, ``partial`` captures) transitively;
  * a field read in ``__post_init__`` (or helpers it calls) counts as
    explicitly validated, which excuses path-specific knobs — e.g.
    ``cohort`` is rejected up front on non-cohort paths instead of read.

Fired when a field is read on no round path at all (dead knob), or read
on some paths but not others without a ``__post_init__`` validation.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.core import Finding, RepoContext, rule

ROUND_PATHS = (
    "round", "round_with_state",
    "round_streamed", "round_streamed_with_state",
    "round_cohort", "round_cohort_with_state",
    "round_virtual", "round_virtual_with_state",
)

ENGINE_SUFFIX = "repro/core/engine.py"


def _class_def(tree: ast.AST, name: str):
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    return None


def _config_fields(cls: ast.ClassDef) -> Dict[str, int]:
    """Dataclass field name -> line, from the annotated class body."""
    fields: Dict[str, int] = {}
    for node in cls.body:
        if (isinstance(node, ast.AnnAssign)
                and isinstance(node.target, ast.Name)
                and not node.target.id.startswith("_")):
            fields[node.target.id] = node.lineno
    return fields


def _method_map(cls: ast.ClassDef) -> Dict[str, ast.AST]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _field_reads(method: ast.AST, fields: Set[str],
                 on_self: str = "cfg") -> Set[str]:
    """Fields read as ``self.cfg.X`` / ``<alias>.X`` within the method."""
    alias_names: Set[str] = set()
    for node in ast.walk(method):
        if isinstance(node, ast.Assign):
            v = node.value
            if (isinstance(v, ast.Attribute) and v.attr == on_self
                    and isinstance(v.value, ast.Name)
                    and v.value.id == "self"):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        alias_names.add(t.id)
    reads: Set[str] = set()
    for node in ast.walk(method):
        if not isinstance(node, ast.Attribute) or node.attr not in fields:
            continue
        v = node.value
        # self.cfg.X
        if (isinstance(v, ast.Attribute) and v.attr == on_self
                and isinstance(v.value, ast.Name) and v.value.id == "self"):
            reads.add(node.attr)
        # <alias>.X where alias = self.cfg
        elif isinstance(v, ast.Name) and v.id in alias_names:
            reads.add(node.attr)
    return reads


def _self_field_reads(method: ast.AST, fields: Set[str]) -> Set[str]:
    """Fields read as ``self.X`` (EngineConfig's own methods)."""
    reads: Set[str] = set()
    for node in ast.walk(method):
        if (isinstance(node, ast.Attribute) and node.attr in fields
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            reads.add(node.attr)
    return reads


def _self_method_refs(method: ast.AST, methods: Set[str]) -> Set[str]:
    """``self.<m>`` references (calls, vmap targets, partial captures)."""
    refs: Set[str] = set()
    for node in ast.walk(method):
        if (isinstance(node, ast.Attribute) and node.attr in methods
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            refs.add(node.attr)
    return refs


def _closure_reads(entry: str, methods: Dict[str, ast.AST],
                   fields: Set[str]) -> Set[str]:
    seen: Set[str] = set()
    stack = [entry]
    reads: Set[str] = set()
    while stack:
        name = stack.pop()
        if name in seen or name not in methods:
            continue
        seen.add(name)
        m = methods[name]
        reads |= _field_reads(m, fields)
        stack.extend(_self_method_refs(m, set(methods)))
    return reads


@rule("FED004", "EngineConfig knob not threaded through every round path")
def check(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    sf = ctx.single(ENGINE_SUFFIX)
    if sf is None or sf.tree is None:
        return findings
    cfg_cls = _class_def(sf.tree, "EngineConfig")
    eng_cls = _class_def(sf.tree, "RoundEngine")
    if cfg_cls is None or eng_cls is None:
        return findings
    field_lines = _config_fields(cfg_cls)
    fields = set(field_lines)
    cfg_methods = _method_map(cfg_cls)
    eng_methods = _method_map(eng_cls)

    # __post_init__ (plus EngineConfig helpers it calls) = validated set
    validated: Set[str] = set()
    stack = ["__post_init__"]
    seen: Set[str] = set()
    while stack:
        name = stack.pop()
        if name in seen or name not in cfg_methods:
            continue
        seen.add(name)
        validated |= _self_field_reads(cfg_methods[name], fields)
        stack.extend(_self_method_refs(cfg_methods[name], set(cfg_methods)))

    present_paths = [p for p in ROUND_PATHS if p in eng_methods]
    for p in ROUND_PATHS:
        if p not in eng_methods:
            findings.append(Finding(
                "FED004", sf.path, eng_cls.lineno,
                f"round path method '{p}' is missing from RoundEngine — "
                f"the engine contract names all eight paths"))
    reads_by_path = {p: _closure_reads(p, eng_methods, fields)
                     for p in present_paths}

    for field in sorted(fields):
        read_on = [p for p in present_paths if field in reads_by_path[p]]
        missing = [p for p in present_paths if field not in reads_by_path[p]]
        if not read_on:
            findings.append(Finding(
                "FED004", sf.path, field_lines[field],
                f"EngineConfig.{field} is never read on any round path — "
                f"dead knob (thread it through the engine or remove it)"))
        elif missing and field not in validated:
            findings.append(Finding(
                "FED004", sf.path, field_lines[field],
                f"EngineConfig.{field} is read on {sorted(read_on)} but not "
                f"on {sorted(missing)} and is not validated in "
                f"__post_init__ — the knob silently no-ops on the missing "
                f"paths"))
    return findings
