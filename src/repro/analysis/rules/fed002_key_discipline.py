"""FED002 — PRNG key discipline.

The repo's determinism story (ROADMAP "Architecture contract", PR 6-9)
hangs on a strict key dataflow: every stream is derived from
``PRNGKey(seed)`` by ``fold_in`` tags on the absolute-round schedule, and
each derived key is consumed **exactly once** by a sampler.  Violations
are silent statistics bugs — two draws that should be independent become
identical — so they are worth a dedicated static check.  Flagged:

  * sampling from a key that a sampler already consumed (classic reuse);
  * sampling from a key that was already ``split`` (sample from one of
    the split keys instead);
  * ``split``/``fold_in`` on a key a sampler already consumed;
  * two ``fold_in(k, <same constant tag>)`` on the same binding of ``k``
    (colliding streams);
  * sampling directly from a raw ``PRNGKey(seed)`` in library code —
    every stream must go through the fold_in schedule so it stays
    disjoint from the solver/data/trace/fault chains (test files are
    exempt: ad-hoc raw-key draws are idiomatic there).

Deliberately allowed, because they are the repo's core idiom: many
``fold_in`` calls with *different* tags off one key, re-deriving
(``k = fold_in(k, t)``), and tuple-unpacking ``split`` results.  The
analysis is branch-aware — a key consumed in both arms of an ``if/else``
is dead afterwards, but consumption in only one arm does not poison the
other path.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import RandomNames
from repro.analysis.core import Finding, RepoContext, rule

RAW, DERIVED, SPLIT, DEAD, KEYARRAY = ("raw", "derived", "split", "dead",
                                       "keyarray")


class _Env:
    """Per-scope key states: name -> state, binding generation, fold tags."""

    def __init__(self):
        self.state: Dict[str, str] = {}
        self.gen: Dict[str, int] = {}
        # (key name, binding generation, tag) -> line of the first fold_in;
        # re-deriving at the SAME site (a loop) is intentional, two
        # different sites with one tag is a stream collision
        self.folds: Dict[Tuple[str, int, object], int] = {}

    def copy(self) -> "_Env":
        e = _Env()
        e.state = dict(self.state)
        e.gen = dict(self.gen)
        e.folds = dict(self.folds)
        return e

    def bind(self, name: str, state: Optional[str]) -> None:
        self.gen[name] = self.gen.get(name, 0) + 1
        if state is None:
            self.state.pop(name, None)
        else:
            self.state[name] = state

    def merge(self, *branches: "_Env") -> None:
        """Join after exclusive branches: keep only facts true on all paths."""
        names = set(self.state)
        for b in branches:
            names |= set(b.state)
        merged: Dict[str, str] = {}
        for n in names:
            states = {b.state.get(n) for b in branches}
            if len(states) == 1 and None not in states:
                merged[n] = states.pop()
        self.state = merged
        for n in names:
            self.gen[n] = max(b.gen.get(n, 0) for b in branches)
        folds = dict(branches[0].folds)
        for b in branches[1:]:
            folds = {k: min(v, b.folds[k]) for k, v in folds.items()
                     if k in b.folds}
        self.folds = folds


class _Analyzer:
    def __init__(self, names: RandomNames, path: str, raw_check: bool):
        self.names = names
        self.path = path
        self.raw_check = raw_check
        self.findings: Set[Finding] = set()

    # -- entry points -------------------------------------------------------

    def run_module(self, tree: ast.Module) -> None:
        self.exec_block(tree.body, _Env())
        for fn in [n for n in ast.walk(tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            env = _Env()
            # parameters start unknown: a caller may pass a fresh key
            self.exec_block(fn.body, env)

    # -- statements ---------------------------------------------------------

    def exec_block(self, stmts, env: _Env) -> None:
        for st in stmts:
            self.exec_stmt(st, env)

    def exec_stmt(self, st: ast.stmt, env: _Env) -> None:
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # analyzed separately with a fresh scope
        if isinstance(st, ast.Assign):
            v = self.eval(st.value, env)
            for t in st.targets:
                self.bind_target(t, v, env)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                v = self.eval(st.value, env)
                self.bind_target(st.target, v, env)
        elif isinstance(st, ast.AugAssign):
            self.eval(st.value, env)
            self.bind_target(st.target, None, env)
        elif isinstance(st, (ast.Expr, ast.Return)):
            if getattr(st, "value", None) is not None:
                self.eval(st.value, env)
        elif isinstance(st, ast.If):
            self.eval(st.test, env)
            e_then, e_else = env.copy(), env.copy()
            self.exec_block(st.body, e_then)
            self.exec_block(st.orelse, e_else)
            env.merge(e_then, e_else)
        elif isinstance(st, (ast.For, ast.AsyncFor)):
            self.eval(st.iter, env)
            # two passes so loop-carried consumption of a loop-invariant key
            # is caught; the loop target rebinds fresh each iteration
            for _ in range(2):
                self.bind_target(st.target, None, env)
                self.exec_block(st.body, env)
            self.exec_block(st.orelse, env)
        elif isinstance(st, ast.While):
            for _ in range(2):
                self.eval(st.test, env)
                self.exec_block(st.body, env)
            self.exec_block(st.orelse, env)
        elif isinstance(st, ast.Try):
            self.exec_block(st.body, env)
            branches = [env.copy()]
            for h in st.handlers:
                eh = env.copy()
                self.exec_block(h.body, eh)
                branches.append(eh)
            env.merge(*branches)
            self.exec_block(st.orelse, env)
            self.exec_block(st.finalbody, env)
        elif isinstance(st, (ast.With, ast.AsyncWith)):
            for item in st.items:
                self.eval(item.context_expr, env)
                if item.optional_vars is not None:
                    self.bind_target(item.optional_vars, None, env)
            self.exec_block(st.body, env)
        elif isinstance(st, (ast.Raise, ast.Assert, ast.Delete)):
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self.eval(child, env)
        # Pass/Break/Continue/Import/Global/Nonlocal: nothing to do

    def bind_target(self, target: ast.expr, value_state: Optional[str],
                    env: _Env) -> None:
        if isinstance(target, ast.Name):
            env.bind(target.id, value_state)
        elif isinstance(target, (ast.Tuple, ast.List)):
            # `k1, k2 = split(key)` — elements of a key array are fresh keys
            elt_state = DERIVED if value_state == KEYARRAY else None
            for elt in target.elts:
                if isinstance(elt, ast.Starred):
                    self.bind_target(elt.value, None, env)
                else:
                    self.bind_target(elt, elt_state, env)
        # Attribute / Subscript targets: untracked

    # -- expressions --------------------------------------------------------

    def eval(self, node: ast.expr, env: _Env) -> Optional[str]:
        """Evaluate for side effects; return the value's key-state."""
        if isinstance(node, ast.Name):
            return env.state.get(node.id)
        if isinstance(node, ast.Call):
            return self.eval_call(node, env)
        if isinstance(node, ast.Lambda):
            # may run zero or many times: analyze on a throwaway copy with
            # the lambda's own params unbound
            e = env.copy()
            for a in node.args.args + node.args.kwonlyargs:
                e.bind(a.arg, None)
            self.eval(node.body, e)
            return None
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            e = env.copy()
            for gen in node.generators:
                self.eval(gen.iter, e)
            # two passes: the body repeats per element, so consuming a
            # comprehension-invariant key twice is loop-carried reuse
            for _ in range(2):
                for gen in node.generators:
                    self.bind_target(gen.target, None, e)
                    for cond in gen.ifs:
                        self.eval(cond, e)
                if isinstance(node, ast.DictComp):
                    self.eval(node.key, e)
                    self.eval(node.value, e)
                else:
                    self.eval(node.elt, e)
            # loop-invariant consumption is real on the actual path too
            for name, state in e.state.items():
                if name in env.state and state == DEAD:
                    env.state[name] = DEAD
            return None
        if isinstance(node, ast.IfExp):
            self.eval(node.test, env)
            e_then, e_else = env.copy(), env.copy()
            s1 = self.eval(node.body, e_then)
            s2 = self.eval(node.orelse, e_else)
            env.merge(e_then, e_else)
            return s1 if s1 == s2 else None
        # generic: evaluate children, value untracked
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.eval(child, env)
        return None

    def eval_call(self, node: ast.Call, env: _Env) -> Optional[str]:
        member = self.names.member_of_call(node)
        arg_states = [self.eval(a, env) for a in node.args]
        for kw in node.keywords:
            self.eval(kw.value, env)
        if member is None:
            return None
        if member in ("PRNGKey", "key"):
            return RAW

        key_arg = node.args[0] if node.args else None
        key_name = key_arg.id if isinstance(key_arg, ast.Name) else None
        key_state = arg_states[0] if arg_states else None

        if member == "fold_in":
            if key_state == DEAD:
                self.report(node, "fold_in on a key a sampler already "
                                  "consumed — derive sub-keys before sampling")
            if key_name is not None and len(node.args) >= 2:
                tag = node.args[1]
                if isinstance(tag, ast.Constant):
                    entry = (key_name, env.gen.get(key_name, 0), tag.value)
                    first = env.folds.setdefault(entry, node.lineno)
                    if first != node.lineno:
                        self.report(
                            node,
                            f"fold_in({key_name}, {tag.value!r}) repeats the "
                            f"fold_in at line {first} with the same tag on "
                            f"the same key binding — the two streams are "
                            f"identical")
            return DERIVED
        if member == "split":
            if key_state == DEAD:
                self.report(node, "split on a key a sampler already consumed")
            if key_name is not None:
                env.state[key_name] = SPLIT
            return KEYARRAY
        if member in ("wrap_key_data", "key_data", "clone", "key_impl",
                      "default_prng_impl"):
            return None

        # every other jax.random member takes a key first and consumes it
        if key_state == DEAD:
            self.report(node, f"jax.random.{member} on a key that was "
                              f"already consumed by a sampler — each derived "
                              f"key must be sampled exactly once")
        elif key_state == SPLIT:
            self.report(node, f"jax.random.{member} on a key that was "
                              f"already split — sample from one of the "
                              f"split keys instead")
        elif key_state == RAW and self.raw_check:
            # covers both `sampler(k)` with k = PRNGKey(...) and the
            # inline `sampler(PRNGKey(...))` spelling (eval returns RAW)
            self.report(node, f"jax.random.{member} on a raw PRNGKey — "
                              f"library code must derive keys through the "
                              f"fold_in schedule (PRNGKey(seed) + tags) so "
                              f"streams stay disjoint across rounds/clients")
        if key_name is not None:
            env.state[key_name] = DEAD
        return None

    def report(self, node: ast.AST, message: str) -> None:
        self.findings.add(Finding("FED002", self.path, node.lineno, message))


@rule("FED002", "PRNG key reuse / raw-key sampling")
def check(ctx: RepoContext) -> List[Finding]:
    findings: List[Finding] = []
    for path, sf in sorted(ctx.files.items()):
        if sf.tree is None:
            continue
        analyzer = _Analyzer(RandomNames(sf.tree), path,
                             raw_check=not sf.is_test)
        analyzer.run_module(sf.tree)
        findings.extend(sorted(analyzer.findings,
                               key=lambda f: (f.line, f.message)))
    return findings
