"""Minimal optimizer library (no optax in this environment).

Each optimizer is an (init, update) pair over arbitrary pytrees:
    state = init(params)
    params, state = update(params, grads, state, step)
AdamW keeps f32 moments regardless of param dtype (the production choice —
moments are sharded like their parameters by the rule engine).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(params, grads, state, step):
        del step
        new = jax.tree.map(lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(params, grads, state, step):
        del step
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        new_p = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype), params, new_m)
        return new_p, new_m

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(params, grads, state, step):
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            step_ = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            p32 = p.astype(jnp.float32)
            p_new = p32 - step_ - lr * weight_decay * p32
            return p_new.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def get(name: str, lr: float, **kw) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adamw": adamw}[name](lr, **kw)
