from repro.optim.optimizers import Optimizer, adamw, get, momentum, sgd

__all__ = ["Optimizer", "adamw", "get", "momentum", "sgd"]
