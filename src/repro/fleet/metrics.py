"""Campaign telemetry: structured JSONL round events.

One line per (cell, round), appended as the campaign runs:

    {"cell": "fedavg", "round": 12, "drawn": 981, "realized": 963,
     "stragglers": 18, "f": 0.5123, "err": 0.241,
     "wall_s": 0.184, "peak_rss_mb": 412.0}

``drawn`` is the round's sampled cohort (availability mask), ``realized``
the deltas that actually arrived (after stragglers), ``f``/``err`` are
``null`` off eval rounds.  Fault-tolerance fields (schema v2, all
defaulting to 0 so pre-fault logs still load): ``faults_injected`` is the
fault model's corrupted-delta count over returned clients,
``clients_rejected`` the deltas a non-finite-rejecting aggregator guard
discarded, and ``rollbacks`` flags a quarantined (rolled-back-and-skipped)
round.  Every field except the ``TIMING_KEYS``
(``wall_s``, ``peak_rss_mb``) is deterministic — a pure function of
(config, seed, round) — which is what makes the kill-and-resume
acceptance check meaningful: :func:`deterministic_view` strips the timing
fields and the remaining event stream must be byte-identical between an
interrupted+resumed campaign and an uninterrupted one.

The log is resume-aware: on restart, :meth:`EventLog.truncate` atomically
rewrites the file without the events a cell will re-emit (rounds at or
after its restored checkpoint), so re-run rounds never duplicate lines.
"""
from __future__ import annotations

import dataclasses
import json
import os
import resource
import sys
from typing import Dict, List, Optional

#: non-deterministic (machine/load-dependent) event fields
TIMING_KEYS = ("wall_s", "peak_rss_mb")


@dataclasses.dataclass
class RoundEvent:
    """One row of campaign telemetry — see the module docstring."""

    cell: str
    round: int
    drawn: int
    realized: int
    stragglers: int
    f: Optional[float] = None
    err: Optional[float] = None
    #: corrupted deltas delivered this round (fault model's recomputable
    #: count over returned clients; 0 when no fault model is installed)
    faults_injected: int = 0
    #: deltas a non-finite-rejecting aggregator guard discarded
    clients_rejected: int = 0
    #: 1 when this round is quarantined (skipped after a guard-rail
    #: rollback), 0 otherwise — deterministic because the quarantine set
    #: is persisted in the cell's guard.json
    rollbacks: int = 0
    wall_s: float = 0.0
    peak_rss_mb: float = 0.0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)


def peak_rss_mb() -> float:
    """The process's high-water RSS in MB — ru_maxrss is KB on Linux,
    bytes on macOS."""
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return rss / 1024.0 if sys.platform != "darwin" else rss / (1024.0 ** 2)


def deterministic_view(event: Dict) -> Dict:
    """The event minus its timing fields — the bit-identity comparand."""
    return {k: v for k, v in event.items() if k not in TIMING_KEYS}


class EventLog:
    """Append-only JSONL writer with atomic resume truncation."""

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def append(self, event: RoundEvent) -> None:
        # line-buffered append + flush: a kill mid-write can at worst leave
        # one torn trailing line, which truncate() discards on resume
        with open(self.path, "a") as f:
            f.write(event.to_json() + "\n")
            f.flush()
            os.fsync(f.fileno())

    def load(self) -> List[Dict]:
        if not os.path.exists(self.path):
            return []
        events = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    events.append(json.loads(line))
                except json.JSONDecodeError:
                    break  # torn tail from a mid-write kill; drop the rest
        return events

    def truncate(self, cell: str, first_rerun_round: int) -> None:
        """Drop ``cell``'s events for rounds >= ``first_rerun_round`` (the
        restored checkpoint's round) — those rounds are about to re-run and
        re-emit.  Atomic rewrite (temp + ``os.replace``), so a kill during
        resume bookkeeping never loses the surviving history."""
        events = self.load()
        keep = [e for e in events
                if not (e.get("cell") == cell
                        and e.get("round", 0) >= first_rerun_round)]
        if len(keep) == len(events):
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            for e in keep:
                f.write(json.dumps(e, sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)


def summarize_events(events: List[Dict]) -> Dict[str, Dict]:
    """Per-cell rollup of an event stream: convergence series (eval rounds
    only), realized-cohort statistics, straggler totals, and wall-time /
    memory aggregates (the latter excluded from bit-identity checks)."""
    cells: Dict[str, Dict] = {}
    for e in events:
        c = cells.setdefault(e["cell"], {
            "rounds": 0, "drawn_total": 0, "realized_total": 0,
            "straggler_total": 0, "faults_injected_total": 0,
            "clients_rejected_total": 0, "rollbacks": 0, "convergence": [],
            "wall_total_s": 0.0, "peak_rss_mb": 0.0,
        })
        c["rounds"] += 1
        c["drawn_total"] += e["drawn"]
        c["realized_total"] += e["realized"]
        c["straggler_total"] += e["stragglers"]
        # .get(): pre-fault-tolerance logs have no fault/rollback fields
        c["faults_injected_total"] += e.get("faults_injected", 0)
        c["clients_rejected_total"] += e.get("clients_rejected", 0)
        c["rollbacks"] += e.get("rollbacks", 0)
        c["wall_total_s"] += e.get("wall_s", 0.0)
        c["peak_rss_mb"] = max(c["peak_rss_mb"], e.get("peak_rss_mb", 0.0))
        if e.get("f") is not None:
            point = {"round": e["round"], "f": e["f"]}
            if e.get("err") is not None:
                point["err"] = e["err"]
            c["convergence"].append(point)
    for c in cells.values():
        n = max(c["rounds"], 1)
        c["drawn_mean"] = c["drawn_total"] / n
        c["realized_mean"] = c["realized_total"] / n
        if c["convergence"]:
            c["final_f"] = c["convergence"][-1]["f"]
            if "err" in c["convergence"][-1]:
                c["final_err"] = c["convergence"][-1]["err"]
    return cells
