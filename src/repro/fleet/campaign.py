"""Resumable fleet campaigns: the Fig.-2 grid under a simulated fleet.

A campaign runs a set of registry solvers ("cells") on one dataset for a
fixed round budget, each under a participation model (trace-driven
availability/stragglers, plain Bernoulli, or full participation), and
emits one JSONL :class:`~repro.fleet.metrics.RoundEvent` per (cell,
round).  Everything about a campaign is engineered to be *resumable*:

  * each cell checkpoints through :mod:`repro.checkpoint` (atomic
    manifest-last saves) every ``checkpoint_every`` rounds;
  * the Trainer's absolute-round key schedule and the trace's
    ``(seed, round)``-pure masks make any round's computation independent
    of where the process last died;
  * on restart, a cell restores its newest checkpoint, the event log
    drops the rounds about to re-run (:meth:`EventLog.truncate`), and the
    re-emitted events are byte-identical (modulo ``TIMING_KEYS``) to what
    an uninterrupted run would have written.

That is the acceptance property: ``kill -9`` at any instant, re-invoke,
and the final iterates and the deterministic view of the event stream
match the uninterrupted run bit-for-bit.

Distribution drift (§1.2's non-stationary clients) is modeled as epoch
segments: every ``drift_every`` rounds the dataset is rebuilt via
:func:`repro.data.synthetic.drifted_dataset` (same shapes, shifted
ground truth and/or resampled client data) and the solver is
reconstructed on the new problem with the carried-over state — the
epoch is a pure function of the absolute round, so resume lands in the
correct segment automatically.

**Divergence guard-rail** (``spec.guard != "none"``): a round that leaves
the iterate non-finite (:class:`~repro.core.trainer.NonFiniteIterateError`
from the Trainer's fail-fast check) or exploding
(``||w|| > explode_norm``, checked before the event is logged) triggers a
*rollback* — the cell restores its last atomic checkpoint, the offending
round is recorded in the cell's ``guard.json`` quarantine set (atomic
write, so the decision survives a kill), the event log drops the rounds
about to re-run, and the re-run *skips* the quarantined round (the round
counter advances, the iterate and per-client state are untouched — as if
every client was dropped that round).  Quarantined rounds emit their
event with ``rollbacks=1``.  More than ``max_rollbacks`` consecutive
rollbacks without completing a segment raises :class:`CampaignDiverged`.
Because the fault draws, the divergence they cause, and the persisted
quarantine set are all pure functions of (spec, round), kill-resume
bit-identity holds *across* rollbacks: an interrupted+resumed campaign
and an uninterrupted one quarantine the same rounds and emit the same
deterministic event stream.

Guard spellings: ``"rollback"`` arms the rail alone; ``"clip"``,
``"trimmed_mean"``, ``"median"`` additionally install the matching
:attr:`~repro.core.engine.EngineConfig.aggregator_guard` in every cell's
engine (robust aggregation usually prevents the divergence the rail would
otherwise have to repair).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.fleet.faults import FaultModel, fault_counts
from repro.fleet.metrics import EventLog, RoundEvent, peak_rss_mb, summarize_events
from repro.fleet.participation import BernoulliParticipation, TraceParticipation
from repro.fleet.traces import FleetTrace

#: guard spellings that install an engine-level aggregator guard
_ENGINE_GUARDS = ("clip", "trimmed_mean", "median")
_GUARD_CHOICES = ("none", "rollback") + _ENGINE_GUARDS


class CampaignDiverged(RuntimeError):
    """The guard-rail gave up: more than ``max_rollbacks`` consecutive
    rollbacks without completing a segment — quarantining rounds is not
    restoring progress, so the campaign aborts instead of spinning."""

    def __init__(self, cell: str, round_index: int, rollbacks: int):
        super().__init__(
            f"cell '{cell}' keeps diverging (round {round_index}, "
            f"{rollbacks} rollbacks so far) — quarantine is not restoring "
            "progress; raise max_rollbacks or install an aggregator guard")
        self.cell = cell
        self.round_index = int(round_index)
        self.rollbacks = int(rollbacks)


class CampaignInterrupted(Exception):
    """Raised by the ``stop_after`` hook to simulate a mid-campaign crash
    (no final checkpoint, possibly a torn event tail) — the resume path's
    test double for a real ``kill -9``."""

    def __init__(self, rounds_done: int):
        super().__init__(f"campaign stopped after {rounds_done} rounds")
        self.rounds_done = rounds_done


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """One campaign = (dataset, fleet, grid, budget) — everything a resumed
    invocation needs to recompute exactly the same run."""

    algos: Tuple[str, ...] = ("gd", "fedavg")
    rounds: int = 30
    seed: int = 0
    #: None -> the paper-K dataset (K=10,000 clients, CI-shrunk d/n_k);
    #: a float runs get_logreg_config().scaled(scale) instead
    scale: Optional[float] = None
    #: "trace" | "bernoulli" | "full"
    model: str = "trace"
    #: Bernoulli rate, or ignored for "trace"/"full"
    participation: float = 0.3
    trace: FleetTrace = dataclasses.field(default_factory=FleetTrace)
    cohort: Optional[int] = None
    client_chunk: Optional[int] = None
    eval_every: int = 1
    checkpoint_every: int = 5
    #: rounds per drift epoch; 0 disables drift
    drift_every: int = 0
    drift_w_scale: float = 1.0
    drift_resample: bool = False
    #: per-algo solver overrides, e.g. {"fedavg": {"stepsize": 0.3}}
    overrides: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    #: fault model corrupting client deltas (None = honest fleet)
    faults: Optional[FaultModel] = None
    #: "none" | "rollback" | "clip" | "trimmed_mean" | "median" — anything
    #: but "none" arms the divergence rollback rail; the last three also
    #: install the matching EngineConfig.aggregator_guard in every cell
    guard: str = "none"
    guard_clip_norm: Optional[float] = None
    guard_trim: float = 0.1
    #: consecutive rollbacks tolerated before CampaignDiverged
    max_rollbacks: int = 3
    #: finite-but-exploding iterate threshold for the rail
    explode_norm: float = 1e8

    def __post_init__(self):
        if self.model not in ("trace", "bernoulli", "full"):
            raise ValueError("model must be 'trace', 'bernoulli', or 'full'")
        if self.rounds < 1:
            raise ValueError("rounds must be >= 1")
        if self.guard not in _GUARD_CHOICES:
            raise ValueError(f"guard must be one of {_GUARD_CHOICES}")
        if self.max_rollbacks < 1:
            raise ValueError("max_rollbacks must be >= 1")
        if self.explode_norm <= 0:
            raise ValueError("explode_norm must be > 0")

    def engine_guard(self) -> Optional[str]:
        """The EngineConfig.aggregator_guard this spec installs (None for
        "none"/"rollback" — the rail without robust aggregation)."""
        return self.guard if self.guard in _ENGINE_GUARDS else None

    def participation_model(self):
        """(model_or_None, capacity_rate) for the engine: the model owns
        the draw, the rate bounds the cohort capacity."""
        if self.model == "trace":
            return TraceParticipation(self.trace), self.trace.max_rate()
        if self.model == "bernoulli" and self.participation < 1.0:
            return BernoulliParticipation(self.participation), self.participation
        return None, 1.0

    def to_jsonable(self) -> Dict:
        return dataclasses.asdict(self)


def _epoch_of(spec: CampaignSpec, r: int) -> int:
    return r // spec.drift_every if spec.drift_every > 0 else 0


def _segment_end(spec: CampaignSpec, r: int) -> int:
    if spec.drift_every <= 0:
        return spec.rounds
    return min(((r // spec.drift_every) + 1) * spec.drift_every, spec.rounds)


def _build_epoch(spec: CampaignSpec, epoch: int):
    """(problem, test_problem) for a drift epoch — a pure function of
    (spec, epoch), which is what makes resume-into-a-segment exact."""
    from repro.configs import get_logreg_config
    from repro.configs.gplus_logreg import PAPER_K_CONFIG
    from repro.core import build_problem, build_test_problem
    from repro.data.synthetic import (drifted_dataset, materialize_dataset,
                                      virtual_dataset)

    cfg = (PAPER_K_CONFIG if spec.scale is None
           else get_logreg_config().scaled(spec.scale))
    vds = virtual_dataset(cfg, seed=spec.seed)
    if spec.drift_every > 0:
        vds = drifted_dataset(vds, epoch, w_true_scale=spec.drift_w_scale,
                              resample_clients=spec.drift_resample)
    ds = materialize_dataset(vds)
    return build_problem(ds), build_test_problem(ds)


def _make_solver_for(spec: CampaignSpec, algo: str, problem):
    from repro.core import make_solver
    model, rate = spec.participation_model()
    kw = dict(participation=rate, participation_model=model,
              client_chunk=spec.client_chunk, cohort=spec.cohort)
    if spec.faults is not None:
        kw["fault_model"] = spec.faults
    eg = spec.engine_guard()
    if eg is not None:
        kw["aggregator_guard"] = eg
        if eg == "clip":
            if spec.guard_clip_norm is not None:
                kw["guard_clip_norm"] = spec.guard_clip_norm
        else:
            kw["guard_trim"] = spec.guard_trim
    kw.update(spec.overrides.get(algo, {}))
    return make_solver(algo, problem, **kw)


def _count_fn(model, fmodel, offsets, sizes):
    """jitted (key, r) -> (drawn, realized, stragglers, faults_injected,
    poisoned) int32 counts, recomputing exactly the masks the engine drew
    and the fault kinds it injected for that round — the single source of
    randomness is shared, not duplicated."""
    total = int(sum(sizes))
    if model is None and fmodel is None:
        return lambda key, r: (total, total, 0, 0, 0)
    # global client ids per bucket, concatenated in bucket order — the same
    # ids RoundEngine._bucket_ids assigns, so kinds() sees the engine's view
    all_ids = (jnp.concatenate(
        [jnp.uint32(o) + jnp.arange(int(s), dtype=jnp.uint32)
         for o, s in zip(offsets, sizes)]) if fmodel is not None else None)

    @jax.jit
    def counts(key, r):
        r32 = jnp.asarray(r, jnp.int32)
        comp = (model.mask_components(key, r32, offsets, sizes)
                if model is not None else None)
        if comp is None:
            drawn = realized = jnp.int32(total)
            stragglers = jnp.int32(0)
            ret = jnp.ones((total,), jnp.float32)
        else:
            avail, returned = comp
            drawn = sum(m.sum() for m in avail).astype(jnp.int32)
            realized = sum(m.sum() for m in returned).astype(jnp.int32)
            stragglers = drawn - realized
            ret = jnp.concatenate([m.astype(jnp.float32) for m in returned])
        if fmodel is None:
            injected = poisoned = jnp.int32(0)
        else:
            injected, poisoned = fault_counts(fmodel, r32, all_ids, ret)
        return drawn, realized, stragglers, injected, poisoned

    def run(key, r):
        d, re, s, i, p = counts(key, r)
        return int(d), int(re), int(s), int(i), int(p)

    return run


def _load_guard(path: str) -> Dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"quarantined": [], "consecutive": 0, "total": 0}


def _save_guard(path: str, guard: Dict) -> None:
    """Atomic write — the quarantine decision must survive a kill taken
    at any instant between detection and the rolled-back re-run."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(guard, f, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


class _QuarantinedSolver:
    """Wraps a solver to *skip* quarantined rounds: the round counter
    advances, the iterate and per-client aux state are untouched — the
    round behaves as if every client was dropped.  The key schedule is
    absolute-round-indexed, so skipping never shifts later rounds' keys."""

    def __init__(self, solver, quarantined):
        self._solver = solver
        self._quarantined = frozenset(int(q) for q in quarantined)

    def round(self, state, key):
        if int(state.round) in self._quarantined:
            return state.replace(round=state.round + 1)
        return self._solver.round(state, key)

    def __getattr__(self, name):
        return getattr(self._solver, name)


def run_cell(spec: CampaignSpec, algo: str, out_dir: str, log: EventLog,
             budget: Optional[Dict] = None, verbose: bool = True) -> Dict:
    """Run (or resume) one campaign cell to its round budget.

    ``budget`` is the cross-cell ``stop_after`` countdown:
    ``{"left": n}`` decrements per completed round and raises
    :class:`CampaignInterrupted` at zero.
    Returns ``{"w": final iterate, "round": rounds}`` (plus the guard
    tally when the rail is armed).
    """
    from repro.core import NonFiniteIterateError, Trainer

    ckpt_dir = os.path.join(out_dir, "cells", algo)
    guard_path = os.path.join(ckpt_dir, "guard.json")
    rail = spec.guard != "none"
    guard = _load_guard(guard_path) if rail else _load_guard("")

    state = None
    if os.path.exists(os.path.join(ckpt_dir, "manifest.json")):
        state = Trainer.restore(ckpt_dir)
        if verbose:
            print(f"[{algo}] resuming from round {int(state.round)}")
    start = 0 if state is None else int(state.round)
    # the rounds >= start are about to re-run and re-emit
    log.truncate(algo, start)

    model, _ = spec.participation_model()
    rejects = spec.engine_guard() is not None
    explode = float(spec.explode_norm)
    base = jax.random.PRNGKey(spec.seed)
    r = start
    while r < spec.rounds:
        epoch = _epoch_of(spec, r)
        seg_end = _segment_end(spec, r)
        problem, test = _build_epoch(spec, epoch)
        solver = _make_solver_for(spec, algo, problem)
        if state is None:
            state = solver.init(jnp.zeros(problem.d))
        quarantined = frozenset(int(q) for q in guard["quarantined"])
        run_solver = (_QuarantinedSolver(solver, quarantined)
                      if rail and quarantined else solver)
        counts = _count_fn(model, spec.faults, solver.engine._offsets,
                           solver.engine._sizes)
        loss = jax.jit(problem.flat.loss)
        err = jax.jit(test.error_rate)
        t_mark = [time.perf_counter()]

        def callback(st, rr, counts=counts, loss=loss, err=err,
                     t_mark=t_mark, quarantined=quarantined):
            # guard-rail explosion check *before* anything is logged, so a
            # diverging round never leaves an event the rollback would have
            # to claw back (the Trainer's NaN/Inf check fires even earlier)
            if rail and not bool(jnp.linalg.norm(st.w) <= explode):
                raise NonFiniteIterateError(algo, rr)
            drawn, realized, stragglers, injected, poisoned = counts(
                jax.random.fold_in(base, rr), rr)
            is_eval = ((rr + 1) % spec.eval_every == 0
                       or rr == spec.rounds - 1)
            f_v = float(loss(st.w)) if is_eval else None
            e_v = float(err(st.w)) if is_eval else None
            now = time.perf_counter()
            log.append(RoundEvent(
                cell=algo, round=rr, drawn=drawn, realized=realized,
                stragglers=stragglers, f=f_v, err=e_v,
                faults_injected=injected,
                clients_rejected=poisoned if rejects else 0,
                rollbacks=1 if rr in quarantined else 0,
                wall_s=now - t_mark[0], peak_rss_mb=peak_rss_mb()))
            t_mark[0] = now
            if verbose and (is_eval or stragglers):
                msg = f"[{algo}] r{rr}: drawn={drawn} realized={realized}"
                if injected:
                    msg += f" faults={injected}"
                if rr in quarantined:
                    msg += " (quarantined)"
                if f_v is not None:
                    msg += f" f={f_v:.5f} err={e_v:.4f}"
                print(msg)
            if budget is not None:
                budget["left"] -= 1
                if budget["left"] <= 0:
                    raise CampaignInterrupted(rr + 1)

        trainer = Trainer(run_solver, rounds=seg_end, seed=spec.seed,
                          callback=callback, checkpoint_dir=ckpt_dir,
                          checkpoint_every=spec.checkpoint_every)
        try:
            res = trainer.fit(state=state)
        except NonFiniteIterateError as e:
            if not rail:
                raise
            bad = int(e.round_index)
            guard["quarantined"] = sorted(set(guard["quarantined"]) | {bad})
            guard["consecutive"] += 1
            guard["total"] += 1
            # quarantine first, atomically: a kill after this point resumes
            # with the round already condemned; a kill before it re-runs
            # into the same deterministic divergence and condemns it again
            _save_guard(guard_path, guard)
            if verbose:
                print(f"[{algo}] r{bad}: diverged — rolling back "
                      f"(quarantined, {guard['total']} total)")
            if guard["consecutive"] > spec.max_rollbacks:
                raise CampaignDiverged(algo, bad, guard["total"]) from e
            # roll back to the last atomic checkpoint (fresh init if the
            # divergence predates the first save)
            if os.path.exists(os.path.join(ckpt_dir, "manifest.json")):
                state = Trainer.restore(ckpt_dir)
                r = int(state.round)
            else:
                state = None
                r = 0
            log.truncate(algo, r)
            continue
        # a completed segment is progress: the consecutive streak resets
        # (the total and the quarantine set are permanent record)
        if rail and guard["consecutive"]:
            guard["consecutive"] = 0
            _save_guard(guard_path, guard)
        state = res.state
        r = seg_end
    out = {"w": state.w, "round": int(state.round)}
    if rail:
        out["rollbacks"] = guard["total"]
        out["quarantined"] = list(guard["quarantined"])
    return out


def run_campaign(spec: CampaignSpec, out_dir: str,
                 stop_after: Optional[int] = None,
                 verbose: bool = True) -> Dict:
    """Run (or resume) every cell of a campaign; write ``events.jsonl``
    and, on completion, an atomic ``summary.json``.

    ``stop_after`` aborts the invocation after that many rounds *of this
    invocation* (simulated crash); the return value then carries
    ``{"interrupted": True}`` and a re-invocation without ``stop_after``
    resumes and completes.
    """
    os.makedirs(out_dir, exist_ok=True)
    log = EventLog(os.path.join(out_dir, "events.jsonl"))
    budget = {"left": stop_after} if stop_after is not None else None
    finals = {}
    try:
        for algo in spec.algos:
            finals[algo] = run_cell(spec, algo, out_dir, log,
                                    budget=budget, verbose=verbose)
    except CampaignInterrupted as e:
        if verbose:
            print(f"campaign interrupted after {e.rounds_done} rounds "
                  f"(this invocation)")
        return {"interrupted": True, "rounds_done": e.rounds_done}

    cells = summarize_events(log.load())
    summary = {"spec": spec.to_jsonable(), "cells": cells,
               "events": os.path.basename(log.path)}
    path = os.path.join(out_dir, "summary.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    summary["finals"] = finals
    return summary
