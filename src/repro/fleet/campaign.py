"""Resumable fleet campaigns: the Fig.-2 grid under a simulated fleet.

A campaign runs a set of registry solvers ("cells") on one dataset for a
fixed round budget, each under a participation model (trace-driven
availability/stragglers, plain Bernoulli, or full participation), and
emits one JSONL :class:`~repro.fleet.metrics.RoundEvent` per (cell,
round).  Everything about a campaign is engineered to be *resumable*:

  * each cell checkpoints through :mod:`repro.checkpoint` (atomic
    manifest-last saves) every ``checkpoint_every`` rounds;
  * the Trainer's absolute-round key schedule and the trace's
    ``(seed, round)``-pure masks make any round's computation independent
    of where the process last died;
  * on restart, a cell restores its newest checkpoint, the event log
    drops the rounds about to re-run (:meth:`EventLog.truncate`), and the
    re-emitted events are byte-identical (modulo ``TIMING_KEYS``) to what
    an uninterrupted run would have written.

That is the acceptance property: ``kill -9`` at any instant, re-invoke,
and the final iterates and the deterministic view of the event stream
match the uninterrupted run bit-for-bit.

Distribution drift (§1.2's non-stationary clients) is modeled as epoch
segments: every ``drift_every`` rounds the dataset is rebuilt via
:func:`repro.data.synthetic.drifted_dataset` (same shapes, shifted
ground truth and/or resampled client data) and the solver is
reconstructed on the new problem with the carried-over state — the
epoch is a pure function of the absolute round, so resume lands in the
correct segment automatically.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.fleet.metrics import EventLog, RoundEvent, peak_rss_mb, summarize_events
from repro.fleet.participation import BernoulliParticipation, TraceParticipation
from repro.fleet.traces import FleetTrace


class CampaignInterrupted(Exception):
    """Raised by the ``stop_after`` hook to simulate a mid-campaign crash
    (no final checkpoint, possibly a torn event tail) — the resume path's
    test double for a real ``kill -9``."""

    def __init__(self, rounds_done: int):
        super().__init__(f"campaign stopped after {rounds_done} rounds")
        self.rounds_done = rounds_done


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """One campaign = (dataset, fleet, grid, budget) — everything a resumed
    invocation needs to recompute exactly the same run."""

    algos: Tuple[str, ...] = ("gd", "fedavg")
    rounds: int = 30
    seed: int = 0
    #: None -> the paper-K dataset (K=10,000 clients, CI-shrunk d/n_k);
    #: a float runs get_logreg_config().scaled(scale) instead
    scale: Optional[float] = None
    #: "trace" | "bernoulli" | "full"
    model: str = "trace"
    #: Bernoulli rate, or ignored for "trace"/"full"
    participation: float = 0.3
    trace: FleetTrace = dataclasses.field(default_factory=FleetTrace)
    cohort: Optional[int] = None
    client_chunk: Optional[int] = None
    eval_every: int = 1
    checkpoint_every: int = 5
    #: rounds per drift epoch; 0 disables drift
    drift_every: int = 0
    drift_w_scale: float = 1.0
    drift_resample: bool = False
    #: per-algo solver overrides, e.g. {"fedavg": {"stepsize": 0.3}}
    overrides: Dict[str, Dict] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.model not in ("trace", "bernoulli", "full"):
            raise ValueError("model must be 'trace', 'bernoulli', or 'full'")
        if self.rounds < 1:
            raise ValueError("rounds must be >= 1")

    def participation_model(self):
        """(model_or_None, capacity_rate) for the engine: the model owns
        the draw, the rate bounds the cohort capacity."""
        if self.model == "trace":
            return TraceParticipation(self.trace), self.trace.max_rate()
        if self.model == "bernoulli" and self.participation < 1.0:
            return BernoulliParticipation(self.participation), self.participation
        return None, 1.0

    def to_jsonable(self) -> Dict:
        return dataclasses.asdict(self)


def _epoch_of(spec: CampaignSpec, r: int) -> int:
    return r // spec.drift_every if spec.drift_every > 0 else 0


def _segment_end(spec: CampaignSpec, r: int) -> int:
    if spec.drift_every <= 0:
        return spec.rounds
    return min(((r // spec.drift_every) + 1) * spec.drift_every, spec.rounds)


def _build_epoch(spec: CampaignSpec, epoch: int):
    """(problem, test_problem) for a drift epoch — a pure function of
    (spec, epoch), which is what makes resume-into-a-segment exact."""
    from repro.configs import get_logreg_config
    from repro.configs.gplus_logreg import PAPER_K_CONFIG
    from repro.core import build_problem, build_test_problem
    from repro.data.synthetic import (drifted_dataset, materialize_dataset,
                                      virtual_dataset)

    cfg = (PAPER_K_CONFIG if spec.scale is None
           else get_logreg_config().scaled(spec.scale))
    vds = virtual_dataset(cfg, seed=spec.seed)
    if spec.drift_every > 0:
        vds = drifted_dataset(vds, epoch, w_true_scale=spec.drift_w_scale,
                              resample_clients=spec.drift_resample)
    ds = materialize_dataset(vds)
    return build_problem(ds), build_test_problem(ds)


def _make_solver_for(spec: CampaignSpec, algo: str, problem):
    from repro.core import make_solver
    model, rate = spec.participation_model()
    kw = dict(participation=rate, participation_model=model,
              client_chunk=spec.client_chunk, cohort=spec.cohort)
    kw.update(spec.overrides.get(algo, {}))
    return make_solver(algo, problem, **kw)


def _count_fn(model, offsets, sizes):
    """jitted (key, r) -> (drawn, realized, stragglers) int32 counts,
    recomputing exactly the masks the engine drew for that round — the
    single source of randomness is shared, not duplicated."""
    total = int(sum(sizes))
    if model is None:
        return lambda key, r: (total, total, 0)

    @jax.jit
    def counts(key, r):
        comp = model.mask_components(key, jnp.asarray(r, jnp.int32),
                                     offsets, sizes)
        if comp is None:
            t = jnp.int32(total)
            return t, t, jnp.int32(0)
        avail, returned = comp
        drawn = sum(m.sum() for m in avail)
        realized = sum(m.sum() for m in returned)
        return (drawn.astype(jnp.int32), realized.astype(jnp.int32),
                (drawn - realized).astype(jnp.int32))

    def run(key, r):
        d, re, s = counts(key, r)
        return int(d), int(re), int(s)

    return run


def run_cell(spec: CampaignSpec, algo: str, out_dir: str, log: EventLog,
             budget: Optional[Dict] = None, verbose: bool = True) -> Dict:
    """Run (or resume) one campaign cell to its round budget.

    ``budget`` is the cross-cell ``stop_after`` countdown:
    ``{"left": n}`` decrements per completed round and raises
    :class:`CampaignInterrupted` at zero.
    Returns ``{"w": final iterate, "round": rounds}``.
    """
    from repro.core import Trainer

    ckpt_dir = os.path.join(out_dir, "cells", algo)
    state = None
    if os.path.exists(os.path.join(ckpt_dir, "manifest.json")):
        state = Trainer.restore(ckpt_dir)
        if verbose:
            print(f"[{algo}] resuming from round {int(state.round)}")
    start = 0 if state is None else int(state.round)
    # the rounds >= start are about to re-run and re-emit
    log.truncate(algo, start)

    model, _ = spec.participation_model()
    base = jax.random.PRNGKey(spec.seed)
    r = start
    while r < spec.rounds:
        epoch = _epoch_of(spec, r)
        seg_end = _segment_end(spec, r)
        problem, test = _build_epoch(spec, epoch)
        solver = _make_solver_for(spec, algo, problem)
        if state is None:
            state = solver.init(jnp.zeros(problem.d))
        counts = _count_fn(model, solver.engine._offsets,
                           solver.engine._sizes)
        loss = jax.jit(problem.flat.loss)
        err = jax.jit(test.error_rate)
        t_mark = [time.perf_counter()]

        def callback(st, rr, counts=counts, loss=loss, err=err,
                     t_mark=t_mark):
            drawn, realized, stragglers = counts(
                jax.random.fold_in(base, rr), rr)
            is_eval = ((rr + 1) % spec.eval_every == 0
                       or rr == spec.rounds - 1)
            f_v = float(loss(st.w)) if is_eval else None
            e_v = float(err(st.w)) if is_eval else None
            now = time.perf_counter()
            log.append(RoundEvent(
                cell=algo, round=rr, drawn=drawn, realized=realized,
                stragglers=stragglers, f=f_v, err=e_v,
                wall_s=now - t_mark[0], peak_rss_mb=peak_rss_mb()))
            t_mark[0] = now
            if verbose and (is_eval or stragglers):
                msg = f"[{algo}] r{rr}: drawn={drawn} realized={realized}"
                if f_v is not None:
                    msg += f" f={f_v:.5f} err={e_v:.4f}"
                print(msg)
            if budget is not None:
                budget["left"] -= 1
                if budget["left"] <= 0:
                    raise CampaignInterrupted(rr + 1)

        trainer = Trainer(solver, rounds=seg_end, seed=spec.seed,
                          callback=callback, checkpoint_dir=ckpt_dir,
                          checkpoint_every=spec.checkpoint_every)
        res = trainer.fit(state=state)
        state = res.state
        r = seg_end
    return {"w": state.w, "round": int(state.round)}


def run_campaign(spec: CampaignSpec, out_dir: str,
                 stop_after: Optional[int] = None,
                 verbose: bool = True) -> Dict:
    """Run (or resume) every cell of a campaign; write ``events.jsonl``
    and, on completion, an atomic ``summary.json``.

    ``stop_after`` aborts the invocation after that many rounds *of this
    invocation* (simulated crash); the return value then carries
    ``{"interrupted": True}`` and a re-invocation without ``stop_after``
    resumes and completes.
    """
    os.makedirs(out_dir, exist_ok=True)
    log = EventLog(os.path.join(out_dir, "events.jsonl"))
    budget = {"left": stop_after} if stop_after is not None else None
    finals = {}
    try:
        for algo in spec.algos:
            finals[algo] = run_cell(spec, algo, out_dir, log,
                                    budget=budget, verbose=verbose)
    except CampaignInterrupted as e:
        if verbose:
            print(f"campaign interrupted after {e.rounds_done} rounds "
                  f"(this invocation)")
        return {"interrupted": True, "rounds_done": e.rounds_done}

    cells = summarize_events(log.load())
    summary = {"spec": spec.to_jsonable(), "cells": cells,
               "events": os.path.basename(log.path)}
    path = os.path.join(out_dir, "summary.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    summary["finals"] = finals
    return summary
