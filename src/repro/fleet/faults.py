"""Deterministic delta-corruption faults — what misbehaving clients send.

The participation layer (:mod:`repro.fleet.participation`) simulates *who*
shows up each round; this module simulates *what they send* going wrong.
A :class:`FaultModel` corrupts the per-client deltas after the client pass
and before aggregation — the wire, not the client: a faulted client's
local auxiliary state (dual blocks, perturbation vectors) is whatever its
pass computed, exactly as if the corruption happened in transit.

The contract mirrors :class:`~repro.fleet.participation.ParticipationModel`
and the PR-7/PR-8 seeding rules:

  * every draw is a pure function of ``(seed, round_index, client_id)`` on
    the model's own ``fold_in`` chain — disjoint from the solver, data,
    and trace chains, so installing a fault model never perturbs which
    clients are sampled or what their honest passes compute;
  * per-client draws fold in the *global* client index, never a batch
    position — the same clients are corrupted identically whether the
    engine runs the plain, streamed, cohort, or virtual path (the
    batch-shape invariance the engine parity tests pin);
  * only batch-shape-stable uniform primitives — no ``normal`` (erfinv)
    or rejection sampling, the bit-stability rule everything else in the
    fleet follows.

:class:`DeltaFaults` draws **one** uniform per (round, client) and
partitions it into disjoint intervals, so each fault kind's rate is exact
and at most one fault hits a client per round:

  ====  ============  ====================================================
  kind  knob          corruption of the returned delta δ
  ====  ============  ====================================================
  1     nan_rate      NaN / +Inf / −Inf poisoning (every coordinate)
  2     sign_rate     sign flip: δ ← −δ
  3     scale_rate    gradient-scaling attack: δ ← scale_factor · δ
  4     replay_rate   stale-delta replay: δ ← v_k(⌊r / replay_window⌋)
  ====  ============  ====================================================

Stale replay is modeled as the strongest *pure-function* form of the
fault: within each ``replay_window``-round window the client re-sends the
same cached pseudo-delta ``v_k`` (a per-(client, window) uniform vector
scaled by ``replay_scale``) every round — the repeated-bytes signature of
a replay, without the cross-round state a literal resend would need (and
which would break the kill-resume contract).

Faults only fire for rounds in ``[start_round, stop_round)`` — campaign
tests inject at a known round and assert the guard-rail's reaction.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.fleet.traces import _per_client_uniform

# tags folded off PRNGKey(seed) — one sub-chain per draw family
_KIND_TAG = 0     # per-(r, k) fault-kind selector uniform
_POISON_TAG = 1   # per-(r, k) NaN / +Inf / -Inf selector
_REPLAY_TAG = 2   # per-(window, k) replayed pseudo-delta

#: fault-kind codes returned by :meth:`FaultModel.kinds`
KIND_NONE, KIND_POISON, KIND_SIGN, KIND_SCALE, KIND_REPLAY = 0, 1, 2, 3, 4


class FaultModel:
    """Protocol base — subclasses override :meth:`kinds` and :meth:`apply`.

    ``kinds(round_index, client_ids)`` returns an int32 fault-kind vector
    (0 = honest) as a pure function of ``(seed, round_index, global id)``;
    ``apply(deltas, round_index, client_ids)`` returns the corrupted
    (K, d) delta block.  Both must be traceable and batch-shape invariant
    so every engine round path corrupts the same clients identically.
    """

    #: fault draws are a function of the round by contract; the engine
    #: rejects legacy round-less calls instead of silently faulting round 0
    needs_round_index: bool = True

    def kinds(self, round_index: jax.Array,
              client_ids: jax.Array) -> jax.Array:
        raise NotImplementedError

    def apply(self, deltas: jax.Array, round_index: jax.Array,
              client_ids: jax.Array) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class DeltaFaults(FaultModel):
    """The standard fault mix — see the module docstring for the kinds."""

    seed: int = 0
    nan_rate: float = 0.0      # NaN/Inf poisoning
    sign_rate: float = 0.0     # sign-flip
    scale_rate: float = 0.0    # gradient-scaling attack
    scale_factor: float = 100.0
    replay_rate: float = 0.0   # stale-delta replay
    replay_window: int = 5     # rounds a replayed delta stays cached
    replay_scale: float = 1.0  # magnitude of the replayed pseudo-delta
    start_round: int = 0       # faults fire for start_round <= r ...
    stop_round: Optional[int] = None   # ... < stop_round (None = forever)

    def __post_init__(self):
        for name in ("nan_rate", "sign_rate", "scale_rate", "replay_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1]")
        if (self.nan_rate + self.sign_rate + self.scale_rate
                + self.replay_rate) > 1.0:
            raise ValueError("fault rates must sum to <= 1 (one uniform is "
                             "partitioned into disjoint kind intervals)")
        if self.replay_window < 1:
            raise ValueError("replay_window must be >= 1")
        if self.stop_round is not None and self.stop_round <= self.start_round:
            raise ValueError("stop_round must be > start_round")

    #: CLI spec knob -> field (shared by benchmarks/campaign.py --faults
    #: and benchmarks/fig2_convergence.py --fault-model)
    _SPEC_KEYS = {
        "nan": "nan_rate", "sign": "sign_rate", "scale": "scale_rate",
        "replay": "replay_rate", "scale-factor": "scale_factor",
        "window": "replay_window", "start": "start_round",
        "stop": "stop_round", "seed": "seed",
    }
    _INT_FIELDS = ("seed", "replay_window", "start_round", "stop_round")

    @classmethod
    def from_spec(cls, spec: str) -> "DeltaFaults":
        """Parse a ``'nan=0.01,sign=0.05,start=10,stop=12'`` CLI spec."""
        kw = {}
        for part in spec.split(","):
            k, _, v = part.partition("=")
            if k not in cls._SPEC_KEYS:
                raise ValueError(f"unknown fault knob {k!r} "
                                 f"(known: {sorted(cls._SPEC_KEYS)})")
            field = cls._SPEC_KEYS[k]
            kw[field] = int(v) if field in cls._INT_FIELDS else float(v)
        return cls(**kw)

    def total_rate(self) -> float:
        return (self.nan_rate + self.sign_rate + self.scale_rate
                + self.replay_rate)

    def _key(self):
        return jax.random.PRNGKey(self.seed)

    def _active(self, r: jax.Array) -> jax.Array:
        on = r >= jnp.int32(self.start_round)
        if self.stop_round is not None:
            on = on & (r < jnp.int32(self.stop_round))
        return on

    def kinds(self, round_index, client_ids):
        """int32 fault-kind per client for this round (0 = honest) — one
        uniform per (r, k), partitioned into disjoint rate intervals so the
        kinds are mutually exclusive and each rate is exact."""
        r = jnp.asarray(round_index, jnp.int32)
        client_ids = jnp.asarray(client_ids, jnp.uint32)
        if self.total_rate() <= 0.0:
            return jnp.zeros(client_ids.shape, jnp.int32)
        u = _per_client_uniform(
            jax.random.fold_in(jax.random.fold_in(self._key(), _KIND_TAG), r),
            client_ids)
        edges = jnp.cumsum(jnp.asarray(
            [self.nan_rate, self.sign_rate, self.scale_rate,
             self.replay_rate], jnp.float32))
        kind = jnp.where(
            u < edges[0], KIND_POISON,
            jnp.where(u < edges[1], KIND_SIGN,
                      jnp.where(u < edges[2], KIND_SCALE,
                                jnp.where(u < edges[3], KIND_REPLAY,
                                          KIND_NONE)))).astype(jnp.int32)
        return jnp.where(self._active(r), kind, KIND_NONE)

    def _poison_values(self, r, client_ids):
        """Per-client poison payload: NaN, +Inf, or -Inf (uniform thirds)."""
        u = _per_client_uniform(
            jax.random.fold_in(jax.random.fold_in(self._key(), _POISON_TAG),
                               r),
            client_ids)
        return jnp.where(u < 1.0 / 3.0, jnp.nan,
                         jnp.where(u < 2.0 / 3.0, jnp.inf, -jnp.inf))

    def _replay_deltas(self, r, client_ids, d: int, dtype):
        """v_k(window) — the cached pseudo-delta a replaying client resends
        every round of the window: per-(client, window) uniform in
        [-replay_scale, replay_scale]^d, constant across the window."""
        window = r // jnp.int32(self.replay_window)
        key = jax.random.fold_in(
            jax.random.fold_in(self._key(), _REPLAY_TAG), window)
        return jax.vmap(
            lambda c: jax.random.uniform(
                jax.random.fold_in(key, c), (d,), dtype,
                minval=-self.replay_scale, maxval=self.replay_scale)
        )(client_ids)

    def apply(self, deltas, round_index, client_ids):
        r = jnp.asarray(round_index, jnp.int32)
        client_ids = jnp.asarray(client_ids, jnp.uint32)
        if self.total_rate() <= 0.0:
            return deltas
        kind = self.kinds(r, client_ids)[:, None]
        out = jnp.where(kind == KIND_SIGN, -deltas, deltas)
        out = jnp.where(kind == KIND_SCALE,
                        jnp.asarray(self.scale_factor, deltas.dtype) * deltas,
                        out)
        if self.replay_rate > 0.0:
            out = jnp.where(
                kind == KIND_REPLAY,
                self._replay_deltas(r, client_ids, deltas.shape[1],
                                    deltas.dtype),
                out)
        if self.nan_rate > 0.0:
            out = jnp.where(kind == KIND_POISON,
                            self._poison_values(r, client_ids)[:, None]
                            .astype(deltas.dtype),
                            out)
        return out


def fault_counts(model: Optional[FaultModel], round_index, client_ids,
                 returned_mask) -> jax.Array:
    """(faults_injected, poisoned) over the *returned* clients — telemetry's
    recomputable view of the round's corruption (a client that never
    reports cannot deliver a corrupted delta).  ``poisoned`` counts the
    non-finite kind specifically: exactly the deltas a non-finite-rejecting
    aggregator guard would discard."""
    if model is None:
        return jnp.int32(0), jnp.int32(0)
    kind = model.kinds(round_index, client_ids)
    live = returned_mask > 0
    injected = (live & (kind != KIND_NONE)).sum().astype(jnp.int32)
    poisoned = (live & (kind == KIND_POISON)).sum().astype(jnp.int32)
    return injected, poisoned
