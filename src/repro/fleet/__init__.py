"""Fleet simulation: what the engine's Bernoulli draw abstracts away.

The paper's setting (§1.2) is a fleet of phones that participate only
when charging and on wifi — availability is diurnal and correlated, slow
devices miss the reporting deadline, and client distributions drift.
This package simulates that fleet *deterministically*:

  traces.py        — bit-stable availability/straggler mask generators:
                     any round's fleet state is a pure function of
                     ``(trace.seed, round)``, invariant to how the engine
                     batches clients (chunk / cohort / bucket)
  participation.py — the :class:`ParticipationModel` protocol plugging
                     those traces into :class:`repro.core.RoundEngine`
                     in place of its i.i.d. Bernoulli draw
  faults.py        — the :class:`FaultModel` twin for *what clients
                     send*: deterministic delta corruptions (NaN
                     poisoning, sign flips, scaling attacks, stale
                     replay) injected between the client pass and
                     aggregation
  metrics.py       — structured JSONL round telemetry (drawn vs realized
                     cohort, stragglers, objective, wall/RSS)
  campaign.py      — the checkpointed, kill-resumable campaign runner
                     over the Fig.-2 solver grid (see
                     ``benchmarks/campaign.py``)
"""
from repro.fleet.campaign import (CampaignDiverged, CampaignInterrupted,
                                  CampaignSpec, run_campaign, run_cell)
from repro.fleet.faults import DeltaFaults, FaultModel, fault_counts
from repro.fleet.metrics import (TIMING_KEYS, EventLog, RoundEvent,
                                 deterministic_view, peak_rss_mb,
                                 summarize_events)
from repro.fleet.participation import (BernoulliParticipation,
                                       FixedParticipation,
                                       ParticipationModel,
                                       TraceParticipation)
from repro.fleet.traces import (FleetMasks, FleetTrace, availability_mask,
                                availability_rate, fleet_masks,
                                straggler_flags)

__all__ = [
    "CampaignDiverged", "CampaignInterrupted", "CampaignSpec",
    "run_campaign", "run_cell",
    "DeltaFaults", "FaultModel", "fault_counts",
    "TIMING_KEYS", "EventLog", "RoundEvent", "deterministic_view",
    "peak_rss_mb", "summarize_events",
    "BernoulliParticipation", "FixedParticipation", "ParticipationModel",
    "TraceParticipation",
    "FleetMasks", "FleetTrace", "availability_mask", "availability_rate",
    "fleet_masks", "straggler_flags",
]
