"""Participation models — the engine's sampling step as a pluggable draw.

:class:`~repro.core.engine.RoundEngine` historically owned one sampling
rule: an i.i.d. Bernoulli(``cfg.participation``) per client per round.
A participation model generalizes the rule while leaving every consumer
of its output untouched — weight zeroing, unbiased reweighting,
dual-state freezing, and the cohort gather all operate on the mask list
the model returns, exactly as they did on the Bernoulli draw.

Contract (:class:`ParticipationModel`):

  * ``masks(key, round_index, offsets, sizes)`` returns the round's
    per-bucket float {0,1} mask list (1.0 = this client's delta enters the
    aggregate), or ``None`` for full participation.  ``key`` is the round
    key (the same one the client passes receive), ``round_index`` the
    absolute round, ``offsets``/``sizes`` the engine's per-bucket first
    client index and client count — a client's *global* index is
    ``offset + position``, which is what trace draws fold in, so masks are
    invariant to how the engine batches clients (chunk, cohort, bucket).
  * ``mask_components(...)`` additionally splits the draw into
    ``(available, returned)`` mask lists for telemetry — drawn vs realized
    cohort, straggler counts — without a second source of randomness.
  * ``needs_round_index`` declares the model round-dependent: the engine
    then refuses mask requests that don't carry the round (solvers always
    forward ``state.round``; only legacy ``(w, key)`` call sites lack it).

When a model is installed, ``EngineConfig.participation`` stops being the
draw and becomes the model's **upper-bound rate** for cohort capacity
sizing (set it to ``trace.max_rate()`` for traces) — the model owns the
actual sampling.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.fleet.traces import FleetTrace, fleet_masks

MaskList = List[jax.Array]


class ParticipationModel:
    """Protocol base — subclasses override :meth:`masks` (and optionally
    :meth:`mask_components`, when "sampled" and "returned" differ)."""

    #: round-dependent models set this so the engine rejects legacy
    #: round-less mask requests instead of silently drawing round 0
    needs_round_index: bool = False

    def masks(self, key: jax.Array, round_index: jax.Array,
              offsets: Sequence[int], sizes: Sequence[int]
              ) -> Optional[MaskList]:
        raise NotImplementedError

    def mask_components(self, key: jax.Array, round_index: jax.Array,
                        offsets: Sequence[int], sizes: Sequence[int]
                        ) -> Optional[Tuple[MaskList, MaskList]]:
        """(available, returned) mask lists — identical for models without
        stragglers, where every sampled client reports."""
        m = self.masks(key, round_index, offsets, sizes)
        return None if m is None else (m, m)


@dataclasses.dataclass(frozen=True)
class BernoulliParticipation(ParticipationModel):
    """The engine's historical i.i.d. draw as a model — bit-identical to
    ``RoundEngine.participation_mask`` by construction (same ``fold_in``
    chain, same 997 tag, same comparison), pinned by
    ``tests/test_fleet.py``.  Exists so campaign configs can treat
    "plain Bernoulli" and "trace-driven" as two values of one knob."""

    participation: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")

    def masks(self, key, round_index, offsets, sizes):
        if self.participation >= 1.0:
            return None
        return [
            (jax.random.uniform(
                jax.random.fold_in(jax.random.fold_in(key, wi), 997), (kb,))
             < self.participation).astype(jnp.float32)
            for wi, kb in zip(offsets, sizes)]


@dataclasses.dataclass(frozen=True)
class TraceParticipation(ParticipationModel):
    """Trace-driven availability + stragglers.

    The mask handed to the engine is the trace's ``returned`` mask
    (available AND reported): dropout-after-compute folded into the single
    draw, so weight zeroing, dual-state freezing, and the cohort gather
    all see one consistent client set — a straggler's delta is zeroed
    *and* its dual state frozen, exactly like a never-sampled client,
    which is the semantics of a delta that never arrived.  Unlike the
    Bernoulli model the draw ignores ``key`` entirely: the fleet's state
    is a pure function of ``(trace.seed, r)``, independent of the solver
    seed, so re-running a round under a different solver seed faces the
    same fleet.
    """

    trace: FleetTrace = dataclasses.field(default_factory=FleetTrace)
    needs_round_index = True

    def _bucket_ids(self, wi: int, kb: int) -> jax.Array:
        return jnp.uint32(wi) + jnp.arange(kb, dtype=jnp.uint32)

    def masks(self, key, round_index, offsets, sizes):
        return [
            fleet_masks(self.trace, round_index,
                        self._bucket_ids(wi, kb)).returned
            for wi, kb in zip(offsets, sizes)]

    def mask_components(self, key, round_index, offsets, sizes):
        avail: MaskList = []
        returned: MaskList = []
        for wi, kb in zip(offsets, sizes):
            fm = fleet_masks(self.trace, round_index,
                             self._bucket_ids(wi, kb))
            avail.append(fm.available)
            returned.append(fm.returned)
        return avail, returned


@dataclasses.dataclass(frozen=True)
class FixedParticipation(ParticipationModel):
    """Replay a fixed mask list every round — the test harness's tool for
    proving mask-consumer identities (e.g. "a straggler behaves exactly
    like a never-sampled client": run a trace model, capture its returned
    masks, replay them here, and the rounds must agree bit-for-bit)."""

    fixed: Tuple[jax.Array, ...]

    def masks(self, key, round_index, offsets, sizes):
        if len(self.fixed) != len(sizes):
            raise ValueError("fixed mask list does not match bucket count")
        return list(self.fixed)
