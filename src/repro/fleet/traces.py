"""Deterministic fleet availability traces — who is reachable, round by round.

The paper's deployment (§1.2) is a fleet of phones that participate only
when charging and on wi-fi: availability is *diurnal* (a device is online
at roughly the same local time every day), *correlated* (a network event
takes a cohort of devices out together), and *unreliable mid-round* (a
sampled device may compute its update and still fail to return it — a
straggler).  This module generates all three as pure functions of
``(trace, r, client_ids)`` with no state carried between rounds, so any
round's fleet can be reproduced bit-for-bit in isolation — the property
the campaign runner's kill-and-resume contract stands on.

Seeding contract (the PR-7 rules, applied to the fleet):

  * every draw comes from the trace's own key chain
    ``fold_in(fold_in(PRNGKey(trace.seed), TAG), ...)`` — disjoint from the
    solver/data chains, so adding a trace never perturbs client updates;
  * per-client quantities fold in the *global client index*, never a batch
    position — a mask regenerated for one client, a chunk, a gathered
    cohort, or the whole fleet is the same bits (the chunk/cohort
    invariance the engine paths rely on);
  * only batch-shape-stable primitives (``uniform``, elementwise math) —
    no ``normal`` (erfinv) or rejection sampling.

The availability *rate* of client k at round r is

    p_k(r) = clip(base + amplitude * sin(2π(r/period + phase_k)), 0, 1)

with ``phase_k`` a per-client uniform phase (each device has its own
"time zone"); a round-level burst event (probability ``burst_prob``)
forces a random ``burst_frac`` of clients to rate 0 for that round.  The
realized availability mask draws one uniform per (r, k) against p_k(r).
Stragglers are an *independent* per-(r, k) Bernoulli(``straggler_rate``)
over the available clients: an available straggler is sampled into the
round but never returns its delta.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

# tags folded off PRNGKey(trace.seed) — one sub-chain per draw family
_PHASE_TAG = 0      # per-client diurnal phase (round-invariant)
_AVAIL_TAG = 1      # per-(r, k) availability uniform
_BURST_TAG = 2      # per-round burst indicator
_BURST_HIT_TAG = 3  # per-(r, k) burst membership
_STRAGGLER_TAG = 4  # per-(r, k) straggler indicator


@dataclasses.dataclass(frozen=True)
class FleetTrace:
    """A deterministic availability/straggler process for a whole fleet.

    ``seed`` roots the trace's own key chain; everything else shapes the
    rate process.  ``base``/``amplitude``/``period`` give each client a
    sinusoidal diurnal rate with its own phase; ``burst_prob`` rounds
    suffer a correlated dropout hitting ``burst_frac`` of clients;
    available clients straggle (compute but never report) i.i.d. with
    ``straggler_rate``.
    """

    seed: int = 0
    base: float = 0.4          # mean availability rate
    amplitude: float = 0.25    # diurnal swing around base
    period: float = 24.0       # rounds per diurnal cycle
    burst_prob: float = 0.05   # P[a round has a correlated dropout burst]
    burst_frac: float = 0.3    # fraction of clients a burst takes out
    straggler_rate: float = 0.02  # P[an available client never reports]

    def __post_init__(self):
        if not 0.0 < self.base <= 1.0:
            raise ValueError("base must be in (0, 1]")
        if self.amplitude < 0.0:
            raise ValueError("amplitude must be >= 0")
        if self.base - self.amplitude <= 0.0:
            raise ValueError("base - amplitude must stay positive, or whole "
                             "diurnal troughs have an empty cohort")
        if self.period <= 0.0:
            raise ValueError("period must be positive")
        if not 0.0 <= self.burst_prob <= 1.0:
            raise ValueError("burst_prob must be in [0, 1]")
        if not 0.0 <= self.burst_frac <= 1.0:
            raise ValueError("burst_frac must be in [0, 1]")
        if not 0.0 <= self.straggler_rate < 1.0:
            raise ValueError("straggler_rate must be in [0, 1)")

    def max_rate(self) -> float:
        """An upper bound on any client's availability rate in any round —
        the value to hand ``EngineConfig.participation`` for cohort
        capacity sizing (the binomial at this rate dominates the trace's
        heterogeneous draw)."""
        return min(1.0, self.base + self.amplitude)

    def _key(self):
        return jax.random.PRNGKey(self.seed)


class FleetMasks(NamedTuple):
    """One round's fleet state over a set of clients (float {0,1} vectors):
    ``available`` — sampled into the round; ``returned`` — available AND
    not a straggler (the clients whose deltas actually arrive)."""

    available: jax.Array
    returned: jax.Array


def _per_client_uniform(key: jax.Array, client_ids: jax.Array) -> jax.Array:
    """One uniform per client, folded in by *global* id — regeneration of
    any subset, in any batch shape, yields the same bits (the same idiom
    as the data layer's per-client row chain)."""
    return jax.vmap(
        lambda c: jax.random.uniform(jax.random.fold_in(key, c)))(client_ids)


def availability_rate(trace: FleetTrace, r: jax.Array,
                      client_ids: jax.Array) -> jax.Array:
    """p_k(r) — each client's availability probability this round, after
    the diurnal curve and any round-level burst."""
    r = jnp.asarray(r, jnp.int32)
    client_ids = jnp.asarray(client_ids, jnp.uint32)
    base_key = trace._key()
    phase = _per_client_uniform(jax.random.fold_in(base_key, _PHASE_TAG),
                                client_ids)
    t = r.astype(jnp.float32) / jnp.float32(trace.period)
    rate = trace.base + trace.amplitude * jnp.sin(
        2.0 * math.pi * (t + phase))
    rate = jnp.clip(rate, 0.0, 1.0)
    if trace.burst_prob > 0.0 and trace.burst_frac > 0.0:
        rk = jax.random.fold_in(jax.random.fold_in(base_key, _BURST_TAG), r)
        burst = jax.random.uniform(rk) < trace.burst_prob
        hit = _per_client_uniform(
            jax.random.fold_in(jax.random.fold_in(base_key, _BURST_HIT_TAG),
                               r),
            client_ids) < trace.burst_frac
        rate = jnp.where(burst & hit, 0.0, rate)
    return rate


def availability_mask(trace: FleetTrace, r: jax.Array,
                      client_ids: jax.Array) -> jax.Array:
    """1.0 where client k is sampled into round r."""
    r = jnp.asarray(r, jnp.int32)
    client_ids = jnp.asarray(client_ids, jnp.uint32)
    u = _per_client_uniform(
        jax.random.fold_in(jax.random.fold_in(trace._key(), _AVAIL_TAG), r),
        client_ids)
    return (u < availability_rate(trace, r, client_ids)).astype(jnp.float32)


def straggler_flags(trace: FleetTrace, r: jax.Array,
                    client_ids: jax.Array) -> jax.Array:
    """1.0 where client k *would* straggle this round if sampled —
    independent of the availability draw (separate tag chain)."""
    r = jnp.asarray(r, jnp.int32)
    client_ids = jnp.asarray(client_ids, jnp.uint32)
    if trace.straggler_rate <= 0.0:
        return jnp.zeros(client_ids.shape, jnp.float32)
    u = _per_client_uniform(
        jax.random.fold_in(jax.random.fold_in(trace._key(), _STRAGGLER_TAG),
                           r),
        client_ids)
    return (u < trace.straggler_rate).astype(jnp.float32)


def fleet_masks(trace: FleetTrace, r: jax.Array,
                client_ids: jax.Array) -> FleetMasks:
    """The round's (available, returned) masks over ``client_ids``.

    ``returned = available * (1 - straggler)`` is the dropout-after-compute
    composition: a straggler is a *sampled* client whose delta is zeroed
    after its pass — and since a zero-weight delta contributes exactly
    nothing to the aggregate, handing the engine the ``returned`` mask is
    bit-identical to running the straggler's pass and discarding it (the
    cohort path exploits this to skip the doomed compute outright).
    """
    r = jnp.asarray(r, jnp.int32)
    client_ids = jnp.asarray(client_ids, jnp.uint32)
    avail = availability_mask(trace, r, client_ids)
    returned = avail * (1.0 - straggler_flags(trace, r, client_ids))
    return FleetMasks(available=avail, returned=returned)
