from repro.checkpoint.checkpoint import ChecksumError, restore, save

__all__ = ["ChecksumError", "restore", "save"]
