"""Pytree checkpointing (npz payload + json manifest).

Layout:  <path>/manifest.json  — treedef, step, user metadata, leaf index
         <path>/arrays.npz     — one entry per leaf ("leaf_<i>")

Works for params, optimizer states, FSVRG server state.  bf16 leaves are
stored via a uint16 view (npz has no bfloat16) and restored exactly.
Sharded arrays are gathered to host before saving (fine at the scale this
container runs; a production TPU deployment would swap in per-shard files —
the manifest format already records per-leaf dtype/shape to allow that).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _to_numpy(leaf) -> Tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(leaf))
    dtype = str(leaf.dtype)
    if dtype == "bfloat16":
        arr = arr.view(np.uint16)
    return arr, dtype


def _from_numpy(arr: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return jnp.asarray(arr).view(jnp.bfloat16)
    return jnp.asarray(arr, dtype=dtype)


def save(path: str, tree: Any, *, step: int = 0,
         metadata: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    payload = {}
    index = []
    for i, leaf in enumerate(leaves):
        arr, dtype = _to_numpy(leaf)
        payload[f"leaf_{i}"] = arr
        index.append({"dtype": dtype, "shape": list(arr.shape)})
    np.savez(os.path.join(path, "arrays.npz"), **payload)
    manifest = {
        "treedef": str(treedef),
        "step": step,
        "metadata": metadata or {},
        "leaves": index,
        "format_version": 1,
    }
    # structure for reconstruction: store the pytree as nested keys
    paths = [jax.tree_util.keystr(kp)
             for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    manifest["paths"] = paths
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # treedef is reconstructed from an example tree: persist via pickle-free
    # nested-dict rebuild (paths are keystrs like "['a']['b']")
    with open(os.path.join(path, "treedef.json"), "w") as f:
        json.dump({"paths": paths}, f)


def _set_path(root: Dict, keystr_path: str, value) -> None:
    import re
    keys = re.findall(r"\['([^']+)'\]|\[(\d+)\]", keystr_path)
    node = root
    flat_keys = [k or int(i) for k, i in keys]
    for k in flat_keys[:-1]:
        node = node.setdefault(k, {})
    node[flat_keys[-1]] = value


def restore(path: str) -> Tuple[Any, Dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    root: Dict = {}
    for i, (meta, kp) in enumerate(zip(manifest["leaves"], manifest["paths"])):
        leaf = _from_numpy(data[f"leaf_{i}"], meta["dtype"])
        _set_path(root, kp, leaf)
    root = _listify(root)
    return root, {"step": manifest["step"], "metadata": manifest["metadata"]}


def _listify(node):
    """Convert int-keyed dicts (from list/tuple indices) back to lists."""
    if isinstance(node, dict):
        if node and all(isinstance(k, int) for k in node):
            return [_listify(node[i]) for i in sorted(node)]
        return {k: _listify(v) for k, v in node.items()}
    return node
