"""Pytree checkpointing (npz payload + json manifest).

Layout:  <path>/manifest.json  — treedef, step, user metadata, leaf index
         <path>/arrays.npz     — one entry per leaf ("leaf_<i>")

Works for params, optimizer states, FSVRG server state.  bf16 leaves are
stored via a uint16 view (npz has no bfloat16) and restored exactly.
Sharded arrays are gathered to host before saving (fine at the scale this
container runs; a production TPU deployment would swap in per-shard files —
the manifest format already records per-leaf dtype/shape to allow that).

Manifest format v2 stores each leaf's key path *structurally* — a list of
``[kind, key]`` pairs where kind is ``"d"`` (dict key), ``"s"`` (sequence
index), ``"a"`` (attribute name), or ``"i"`` (flattened index).  The v1
format stored only ``jax.tree_util.keystr`` strings, which cannot tell a
list index ``[0]`` from an int dict key ``[0]`` (so restore silently
converted int-keyed dicts to lists) and indexed into an empty key list for
a bare-array pytree (root leaf, keystr ``""`` → IndexError).  v1
checkpoints still restore through the legacy string parser.

Saves are **atomic**: a save interrupted at any point (SIGKILL mid-write —
the campaign runner's crash model) leaves the previous checkpoint fully
restorable.  The payload goes to a step-unique ``arrays-<step>.npz``
written via a temp file + ``os.replace``; the manifest (which records the
payload filename in ``arrays_file``) is replaced *last*, so the manifest
on disk always references a payload that was completely written before
the manifest became visible.  Superseded payload files are deleted only
after the new manifest is committed (a crash in between leaves an unused
extra file, never a broken checkpoint).  Pre-atomic checkpoints (a plain
``arrays.npz``, no ``arrays_file`` key) still restore.

Manifest format v3 adds ``payload_crc32``: the CRC-32 of the complete npz
payload bytes, computed at save time and verified on restore — a torn or
bit-rotted ``arrays-<step>.npz`` (the failure the atomic-rename protocol
cannot see, e.g. filesystem corruption after the commit) raises a clear
``ChecksumError`` instead of restoring garbage iterates.  v1/v2 manifests
have no checksum and restore exactly as before.
"""
from __future__ import annotations

import io
import json
import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ChecksumError(RuntimeError):
    """The payload on disk does not match the checksum its manifest
    recorded at save time."""


def _to_numpy(leaf) -> Tuple[np.ndarray, str]:
    arr = np.asarray(jax.device_get(leaf))
    dtype = str(leaf.dtype)
    if dtype == "bfloat16":
        arr = arr.view(np.uint16)
    return arr, dtype


def _from_numpy(arr: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return jnp.asarray(arr).view(jnp.bfloat16)
    return jnp.asarray(arr, dtype=dtype)


def _encode_key_path(kp) -> List[List[Any]]:
    """A leaf's key path as JSON-safe ``[kind, key]`` pairs — the
    disambiguation the keystr strings lose (list index vs int dict key)."""
    out: List[List[Any]] = []
    for entry in kp:
        if isinstance(entry, jax.tree_util.DictKey):
            out.append(["d", entry.key])
        elif isinstance(entry, jax.tree_util.SequenceKey):
            out.append(["s", entry.idx])
        elif isinstance(entry, jax.tree_util.GetAttrKey):
            out.append(["a", entry.name])
        elif isinstance(entry, jax.tree_util.FlattenedIndexKey):
            out.append(["i", entry.key])
        else:  # pragma: no cover - future key types degrade to their repr
            out.append(["d", str(entry)])
    return out


def _replace_file(path: str, write_fn) -> None:
    """Write via a same-directory temp file, then atomically rename over
    ``path``.  ``write_fn`` receives an open binary-mode file object."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def save(path: str, tree: Any, *, step: int = 0,
         metadata: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    flat_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {}
    index = []
    for i, leaf in enumerate(leaves):
        arr, dtype = _to_numpy(leaf)
        payload[f"leaf_{i}"] = arr
        index.append({"dtype": dtype, "shape": list(arr.shape)})
    # Step-unique payload name: the old manifest keeps referencing the old
    # payload until the new manifest lands, so a kill at any point leaves a
    # consistent (manifest, payload) pair on disk.
    arrays_file = f"arrays-{step:09d}.npz"
    # serialize once to memory so the manifest can record the checksum of
    # exactly the bytes that hit disk
    blob = io.BytesIO()
    np.savez(blob, **payload)
    payload_bytes = blob.getvalue()
    payload_crc32 = zlib.crc32(payload_bytes)
    _replace_file(os.path.join(path, arrays_file),
                  lambda f: f.write(payload_bytes))
    # structure for reconstruction: keystrs stay for human inspection (and
    # v1 readers); key_paths carry the [kind, key] pairs restore uses
    paths = [jax.tree_util.keystr(kp) for kp, _ in flat_with_path]
    key_paths = [_encode_key_path(kp) for kp, _ in flat_with_path]
    manifest = {
        "treedef": str(treedef),
        "step": step,
        "metadata": metadata or {},
        "leaves": index,
        "format_version": 3,
        "paths": paths,
        "key_paths": key_paths,
        "arrays_file": arrays_file,
        "payload_crc32": payload_crc32,
    }
    # treedef is reconstructed from an example tree: persist via pickle-free
    # nested-dict rebuild
    _replace_file(os.path.join(path, "treedef.json"),
                  lambda f: f.write(json.dumps(
                      {"paths": paths, "key_paths": key_paths}).encode()))
    # manifest last — its replacement is the commit point
    _replace_file(os.path.join(path, "manifest.json"),
                  lambda f: f.write(json.dumps(manifest).encode()))
    # best-effort cleanup of superseded payloads (post-commit, so a crash
    # here only leaves an unused extra file)
    for name in os.listdir(path):
        stale = (name == "arrays.npz"
                 or (name.startswith("arrays-") and name.endswith(".npz")
                     and name != arrays_file))
        if stale:
            try:
                os.remove(os.path.join(path, name))
            except OSError:  # pragma: no cover - cleanup is advisory
                pass


# --------------------------------------------------------------------- #
# v2 reconstruction: kind-tagged paths -> nested dicts / lists
# --------------------------------------------------------------------- #


def _build_from_key_paths(key_paths, leaves):
    if len(leaves) == 1 and not key_paths[0]:
        # bare-array pytree: the root IS the leaf (v1 crashed here)
        return leaves[0]
    root: Dict = {}
    for kp, leaf in zip(key_paths, leaves):
        node = root
        for kind, key in kp[:-1]:
            node = node.setdefault((kind, key), {})
        kind, key = kp[-1]
        node[(kind, key)] = leaf
    return _finish(root)


def _finish(node):
    """Collapse the (kind, key)-keyed build dicts into their containers:
    "s"/"i" kinds become lists (sorted by index), "d"/"a" become dicts —
    an int-keyed dict stays a dict because its kind says so."""
    if not isinstance(node, dict):
        return node
    kinds = {kind for kind, _ in node}
    if kinds <= {"s", "i"}:
        idxs = sorted(key for _, key in node)
        if idxs != list(range(len(idxs))):  # pragma: no cover - corrupt file
            raise ValueError(f"non-contiguous sequence indices: {idxs}")
        return [_finish(node[(kind, i)]) for i in idxs
                for kind in ("s", "i") if (kind, i) in node]
    if kinds & {"s", "i"}:  # pragma: no cover - corrupt file
        raise ValueError("mixed sequence/dict keys at one tree node")
    return {key: _finish(v) for (_, key), v in node.items()}


# --------------------------------------------------------------------- #
# v1 fallback: parse keystr strings (list index vs int dict key is
# ambiguous there — int-keyed dicts come back as lists, as they always did)
# --------------------------------------------------------------------- #


def _set_path(root: Dict, keystr_path: str, value) -> None:
    import re
    keys = re.findall(r"\['([^']+)'\]|\[(\d+)\]", keystr_path)
    node = root
    flat_keys = [k or int(i) for k, i in keys]
    for k in flat_keys[:-1]:
        node = node.setdefault(k, {})
    node[flat_keys[-1]] = value


def _listify(node):
    """Convert int-keyed dicts (from list/tuple indices) back to lists."""
    if isinstance(node, dict):
        if node and all(isinstance(k, int) for k in node):
            return [_listify(node[i]) for i in sorted(node)]
        return {k: _listify(v) for k, v in node.items()}
    return node


def restore(path: str) -> Tuple[Any, Dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays_path = os.path.join(path, manifest.get("arrays_file",
                                                  "arrays.npz"))
    expected_crc = manifest.get("payload_crc32")
    if expected_crc is not None:
        with open(arrays_path, "rb") as f:
            payload_bytes = f.read()
        actual_crc = zlib.crc32(payload_bytes)
        if actual_crc != expected_crc:
            raise ChecksumError(
                f"checkpoint payload {arrays_path} is corrupt: "
                f"crc32 {actual_crc:#010x} != manifest's "
                f"{expected_crc:#010x} — the file was torn or bit-rotted "
                "after the atomic commit")
        data = np.load(io.BytesIO(payload_bytes))
    else:
        # v1/v2 manifest: no checksum was recorded; load as before
        data = np.load(arrays_path)
    leaves = [_from_numpy(data[f"leaf_{i}"], meta["dtype"])
              for i, meta in enumerate(manifest["leaves"])]
    info = {"step": manifest["step"], "metadata": manifest["metadata"]}
    if manifest.get("key_paths") is not None:
        return _build_from_key_paths(manifest["key_paths"], leaves), info
    # legacy v1 manifest
    paths = manifest["paths"]
    if len(leaves) == 1 and paths[0] == "":
        return leaves[0], info
    root: Dict = {}
    for kp, leaf in zip(paths, leaves):
        _set_path(root, kp, leaf)
    return _listify(root), info
