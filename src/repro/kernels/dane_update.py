"""Pallas TPU kernel: fused DANE local-subproblem GD step (eq. 10).

The inexact-GD local solver for DANE's subproblem

    w_k = argmin_w F_k(w) − a_kᵀ w + (µ/2)||w − w^t||²,
    a_k = ∇F_k(w^t) − η ∇f(w^t)

iterates  w ← w − h (∇F_k(w) − a_k + µ(w − w^t)).  Splitting ∇F_k into its
sparse data part g and the dense L2 part λw, one step is

    w ← (1 − h(λ+µ)) · w − h · g + h · a_k + h · µ · w^t

— four dense d-vectors combined with three scalars.  Unfused, the gradient
perturbation (−a_k), the prox pull (µ(w − w^t)), and the weight decay each
make their own pass with intermediates; the fused kernel makes exactly one
VMEM pass (4 reads, 1 write — VPU-bound, zero intermediates), executed
``local_steps`` times per client per round.  Passing h = 0 is an exact
no-op.

Tiling: the parameter vector is viewed as (rows, 128) and blocked
(BLOCK_ROWS, 128) — lane-dim 128 with (8,128)-aligned sublanes, the native
VREG layout for f32/bf16 elementwise work (same discipline as
``fedavg_update.py`` / ``fsvrg_update.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256          # (256, 128) f32 tile = 128 KiB / input buffer


def _dane_update_kernel(w_ref, g_ref, a_ref, wt_ref, lr_ref, lam_ref, mu_ref,
                        out_ref):
    lr = lr_ref[0, 0]
    lam = lam_ref[0, 0]
    mu = mu_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    wt = wt_ref[...].astype(jnp.float32)
    out = (1.0 - lr * (lam + mu)) * w - lr * g + lr * a + lr * mu * wt
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def dane_update(w, g, a, w_t, lr, lam, mu, *, block_rows: int = BLOCK_ROWS,
                interpret: bool = False):
    """w, g, a, w_t are 1-D of equal length; lr, lam, mu are scalars.

    Computes ``(1 − lr(λ+µ))·w − lr·g + lr·a + lr·µ·w_t`` — one inexact-GD
    step on DANE's local subproblem, with g the sparse data-gradient part of
    ∇F_k(w).  Pads to a (rows, 128) grid internally; returns the updated w
    (same shape and dtype as the input).
    """
    (d,) = w.shape
    rows = -(-d // LANE)
    rows_pad = -(-rows // block_rows) * block_rows
    padded = rows_pad * LANE

    def pad2(x):
        x = jnp.pad(x, (0, padded - d))
        return x.reshape(rows_pad, LANE)

    w2, g2, a2, wt2 = pad2(w), pad2(g), pad2(a), pad2(w_t)
    scalars = [jnp.asarray(s, jnp.float32).reshape(1, 1) for s in (lr, lam, mu)]

    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _dane_update_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec, s_spec, s_spec, s_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANE), w.dtype),
        interpret=interpret,
    )(w2, g2, a2, wt2, *scalars)
    return out.reshape(-1)[:d]
