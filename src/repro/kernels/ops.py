"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs as traced JAX ops, validating indexing/accumulation logic
against ``ref.py``.  On TPU backends the same call sites compile to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.cocoa_sdca import cocoa_sdca_update as _cocoa_sdca_update
from repro.kernels.dane_update import dane_update as _dane_update
from repro.kernels.fedavg_update import fedavg_update as _fedavg_update
from repro.kernels.fsvrg_update import fsvrg_update as _fsvrg_update
from repro.kernels.robust_aggregate import robust_aggregate as _robust_aggregate
from repro.kernels.scaled_aggregate import fused_accumulate as _fused_accumulate
from repro.kernels.scaled_aggregate import fused_aggregate as _fused_aggregate
from repro.kernels.scaled_aggregate import fused_epilogue as _fused_epilogue
from repro.kernels.scaled_aggregate import scaled_aggregate as _scaled_aggregate
from repro.kernels.wkv6 import wkv6 as _wkv6


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fsvrg_update(w, s, g_new, g_old, g_bar, h, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _fsvrg_update(w, s, g_new, g_old, g_bar, h, **kw)


def fedavg_update(w, g, h, lam, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _fedavg_update(w, g, h, lam, **kw)


def dane_update(w, g, a, w_t, lr, lam, mu, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _dane_update(w, g, a, w_t, lr, lam, mu, **kw)


def cocoa_sdca_update(beta0, mcoef, ccoef, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _cocoa_sdca_update(beta0, mcoef, ccoef, **kw)


def scaled_aggregate(w_t, w_ks, weights, a_diag, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _scaled_aggregate(w_t, w_ks, weights, a_diag, **kw)


def fused_aggregate(w_t, deltas, weights, a_diag, scale=1.0, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _fused_aggregate(w_t, deltas, weights, a_diag, scale, **kw)


def fused_accumulate(acc, deltas, weights, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _fused_accumulate(acc, deltas, weights, **kw)


def fused_epilogue(w_t, acc, a_diag, scale=1.0, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _fused_epilogue(w_t, acc, a_diag, scale, **kw)


def robust_aggregate(w_t, deltas, valid, a_diag, trim=0.1,
                     mode="trimmed_mean", **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _robust_aggregate(w_t, deltas, valid, a_diag, trim, mode, **kw)


def wkv6(r, k, v, w, u, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return _wkv6(r, k, v, w, u, **kw)
