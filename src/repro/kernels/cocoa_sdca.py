"""Pallas TPU kernel: CoCoA+ local-SDCA dual-coordinate update (eq. 15).

For logistic loss with y ∈ {−1,1} the dual variable is parametrized
β_i = y_i α_i ∈ (0,1) and the per-coordinate SDCA subproblem is

    min_{β∈(0,1)}  m_i (β − β₀) + c_i (β − β₀)² + H(β),
    H(β) = β log β + (1−β) log(1−β),

with m_i the margin under the σ′-shifted iterate and c_i = σ′||x_i||²/(2λn).
There is no closed form; the solver is a fixed-iteration clipped Newton from
β = clip(sigmoid(−m)).  The kernel fuses the whole Newton recursion — log,
reciprocal, clip, NEWTON_ITERS times — over a vector of independent
coordinates in registers: one VMEM pass over (β₀, m, c) regardless of the
iteration count, instead of 3·NEWTON_ITERS elementwise passes.  Inside a
client round this is the β-solve for the vmapped client batch (every client
updates its own coordinate of the permutation in lockstep), the innermost
hot loop of the CoCoA+ round.

Tiling: inputs are viewed as (rows, 128) and blocked (BLOCK_ROWS, 128) —
the native VREG layout for f32 elementwise work, same discipline as the
other update kernels.  Padded slots are seeded with β₀ = 1/2, m = c = 0,
which the Newton iteration maps to harmless interior values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256
NEWTON_ITERS = 12
_EPS = 1e-6


def _cocoa_sdca_kernel(newton_iters, b0_ref, m_ref, c_ref, out_ref):
    beta0 = b0_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)

    def newton_step(_, b):
        gb = m + 2.0 * c * (b - beta0) + jnp.log(b / (1.0 - b))
        hb = 2.0 * c + 1.0 / (b * (1.0 - b))
        return jnp.clip(b - gb / hb, _EPS, 1.0 - _EPS)

    b = jnp.clip(jax.nn.sigmoid(-m), _EPS, 1.0 - _EPS)
    b = jax.lax.fori_loop(0, newton_iters, newton_step, b)
    out_ref[...] = b.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("newton_iters", "block_rows", "interpret"))
def cocoa_sdca_update(beta0, mcoef, ccoef, *, newton_iters: int = NEWTON_ITERS,
                      block_rows: int = BLOCK_ROWS, interpret: bool = False):
    """beta0, mcoef, ccoef are 1-D of equal length; returns the new β vector
    (same shape and dtype as beta0), each coordinate solved independently.

    Pads to a (rows, 128) grid internally; β₀ pads with 1/2 so the Newton
    entropy terms stay finite on dead lanes.
    """
    (d,) = beta0.shape
    rows = -(-d // LANE)
    # the production call site hands (Kb,)-sized client batches — clamp the
    # block to the data (8-sublane minimum) instead of padding tiny inputs
    # out to a full 256-row tile of dead lanes
    block_rows = min(block_rows, max(8, rows))
    rows_pad = -(-rows // block_rows) * block_rows
    padded = rows_pad * LANE

    def pad2(x, fill):
        x = jnp.pad(x, (0, padded - d), constant_values=fill)
        return x.reshape(rows_pad, LANE)

    b2 = pad2(beta0, 0.5)
    m2 = pad2(mcoef, 0.0)
    c2 = pad2(ccoef, 0.0)

    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_cocoa_sdca_kernel, newton_iters),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANE), beta0.dtype),
        interpret=interpret,
    )(b2, m2, c2)
    return out.reshape(-1)[:d]
