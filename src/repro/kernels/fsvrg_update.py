"""Pallas TPU kernel: fused FSVRG inner-loop update (Alg. 4 line 8).

    w ← w − h · (S ⊙ (g_new − g_old) + ḡ)

This is the paper's compute hot spot: executed n_k times per client per
round over the full d-dimensional iterate.  Unfused, the expression reads
w, s, g_new, g_old, ḡ and writes w with 4 intermediate buffers; the fused
kernel makes exactly one VMEM pass (5 reads, 1 write — VPU-bound, zero
intermediates), which is the TPU adaptation of the paper's "cheap local
iterations" requirement (DESIGN.md §3).

Tiling: the parameter vector is viewed as (rows, 128) and blocked
(BLOCK_ROWS, 128) — lane-dim 128 with (8,128)-aligned sublanes, the native
VREG layout for f32/bf16 elementwise work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256          # (256, 128) f32 tile = 128 KiB / input buffer


def _fsvrg_update_kernel(w_ref, s_ref, gnew_ref, gold_ref, gbar_ref, h_ref, out_ref):
    h = h_ref[0, 0]
    diff = gnew_ref[...].astype(jnp.float32) - gold_ref[...].astype(jnp.float32)
    upd = s_ref[...].astype(jnp.float32) * diff + gbar_ref[...].astype(jnp.float32)
    out_ref[...] = (w_ref[...].astype(jnp.float32) - h * upd).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fsvrg_update(w, s, g_new, g_old, g_bar, h, *, block_rows: int = BLOCK_ROWS,
                 interpret: bool = False):
    """All array args are 1-D of equal length; h is a scalar.

    Pads to a (rows, 128) grid internally; returns the updated w (same shape
    and dtype as the input).
    """
    (d,) = w.shape
    rows = -(-d // LANE)
    rows_pad = -(-rows // block_rows) * block_rows
    padded = rows_pad * LANE

    def pad2(x):
        x = jnp.pad(x, (0, padded - d))
        return x.reshape(rows_pad, LANE)

    w2, s2, gn2, go2, gb2 = map(pad2, (w, s, g_new, g_old, g_bar))
    h_arr = jnp.asarray(h, jnp.float32).reshape(1, 1)

    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    h_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _fsvrg_update_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec, spec, h_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANE), w.dtype),
        interpret=interpret,
    )(w2, s2, gn2, go2, gb2, h_arr)
    return out.reshape(-1)[:d]
