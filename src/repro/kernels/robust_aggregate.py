"""Pallas TPU kernel: coordinate-wise robust server aggregation.

    w ← w^t + A ⊙ robust_agg({δ_k : valid_k}),

where ``robust_agg`` is the coordinate-wise **trimmed mean** (drop the
``trim``-fraction smallest and largest values per coordinate, average the
rest) or **median** over the valid clients' deltas.  This is the
order-statistic arm of ``EngineConfig.aggregator_guard``: unlike the
weighted sum, a bounded fraction of adversarial or corrupted deltas
cannot move the aggregate arbitrarily far.

Order statistics need the whole client axis at once, so the grid is
(d_blocks,) with every program sorting its own (K, d_block) column block
— the revisiting-output trick the weighted-sum kernel uses does not apply
(a sort cannot be folded one chunk at a time), which is exactly why the
engine rejects ``aggregator_guard="trimmed_mean"`` on the streamed path.
Invalid rows are replaced with +inf before the sort, so they land past
``hi`` and never enter the averaged rank window; the dynamic valid count
``m`` turns the rank window into a mask, so one kernel serves both modes:

    trimmed mean:  lo = floor(trim·m),  hi = m − lo
    median:        lo = (m−1)//2,       hi = m//2 + 1   (1- or 2-rank mean)

VMEM note: a (K, d_block) f32 block is K·d_block·4 bytes — at the paper's
K=10,000 the default d_block=128 keeps a block at ~5 MB.  On CPU (this
container) the kernel runs in interpret mode for the parity tests; the
engine's hot path resolves the identical jnp oracle
(:func:`repro.kernels.ref.robust_aggregate_ref`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

D_BLOCK = 128

MODES = ("trimmed_mean", "median")


def _robust_kernel(mode, trim, wt_ref, dk_ref, valid_ref, a_ref, out_ref):
    deltas = dk_ref[...].astype(jnp.float32)        # (K, d_block)
    valid = valid_ref[...].astype(jnp.float32)      # (K, 1)
    x = jnp.where(valid > 0, deltas, jnp.inf)       # invalid rows sort last
    xs = jnp.sort(x, axis=0)
    m = valid.sum().astype(jnp.int32)
    if mode == "median":
        lo = (m - 1) // 2
        hi = m // 2 + 1
    else:
        lo = jnp.floor(jnp.float32(trim)
                       * m.astype(jnp.float32)).astype(jnp.int32)
        hi = m - lo
    ranks = jax.lax.broadcasted_iota(jnp.int32, xs.shape, 0)
    inc = (ranks >= lo) & (ranks < hi)
    cnt = jnp.maximum(hi - lo, 1).astype(jnp.float32)
    agg = jnp.where(inc, xs, 0.0).sum(axis=0) / cnt
    agg = jnp.where(m > 0, agg, 0.0)                # empty round: no update
    out_ref[...] = (wt_ref[...].astype(jnp.float32)
                    + a_ref[...].astype(jnp.float32) * agg)


@functools.partial(jax.jit,
                   static_argnames=("trim", "mode", "d_block", "interpret"))
def robust_aggregate(w_t, deltas, valid, a_diag, trim: float = 0.1,
                     mode: str = "trimmed_mean", *,
                     d_block: int = D_BLOCK, interpret: bool = False):
    """w_t, a_diag: (d,); deltas: (K, d) client deltas; valid: (K,) bool or
    {0,1} — rows excluded from the order statistics when 0 (non-participants
    and guard-rejected non-finite deltas)."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}")
    if not 0.0 <= trim < 0.5:
        raise ValueError("trim must be in [0, 0.5)")
    K, d = deltas.shape
    d_pad = -(-d // d_block) * d_block

    wt2 = jnp.pad(w_t, (0, d_pad - d))
    a2 = jnp.pad(a_diag, (0, d_pad - d))
    dk2 = jnp.pad(deltas, ((0, 0), (0, d_pad - d)))
    v2 = valid.astype(jnp.float32).reshape(K, 1)

    grid = (d_pad // d_block,)
    out = pl.pallas_call(
        functools.partial(_robust_kernel, mode, trim),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_block,), lambda i: (i,)),      # w_t
            pl.BlockSpec((K, d_block), lambda i: (0, i)),  # deltas (all K)
            pl.BlockSpec((K, 1), lambda i: (0, 0)),        # valid
            pl.BlockSpec((d_block,), lambda i: (i,)),      # a_diag
        ],
        out_specs=pl.BlockSpec((d_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        interpret=interpret,
    )(wt2, dk2, v2, a2)
    return out[:d]
