"""Pallas TPU kernel: delta-native fused server aggregation (Alg. 4 line 11).

    w ← w^t + A ⊙ (s · Σ_k weights_k · δ_k),      δ_k = w_k − w^t

The kernel consumes the stacked client-delta matrix Δ: (K, d) *directly* —
exactly what every engine client pass produces — so the server update is one
true HBM pass over Δ.  The pre-delta-native kernel consumed the iterate
matrix W = w^t + Δ instead, which forced the caller to materialize a full
(K, d) add (an extra HBM round-trip) only so the kernel could subtract
(Σ weights)·w^t back out.  The unbiased-participation reweight scalar ``s``
and the per-coordinate A-diagonal epilogue are folded into the same pass:
weighting, reweighting, scaling, and the server update all happen while each
output tile is VMEM-resident.

Grid: (d_blocks, k_blocks) — k is the *inner* (minor) dimension so each
output tile stays resident in VMEM across the whole client reduction
(revisiting-output accumulation pattern), accumulating in f32.

:func:`scaled_aggregate` (the iterate-consuming entry point) survives as a
thin compatibility wrapper; its pure-jnp oracle stays in ``kernels/ref.py``
alongside the new :func:`~repro.kernels.ref.fused_aggregate_ref`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
K_BLOCK = 8
D_BLOCK = 512


def _fused_kernel(k_block, wt_ref, dk_ref, wts_ref, s_ref, a_ref, out_ref):
    kb = pl.program_id(1)
    block_wts = jax.lax.dynamic_slice_in_dim(
        wts_ref[...].reshape(-1), kb * k_block, k_block).astype(jnp.float32)
    partial = jnp.einsum(
        "kd,k->d",
        dk_ref[...].astype(jnp.float32),
        block_wts,
        preferred_element_type=jnp.float32,
    )

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(kb > 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial

    @pl.when(kb == pl.num_programs(1) - 1)
    def _final():
        # out_ref holds Σ_k weights_k·δ_k; the whole epilogue — reweight
        # scalar s, A diagonal, and the +w^t server update — lands here while
        # the tile is still VMEM-resident.
        s = s_ref[0, 0].astype(jnp.float32)
        out_ref[...] = (wt_ref[...].astype(jnp.float32)
                        + a_ref[...].astype(jnp.float32) * (s * out_ref[...]))


@functools.partial(jax.jit, static_argnames=("k_block", "d_block", "interpret"))
def fused_aggregate(w_t, deltas, weights, a_diag, scale=1.0, *,
                    k_block: int = K_BLOCK, d_block: int = D_BLOCK,
                    interpret: bool = False):
    """w_t, a_diag: (d,); deltas: (K, d) client deltas w_k − w^t;
    weights: (K,); scale: scalar reweight (1.0 under full participation)."""
    K, d = deltas.shape
    k_block = min(k_block, K)
    d_pad = -(-d // d_block) * d_block
    K_pad = -(-K // k_block) * k_block

    wt2 = jnp.pad(w_t, (0, d_pad - d))
    a2 = jnp.pad(a_diag, (0, d_pad - d))
    dk2 = jnp.pad(deltas, ((0, K_pad - K), (0, d_pad - d)))
    wts2 = jnp.pad(weights, (0, K_pad - K)).reshape(K_pad, 1)
    s2 = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    grid = (d_pad // d_block, K_pad // k_block)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, k_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_block,), lambda i, k: (i,)),            # w_t
            pl.BlockSpec((k_block, d_block), lambda i, k: (k, i)),  # deltas
            pl.BlockSpec((K_pad, 1), lambda i, k: (0, 0)),          # all weights
            pl.BlockSpec((1, 1), lambda i, k: (0, 0)),              # reweight s
            pl.BlockSpec((d_block,), lambda i, k: (i,)),            # a_diag
        ],
        out_specs=pl.BlockSpec((d_block,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        interpret=interpret,
    )(wt2, dk2, wts2, s2, a2)
    return out[:d]


def fused_accumulate(acc, deltas, weights, **kw):
    """Chunk-accumulating entry: acc + Σ_k weights_k·δ_k over one client
    chunk.

    Reuses the kernel's init/acc/epilogue split with an *identity* epilogue
    (w^t = acc, A = 1, s = 1): the streamed round
    (``EngineConfig.client_chunk``) feeds each (chunk, d) delta block through
    this entry, so peak delta memory is O(chunk·d) instead of O(K·d)."""
    return fused_aggregate(acc, deltas, weights, jnp.ones_like(acc), 1.0, **kw)


def fused_epilogue(w_t, acc, a_diag, scale=1.0, **kw):
    """Epilogue-only entry: w^t + A ⊙ (s · acc), with ``acc`` the streamed
    weighted delta sum — the kernel's final grid step applied to a single
    pre-reduced (d,) row."""
    return fused_aggregate(w_t, acc[None, :], jnp.ones((1,), jnp.float32),
                           a_diag, scale, **kw)


def scaled_aggregate(w_t, w_ks, weights, a_diag, **kw):
    """Iterate-consuming compatibility entry: w^t + A ⊙ Σ_k weights_k (w_k − w^t).

    Materializes the (K, d) delta matrix from the stacked iterates and defers
    to :func:`fused_aggregate` — callers with deltas in hand (the engine)
    should call the delta-native kernel directly and skip the subtraction.
    """
    return fused_aggregate(w_t, w_ks - w_t[None, :], weights, a_diag, 1.0, **kw)
