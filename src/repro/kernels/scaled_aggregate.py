"""Pallas TPU kernel: FSVRG server-side scaled aggregation (Alg. 4 line 11).

    w ← w^t + A ⊙ Σ_k (n_k/n) (w_k − w^t)

Input is the K-stacked client-iterate matrix W: (K, d).  The kernel tiles
(K_BLOCK, D_BLOCK) through VMEM and accumulates the weighted reduction over
clients in f32 before applying the per-coordinate A diagonal — one HBM pass
over W instead of the K separate axpy passes of the naive implementation.

Grid: (d_blocks, k_blocks) — k is the *inner* (minor) dimension so each
output tile stays resident in VMEM across the whole client reduction
(revisiting-output accumulation pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
K_BLOCK = 8
D_BLOCK = 512


def _aggregate_kernel(k_block, wt_ref, wks_ref, wts_ref, a_ref, out_ref):
    kb = pl.program_id(1)
    block_wts = jax.lax.dynamic_slice_in_dim(
        wts_ref[...].reshape(-1), kb * k_block, k_block).astype(jnp.float32)
    partial = jnp.einsum(
        "kd,k->d",
        wks_ref[...].astype(jnp.float32),
        block_wts,
        preferred_element_type=jnp.float32,
    )

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(kb > 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial

    @pl.when(kb == pl.num_programs(1) - 1)
    def _final():
        base = wt_ref[...].astype(jnp.float32)
        # out_ref holds Σ_k wts_k·w_k; convert to Σ wts_k (w_k − w^t) by
        # subtracting (Σ wts)·w^t, then apply A and add back w^t.
        total_w = wts_ref[...].astype(jnp.float32).sum()
        delta = out_ref[...] - total_w * base
        out_ref[...] = base + a_ref[...].astype(jnp.float32) * delta


@functools.partial(jax.jit, static_argnames=("k_block", "d_block", "interpret"))
def scaled_aggregate(w_t, w_ks, weights, a_diag, *, k_block: int = K_BLOCK,
                     d_block: int = D_BLOCK, interpret: bool = False):
    """w_t, a_diag: (d,); w_ks: (K, d); weights: (K,) = n_k/n."""
    K, d = w_ks.shape
    k_block = min(k_block, K)
    d_pad = -(-d // d_block) * d_block
    K_pad = -(-K // k_block) * k_block

    wt2 = jnp.pad(w_t, (0, d_pad - d))
    a2 = jnp.pad(a_diag, (0, d_pad - d))
    wks2 = jnp.pad(w_ks, ((0, K_pad - K), (0, d_pad - d)))
    wts2 = jnp.pad(weights, (0, K_pad - K)).reshape(K_pad, 1)

    grid = (d_pad // d_block, K_pad // k_block)
    out = pl.pallas_call(
        functools.partial(_aggregate_kernel, k_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_block,), lambda i, k: (i,)),            # w_t
            pl.BlockSpec((k_block, d_block), lambda i, k: (k, i)),  # w_ks
            pl.BlockSpec((K_pad, 1), lambda i, k: (0, 0)),          # all weights
            pl.BlockSpec((d_block,), lambda i, k: (i,)),            # a_diag
        ],
        out_specs=pl.BlockSpec((d_block,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        interpret=interpret,
    )(wt2, wks2, wts2, a2)
    return out[:d]
