"""Pallas TPU kernel: RWKV-6 chunk-parallel WKV with VMEM-resident state.

The §Perf pair-3 analysis showed the recurrence is HBM-bound under vanilla
XLA: the (D,D) per-head state round-trips HBM every chunk (and, pre-
optimization, every token).  This kernel walks the grid (batch·head major,
chunk minor — TPU grids execute sequentially) and keeps the running state in
a VMEM scratch accumulator across *all* chunks of a (batch, head) pair, so
state traffic to HBM is exactly one write per pair instead of S/L
round-trips.

Per chunk of length L (same math as models/rwkv._wkv_chunked):
    c_t   = Π_{i<=t} w_i                     (cumulative decay, f32)
    intra = [(r ⊙ c_prev)(k/c)^T ⊙ M_strict] v
    bonus = rowsum(r ⊙ u ⊙ k) v
    inter = (r ⊙ c_prev) S
    S    ←  diag(c_L) (S + (k/c)^T v)

Shapes: r,k,v,w: (BH, S, D); out: (BH, S, D); final state (BH, D, D).
L = chunk (default 32; decay-underflow bound, see models/rwkv.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, out_ref, state_out_ref,
                state_scr):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)          # (L, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (D,)
    s = state_scr[...]                        # (D, D)

    L, D = r.shape
    c = jnp.cumprod(w, axis=0)
    c_prev = jnp.concatenate([jnp.ones_like(c[:1]), c[:-1]], axis=0)
    r_t = r * c_prev
    k_t = k / jnp.maximum(c, 1e-30)

    mask = jnp.tril(jnp.ones((L, L), jnp.float32), k=-1)
    scores = jnp.dot(r_t, k_t.T, preferred_element_type=jnp.float32) * mask
    intra = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    inter = jnp.dot(r_t, s, preferred_element_type=jnp.float32)
    out_ref[0] = (intra + bonus + inter).astype(out_ref.dtype)

    s_new = c[-1][:, None] * (s + jnp.dot(k_t.T, v, preferred_element_type=jnp.float32))
    state_scr[...] = s_new

    @pl.when(ci == nc - 1)
    def _final():
        state_out_ref[0] = s_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, *, chunk: int = CHUNK, interpret: bool = False):
    """r,k,v,w: (BH, S, D); u: (BH, D).  Returns out (BH,S,D), state (BH,D,D)."""
    BH, S, D = r.shape
    if S % chunk:
        raise ValueError(f"S={S} must be a multiple of chunk={chunk}")
    nc = S // chunk

    seq_spec = pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0))
    u_spec = pl.BlockSpec((1, D), lambda b, c: (b, 0))
    out_specs = [
        pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),   # out
        pl.BlockSpec((1, D, D), lambda b, c: (b, 0, 0)),       # final state
    ]
    out, state = pl.pallas_call(
        _wkv_kernel,
        grid=(BH, nc),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, u_spec],
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, D), r.dtype),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out, state
