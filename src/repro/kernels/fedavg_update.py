"""Pallas TPU kernel: fused FedAvg local-SGD step (1602.05629 local update).

    w ← (1 − h·λ) · w − h · g        [ = w − h (g + λw) ]

This is FedAvg's compute hot spot: executed n_k·E times per client per round
over the full d-dimensional iterate.  Unfused, the weight-decay multiply and
the gradient axpy each make their own pass with an intermediate buffer; the
fused kernel makes exactly one VMEM pass (2 reads, 1 write — VPU-bound, zero
intermediates), matching the "cheap local iterations" discipline of
``fsvrg_update``.  Passing h = 0 makes the step an exact no-op, which is how
padded permutation slots are masked.

Tiling: the parameter vector is viewed as (rows, 128) and blocked
(BLOCK_ROWS, 128) — lane-dim 128 with (8,128)-aligned sublanes, the native
VREG layout for f32/bf16 elementwise work (same discipline as
``fsvrg_update.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
BLOCK_ROWS = 256          # (256, 128) f32 tile = 128 KiB / input buffer


def _fedavg_update_kernel(w_ref, g_ref, h_ref, lam_ref, out_ref):
    h = h_ref[0, 0]
    lam = lam_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = ((1.0 - h * lam) * w - h * g).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fedavg_update(w, g, h, lam, *, block_rows: int = BLOCK_ROWS,
                  interpret: bool = False):
    """w, g are 1-D of equal length; h, lam are scalars.

    Pads to a (rows, 128) grid internally; returns the updated w (same shape
    and dtype as the input).
    """
    (d,) = w.shape
    rows = -(-d // LANE)
    rows_pad = -(-rows // block_rows) * block_rows
    padded = rows_pad * LANE

    def pad2(x):
        x = jnp.pad(x, (0, padded - d))
        return x.reshape(rows_pad, LANE)

    w2, g2 = pad2(w), pad2(g)
    h_arr = jnp.asarray(h, jnp.float32).reshape(1, 1)
    lam_arr = jnp.asarray(lam, jnp.float32).reshape(1, 1)

    grid = (rows_pad // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        _fedavg_update_kernel,
        grid=grid,
        in_specs=[spec, spec, s_spec, s_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANE), w.dtype),
        interpret=interpret,
    )(w2, g2, h_arr, lam_arr)
    return out.reshape(-1)[:d]
