"""Pure-jnp oracles for the Pallas kernels (the allclose reference)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fsvrg_update_ref(w, s, g_new, g_old, g_bar, h):
    """w − h (S ⊙ (g_new − g_old) + ḡ), computed in f32, cast back."""
    upd = (s.astype(jnp.float32)
           * (g_new.astype(jnp.float32) - g_old.astype(jnp.float32))
           + g_bar.astype(jnp.float32))
    return (w.astype(jnp.float32) - jnp.asarray(h, jnp.float32) * upd).astype(w.dtype)


def fedavg_update_ref(w, g, h, lam):
    """(1 − h·λ)·w − h·g, computed in f32, cast back."""
    h = jnp.asarray(h, jnp.float32)
    lam = jnp.asarray(lam, jnp.float32)
    out = (1.0 - h * lam) * w.astype(jnp.float32) - h * g.astype(jnp.float32)
    return out.astype(w.dtype)


def dane_update_ref(w, g, a, w_t, lr, lam, mu):
    """(1 − lr(λ+µ))·w − lr·g + lr·a + lr·µ·w_t, computed in f32, cast back."""
    lr = jnp.asarray(lr, jnp.float32)
    lam = jnp.asarray(lam, jnp.float32)
    mu = jnp.asarray(mu, jnp.float32)
    out = ((1.0 - lr * (lam + mu)) * w.astype(jnp.float32)
           - lr * g.astype(jnp.float32) + lr * a.astype(jnp.float32)
           + lr * mu * w_t.astype(jnp.float32))
    return out.astype(w.dtype)


def cocoa_sdca_update_ref(beta0, mcoef, ccoef, newton_iters: int = 12):
    """Clipped-Newton solve of the per-coordinate SDCA dual subproblem
    min_β m(β−β₀) + c(β−β₀)² + β log β + (1−β)log(1−β), in f32.

    Also the jnp fallback path of ``repro.core.cocoa._sdca_local_pass``;
    the Newton recursion is a rolled ``fori_loop`` on purpose — a
    Python-unrolled loop embedded in the SDCA scan body blows XLA CPU
    compile time up by two orders of magnitude."""
    eps = 1e-6
    b0 = beta0.astype(jnp.float32)
    m = mcoef.astype(jnp.float32)
    c = ccoef.astype(jnp.float32)

    def it(_, b):
        gb = m + 2.0 * c * (b - b0) + jnp.log(b / (1.0 - b))
        hb = 2.0 * c + 1.0 / (b * (1.0 - b))
        return jnp.clip(b - gb / hb, eps, 1.0 - eps)

    b = jnp.clip(jax.nn.sigmoid(-m), eps, 1.0 - eps)
    b = jax.lax.fori_loop(0, newton_iters, it, b)
    return b.astype(beta0.dtype)


def scaled_aggregate_ref(w_t, w_ks, weights, a_diag):
    """w^t + A ⊙ Σ_k weights_k (w_k − w^t), in f32 — the iterate-consuming
    oracle (the pre-delta-native kernel's entry-point semantics)."""
    wt = w_t.astype(jnp.float32)
    delta = ((w_ks.astype(jnp.float32) - wt[None, :])
             * weights.astype(jnp.float32)[:, None]).sum(axis=0)
    return wt + a_diag.astype(jnp.float32) * delta


def fused_aggregate_ref(w_t, deltas, weights, a_diag, scale=1.0):
    """w^t + A ⊙ (scale · Σ_k weights_k δ_k), in f32 — the delta-native
    oracle, with the participation-reweight scalar in the epilogue."""
    agg = (deltas.astype(jnp.float32)
           * weights.astype(jnp.float32)[:, None]).sum(axis=0)
    return (w_t.astype(jnp.float32)
            + a_diag.astype(jnp.float32) * (jnp.asarray(scale, jnp.float32)
                                            * agg))


def fused_accumulate_ref(acc, deltas, weights):
    """acc + Σ_k weights_k δ_k, in f32 — the chunk-accumulating phase of
    :func:`fused_aggregate_ref` with an identity epilogue.  The streamed
    round (``EngineConfig.client_chunk``) folds each (chunk, d) delta block
    through this so the full (K, d) stack is never materialized."""
    return (acc.astype(jnp.float32)
            + (deltas.astype(jnp.float32)
               * weights.astype(jnp.float32)[:, None]).sum(axis=0))


def fused_epilogue_ref(w_t, acc, a_diag, scale=1.0):
    """w^t + A ⊙ (scale · acc), in f32 — the epilogue-only phase applied to
    a streamed delta-sum accumulator."""
    return (w_t.astype(jnp.float32)
            + a_diag.astype(jnp.float32) * (jnp.asarray(scale, jnp.float32)
                                            * acc.astype(jnp.float32)))


def robust_aggregate_ref(w_t, deltas, valid, a_diag, trim=0.1,
                         mode="trimmed_mean"):
    """w^t + A ⊙ robust_agg({δ_k : valid_k}), in f32 — the order-statistic
    oracle behind ``EngineConfig.aggregator_guard``.  ``robust_agg`` is the
    coordinate-wise trimmed mean (drop the ``trim``-fraction smallest and
    largest per coordinate, average the rest) or median over the valid
    rows; invalid rows (non-participants, guard-rejected non-finite
    deltas) are sorted past the rank window via a +inf sentinel, so the
    dynamic valid count ``m`` sets the window and one expression serves
    both modes (the median is the 1- or 2-rank trimmed mean)."""
    if mode not in ("trimmed_mean", "median"):
        raise ValueError("mode must be 'trimmed_mean' or 'median'")
    x = jnp.where(valid.reshape(-1, 1) > 0, deltas.astype(jnp.float32),
                  jnp.inf)
    xs = jnp.sort(x, axis=0)
    m = valid.astype(jnp.int32).sum()
    if mode == "median":
        lo = (m - 1) // 2
        hi = m // 2 + 1
    else:
        lo = jnp.floor(jnp.asarray(trim, jnp.float32)
                       * m.astype(jnp.float32)).astype(jnp.int32)
        hi = m - lo
    ranks = jnp.arange(xs.shape[0])[:, None]
    inc = (ranks >= lo) & (ranks < hi)
    cnt = jnp.maximum(hi - lo, 1).astype(jnp.float32)
    agg = jnp.where(inc, xs, 0.0).sum(axis=0) / cnt
    agg = jnp.where(m > 0, agg, 0.0)
    return w_t.astype(jnp.float32) + a_diag.astype(jnp.float32) * agg


def wkv6_ref(r, k, v, w, u):
    """Token-by-token WKV-6 recurrence in f32 — the oracle for the
    chunk-parallel ``kernels/wkv6.wkv6``.  Per (batch·head) pair with
    state S ∈ R^{D×D} starting at zero:

        out_t = r_t S + (Σ_i r_ti u_i k_ti) v_t
        S    ← diag(w_t) S + k_t^T v_t

    which is the kernel's chunk math at L = 1 (c = w_t, strict intra
    mask empty).  Shapes match the kernel: r,k,v,w (BH, S, D), u (BH, D);
    returns out (BH, S, D) in r.dtype and the final state (BH, D, D) in
    f32."""
    def one_pair(r, k, v, w, u):
        def step(s, x):
            rt, kt, vt, wt = x
            out = rt @ s + (rt * u * kt).sum() * vt
            return wt[:, None] * s + kt[:, None] * vt[None, :], out
        s0 = jnp.zeros((r.shape[-1], r.shape[-1]), jnp.float32)
        s_fin, out = jax.lax.scan(step, s0, (r, k, v, w))
        return out, s_fin

    out, state = jax.vmap(one_pair)(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u.astype(jnp.float32))
    return out.astype(r.dtype), state
