"""Pure-jnp oracles for the Pallas kernels (the allclose reference)."""
from __future__ import annotations

import jax.numpy as jnp


def fsvrg_update_ref(w, s, g_new, g_old, g_bar, h):
    """w − h (S ⊙ (g_new − g_old) + ḡ), computed in f32, cast back."""
    upd = (s.astype(jnp.float32)
           * (g_new.astype(jnp.float32) - g_old.astype(jnp.float32))
           + g_bar.astype(jnp.float32))
    return (w.astype(jnp.float32) - jnp.asarray(h, jnp.float32) * upd).astype(w.dtype)


def fedavg_update_ref(w, g, h, lam):
    """(1 − h·λ)·w − h·g, computed in f32, cast back."""
    h = jnp.asarray(h, jnp.float32)
    lam = jnp.asarray(lam, jnp.float32)
    out = (1.0 - h * lam) * w.astype(jnp.float32) - h * g.astype(jnp.float32)
    return out.astype(w.dtype)


def scaled_aggregate_ref(w_t, w_ks, weights, a_diag):
    """w^t + A ⊙ Σ_k weights_k (w_k − w^t), in f32."""
    wt = w_t.astype(jnp.float32)
    delta = ((w_ks.astype(jnp.float32) - wt[None, :])
             * weights.astype(jnp.float32)[:, None]).sum(axis=0)
    return wt + a_diag.astype(jnp.float32) * delta
