"""Sparsity-pattern statistics behind FSVRG's S_k and A matrices (§3.6.1).

  n^j   — #examples with nonzero coordinate j
  n_k^j — #examples on client k with nonzero coordinate j
  φ^j   = n^j / n,   φ_k^j = n_k^j / n_k
  s_k^j = φ^j / φ_k^j           (stochastic-gradient scaling, S_k = Diag)
  ω^j   — #clients containing coordinate j
  a^j   = K / ω^j               (aggregation scaling, A = Diag)

S_k is computed *on the fly* inside each client pass (a (d,) scatter per
client) so full-scale K×d storage is never materialized; ω/A are global and
precomputed once here.

``expert_occupancy`` is the MoE analogue used by the federated-LLM bridge:
which experts a client's tokens route to plays the role of which features a
client's examples touch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_feature_counts(flat) -> jax.Array:
    """n^j for a LogRegProblem (or a VirtualFlat, which streams the count
    over regenerated client chunks — integer sums, so the two layouts
    agree exactly)."""
    if hasattr(flat, "feature_counts"):
        return flat.feature_counts()
    present = (flat.val != 0).astype(jnp.float32)
    return jnp.zeros((flat.num_features,)).at[flat.idx].add(present)


def client_feature_counts(idx, val, num_features) -> jax.Array:
    """n_k^j for one client's (m, nnz) rows (padded rows have val==0)."""
    present = (val != 0).astype(jnp.float32)
    return jnp.zeros((num_features,)).at[idx].add(present)


def omega(problem) -> jax.Array:
    """ω^j — #clients whose data touches coordinate j.  Virtual problems
    stream the count over regenerated chunks (exact, same integer sums)."""
    if getattr(problem, "virtual", None) is not None:
        return problem.flat.omega()
    d = problem.d
    om = jnp.zeros((d,))
    for b in problem.buckets:
        cc = jax.vmap(lambda i, v: client_feature_counts(i, v, d))(b.idx, b.val)
        om = om + (cc > 0).sum(axis=0).astype(jnp.float32)
    return om


def aggregation_diag(problem) -> jax.Array:
    """A = Diag(K / ω^j); coordinates on no client get a^j = 1."""
    om = omega(problem)
    K = problem.num_clients
    return jnp.where(om > 0, K / jnp.maximum(om, 1.0), 1.0)


def s_k_diag(phi_global: jax.Array, idx, val, n_k) -> jax.Array:
    """s_k^j = φ^j / φ_k^j for one client; 1 where the client lacks j."""
    d = phi_global.shape[0]
    nkj = client_feature_counts(idx, val, d)
    phi_k = nkj / jnp.maximum(n_k.astype(jnp.float32), 1.0)
    return jnp.where(nkj > 0, phi_global / jnp.maximum(phi_k, 1e-12), 1.0)


def expert_occupancy(router_probs: jax.Array, top_k: int) -> jax.Array:
    """MoE analogue of n_k^j: which experts this client's tokens route to.

    router_probs: (tokens, E) softmax router outputs for one client's batch.
    Returns (E,) counts of tokens whose top-k includes each expert.
    """
    E = router_probs.shape[-1]
    _, topi = jax.lax.top_k(router_probs, top_k)
    onehot = jax.nn.one_hot(topi, E).sum(axis=1)
    return onehot.sum(axis=0)
