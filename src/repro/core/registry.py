"""String-keyed solver registry: ``make_solver("fedavg", problem)``.

Every round-based algorithm registers a factory under a stable name, with
its run defaults pulled lazily from :mod:`repro.configs` — adding an
algorithm is one module with a ``register(...)`` call at the bottom, zero
benchmark edits (``benchmarks/fig2_convergence.py`` and the examples just
loop over names).

``layout`` records which problem layout a factory expects:

  * ``"sparse"`` — the bucketed sparse logreg problem from
    :func:`repro.core.problem.build_problem` (the paper's §4 setting).
  * ``"dense"``  — a :func:`repro.core.problem.build_dense_problem` ridge
    layout (equal n_k for the Appendix-A methods).

Registration happens on import of the algorithm modules; ``make_solver`` /
``available`` force that import, so callers never need to pre-import
``repro.core``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

from repro.core.problem import FederatedLogReg
from repro.core.solver import FederatedSolver

_LAYOUTS = ("sparse", "dense")

#: factory(problem, **kwargs) -> FederatedSolver
SolverFactory = Callable[..., FederatedSolver]

#: defaults() -> dict of factory kwargs (lazy, so repro.configs loads on use)
DefaultsFn = Callable[[], Dict[str, Any]]


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    name: str
    factory: SolverFactory
    layout: str = "sparse"
    defaults: Optional[DefaultsFn] = None
    description: str = ""


_REGISTRY: Dict[str, SolverSpec] = {}


def register(name: str, *, layout: str = "sparse",
             defaults: Optional[DefaultsFn] = None, description: str = ""):
    """Decorator/registrar for a solver factory.

    ``defaults`` is a zero-arg callable returning the factory's default
    kwargs (typically read from a ``repro.configs`` run config); overrides
    passed to :func:`make_solver` win key-by-key.
    """
    if layout not in _LAYOUTS:
        raise ValueError(f"layout must be one of {_LAYOUTS}")

    def deco(factory: SolverFactory) -> SolverFactory:
        if name in _REGISTRY:
            raise ValueError(f"solver {name!r} already registered")
        _REGISTRY[name] = SolverSpec(name=name, factory=factory,
                                     layout=layout, defaults=defaults,
                                     description=description)
        return factory

    return deco


def _populate() -> None:
    """Import the algorithm modules so their ``register`` calls run."""
    import repro.core.baselines  # noqa: F401  (gd)
    import repro.core.cocoa      # noqa: F401  (cocoa, primal, dual)
    import repro.core.dane       # noqa: F401  (dane, dane_ridge)
    import repro.core.fedavg     # noqa: F401  (fedavg)
    import repro.core.fsvrg      # noqa: F401  (fsvrg, svrg_naive)


def get_spec(name: str) -> SolverSpec:
    _populate()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown solver {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def make_solver(name: str, problem: FederatedLogReg,
                **overrides) -> FederatedSolver:
    """Construct a registered solver on ``problem``.

    Defaults come from the spec's config hook; ``overrides`` replace them
    key-by-key (unknown keys fail loudly in the factory/config signature).
    """
    spec = get_spec(name)
    kwargs = dict(spec.defaults()) if spec.defaults is not None else {}
    kwargs.update(overrides)
    return spec.factory(problem, **kwargs)


def available() -> tuple:
    """All registered solver names, sorted."""
    _populate()
    return tuple(sorted(_REGISTRY))
