"""Federated Averaging (McMahan et al., arXiv:1602.05629) on the RoundEngine.

The companion algorithm to the paper's FSVRG: each participating client runs
``local_epochs`` permutation passes of plain SGD on its own data, the server
n_k/n-averages the resulting deltas.  In the 1602.05629 notation this is
B=∞ (full sequential pass per epoch), E=``local_epochs``,
C=``participation``.

One local step on the L2-regularized logistic objective is

    w ← w − h (∇f_i(w) + λ w)  =  (1 − hλ)·w − h·∇f_i(w)

— the compute hot spot, executed n_k·E times per client per round.  On TPU
the dense part (weight-decay multiply + gradient axpy over all d
coordinates) runs as the fused Pallas kernel
:func:`repro.kernels.fedavg_update.fedavg_update` (one VMEM pass, same
(rows, 128) tiling as ``fsvrg_update``); elsewhere it runs as the identical
jnp expression.  Padded permutation slots fold into the kernel's stepsize
(h_eff = 0 ⇒ exact no-op), so validity masking costs nothing extra.

Round scheduling (client sampling, n_k/n vs uniform weighting, partial-
participation reweighting) is entirely the engine's: FedAvg only supplies
the local-SGD client pass.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import EngineConfig, RoundEngine
from repro.core.problem import ClientBucket, FederatedLogReg
from repro.core.registry import register
from repro.core.solver import FederatedSolver, SolverState


@dataclasses.dataclass(frozen=True)
class FedAvgConfig:
    stepsize: float = 0.1          # h, the raw per-step local stepsize
    local_epochs: int = 1          # E: permutation passes per client per round
    participation: float = 1.0     # C: i.i.d. client fraction per round
    use_weighted_agg: bool = True  # n_k/n (True) vs uniform 1/K averaging
    # None -> auto: fused Pallas kernel on TPU, plain jnp elsewhere.
    use_kernel: Optional[bool] = None
    aggregator: str = "dense"      # engine aggregator: "dense" | "pallas"
    # None -> materialize each bucket's (Kb, d) delta stack; an int streams
    # the client axis in chunks of this size (paper-scale K on bounded
    # memory; see EngineConfig.client_chunk)
    client_chunk: Optional[int] = None
    # under partial participation, compute only the sampled cohort (padded
    # to this per-bucket capacity; see EngineConfig.cohort / cohort_capacity)
    cohort: Optional[int] = None
    # run on a build_virtual_problem layout: rows regenerate on demand
    # inside the round (see EngineConfig.virtual_data; auto-detected)
    virtual_data: bool = False
    # replace the Bernoulli draw with a repro.fleet participation model
    # (trace-driven availability/stragglers); `participation` then serves
    # as the model's upper-bound rate for cohort capacity sizing
    participation_model: Optional[Any] = None
    # corrupt returned deltas through a repro.fleet.faults fault model
    # (NaN poisoning, sign flips, scaling attacks, stale replay)
    fault_model: Optional[Any] = None
    # robust server aggregation: None | "clip" | "trimmed_mean" | "median"
    # (see EngineConfig.aggregator_guard for the composition rules)
    aggregator_guard: Optional[str] = None
    guard_clip_norm: Optional[float] = None
    guard_trim: float = 0.1


def _local_sgd_pass(w0, bucket: ClientBucket, lam, cfg: FedAvgConfig,
                    use_kernel: bool, key):
    """vmapped over clients in a bucket: E epochs of permutation-order SGD.
    Returns (Kb, d) client deltas w_k - w0."""
    keys = jax.random.split(key, bucket.num_clients)
    return _local_sgd_pass_keyed(w0, bucket, lam, cfg, use_kernel, keys)


def _local_sgd_pass_keyed(w0, bucket: ClientBucket, lam, cfg: FedAvgConfig,
                          use_kernel: bool, keys):
    """:func:`_local_sgd_pass` over explicit per-client keys — the engine's
    streamed (``client_chunk``) path hands in chunk-sized bucket slices with
    the matching slice of the bucket's key split."""

    h = cfg.stepsize

    def one_client(idx, val, y, n_k, ck):
        d = w0.shape[0]
        m_pad = y.shape[0]

        def epoch(wk, ek):
            perm = jax.random.permutation(ek, m_pad)

            def step(wk, i):
                xi, vi, yi = idx[i], val[i], y[i]
                valid = (i < n_k).astype(jnp.float32)
                z = (vi * wk[xi]).sum()
                g_sc = -yi * jax.nn.sigmoid(-yi * z)
                g = jnp.zeros((d,)).at[xi].add(g_sc * vi)
                h_eff = valid * h                  # padded slot -> exact no-op
                if use_kernel:
                    from repro.kernels import ops
                    return ops.fedavg_update(wk, g, h_eff, lam), None
                return (1.0 - h_eff * lam) * wk - h_eff * g, None

            wk, _ = jax.lax.scan(step, wk, perm)
            return wk, None

        wk, _ = jax.lax.scan(epoch, w0, jax.random.split(ck, cfg.local_epochs))
        return wk - w0

    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y, bucket.n_k, keys)


class FedAvg(FederatedSolver):
    """:class:`~repro.core.solver.FederatedSolver` mirroring
    :class:`repro.core.fsvrg.FSVRG`."""

    name = "fedavg"

    def __init__(self, problem: FederatedLogReg, cfg: FedAvgConfig = FedAvgConfig()):
        self.problem = problem
        self.cfg = cfg
        use_kernel = cfg.use_kernel
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        virtual = cfg.virtual_data or problem.virtual is not None
        self._passes = [] if virtual else [
            jax.jit(functools.partial(_local_sgd_pass, bucket=b,
                                      lam=problem.flat.lam, cfg=cfg,
                                      use_kernel=use_kernel))
            for b in problem.buckets
        ]
        self.engine = RoundEngine(
            problem,
            EngineConfig(
                participation=cfg.participation,
                weighting="nk" if cfg.use_weighted_agg else "uniform",
                aggregator=cfg.aggregator,
                client_chunk=cfg.client_chunk,
                cohort=cfg.cohort,
                virtual_data=virtual,
                aggregator_guard=cfg.aggregator_guard,
                guard_clip_norm=cfg.guard_clip_norm,
                guard_trim=cfg.guard_trim,
            ),
            participation_model=cfg.participation_model,
            fault_model=cfg.fault_model,
        )

        def fedavg_pass(w, bi, bucket, kb):
            return self._passes[bi](w, key=kb)

        def fedavg_chunk_pass(w, bi, chunk_bucket, keys):
            return _local_sgd_pass_keyed(w, chunk_bucket, problem.flat.lam,
                                         cfg, use_kernel, keys)

        self._round_fast = self.engine.compile(fedavg_pass,
                                               chunk_pass=fedavg_chunk_pass)
        self._round_ref = self.engine.reference(fedavg_pass,
                                                chunk_pass=fedavg_chunk_pass)

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        return state.replace(w=self._round_fast(state.w, key,
                                                round_index=state.round),
                             round=state.round + 1)


def _fedavg_defaults():
    from repro.configs import get_fedavg_config
    c = get_fedavg_config()
    return {"stepsize": c.stepsize, "local_epochs": c.local_epochs,
            "participation": c.participation}


@register("fedavg", defaults=_fedavg_defaults,
          description="Federated Averaging (arXiv:1602.05629, B=∞)")
def _make_fedavg(problem: FederatedLogReg, **kw) -> FedAvg:
    return FedAvg(problem, FedAvgConfig(**kw))
