"""FSVRG / FedAvg for neural-network pytrees — the paper's technique as a
first-class feature of the LLM training framework.

Clients are mapped onto the `data` (and `pod`) mesh axes: the client axis of
every batch tensor is sharded over them, so one :func:`fsvrg_round` is a
single SPMD program whose only cross-shard collectives are

  1. the full-gradient all-reduce (Alg. 4 line 3), and
  2. the weighted aggregation all-reduce (Alg. 4 line 11),

exactly the paper's two communications per round.  Local variance-reduced
epochs (`lax.scan` over a client's microbatches) are communication-free.

Sparsity scaling on TPU (hardware adaptation, see DESIGN.md §3): the paper's
features-j are *vocabulary rows* — a client's tokens only touch the embedding
rows they contain, the exact analogue of bag-of-words sparsity.  S_k and A
are computed from client token histograms and applied to embedding-like
parameters only; dense body parameters get S=I (they are touched by every
example, so φ^j/φ_k^j = 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.utils import flags


@dataclasses.dataclass(frozen=True)
class FedNeuralConfig:
    stepsize: float = 0.3          # h; per-client h_k = h / n_k(tokens)
    local_steps: int = 1           # microbatch steps per client per round
    use_S: bool = True             # per-vocab-row stochastic-gradient scaling
    use_A: bool = True             # per-vocab-row aggregation scaling
    algorithm: str = "fsvrg"       # 'fsvrg' | 'fedavg'
    server_lr: float = 1.0         # beyond-paper: server-side step on aggregate


# --------------------------------------------------------------------- #
# vocab-occupancy statistics (the neural analogue of §3.6.1)
# --------------------------------------------------------------------- #


def vocab_histogram(tokens: jax.Array, vocab: int) -> jax.Array:
    """tokens: (..., S) -> (vocab,) counts."""
    flat = tokens.reshape(-1)
    return jnp.zeros((vocab,), jnp.float32).at[flat].add(1.0)


def vocab_stats(client_tokens: jax.Array, vocab: int):
    """client_tokens: (C, B_c, S).  Returns (phi_global, omega, a_diag).

    phi_global^j: fraction of all tokens equal to j; omega^j: #clients whose
    data contains token j; a^j = C/omega^j (1 where absent everywhere).
    """
    C = client_tokens.shape[0]
    per_client = jax.vmap(lambda t: vocab_histogram(t, vocab))(client_tokens)  # (C, V)
    total = per_client.sum(axis=0)
    phi_global = total / jnp.maximum(total.sum(), 1.0)
    omega = (per_client > 0).sum(axis=0).astype(jnp.float32)
    a_diag = jnp.where(omega > 0, C / jnp.maximum(omega, 1.0), 1.0)
    return phi_global, omega, a_diag


def s_k_vocab(phi_global: jax.Array, tokens_k: jax.Array, vocab: int) -> jax.Array:
    """s_k^j = φ^j / φ_k^j over vocabulary rows for one client."""
    hist = vocab_histogram(tokens_k, vocab)
    n_k = jnp.maximum(hist.sum(), 1.0)
    phi_k = hist / n_k
    return jnp.where(hist > 0, phi_global / jnp.maximum(phi_k, 1e-12), 1.0)


def _is_vocab_row_param(path: str, vocab: int, shape) -> bool:
    return ("embed" in path and "unembed" not in path) and len(shape) >= 1 and shape[0] == vocab


def _tree_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(k): v for k, v in flat}


# --------------------------------------------------------------------- #
# the round
# --------------------------------------------------------------------- #


def _axpy(wk, w0, scale_tree, direction, h_k):
    """wk ← wk − h_k * (S ⊙ direction)  elementwise over the pytree."""
    return jax.tree.map(
        lambda w, s, g: (w.astype(jnp.float32) - h_k * s * g.astype(jnp.float32)).astype(w.dtype),
        wk, scale_tree, direction)


def make_fsvrg_round(model, cfg: FedNeuralConfig) -> Callable:
    """Returns round_fn(params, client_batches) -> (params, metrics).

    client_batches: every leaf has leading axes (C, local_steps, ...) —
    C clients × local_steps microbatches.  Shard C over ('pod','data').
    """
    vocab = model.cfg.vocab_size
    loss_fn = lambda p, b: model.loss(p, b)[0]
    grad_fn = jax.grad(loss_fn)

    def scale_tree_for(params, s_vocab):
        def one(path, p):
            if cfg.use_S and _is_vocab_row_param(path, vocab, p.shape):
                return s_vocab[: p.shape[0], None]
            return jnp.ones((), jnp.float32)
        flat, tdef = jax.tree_util.tree_flatten_with_path(params)
        return tdef.unflatten([one(jax.tree_util.keystr(k), v) for k, v in flat])

    def a_tree_for(params, a_vocab):
        def one(path, p):
            if cfg.use_A and _is_vocab_row_param(path, vocab, p.shape):
                return a_vocab[: p.shape[0], None]
            return jnp.ones((), jnp.float32)
        flat, tdef = jax.tree_util.tree_flatten_with_path(params)
        return tdef.unflatten([one(jax.tree_util.keystr(k), v) for k, v in flat])

    def round_fn(params, client_batches):
        """Clients are processed as sequential *waves* (`lax.scan` over the
        client axis).  This is how a pod simulates the paper's K ≫ chips
        massively-distributed clients (cf. FedJAX-style simulation): each
        wave's microbatch is sharded over ('pod','data') and the per-client
        model copy w_k inherits the FSDP/TP parameter sharding, so even the
        132B arch fits.  The aggregate is accumulated in the scan carry —
        no (C × params) buffer is ever materialized.
        """
        C = jax.tree.leaves(client_batches)[0].shape[0]
        all_tokens = client_batches["tokens"]                  # (C, T, B_c, S)
        phi_global, _, a_vocab = vocab_stats(
            all_tokens.reshape(C, -1, all_tokens.shape[-1]), vocab)

        # ---- 1. full gradient ∇f(w^t) (Alg. 4 line 3) ---- #
        # client-level remat: without it the scan saves every wave's
        # activation residuals simultaneously (4x the single-wave footprint;
        # EXPERIMENTS.md §Perf iter 4)
        def mean_loss(p):
            @jax.checkpoint
            def body(acc, b):
                def per_step(bb):
                    return loss_fn(p, bb)
                return acc + jax.vmap(per_step)(b).mean(), None
            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), client_batches,
                                    unroll=flags.scan_unroll())
            return total / C

        full_grad = jax.grad(mean_loss)(params)

        # ---- 2. local variance-reduced epochs, one wave at a time ---- #
        token_counts = jax.vmap(
            lambda t: jnp.asarray(t.size, jnp.float32))(all_tokens)  # (C,)
        n_total = token_counts.sum()

        def client_body(agg, inp):
            batches_k, n_k = inp
            tokens_k = batches_k["tokens"]
            s_vocab = s_k_vocab(phi_global, tokens_k.reshape(-1), vocab)
            S = scale_tree_for(params, s_vocab)
            h_k = cfg.stepsize / jnp.maximum(n_k / n_total * C, 1e-6)

            def step(wk, microbatch):
                if cfg.algorithm == "fedavg":
                    direction = grad_fn(wk, microbatch)
                else:
                    g_new = grad_fn(wk, microbatch)
                    g_old = grad_fn(params, microbatch)
                    direction = jax.tree.map(
                        lambda a, b, c: (a.astype(jnp.float32) - b.astype(jnp.float32))
                        + c.astype(jnp.float32), g_new, g_old, full_grad)
                from repro.sharding.hints import constrain_param_tree
                return constrain_param_tree(_axpy(wk, params, S, direction, h_k)), None

            wk, _ = jax.lax.scan(step, params, batches_k, unroll=flags.scan_unroll())
            wt = n_k / n_total
            from repro.sharding.hints import constrain_param_tree
            agg = jax.tree.map(
                lambda a, new, old: a + wt * (new.astype(jnp.float32)
                                              - old.astype(jnp.float32)),
                agg, wk, params)
            return constrain_param_tree(agg), None

        from repro.sharding.hints import constrain_param_tree
        agg0 = constrain_param_tree(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        agg, _ = jax.lax.scan(client_body, agg0, (client_batches, token_counts),
                              unroll=flags.scan_unroll())

        # ---- 3. aggregation with per-coordinate A scaling (line 11) ---- #
        A = a_tree_for(params, a_vocab)
        new_params = jax.tree.map(
            lambda p, a, dl: (p.astype(jnp.float32)
                              + cfg.server_lr * a * dl).astype(p.dtype),
            params, A, agg)

        gnorm = jnp.sqrt(sum(jnp.vdot(g, g).real for g in
                             jax.tree.leaves(jax.tree.map(
                                 lambda x: x.astype(jnp.float32), full_grad))))
        return new_params, {"full_grad_norm": gnorm}

    return round_fn


def make_client_batches(batch: Dict[str, jax.Array], num_clients: int,
                        local_steps: int) -> Dict[str, jax.Array]:
    """Reshape a global batch (B, ...) into (C, local_steps, B//(C*T), ...)."""

    def reshape(x):
        B = x.shape[0]
        per = B // (num_clients * local_steps)
        assert per * num_clients * local_steps == B, (B, num_clients, local_steps)
        return x.reshape(num_clients, local_steps, per, *x.shape[1:])

    return jax.tree.map(reshape, batch)
