"""Algorithm 1 — single-machine SVRG [43, 47], the paper's §3.2 building
block.  Included (a) for fidelity: FSVRG reduces to it when K=1, and the
§3.1 property (B) test relies on that; (b) as the reference local solver in
the Prop.-1 construction.

    for s = 0,1,2,...:
        ḡ = ∇f(w^t)                      # full pass
        w = w^t
        for t = 1..m:
            i ~ U{1..n}
            w ← w − h (∇f_i(w) − ∇f_i(w^t) + ḡ)
        w^{t+1} = w
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.problem import LogRegProblem


def svrg_epoch(problem: LogRegProblem, w_t: jax.Array, key, *, stepsize: float,
               m: int) -> jax.Array:
    """One outer iteration of Algorithm 1 on the flat problem."""
    full_grad = problem.grad(w_t)
    n = problem.n
    lam = problem.lam
    idx, val, y = problem.idx, problem.val, problem.y
    d = w_t.shape[0]

    samples = jax.random.randint(key, (m,), 0, n)

    def step(w, i):
        xi, vi, yi = idx[i], val[i], y[i]
        z_new = (vi * w[xi]).sum()
        z_old = (vi * w_t[xi]).sum()
        g_new = -yi * jax.nn.sigmoid(-yi * z_new)
        g_old = -yi * jax.nn.sigmoid(-yi * z_old)
        diff = jnp.zeros((d,)).at[xi].add((g_new - g_old) * vi) + lam * (w - w_t)
        return w - stepsize * (diff + full_grad), None

    w, _ = jax.lax.scan(step, w_t, samples)
    return w


def run_svrg(problem: LogRegProblem, w0: jax.Array, *, epochs: int,
             stepsize: float, m: int | None = None, seed: int = 0):
    """Algorithm 1 for `epochs` outer iterations; m defaults to n (one pass,
    the paper's 'small multiple of n' guidance)."""
    m = m or problem.n
    w = w0
    hist = []
    key = jax.random.PRNGKey(seed)
    epoch = jax.jit(lambda w, k: svrg_epoch(problem, w, k, stepsize=stepsize, m=m))
    for s in range(epochs):
        w = epoch(w, jax.random.fold_in(key, s))
        hist.append(float(problem.loss(w)))
    return w, hist
