"""Federated finite-sum problem (eq. 1/7/8): sparse L2-regularized logistic
regression, stored in fixed-nnz sparse row format, partitioned over clients.

Provides the flat (all-data) objective/gradient used for evaluation and the
full-gradient round of FSVRG, plus a *bucketed* per-client layout: clients
are grouped by ceil(log2 n_k) so each bucket pads to its own max and local
passes run as `vmap(scan)` — the production answer to the paper's
"unbalanced" data characteristic.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """Flat sparse dataset + lambda, as jnp arrays."""

    idx: jax.Array   # (n, nnz) int32
    val: jax.Array   # (n, nnz) f32
    y: jax.Array     # (n,) f32 {-1,+1}
    lam: float
    num_features: int

    @property
    def n(self) -> int:
        return self.y.shape[0]

    def margins(self, w: jax.Array) -> jax.Array:
        return (self.val * w[self.idx]).sum(axis=1)

    def loss(self, w: jax.Array) -> jax.Array:
        z = self.y * self.margins(w)
        return jnp.mean(jax.nn.softplus(-z)) + 0.5 * self.lam * jnp.dot(w, w)

    def grad(self, w: jax.Array) -> jax.Array:
        z = self.y * self.margins(w)
        g_scalar = -self.y * jax.nn.sigmoid(-z) / self.n       # (n,)
        g = jnp.zeros_like(w).at[self.idx].add(g_scalar[:, None] * self.val)
        return g + self.lam * w

    def error_rate(self, w: jax.Array) -> jax.Array:
        # Deterministic tie-break: a zero margin predicts +1.  (jnp.sign(0)
        # is 0, which equals neither label — an all-zero iterate would be
        # "wrong" on every example of both classes.)
        preds = jnp.where(self.margins(w) >= 0, 1.0, -1.0)
        return jnp.mean((preds != self.y).astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class ClientBucket:
    """Clients padded to a common example count m_pad.

    idx/val: (Kb, m_pad, nnz); y: (Kb, m_pad); n_k: (Kb,) true sizes.
    Padded rows have val==0 and are masked in local passes.
    """

    idx: jax.Array
    val: jax.Array
    y: jax.Array
    n_k: jax.Array

    @property
    def num_clients(self) -> int:
        return self.n_k.shape[0]

    @property
    def m_pad(self) -> int:
        return self.y.shape[1]


@dataclasses.dataclass(frozen=True)
class FederatedLogReg:
    """The problem as the algorithms see it: flat view + client buckets."""

    flat: LogRegProblem
    buckets: List[ClientBucket]
    client_weights: jax.Array    # (K,) n_k / n, bucket-concatenated order
    num_clients: int

    @property
    def d(self) -> int:
        return self.flat.num_features


def _equal_runs(order, sorted_keys) -> List[List[int]]:
    """Contiguous runs of equal key in a stably key-sorted index order —
    one O(K) pass (the grouping is exact because equal keys are adjacent
    after the sort)."""
    if len(order) == 0:
        return []
    starts = np.flatnonzero(np.r_[True, sorted_keys[1:] != sorted_keys[:-1]])
    ends = np.r_[starts[1:], len(order)]
    return [[int(k) for k in order[s:e]] for s, e in zip(starts, ends)]


def _split_by_rows(groups: List[List[int]], sizes,
                   max_bucket_rows: int | None) -> List[List[int]]:
    """Split any group whose padded row count Kb·m_pad would exceed
    ``max_bucket_rows`` into consecutive sub-groups under the cap (a single
    client is never split, so one oversized client keeps its own bucket).
    Member order — and therefore the bucket-concatenated client order the
    weights and fold_in offsets depend on — is preserved."""
    if max_bucket_rows is None:
        return groups
    out: List[List[int]] = []
    for members in groups:
        cur: List[int] = []
        cur_pad = 0
        for k in members:
            m_pad = max(cur_pad, int(sizes[k]))
            if cur and (len(cur) + 1) * m_pad > max_bucket_rows:
                out.append(cur)
                cur, cur_pad = [k], int(sizes[k])
            else:
                cur.append(k)
                cur_pad = m_pad
        if cur:
            out.append(cur)
    return out


def build_problem(ds, lam: float | None = None, *,
                  max_bucket_rows: int | None = None) -> FederatedLogReg:
    """ds: repro.data.synthetic.FederatedDataset.

    ``max_bucket_rows`` caps each bucket's padded example-row count
    Kb·m_pad: oversized ceil(log2 n_k) groups are split into consecutive
    sub-buckets so peak host memory per bucket stays bounded at paper scale
    (K = 10,000 puts thousands of clients in one level).  ``None`` keeps the
    historical one-bucket-per-level grouping bit-for-bit.
    """
    n = ds.num_examples
    lam = (1.0 / n) if lam is None else lam
    flat = LogRegProblem(
        idx=jnp.asarray(ds.idx), val=jnp.asarray(ds.val), y=jnp.asarray(ds.y),
        lam=float(lam), num_features=ds.num_features,
    )

    slices = ds.client_slices()
    sizes = ds.client_sizes.astype(np.int64)
    levels = np.ceil(np.log2(np.maximum(sizes, 1))).astype(np.int64)
    order = np.argsort(levels, kind="stable")

    buckets: List[ClientBucket] = []
    weights: List[float] = []
    # One pass over the sorted order: each bucket is a contiguous run of
    # equal ceil(log2 n_k), so the run boundaries are where the sorted level
    # sequence changes — no per-bucket rescan of the tail.
    groups = _split_by_rows(_equal_runs(order, levels[order]), sizes,
                            max_bucket_rows)
    for members in groups:
        m_pad = int(max(sizes[k] for k in members))
        Kb = len(members)
        nnz = ds.idx.shape[1]
        bi = np.zeros((Kb, m_pad, nnz), np.int32)
        bv = np.zeros((Kb, m_pad, nnz), np.float32)
        by = np.ones((Kb, m_pad), np.float32)
        nk = np.zeros(Kb, np.int32)
        for j, k in enumerate(members):
            sl = slices[k]
            m = int(sizes[k])
            bi[j, :m] = ds.idx[sl]
            bv[j, :m] = ds.val[sl]
            by[j, :m] = ds.y[sl]
            nk[j] = m
            weights.append(m / n)
        buckets.append(ClientBucket(jnp.asarray(bi), jnp.asarray(bv),
                                    jnp.asarray(by), jnp.asarray(nk)))

    return FederatedLogReg(
        flat=flat, buckets=buckets,
        client_weights=jnp.asarray(np.array(weights, np.float32)),
        num_clients=int(ds.num_clients),
    )


def build_dense_problem(Xs, ys, lam: float) -> FederatedLogReg:
    """Dense per-client data (X_k: (d, m_k), y_k: (m_k,)) as a bucketed
    :class:`FederatedLogReg`, so the ridge algorithms (DANERidge and the
    Appendix-A primal/dual methods) run on the same :class:`RoundEngine`
    layout as the sparse logreg ones.

    Each example row stores its *dense* feature vector (idx = arange(d),
    val = x_i) — the fixed-nnz sparse format degenerates to dense.  Clients
    are grouped into one bucket per distinct m_k (stable, so equal-size
    clients keep their input order), and every client in a bucket has
    exactly m_k rows — no padding.  The flat view's loss/grad are logistic
    and are NOT meaningful for ridge data — ridge algorithms use only the
    bucket layout, ``client_weights``, and ``flat.n``/``flat.lam``.
    """
    d = int(Xs[0].shape[0])
    sizes = [int(y.shape[0]) for y in ys]
    n = sum(sizes)
    dtype = jnp.result_type(*[X.dtype for X in Xs])

    order = np.argsort(np.asarray(sizes, np.int64), kind="stable")
    buckets: List[ClientBucket] = []
    weights: List[float] = []
    for members in _equal_runs(order, np.asarray(sizes, np.int64)[order]):
        m = sizes[members[0]]
        bi = jnp.tile(jnp.arange(d, dtype=jnp.int32), (len(members), m, 1))
        bv = jnp.stack([jnp.asarray(Xs[k], dtype).T for k in members])
        by = jnp.stack([jnp.asarray(ys[k], dtype) for k in members])
        nk = jnp.full((len(members),), m, jnp.int32)
        weights.extend(sizes[k] / n for k in members)
        buckets.append(ClientBucket(bi, bv, by, nk))

    flat = LogRegProblem(
        idx=jnp.tile(jnp.arange(d, dtype=jnp.int32), (n, 1)),
        val=jnp.concatenate([jnp.asarray(X, dtype).T for X in Xs], axis=0),
        y=jnp.concatenate([jnp.asarray(y, dtype) for y in ys]),
        lam=float(lam), num_features=d,
    )
    return FederatedLogReg(
        flat=flat, buckets=buckets,
        client_weights=jnp.asarray(np.array(weights, np.float32)),
        num_clients=len(Xs),
    )


def build_test_problem(ds, lam: float | None = None) -> LogRegProblem:
    n = ds.num_examples
    lam = (1.0 / n) if lam is None else lam
    return LogRegProblem(
        idx=jnp.asarray(ds.test_idx), val=jnp.asarray(ds.test_val),
        y=jnp.asarray(ds.test_y), lam=float(lam), num_features=ds.num_features,
    )
