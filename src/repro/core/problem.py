"""Federated finite-sum problem (eq. 1/7/8): sparse L2-regularized logistic
regression, stored in fixed-nnz sparse row format, partitioned over clients.

Provides the flat (all-data) objective/gradient used for evaluation and the
full-gradient round of FSVRG, plus a *bucketed* per-client layout: clients
are grouped by ceil(log2 n_k) so each bucket pads to its own max and local
passes run as `vmap(scan)` — the production answer to the paper's
"unbalanced" data characteristic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """Flat sparse dataset + lambda, as jnp arrays."""

    idx: jax.Array   # (n, nnz) int32
    val: jax.Array   # (n, nnz) f32
    y: jax.Array     # (n,) f32 {-1,+1}
    lam: float
    num_features: int

    @property
    def n(self) -> int:
        return self.y.shape[0]

    def margins(self, w: jax.Array) -> jax.Array:
        return (self.val * w[self.idx]).sum(axis=1)

    def loss(self, w: jax.Array) -> jax.Array:
        z = self.y * self.margins(w)
        return jnp.mean(jax.nn.softplus(-z)) + 0.5 * self.lam * jnp.dot(w, w)

    def grad(self, w: jax.Array) -> jax.Array:
        z = self.y * self.margins(w)
        g_scalar = -self.y * jax.nn.sigmoid(-z) / self.n       # (n,)
        g = jnp.zeros_like(w).at[self.idx].add(g_scalar[:, None] * self.val)
        return g + self.lam * w

    def error_rate(self, w: jax.Array) -> jax.Array:
        # Deterministic tie-break: a zero margin predicts +1.  (jnp.sign(0)
        # is 0, which equals neither label — an all-zero iterate would be
        # "wrong" on every example of both classes.)
        preds = jnp.where(self.margins(w) >= 0, 1.0, -1.0)
        return jnp.mean((preds != self.y).astype(jnp.float32))


@dataclasses.dataclass(frozen=True)
class ClientBucket:
    """Clients padded to a common example count m_pad.

    idx/val: (Kb, m_pad, nnz); y: (Kb, m_pad); n_k: (Kb,) true sizes.
    Padded rows have val==0 and are masked in local passes.
    """

    idx: jax.Array
    val: jax.Array
    y: jax.Array
    n_k: jax.Array

    @property
    def num_clients(self) -> int:
        return self.n_k.shape[0]

    @property
    def m_pad(self) -> int:
        return self.y.shape[1]


@dataclasses.dataclass(frozen=True)
class VirtualBucket:
    """A bucket of *virtual* clients: who they are and how many rows they
    have, but no rows — those regenerate on demand from the client ids
    (see :class:`VirtualLayout`).  Mirrors :class:`ClientBucket`'s
    ``num_clients``/``m_pad``/``n_k`` surface so engine bookkeeping
    (weights, offsets, masks) is layout-blind.
    """

    client_ids: jax.Array    # (Kb,) int32 global client ids
    n_k: jax.Array           # (Kb,) int32 true TRAIN sizes
    m_pad: int

    @property
    def num_clients(self) -> int:
        return self.n_k.shape[0]


@dataclasses.dataclass(frozen=True)
class VirtualLayout:
    """The bridge from virtual buckets to the rows the client passes eat.

    Wraps the :class:`~repro.data.synthetic.VirtualDataset` spec;
    ``materialize`` is traceable, so the engine can call it *inside* a
    ``lax.scan`` body to regenerate just one chunk's (or one gathered
    cohort's) rows — peak data memory O(chunk · m_pad · nnz) regardless
    of K.
    """

    vds: Any   # repro.data.synthetic.VirtualDataset

    def materialize(self, client_ids, n_k, m_pad: int) -> ClientBucket:
        idx, val, y = self.vds.client_rows_padded(client_ids, n_k, m_pad)
        return ClientBucket(idx, val, y, jnp.asarray(n_k, jnp.int32))

    def realize(self, vb: VirtualBucket) -> ClientBucket:
        return self.materialize(vb.client_ids, vb.n_k, vb.m_pad)


class VirtualFlat:
    """Flat-view twin over virtual data, streamed in client chunks.

    Provides what solvers and scaling actually consume from
    :class:`LogRegProblem` — ``lam``/``n``/``num_features``,
    ``grad``/``loss``/``error_rate`` — plus exact ``feature_counts``/
    ``omega`` for FSVRG's diagonal scalings, all computed by regenerating
    ``eval_chunk`` clients at a time inside a ``lax.scan`` (O(chunk·m_pad)
    live rows, never the full (n, nnz) arrays).  Per-row quantities use the
    exact :class:`LogRegProblem` expressions (``g_scalar = -y·σ(-z)/n``
    *before* the scatter), so only cross-row summation order differs from
    the materialized flat view — iterate-level parity is tight-tolerance,
    per-count quantities (feature_counts, omega, error counts) are exact.
    """

    def __init__(self, layout: VirtualLayout, buckets: List[VirtualBucket],
                 lam: float, num_features: int, n: int,
                 eval_chunk: int = 256):
        self.layout = layout
        self.lam = float(lam)
        self.num_features = int(num_features)
        self._n = int(n)
        self.eval_chunk = int(eval_chunk)
        # per-bucket (cids, nks) padded to a whole number of chunks; padded
        # clients have n_k == 0, so client_rows_padded zeroes all their rows
        # (idx 0 / val 0 / y 1) and they drop out of every masked reduction
        self._chunks: List[Tuple[jax.Array, jax.Array, int]] = []
        for vb in buckets:
            chunk = min(self.eval_chunk, vb.num_clients)
            nch = -(-vb.num_clients // chunk)
            pad = nch * chunk - vb.num_clients
            cid = jnp.concatenate(
                [vb.client_ids, jnp.zeros((pad,), vb.client_ids.dtype)])
            nk = jnp.concatenate([vb.n_k, jnp.zeros((pad,), vb.n_k.dtype)])
            self._chunks.append((cid.reshape(nch, chunk),
                                 nk.reshape(nch, chunk), vb.m_pad))
        self._stats_fns: Dict[int, Any] = {}
        self._count_fns: Dict[int, Any] = {}

    @property
    def n(self) -> int:
        return self._n

    def margins(self, w: jax.Array) -> jax.Array:
        raise NotImplementedError(
            "VirtualFlat has no materialized row axis; use loss/grad/"
            "error_rate, which stream over regenerated client chunks.")

    def _stats_fn(self, m_pad: int):
        fn = self._stats_fns.get(m_pad)
        if fn is None:
            vds, n, d = self.layout.vds, self._n, self.num_features

            @jax.jit
            def fn(w, cids, nks):
                def body(carry, x):
                    g, ls, err = carry
                    cid, nk = x
                    idx, val, y = vds.client_rows_padded(cid, nk, m_pad)
                    mask = (jnp.arange(m_pad)[None, :]
                            < nk[:, None]).astype(jnp.float32)
                    margins = (val * w[idx]).sum(-1)
                    z = y * margins
                    g_scalar = -y * jax.nn.sigmoid(-z) / n
                    g = g.at[idx].add((g_scalar * mask)[..., None] * val)
                    ls = ls + (jax.nn.softplus(-z) * mask).sum()
                    preds = jnp.where(margins >= 0, 1.0, -1.0)
                    err = err + ((preds != y).astype(jnp.float32)
                                 * mask).sum()
                    return (g, ls, err), None

                init = (jnp.zeros((d,), w.dtype), jnp.float32(0.0),
                        jnp.float32(0.0))
                (g, ls, err), _ = jax.lax.scan(body, init, (cids, nks))
                return g, ls, err

            self._stats_fns[m_pad] = fn
        return fn

    def _stats(self, w: jax.Array):
        g = jnp.zeros((self.num_features,), jnp.float32)
        ls = jnp.float32(0.0)
        err = jnp.float32(0.0)
        for cids, nks, m_pad in self._chunks:
            bg, bl, be = self._stats_fn(m_pad)(w, cids, nks)
            g, ls, err = g + bg, ls + bl, err + be
        return g, ls, err

    def grad(self, w: jax.Array) -> jax.Array:
        return self._stats(w)[0] + self.lam * w

    def loss(self, w: jax.Array) -> jax.Array:
        return (self._stats(w)[1] / self._n
                + 0.5 * self.lam * jnp.dot(w, w))

    def error_rate(self, w: jax.Array) -> jax.Array:
        return self._stats(w)[2] / self._n

    def _count_fn(self, m_pad: int):
        fn = self._count_fns.get(m_pad)
        if fn is None:
            vds, d = self.layout.vds, self.num_features

            @jax.jit
            def fn(cids, nks):
                def body(carry, x):
                    cnt, om = carry
                    cid, nk = x
                    idx, val, _ = vds.client_rows_padded(cid, nk, m_pad)
                    nz = (val != 0).astype(jnp.float32)
                    cnt = cnt.at[idx].add(nz)
                    chunk = cid.shape[0]
                    pres = jnp.zeros((chunk, d), jnp.float32).at[
                        jnp.arange(chunk)[:, None, None], idx].add(nz)
                    om = om + (pres > 0).astype(jnp.float32).sum(0)
                    return (cnt, om), None

                init = (jnp.zeros((d,), jnp.float32),
                        jnp.zeros((d,), jnp.float32))
                (cnt, om), _ = jax.lax.scan(body, init, (cids, nks))
                return cnt, om

            self._count_fns[m_pad] = fn
        return fn

    def _counts(self):
        cnt = jnp.zeros((self.num_features,), jnp.float32)
        om = jnp.zeros((self.num_features,), jnp.float32)
        for cids, nks, m_pad in self._chunks:
            bc, bo = self._count_fn(m_pad)(cids, nks)
            cnt, om = cnt + bc, om + bo
        return cnt, om

    def feature_counts(self) -> jax.Array:
        """#examples with feature j present — the materialized
        ``scaling.global_feature_counts`` streamed (exact: integer sums)."""
        return self._counts()[0]

    def omega(self) -> jax.Array:
        """#clients with feature j present — the materialized
        ``scaling.omega`` streamed (exact: integer sums)."""
        return self._counts()[1]


@dataclasses.dataclass(frozen=True)
class FederatedLogReg:
    """The problem as the algorithms see it: flat view + client buckets.

    When ``virtual`` is set (see :func:`build_virtual_problem`), ``flat``
    is a :class:`VirtualFlat` and ``buckets`` hold :class:`VirtualBucket`
    specs; the engine materializes rows on demand through ``virtual``
    under ``EngineConfig.virtual_data``.
    """

    flat: LogRegProblem
    buckets: List[ClientBucket]
    client_weights: jax.Array    # (K,) n_k / n, bucket-concatenated order
    num_clients: int
    virtual: Optional[VirtualLayout] = None

    @property
    def d(self) -> int:
        return self.flat.num_features


def _equal_runs(order, sorted_keys) -> List[List[int]]:
    """Contiguous runs of equal key in a stably key-sorted index order —
    one O(K) pass (the grouping is exact because equal keys are adjacent
    after the sort)."""
    if len(order) == 0:
        return []
    starts = np.flatnonzero(np.r_[True, sorted_keys[1:] != sorted_keys[:-1]])
    ends = np.r_[starts[1:], len(order)]
    return [[int(k) for k in order[s:e]] for s, e in zip(starts, ends)]


def _split_by_rows(groups: List[List[int]], sizes,
                   max_bucket_rows: int | None) -> List[List[int]]:
    """Split any group whose padded row count Kb·m_pad would exceed
    ``max_bucket_rows`` into consecutive sub-groups under the cap (a single
    client is never split, so one oversized client keeps its own bucket).
    Member order — and therefore the bucket-concatenated client order the
    weights and fold_in offsets depend on — is preserved."""
    if max_bucket_rows is None:
        return groups
    out: List[List[int]] = []
    for members in groups:
        cur: List[int] = []
        cur_pad = 0
        for k in members:
            m_pad = max(cur_pad, int(sizes[k]))
            if cur and (len(cur) + 1) * m_pad > max_bucket_rows:
                out.append(cur)
                cur, cur_pad = [k], int(sizes[k])
            else:
                cur.append(k)
                cur_pad = m_pad
        if cur:
            out.append(cur)
    return out


def _level_groups(sizes, max_bucket_rows: int | None) -> List[List[int]]:
    """The canonical client grouping: stable-sort by ceil(log2 n_k), one
    group per level, split under ``max_bucket_rows``.  Shared by
    :func:`build_problem` and :func:`build_virtual_problem` so the two
    layouts produce the *identical* bucket-concatenated client order —
    and therefore identical weights, fold_in offsets, and per-client
    keys — which is what makes virtual rounds bit-for-bit comparable to
    materialized ones."""
    levels = np.ceil(np.log2(np.maximum(sizes, 1))).astype(np.int64)
    order = np.argsort(levels, kind="stable")
    return _split_by_rows(_equal_runs(order, levels[order]), sizes,
                          max_bucket_rows)


def build_problem(ds, lam: float | None = None, *,
                  max_bucket_rows: int | None = None) -> FederatedLogReg:
    """ds: repro.data.synthetic.FederatedDataset.

    ``max_bucket_rows`` caps each bucket's padded example-row count
    Kb·m_pad: oversized ceil(log2 n_k) groups are split into consecutive
    sub-buckets so peak host memory per bucket stays bounded at paper scale
    (K = 10,000 puts thousands of clients in one level).  ``None`` keeps the
    historical one-bucket-per-level grouping bit-for-bit.
    """
    n = ds.num_examples
    lam = (1.0 / n) if lam is None else lam
    flat = LogRegProblem(
        idx=jnp.asarray(ds.idx), val=jnp.asarray(ds.val), y=jnp.asarray(ds.y),
        lam=float(lam), num_features=ds.num_features,
    )

    slices = ds.client_slices()
    sizes = ds.client_sizes.astype(np.int64)

    buckets: List[ClientBucket] = []
    weights: List[float] = []
    # One pass over the sorted order: each bucket is a contiguous run of
    # equal ceil(log2 n_k), so the run boundaries are where the sorted level
    # sequence changes — no per-bucket rescan of the tail.
    groups = _level_groups(sizes, max_bucket_rows)
    for members in groups:
        m_pad = int(max(sizes[k] for k in members))
        Kb = len(members)
        nnz = ds.idx.shape[1]
        bi = np.zeros((Kb, m_pad, nnz), np.int32)
        bv = np.zeros((Kb, m_pad, nnz), np.float32)
        by = np.ones((Kb, m_pad), np.float32)
        nk = np.zeros(Kb, np.int32)
        for j, k in enumerate(members):
            sl = slices[k]
            m = int(sizes[k])
            bi[j, :m] = ds.idx[sl]
            bv[j, :m] = ds.val[sl]
            by[j, :m] = ds.y[sl]
            nk[j] = m
            weights.append(m / n)
        buckets.append(ClientBucket(jnp.asarray(bi), jnp.asarray(bv),
                                    jnp.asarray(by), jnp.asarray(nk)))

    return FederatedLogReg(
        flat=flat, buckets=buckets,
        client_weights=jnp.asarray(np.array(weights, np.float32)),
        num_clients=int(ds.num_clients),
    )


def build_virtual_problem(vds, lam: float | None = None, *,
                          max_bucket_rows: int | None = None,
                          eval_chunk: int = 256) -> FederatedLogReg:
    """vds: repro.data.synthetic.VirtualDataset.

    The virtual twin of :func:`build_problem`: same client grouping
    (:func:`_level_groups` over the TRAIN sizes), same weights, same
    default lam — but buckets carry only (client_ids, n_k, m_pad) and the
    flat view streams (:class:`VirtualFlat`), so the build is O(K) in
    memory and time regardless of Σ n_k.  Run rounds on it with
    ``EngineConfig(virtual_data=True, ...)``.
    """
    sizes = np.asarray(vds.client_sizes, np.int64)
    n = int(sizes.sum())
    lam = (1.0 / n) if lam is None else lam

    layout = VirtualLayout(vds)
    buckets: List[VirtualBucket] = []
    weight_parts: List[np.ndarray] = []
    for members in _level_groups(sizes, max_bucket_rows):
        mem = np.asarray(members, np.int64)
        buckets.append(VirtualBucket(
            client_ids=jnp.asarray(mem.astype(np.int32)),
            n_k=jnp.asarray(sizes[mem].astype(np.int32)),
            m_pad=int(sizes[mem].max()),
        ))
        weight_parts.append(sizes[mem] / n)

    flat = VirtualFlat(layout, buckets, lam=float(lam),
                       num_features=vds.num_features, n=n,
                       eval_chunk=eval_chunk)
    return FederatedLogReg(
        flat=flat, buckets=buckets,
        client_weights=jnp.asarray(
            np.concatenate(weight_parts).astype(np.float32)),
        num_clients=int(vds.num_clients),
        virtual=layout,
    )


def build_dense_problem(Xs, ys, lam: float) -> FederatedLogReg:
    """Dense per-client data (X_k: (d, m_k), y_k: (m_k,)) as a bucketed
    :class:`FederatedLogReg`, so the ridge algorithms (DANERidge and the
    Appendix-A primal/dual methods) run on the same :class:`RoundEngine`
    layout as the sparse logreg ones.

    Each example row stores its *dense* feature vector (idx = arange(d),
    val = x_i) — the fixed-nnz sparse format degenerates to dense.  Clients
    are grouped into one bucket per distinct m_k (stable, so equal-size
    clients keep their input order), and every client in a bucket has
    exactly m_k rows — no padding.  The flat view's loss/grad are logistic
    and are NOT meaningful for ridge data — ridge algorithms use only the
    bucket layout, ``client_weights``, and ``flat.n``/``flat.lam``.
    """
    d = int(Xs[0].shape[0])
    sizes = [int(y.shape[0]) for y in ys]
    n = sum(sizes)
    dtype = jnp.result_type(*[X.dtype for X in Xs])

    order = np.argsort(np.asarray(sizes, np.int64), kind="stable")
    buckets: List[ClientBucket] = []
    weights: List[float] = []
    for members in _equal_runs(order, np.asarray(sizes, np.int64)[order]):
        m = sizes[members[0]]
        bi = jnp.tile(jnp.arange(d, dtype=jnp.int32), (len(members), m, 1))
        bv = jnp.stack([jnp.asarray(Xs[k], dtype).T for k in members])
        by = jnp.stack([jnp.asarray(ys[k], dtype) for k in members])
        nk = jnp.full((len(members),), m, jnp.int32)
        weights.extend(sizes[k] / n for k in members)
        buckets.append(ClientBucket(bi, bv, by, nk))

    flat = LogRegProblem(
        idx=jnp.tile(jnp.arange(d, dtype=jnp.int32), (n, 1)),
        val=jnp.concatenate([jnp.asarray(X, dtype).T for X in Xs], axis=0),
        y=jnp.concatenate([jnp.asarray(y, dtype) for y in ys]),
        lam=float(lam), num_features=d,
    )
    return FederatedLogReg(
        flat=flat, buckets=buckets,
        client_weights=jnp.asarray(np.array(weights, np.float32)),
        num_clients=len(Xs),
    )


def build_test_problem(ds, lam: float | None = None) -> LogRegProblem:
    n = ds.num_examples
    lam = (1.0 / n) if lam is None else lam
    return LogRegProblem(
        idx=jnp.asarray(ds.test_idx), val=jnp.asarray(ds.test_val),
        y=jnp.asarray(ds.test_y), lam=float(lam), num_features=ds.num_features,
    )
