"""Baseline algorithms the paper compares against (§2, §4, Fig. 2).

  * Distributed GD — the "trivial benchmark" (teal diamonds in Fig. 2).
  * One-shot averaging [107] — each node fully optimizes locally, average
    once; the paper cites [91, App. A] showing it cannot beat a single
    machine in general.  We include it because it is the extreme point of
    the communication-efficiency spectrum.
  * FedAvg-style local SGD [62] — local epochs + n_k/n-weighted averaging
    (the follow-up paper's algorithm; a natural baseline here).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.problem import FederatedLogReg


def gd_round(problem: FederatedLogReg, w: jax.Array, stepsize: float) -> jax.Array:
    """One round of distributed gradient descent (1 communication)."""
    return w - stepsize * problem.flat.grad(w)


def run_gd(problem, w0, rounds: int, stepsize: float, callback=None):
    w = w0
    hist = []
    g = jax.jit(problem.flat.grad)
    for r in range(rounds):
        w = w - stepsize * g(w)
        if callback:
            hist.append(callback(w, r))
    return w, hist


def _local_sgd_pass(w0, bucket, lam, stepsize, epochs, key):
    """vmap over clients: `epochs` permutation passes of plain SGD."""

    def one_client(idx, val, y, n_k, ck):
        d = w0.shape[0]
        nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
        m_pad = y.shape[0]

        def epoch(wk, ek):
            perm = jax.random.permutation(ek, m_pad)

            def step(wk, i):
                xi, vi, yi = idx[i], val[i], y[i]
                valid = (i < n_k).astype(jnp.float32)
                z = (vi * wk[xi]).sum()
                g_sc = -yi * jax.nn.sigmoid(-yi * z)
                grad = jnp.zeros((d,)).at[xi].add(g_sc * vi) + lam * wk
                return wk - valid * stepsize * grad, None

            wk, _ = jax.lax.scan(step, wk, perm)
            return wk, None

        wk, _ = jax.lax.scan(epoch, w0, jax.random.split(ck, epochs))
        return wk - w0

    keys = jax.random.split(key, bucket.num_clients)
    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y, bucket.n_k, keys)


def fedavg_round(problem: FederatedLogReg, w, key, stepsize: float, epochs: int = 1):
    """Local SGD + n_k/n-weighted averaging (FedAvg, [62])."""
    agg = jnp.zeros_like(w)
    wi = 0
    for b in problem.buckets:
        deltas = _local_sgd_pass(w, b, problem.flat.lam, stepsize, epochs,
                                 jax.random.fold_in(key, wi))
        wts = problem.client_weights[wi : wi + b.num_clients]
        agg = agg + (wts[:, None] * deltas).sum(axis=0)
        wi += b.num_clients
    return w + agg


def one_shot_average(problem: FederatedLogReg, w0, key, stepsize: float,
                     epochs: int = 50):
    """[107]: clients optimize to (near-)completion locally; average once."""
    return fedavg_round(problem, w0, key, stepsize, epochs=epochs)


def majority_baseline_error(train_y, train_client_of, test_y, test_client_of):
    """Per-client majority-vote error (the paper's 17.14% analogue)."""
    import numpy as np
    K = int(max(train_client_of.max(), test_client_of.max())) + 1
    maj = np.zeros(K, np.float32)
    for k in range(K):
        yk = train_y[train_client_of == k]
        maj[k] = 1.0 if (yk > 0).mean() >= 0.5 else -1.0
    pred = maj[test_client_of]
    return float((pred != test_y).mean())
