"""Baseline algorithms the paper compares against (§2, §4, Fig. 2).

  * Distributed GD — the "trivial benchmark" (teal diamonds in Fig. 2).
  * One-shot averaging [107] — each node fully optimizes locally, average
    once; the paper cites [91, App. A] showing it cannot beat a single
    machine in general.  We include it because it is the extreme point of
    the communication-efficiency spectrum.
  * FedAvg-style local SGD [62] — local epochs + n_k/n-weighted averaging;
    the full subsystem lives in :mod:`repro.core.fedavg`, the wrappers here
    keep the original one-call entry points.

All round-based baselines run on the shared
:class:`~repro.core.engine.RoundEngine`: distributed GD is the degenerate
client pass ``delta_k = −h (∇f_k(w) + λw)``, whose n_k/n-weighted aggregate
is exactly ``−h ∇f(w)`` (Σ_k n_k/n = 1).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.engine import EngineConfig, RoundEngine
from repro.core.fedavg import FedAvg, FedAvgConfig
from repro.core.problem import FederatedLogReg
from repro.core.registry import register
from repro.core.solver import FederatedSolver, SolverState


def gd_round(problem: FederatedLogReg, w: jax.Array, stepsize: float) -> jax.Array:
    """One round of distributed gradient descent (1 communication), computed
    on the flat view — the cheap reference for :class:`DistributedGD`."""
    return w - stepsize * problem.flat.grad(w)


def _gd_client_pass(w, bucket, lam, stepsize):
    """vmapped over clients: delta_k = −h (mean data grad on P_k + λw)."""

    def one_client(idx, val, y, n_k):
        nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
        z = (val * w[idx]).sum(axis=1)                       # (m_pad,)
        g_sc = -y * jax.nn.sigmoid(-y * z) / nkf             # padded rows: val==0
        g = jnp.zeros_like(w).at[idx].add(g_sc[:, None] * val)
        return -stepsize * (g + lam * w)

    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y, bucket.n_k)


class DistributedGD(FederatedSolver):
    """Distributed GD expressed on the RoundEngine (client pass = exact local
    gradient, n_k/n aggregation).  Deterministic — the round key is unused."""

    name = "gd"

    def __init__(self, problem: FederatedLogReg, stepsize: float = 2.0,
                 aggregator: str = "dense",
                 client_chunk: Optional[int] = None,
                 participation: float = 1.0,
                 cohort: Optional[int] = None,
                 virtual_data: bool = False,
                 participation_model=None,
                 fault_model=None,
                 aggregator_guard: Optional[str] = None,
                 guard_clip_norm: Optional[float] = None,
                 guard_trim: float = 0.1):
        self.problem = problem
        self.stepsize = stepsize
        virtual = virtual_data or problem.virtual is not None
        self.engine = RoundEngine(problem,
                                  EngineConfig(aggregator=aggregator,
                                               client_chunk=client_chunk,
                                               participation=participation,
                                               cohort=cohort,
                                               virtual_data=virtual,
                                               aggregator_guard=aggregator_guard,
                                               guard_clip_norm=guard_clip_norm,
                                               guard_trim=guard_trim),
                                  participation_model=participation_model,
                                  fault_model=fault_model)
        self._passes = [] if virtual else [
            jax.jit(functools.partial(_gd_client_pass, bucket=b,
                                      lam=problem.flat.lam, stepsize=stepsize))
            for b in problem.buckets
        ]
        gd_pass = lambda w, bi, b, kb: self._passes[bi](w)
        # deterministic pass: the per-client keys of the streamed contract
        # are simply unused
        gd_chunk_pass = lambda w, bi, cb, keys: _gd_client_pass(
            w, cb, problem.flat.lam, stepsize)
        self._round_fast = self.engine.compile(gd_pass,
                                               chunk_pass=gd_chunk_pass)
        self._round_ref = self.engine.reference(gd_pass,
                                                chunk_pass=gd_chunk_pass)

    @property
    def hyperparams(self):
        return {"stepsize": self.stepsize}

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        return state.replace(w=self._round_fast(state.w, key,
                                                round_index=state.round),
                             round=state.round + 1)


def run_gd(problem, w0, rounds: int, stepsize: float, callback=None):
    """Round loop on the flat view — one jitted O(d) gradient per round.
    Mathematically identical to :class:`DistributedGD` (see
    tests/test_engine.py), which materializes per-client deltas and is kept
    for engine parity, not for the hot path."""
    w = w0
    hist = []
    g = jax.jit(problem.flat.grad)
    for r in range(rounds):
        w = w - stepsize * g(w)
        if callback:
            hist.append(callback(w, r))
    return w, hist


def _gd_defaults():
    from repro.configs import get_gd_config
    return {"stepsize": get_gd_config().stepsize}


@register("gd", defaults=_gd_defaults,
          description="distributed gradient descent (the trivial benchmark)")
def _make_gd(problem: FederatedLogReg, **kw) -> DistributedGD:
    return DistributedGD(problem, **kw)


def fedavg_round(problem: FederatedLogReg, w, key, stepsize: float, epochs: int = 1):
    """Local SGD + n_k/n-weighted averaging (FedAvg, [62])."""
    cfg = FedAvgConfig(stepsize=stepsize, local_epochs=epochs)
    solver = FedAvg(problem, cfg)
    return solver.round(solver.init(w), key).w


def one_shot_average(problem: FederatedLogReg, w0, key, stepsize: float,
                     epochs: int = 50):
    """[107]: clients optimize to (near-)completion locally; average once."""
    return fedavg_round(problem, w0, key, stepsize, epochs=epochs)


def majority_baseline_error(train_y, train_client_of, test_y, test_client_of):
    """Per-client majority-vote error (the paper's 17.14% analogue)."""
    import numpy as np
    K = int(max(train_client_of.max(), test_client_of.max())) + 1
    maj = np.zeros(K, np.float32)
    for k in range(K):
        yk = train_y[train_client_of == k]
        maj[k] = 1.0 if (yk > 0).mean() >= 0.5 else -1.0
    pred = maj[test_client_of]
    return float((pred != test_y).mean())
