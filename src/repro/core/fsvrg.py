"""Federated SVRG — the paper's Algorithm 4 (and the naive Algorithm 3).

One round (Algorithm 4):
  1. server: compute ∇f(w^t) over all data      — 1 round of communication
  2. each client k, in parallel:
       w_k = w^t;  h_k = h / n_k
       for t over a random permutation of P_k:
         w_k ← w_k − h_k ( S_k [∇f_i(w_k) − ∇f_i(w^t)] + ∇f(w^t) )
  3. server: w ← w^t + A Σ_k (n_k/n)(w_k − w^t)

The four FSVRG modifications vs naive distributed SVRG (§3.6.2):
  (1) local stepsize h_k = h/n_k, (2) n_k/n-weighted aggregation,
  (3) per-coordinate stochastic-gradient scaling S_k,
  (4) per-coordinate aggregation scaling A.

Clients run as vmap-over-bucket × scan-over-permutation; padded permutation
slots are masked no-ops, so every real example is visited exactly once per
round (the paper uses permutation sampling, line 6 of Alg. 4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import scaling
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.problem import ClientBucket, FederatedLogReg
from repro.core.registry import register
from repro.core.solver import FederatedSolver, SolverState


@dataclasses.dataclass(frozen=True)
class FSVRGConfig:
    stepsize: float = 1.0          # h; h_k = h/n_k per client
    naive: bool = False            # Algorithm 3: S=I, A=I, h_k=h, uniform agg
    naive_steps: int = 0           # m for Algorithm 3 (0 -> one pass, m=n_k)
    use_S: bool = True             # ablation switches
    use_A: bool = True
    use_local_stepsize: bool = True
    use_weighted_agg: bool = True
    # partial participation (beyond-paper, the deployment reality the paper
    # motivates in §1.2: devices only participate when charging/on-wifi).
    # Each round samples clients i.i.d. with this probability; aggregation
    # reweights by the realized participating mass so the update direction
    # stays unbiased.
    participation: float = 1.0
    # engine aggregator: "dense" (eager jnp reference) | "pallas" (the
    # delta-native fused_aggregate kernel — one HBM pass over the deltas)
    aggregator: str = "dense"
    # None -> materialize each bucket's (Kb, d) delta stack; an int streams
    # the client axis in chunks of this size (paper-scale K on bounded
    # memory; see EngineConfig.client_chunk)
    client_chunk: Optional[int] = None
    # under partial participation, compute only the sampled cohort (padded
    # to this per-bucket capacity; see EngineConfig.cohort / cohort_capacity)
    cohort: Optional[int] = None
    # run on a build_virtual_problem layout: rows regenerate on demand
    # inside the round (see EngineConfig.virtual_data).  Auto-detected from
    # the problem, so passing a virtual problem is enough.
    virtual_data: bool = False
    # replace the Bernoulli draw with a repro.fleet participation model
    # (trace-driven availability/stragglers); `participation` then serves
    # as the model's upper-bound rate for cohort capacity sizing
    participation_model: Optional[Any] = None
    # corrupt returned deltas through a repro.fleet.faults fault model
    fault_model: Optional[Any] = None
    # robust server aggregation: None | "clip" | "trimmed_mean" | "median"
    # (see EngineConfig.aggregator_guard for the composition rules)
    aggregator_guard: Optional[str] = None
    guard_clip_norm: Optional[float] = None
    guard_trim: float = 0.1


def _client_pass(w0, full_grad, bucket: ClientBucket, lam, phi, cfg: FSVRGConfig, key):
    """vmapped over clients in a bucket. Returns (Kb, d) client deltas w_k - w0."""
    keys = jax.random.split(key, bucket.num_clients)
    return _client_pass_keyed(w0, full_grad, bucket, lam, phi, cfg, keys)


def _client_pass_keyed(w0, full_grad, bucket: ClientBucket, lam, phi,
                       cfg: FSVRGConfig, keys):
    """:func:`_client_pass` over explicit per-client keys — the engine's
    streamed (``client_chunk``) path hands in chunk-sized bucket slices with
    the matching slice of the bucket's key split, so chunked and unchunked
    clients consume identical randomness."""

    def one_client(idx, val, y, n_k, ck):
        d = w0.shape[0]
        nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
        if cfg.naive or not cfg.use_S:
            s_diag = jnp.ones((d,))
        else:
            s_diag = scaling.s_k_diag(phi, idx, val, n_k)
        if cfg.naive or not cfg.use_local_stepsize:
            h_k = cfg.stepsize                      # Alg. 3: fixed h
        else:
            h_k = cfg.stepsize / nkf                # Alg. 4: h/n_k

        m_pad = y.shape[0]
        if cfg.naive:
            # Alg. 3 line 7: m uniform samples with replacement from P_k
            m = cfg.naive_steps if cfg.naive_steps > 0 else m_pad
            samples = jax.random.randint(ck, (m,), 0, jnp.maximum(n_k, 1))
            valid_fn = lambda i: jnp.float32(1.0)
        else:
            # Alg. 4 line 6: one pass over a random permutation of P_k
            samples = jax.random.permutation(ck, m_pad)
            valid_fn = lambda i: (i < n_k).astype(jnp.float32)

        # margins at the anchor w^t are recomputed per step (O(nnz));
        # the anchor per-example gradient scalar needs only x·w0.
        def step(wk, i):
            xi, vi, yi = idx[i], val[i], y[i]
            valid = valid_fn(i)
            zi_new = (vi * wk[xi]).sum()
            zi_old = (vi * w0[xi]).sum()
            g_new = -yi * jax.nn.sigmoid(-yi * zi_new)
            g_old = -yi * jax.nn.sigmoid(-yi * zi_old)
            # sparse part of ∇f_i(w_k) − ∇f_i(w^t)
            diff = jnp.zeros((d,)).at[xi].add((g_new - g_old) * vi)
            diff = diff + lam * (wk - w0)          # L2 part of the difference
            upd = h_k * (s_diag * diff + full_grad)
            return wk - valid * upd, None

        wk, _ = jax.lax.scan(step, w0, samples)
        return wk - w0

    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y, bucket.n_k, keys)


class FSVRG(FederatedSolver):
    """:class:`~repro.core.solver.FederatedSolver` for Algorithms 3 & 4:
    precomputes φ and A once, then runs rounds on the shared
    :class:`~repro.core.engine.RoundEngine` (which owns client sampling,
    weighting, and aggregation — mods. 2 & 4 map onto its ``weighting`` /
    ``server_scaling`` knobs)."""

    def __init__(self, problem: FederatedLogReg, cfg: FSVRGConfig = FSVRGConfig()):
        self.problem = problem
        self.cfg = cfg
        self.name = "svrg_naive" if cfg.naive else "fsvrg"
        flat = problem.flat
        n = flat.n
        virtual = cfg.virtual_data or problem.virtual is not None
        self.phi = scaling.global_feature_counts(flat) / n
        self.a_diag = scaling.aggregation_diag(problem) if cfg.use_A else jnp.ones((problem.d,))
        # virtual problems have no materialized buckets to close over — all
        # round paths go through the keyed chunk pass instead
        self._passes = [] if virtual else [
            jax.jit(functools.partial(_client_pass, bucket=b, lam=flat.lam, cfg=cfg))
            for b in problem.buckets
        ]
        plain = cfg.naive  # Alg. 3: uniform aggregation, no A scaling
        self.engine = RoundEngine(
            problem,
            EngineConfig(
                participation=cfg.participation,
                weighting="uniform" if (plain or not cfg.use_weighted_agg) else "nk",
                server_scaling="diag" if (cfg.use_A and not plain) else "none",
                aggregator=cfg.aggregator,
                client_chunk=cfg.client_chunk,
                cohort=cfg.cohort,
                virtual_data=virtual,
                aggregator_guard=cfg.aggregator_guard,
                guard_clip_norm=cfg.guard_clip_norm,
                guard_trim=cfg.guard_trim,
            ),
            a_diag=self.a_diag,
            participation_model=cfg.participation_model,
            fault_model=cfg.fault_model,
        )
        # The full gradient is the round's own communication (Alg. 4 line 3),
        # so it is the eager prelude; everything after it is one compiled
        # dispatch.  The eager reference twin backs the pin tests and the
        # round-latency benchmark's baseline.
        def fsvrg_pass(w, bi, bucket, kb, full_grad):
            return self._passes[bi](w, full_grad, phi=self.phi, key=kb)

        def fsvrg_chunk_pass(w, bi, chunk_bucket, keys, full_grad):
            return _client_pass_keyed(w, full_grad, chunk_bucket, flat.lam,
                                      self.phi, cfg, keys)

        prelude = lambda w: (self.problem.flat.grad(w),)
        self._round_fast = self.engine.compile(fsvrg_pass, prelude=prelude,
                                               chunk_pass=fsvrg_chunk_pass)
        self._round_ref = self.engine.reference(fsvrg_pass, prelude=prelude,
                                                chunk_pass=fsvrg_chunk_pass)

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        return state.replace(w=self._round_fast(state.w, key,
                                                round_index=state.round),
                             round=state.round + 1)


def naive_fsvrg_round(problem: FederatedLogReg, w, key, stepsize: float, m: Optional[int] = None):
    """Algorithm 3: S=I, A=I, h_k=h, m uniform samples, (1/K)-average agg."""
    cfg = FSVRGConfig(stepsize=stepsize, naive=True, naive_steps=m or 0)
    solver = FSVRG(problem, cfg)
    return solver.round(solver.init(w), key).w


def _fsvrg_defaults():
    from repro.configs import get_fsvrg_config
    c = get_fsvrg_config()
    return {"stepsize": c.stepsize}


@register("fsvrg", defaults=_fsvrg_defaults,
          description="Federated SVRG (Algorithm 4, all four modifications)")
def _make_fsvrg(problem: FederatedLogReg, **kw) -> FSVRG:
    return FSVRG(problem, FSVRGConfig(**kw))


@register("svrg_naive",
          defaults=lambda: {"stepsize": 0.01, "naive_steps": 50},
          description="naive distributed SVRG (Algorithm 3: S=I, A=I, "
                      "fixed h, uniform averaging)")
def _make_svrg_naive(problem: FederatedLogReg, **kw) -> FSVRG:
    return FSVRG(problem, FSVRGConfig(naive=True, **kw))
