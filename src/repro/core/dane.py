"""DANE — Distributed Approximate Newton (Algorithm 2), and the Prop.-1
variant (DANE with a single epoch of SVRG as the local solver).

Local subproblem (10):
    w_k = argmin_w F_k(w) − (∇F_k(w^t) − η∇f(w^t))ᵀ w + (µ/2)||w − w^t||²

We provide
  * an exact solver for ridge regression (d×d linear solve) — used for the
    convergence comparisons and the Appendix-A tests,
  * an inexact GD local solver for logistic regression,
  * :func:`dane_svrg_round` — the Prop.-1 construction: the subproblem is
    built explicitly (linear perturbation and all) and solved with one epoch
    of generic SVRG.  Proposition 1 says its iterates are *identical* to
    naive FSVRG (Algorithm 3) given the same sample sequence; the test
    suite checks this to float tolerance against an independently coded
    Algorithm 3.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.problem import FederatedLogReg


# --------------------------------------------------------------------- #
# exact DANE for ridge regression (dense per-client data)
# --------------------------------------------------------------------- #


def ridge_grad(X, y, w, lam):
    """F(w) = 1/(2m) ||X^T w - y||^2 + lam/2 ||w||^2 with X: (d, m)."""
    m = y.shape[0]
    return X @ (X.T @ w - y) / m + lam * w


def dane_round_ridge(Xs: Sequence[jax.Array], ys: Sequence[jax.Array], w, lam,
                     eta: float = 1.0, mu: float = 0.0):
    """One exact DANE round on ridge. Xs[k]: (d, n_k)."""
    K = len(Xs)
    n = sum(int(y.shape[0]) for y in ys)
    # ∇f(w^t) = Σ (n_k/n) ∇F_k(w^t)
    full_grad = sum((ys[k].shape[0] / n) * ridge_grad(Xs[k], ys[k], w, lam)
                    for k in range(K))
    d = w.shape[0]
    w_next = jnp.zeros_like(w)
    for k in range(K):
        X, y = Xs[k], ys[k]
        m = y.shape[0]
        a_k = ridge_grad(X, y, w, lam) - eta * full_grad
        # (H_k + µI) w = c_k + a_k + µ w^t,  H_k = XXᵀ/m + λI, c_k = Xy/m
        H = X @ X.T / m + (lam + mu) * jnp.eye(d)
        rhs = X @ y / m + a_k + mu * w
        w_next = w_next + jnp.linalg.solve(H, rhs) / K
    return w_next


# --------------------------------------------------------------------- #
# inexact DANE for logistic regression (GD local solver)
# --------------------------------------------------------------------- #


def dane_round_logreg_gd(problem: FederatedLogReg, w, *, eta: float = 1.0,
                         mu: float = 0.0, local_steps: int = 50,
                         local_lr: float = 1.0):
    """DANE with a GD local solver, on the bucketed sparse problem."""
    flat = problem.flat
    full_grad = flat.grad(w)
    lam = flat.lam
    agg = jnp.zeros_like(w)
    wi = 0
    for b in problem.buckets:

        def one_client(idx, val, y, n_k):
            d = w.shape[0]
            nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
            valid = (jnp.arange(y.shape[0]) < n_k).astype(jnp.float32)

            def Fk_grad(wk):
                z = y * (val * wk[idx]).sum(axis=1)
                gs = -y * jax.nn.sigmoid(-y * z) * valid / nkf
                return jnp.zeros((d,)).at[idx].add(gs[:, None] * val) + lam * wk

            a_k = Fk_grad(w) - eta * full_grad

            def gd_step(wk, _):
                g = Fk_grad(wk) - a_k + mu * (wk - w)
                return wk - local_lr * g, None

            wk, _ = jax.lax.scan(gd_step, w, None, length=local_steps)
            return wk

        wks = jax.vmap(one_client)(b.idx, b.val, b.y, b.n_k)   # (Kb, d)
        agg = agg + wks.sum(axis=0)
        wi += b.num_clients
    return agg / problem.num_clients


# --------------------------------------------------------------------- #
# Proposition 1: DANE(η=1, µ=0) + one SVRG epoch as the local solver
# --------------------------------------------------------------------- #


def dane_svrg_round(problem: FederatedLogReg, w, key, stepsize: float, m: int):
    """Solve the DANE subproblem *as a subproblem* with one SVRG epoch.

    The SVRG epoch on G_k(w') = F_k(w') − a_kᵀw' (µ=0, η=1) starting at w^t:
      full gradient of G_k at anchor w^t is ∇F_k(w^t) − a_k = ∇f(w^t)
      (no extra pass needed — exactly the observation in §3.5);
      stochastic update uses ∇g_i(w') − ∇g_i(w^t) + ∇G_k(w^t), where
      g_i(w') = f_i(w') − a_kᵀw' so the linear term cancels in the
      difference.  The code below nevertheless *materializes a_k and the
      linear term explicitly* so the equivalence with Algorithm 3 is a real
      test, not a tautology.
    """
    flat = problem.flat
    full_grad = flat.grad(w)
    lam = flat.lam
    agg = jnp.zeros_like(w)
    wi = 0
    for b in problem.buckets:
        kb = jax.random.fold_in(key, wi)

        def one_client(idx, val, y, n_k, ck):
            d = w.shape[0]
            nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
            valid_rows = (jnp.arange(y.shape[0]) < n_k).astype(jnp.float32)

            def Fk_grad(wk):
                z = y * (val * wk[idx]).sum(axis=1)
                gs = -y * jax.nn.sigmoid(-y * z) * valid_rows / nkf
                return jnp.zeros((d,)).at[idx].add(gs[:, None] * val) + lam * wk

            a_k = Fk_grad(w) - full_grad           # η = 1
            G_anchor_grad = Fk_grad(w) - a_k       # = ∇f(w^t), materialized

            def fi_grad(wk, i):
                xi, vi, yi = idx[i], val[i], y[i]
                z = (vi * wk[xi]).sum()
                gs = -yi * jax.nn.sigmoid(-yi * z)
                return jnp.zeros((d,)).at[xi].add(gs * vi) + lam * wk

            samples = jax.random.randint(ck, (m,), 0, jnp.maximum(n_k, 1))

            def step(wk, i):
                gi_new = fi_grad(wk, i) - a_k      # ∇g_i(w')
                gi_old = fi_grad(w, i) - a_k       # ∇g_i(w^t)
                wk = wk - stepsize * (gi_new - gi_old + G_anchor_grad)
                return wk, None

            wk, _ = jax.lax.scan(step, w, samples)
            return wk - w

        keys = jax.random.split(kb, b.num_clients)
        deltas = jax.vmap(one_client)(b.idx, b.val, b.y, b.n_k, keys)
        agg = agg + deltas.sum(axis=0)
        wi += b.num_clients
    return w + agg / problem.num_clients
