"""DANE — Distributed Approximate Newton (Algorithm 2) on the RoundEngine.

Local subproblem (eq. 10):

    w_k = argmin_w F_k(w) − (∇F_k(w^t) − η∇f(w^t))ᵀ w + (µ/2)||w − w^t||²

Every variant is expressed as a :data:`~repro.core.engine.ClientPassFn`
returning per-client deltas ``w_k − w^t``; the shared
:class:`~repro.core.engine.RoundEngine` owns client sampling and the
(uniform, per the paper's "averages the solutions" step) aggregation:

  * :class:`DANE` — sparse L2-logistic regression (the Fig.-2 problem), with
    two inexact local solvers: ``local_solver="gd"`` runs ``local_steps``
    gradient steps on the subproblem (each step the fused Pallas
    :func:`repro.kernels.dane_update.dane_update` on TPU, the identical jnp
    expression elsewhere); ``local_solver="svrg"`` is the Proposition-1
    construction — one epoch of generic SVRG on the *explicitly
    materialized* subproblem (linear perturbation and all), whose iterates
    Prop. 1 proves identical to naive Federated SVRG (Algorithm 3) given
    the same sample sequence.  tests/test_equivalence.py checks this to
    float tolerance against the independently coded Algorithm 3.
  * :class:`DANERidge` — the exact solver for ridge regression (per-client
    d×d linear solves, vmapped over each bucket of a
    :func:`~repro.core.problem.build_dense_problem` layout); used for the
    §3.4 property tests (one-round solve on identical data, Property A
    fixed point) and pinned against the pre-port list implementation in
    tests/test_dane_cocoa_engine.py.

:func:`dane_svrg_round` keeps the original one-call entry point for the
Prop.-1 equivalence test.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import EngineConfig, RoundEngine
from repro.core.problem import ClientBucket, FederatedLogReg
from repro.core.registry import register
from repro.core.solver import FederatedSolver, SolverState

_SOLVERS = ("gd", "svrg")


def ridge_grad(X, y, w, lam):
    """F(w) = 1/(2m) ||X^T w - y||^2 + lam/2 ||w||^2 with X: (d, m)."""
    m = y.shape[0]
    return X @ (X.T @ w - y) / m + lam * w


@dataclasses.dataclass(frozen=True)
class DANEConfig:
    """Knobs of Algorithm 2 and its local solvers."""

    eta: float = 1.0               # η: full-gradient weight in a_k (eq. 10)
    mu: float = 0.0                # µ: prox coefficient (eq. 10)
    local_solver: str = "gd"       # "gd" | "svrg" (the Prop.-1 construction)
    local_steps: int = 50          # GD solver: iterations on the subproblem
    local_lr: float = 1.0          # GD solver: stepsize
    svrg_stepsize: float = 0.05    # SVRG solver: stepsize h
    svrg_steps: int = 25           # SVRG solver: samples m per epoch
    participation: float = 1.0     # i.i.d. per-round client participation
    # None -> auto: fused Pallas dane_update kernel on TPU, jnp elsewhere.
    use_kernel: Optional[bool] = None
    aggregator: str = "dense"      # engine aggregator: "dense" | "pallas"
    # None -> materialize each bucket's (Kb, d) delta stack; an int streams
    # the client axis in chunks of this size (see EngineConfig.client_chunk)
    client_chunk: Optional[int] = None
    # under partial participation, compute only the sampled cohort (padded
    # to this per-bucket capacity; see EngineConfig.cohort / cohort_capacity)
    cohort: Optional[int] = None
    # run on a build_virtual_problem layout: rows regenerate on demand
    # inside the round (see EngineConfig.virtual_data; auto-detected)
    virtual_data: bool = False
    # replace the Bernoulli draw with a repro.fleet participation model
    # (trace-driven availability/stragglers); `participation` then serves
    # as the model's upper-bound rate for cohort capacity sizing
    participation_model: Optional[Any] = None
    # corrupt returned deltas through a repro.fleet.faults fault model
    fault_model: Optional[Any] = None
    # robust server aggregation: None | "clip" | "trimmed_mean" | "median"
    # (see EngineConfig.aggregator_guard for the composition rules)
    aggregator_guard: Optional[str] = None
    guard_clip_norm: Optional[float] = None
    guard_trim: float = 0.1

    def __post_init__(self):
        if self.local_solver not in _SOLVERS:
            raise ValueError(f"local_solver must be one of {_SOLVERS}")


def _dane_gd_pass(w0, full_grad, bucket: ClientBucket, lam, cfg: DANEConfig,
                  use_kernel: bool, key):
    """vmapped over clients: ``local_steps`` GD steps on subproblem (10).
    Deterministic — ``key`` is part of the ClientPassFn signature only.
    Returns (Kb, d) client deltas w_k − w0."""
    del key
    lr, eta, mu = cfg.local_lr, cfg.eta, cfg.mu

    def one_client(idx, val, y, n_k):
        d = w0.shape[0]
        nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
        valid = (jnp.arange(y.shape[0]) < n_k).astype(jnp.float32)

        def data_grad(wk):
            """Sparse data part of ∇F_k; the dense λ·wk part is fused into
            the update step."""
            z = y * (val * wk[idx]).sum(axis=1)
            gs = -y * jax.nn.sigmoid(-y * z) * valid / nkf
            return jnp.zeros((d,)).at[idx].add(gs[:, None] * val)

        a_k = data_grad(w0) + lam * w0 - eta * full_grad   # ∇F_k(w^t) − η∇f(w^t)

        def gd_step(wk, _):
            g = data_grad(wk)
            if use_kernel:
                from repro.kernels import ops
                wk = ops.dane_update(wk, g, a_k, w0, lr, lam, mu)
            else:
                wk = ((1.0 - lr * (lam + mu)) * wk - lr * g + lr * a_k
                      + lr * mu * w0)
            return wk, None

        wk, _ = jax.lax.scan(gd_step, w0, None, length=cfg.local_steps)
        return wk - w0

    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y, bucket.n_k)


def _dane_svrg_pass(w0, full_grad, bucket: ClientBucket, lam, cfg: DANEConfig,
                    key):
    keys = jax.random.split(key, bucket.num_clients)
    return _dane_svrg_pass_keyed(w0, full_grad, bucket, lam, cfg, keys)


def _dane_svrg_pass_keyed(w0, full_grad, bucket: ClientBucket, lam,
                          cfg: DANEConfig, keys):
    """Proposition 1: solve subproblem (10) *as a subproblem* (η=1, µ=0)
    with one epoch of generic SVRG.  Returns (Kb, d) deltas w_k − w0.
    Takes explicit per-client keys so the engine's streamed path can hand
    chunk-sized slices of the bucket's key split.

    The SVRG epoch on G_k(w') = F_k(w') − a_kᵀw' starting at w^t:
      full gradient of G_k at anchor w^t is ∇F_k(w^t) − a_k = ∇f(w^t)
      (no extra pass needed — exactly the observation in §3.5);
      stochastic update uses ∇g_i(w') − ∇g_i(w^t) + ∇G_k(w^t), where
      g_i(w') = f_i(w') − a_kᵀw' so the linear term cancels in the
      difference.  The code below nevertheless *materializes a_k and the
      linear term explicitly* so the equivalence with Algorithm 3 is a real
      test, not a tautology.
    """
    stepsize, m = cfg.svrg_stepsize, cfg.svrg_steps

    def one_client(idx, val, y, n_k, ck):
        d = w0.shape[0]
        nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
        valid_rows = (jnp.arange(y.shape[0]) < n_k).astype(jnp.float32)

        def Fk_grad(wk):
            z = y * (val * wk[idx]).sum(axis=1)
            gs = -y * jax.nn.sigmoid(-y * z) * valid_rows / nkf
            return jnp.zeros((d,)).at[idx].add(gs[:, None] * val) + lam * wk

        a_k = Fk_grad(w0) - full_grad          # η = 1
        G_anchor_grad = Fk_grad(w0) - a_k      # = ∇f(w^t), materialized

        def fi_grad(wk, i):
            xi, vi, yi = idx[i], val[i], y[i]
            z = (vi * wk[xi]).sum()
            gs = -yi * jax.nn.sigmoid(-yi * z)
            return jnp.zeros((d,)).at[xi].add(gs * vi) + lam * wk

        samples = jax.random.randint(ck, (m,), 0, jnp.maximum(n_k, 1))

        def step(wk, i):
            gi_new = fi_grad(wk, i) - a_k      # ∇g_i(w')
            gi_old = fi_grad(w0, i) - a_k      # ∇g_i(w^t)
            wk = wk - stepsize * (gi_new - gi_old + G_anchor_grad)
            return wk, None

        wk, _ = jax.lax.scan(step, w0, samples)
        return wk - w0

    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y, bucket.n_k,
                                keys)


class DANE(FederatedSolver):
    """:class:`~repro.core.solver.FederatedSolver` for Algorithm 2: per-round
    full gradient (1 extra communication, as in Alg. 2 step 1) closed over
    the client pass; sampling/aggregation on the shared engine with uniform
    1/K weighting (Alg. 2 step 3: "averages the solutions")."""

    name = "dane"

    def __init__(self, problem: FederatedLogReg, cfg: DANEConfig = DANEConfig()):
        self.problem = problem
        self.cfg = cfg
        use_kernel = cfg.use_kernel
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        lam = problem.flat.lam
        virtual = cfg.virtual_data or problem.virtual is not None
        if virtual:
            self._passes = []
        elif cfg.local_solver == "gd":
            self._passes = [
                jax.jit(functools.partial(_dane_gd_pass, bucket=b, lam=lam,
                                          cfg=cfg, use_kernel=use_kernel))
                for b in problem.buckets
            ]
        else:
            self._passes = [
                jax.jit(functools.partial(_dane_svrg_pass, bucket=b, lam=lam,
                                          cfg=cfg))
                for b in problem.buckets
            ]
        self.engine = RoundEngine(
            problem,
            EngineConfig(participation=cfg.participation, weighting="uniform",
                         aggregator=cfg.aggregator,
                         client_chunk=cfg.client_chunk,
                         cohort=cfg.cohort,
                         virtual_data=virtual,
                         aggregator_guard=cfg.aggregator_guard,
                         guard_clip_norm=cfg.guard_clip_norm,
                         guard_trim=cfg.guard_trim),
            participation_model=cfg.participation_model,
            fault_model=cfg.fault_model,
        )

        # Alg. 2 step 1's full gradient is the eager prelude (its own round
        # of communication); the rest of the round is one compiled dispatch.
        def dane_pass(w, bi, bucket, kb, full_grad):
            return self._passes[bi](w, full_grad, key=kb)

        if cfg.local_solver == "gd":
            def dane_chunk_pass(w, bi, chunk_bucket, keys, full_grad):
                return _dane_gd_pass(w, full_grad, chunk_bucket, lam, cfg,
                                     use_kernel, key=None)
        else:
            def dane_chunk_pass(w, bi, chunk_bucket, keys, full_grad):
                return _dane_svrg_pass_keyed(w, full_grad, chunk_bucket, lam,
                                             cfg, keys)

        prelude = lambda w: (self.problem.flat.grad(w),)
        self._round_fast = self.engine.compile(dane_pass, prelude=prelude,
                                               chunk_pass=dane_chunk_pass)
        self._round_ref = self.engine.reference(dane_pass, prelude=prelude,
                                                chunk_pass=dane_chunk_pass)

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        return state.replace(w=self._round_fast(state.w, key,
                                                round_index=state.round),
                             round=state.round + 1)


def dane_svrg_round(problem: FederatedLogReg, w, key, stepsize: float, m: int):
    """One Prop.-1 round (DANE η=1, µ=0, one SVRG epoch as local solver) —
    the original entry point, now a thin wrapper over the engine port."""
    cfg = DANEConfig(eta=1.0, mu=0.0, local_solver="svrg",
                     svrg_stepsize=stepsize, svrg_steps=m)
    solver = DANE(problem, cfg)
    return solver.round(solver.init(w), key).w


class DANERidge(FederatedSolver):
    """Exact DANE for ridge regression (d×d local solves) on the engine.

    F_k(w) = 1/(2 n_k)||X_kᵀw − y_k||² + (λ/2)||w||²; subproblem (10) is the
    linear system (H_k + µI) w = c_k + a_k + µw^t with H_k = X_kX_kᵀ/n_k + λI
    and c_k = X_k y_k / n_k, solved exactly per client (vmapped over each
    bucket) and uniformly averaged by the engine.  ``problem`` must be a
    :func:`~repro.core.problem.build_dense_problem` layout; λ is read from
    ``problem.flat.lam``."""

    name = "dane_ridge"

    def __init__(self, problem: FederatedLogReg, *, eta: float = 1.0,
                 mu: float = 0.0, aggregator: str = "dense"):
        self.problem = problem
        self.lam = float(problem.flat.lam)
        self.eta, self.mu = float(eta), float(mu)
        self.engine = RoundEngine(self.problem,
                                  EngineConfig(weighting="uniform",
                                               aggregator=aggregator))
        self._round_fast = self.engine.compile(self._ridge_pass,
                                               prelude=self._prelude)
        self._round_ref = self.engine.reference(self._ridge_pass,
                                                prelude=self._prelude)

    @property
    def hyperparams(self):
        return {"eta": self.eta, "mu": self.mu}

    def full_grad(self, w: jax.Array) -> jax.Array:
        """∇f(w) = (1/n) Σ_k X_k (X_kᵀ w − y_k) + λw, from the buckets."""
        n = self.problem.flat.n
        g = self.lam * w
        for b in self.problem.buckets:
            resid = jnp.einsum("kmd,d->km", b.val, w) - b.y
            g = g + jnp.einsum("kmd,km->d", b.val, resid) / n
        return g

    def _prelude(self, w):
        return (self.full_grad(w),)

    def _ridge_pass(self, w, bi, bucket, kb, fg):
        lam, eta, mu = self.lam, self.eta, self.mu

        def one_client(val, y, n_k):
            d = w.shape[0]
            X = val.T                                  # (d, m)
            m = jnp.maximum(n_k, 1).astype(val.dtype)
            grad_k = X @ (X.T @ w - y) / m + lam * w
            a_k = grad_k - eta * fg
            H = X @ X.T / m + (lam + mu) * jnp.eye(d, dtype=val.dtype)
            rhs = X @ y / m + a_k + mu * w
            return jnp.linalg.solve(H, rhs) - w

        return jax.vmap(one_client)(bucket.val, bucket.y, bucket.n_k)

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        return state.replace(w=self._round_fast(state.w, key),
                             round=state.round + 1)


def _dane_defaults():
    from repro.configs import get_dane_config
    c = get_dane_config()
    return {"eta": c.eta, "mu": c.mu, "local_steps": c.local_steps,
            "local_lr": c.local_lr}


@register("dane", defaults=_dane_defaults,
          description="DANE (Algorithm 2) with inexact GD/SVRG local solvers")
def _make_dane(problem: FederatedLogReg, **kw) -> DANE:
    return DANE(problem, DANEConfig(**kw))


@register("dane_ridge", layout="dense",
          description="exact DANE for ridge regression (d×d local solves)")
def _make_dane_ridge(problem: FederatedLogReg, **kw) -> DANERidge:
    return DANERidge(problem, **kw)
