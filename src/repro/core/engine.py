"""Unified federated round engine — the paper's round template (§1, §3).

Every algorithm in this repo follows the same communication pattern:

  1. (algorithm) server broadcasts state to clients
  2. clients compute local updates in parallel         — vmap over buckets
  3. server samples/weights the participating clients  — full or i.i.d. partial
  4. server aggregates deltas and applies the update   — uniform / n_k/n /
                                                         A-scaled (Pallas)

Steps 2–4 are algorithm-independent: FSVRG (Alg. 4), naive SVRG (Alg. 3),
FedAvg, and distributed GD differ only in the *client pass* that produces the
per-client deltas ``w_k − w`` and in the weighting/scaling choices.  The
``RoundEngine`` owns steps 2–4 so algorithms supply one function instead of
hand-rolling the loop (the pre-refactor state: four divergent copies).

Aggregation is pluggable:

  * ``weighting``      — ``"nk"`` (n_k/n, the paper's mod. 2), ``"uniform"``
                          (1/K), or ``"sum"`` (weight 1 per client — the plain
                          Σ_k used by dual methods, where each delta already
                          carries its own normalization)
  * ``server_scaling`` — ``"none"`` or ``"diag"`` (A = Diag(K/ω), mod. 4)
  * ``aggregator``     — ``"dense"`` (eager jnp weighted sum, the reference
                          path) or ``"pallas"`` (one HBM pass over the stacked
                          client deltas via ``kernels.scaled_aggregate``)

Algorithms whose clients carry *auxiliary per-client state* across rounds —
CoCoA+'s dual blocks α_k, the Primal Method's perturbation vectors g_k —
use :meth:`RoundEngine.round_with_state`: the client pass receives and
returns the bucket's state alongside the deltas, and under partial
participation the engine freezes the state of exactly the clients whose
aggregation weight the same Bernoulli draw zeroed.

Partial participation samples clients i.i.d. with probability
``participation`` per round and reweights the aggregate by
(expected mass / realized mass) so the update direction stays unbiased —
the deployment reality the paper motivates in §1.2 (devices participate
only when charging / on wi-fi).  ``weighting="sum"`` is exempt from the
reweighting: dual methods need the plain sum of the participants' deltas,
matching their frozen dual blocks exactly.  Each round's Bernoulli masks
are drawn **once** (:meth:`RoundEngine.participation_masks`) and shared by
state freezing and aggregation — one draw, two consumers, bit-identical to
the historical re-derivation by construction (same ``fold_in`` chain).

The single Bernoulli draw is itself pluggable: a **participation model**
(``repro.fleet.participation``) handed to the engine replaces the draw
with arbitrary per-round per-client masks — diurnal availability traces,
correlated dropout bursts, stragglers — while every consumer downstream
(weight zeroing, reweighting, dual-state freezing, the cohort gather) is
unchanged, because they only ever see the mask list.  Round-dependent
models need the round index, so every round entry point (and the compiled
closures) accepts ``round_index``; solvers forward ``state.round``, and
``cfg.participation`` becomes the model's *upper-bound* rate used for
cohort capacity sizing (the model owns the actual draw).

Because rounds are the scarce resource (§1: "minimizing the number of
rounds of communication is the principal goal"), the per-round server work
should be a *constant number of compiled dispatches*, not a Python loop of
per-bucket calls.  :meth:`RoundEngine.compile` /
:meth:`RoundEngine.compile_with_state` return jitted round closures — the
per-bucket ``fold_in`` offsets are precomputed, the client passes and the
aggregation run inside a single ``jax.jit`` (with donated iterate/state
buffers off-CPU), and an optional eager ``prelude`` carries per-round
server state (e.g. FSVRG's full gradient — its own round of communication
in the paper, so it stays outside the jitted body; the compiled round then
tracks the eager reference to tight float tolerance — bit-identically on
single-bucket problems, where the jit has no cross-bucket aggregation sum
to re-associate).  Every solver's ``round``
calls its compiled closure; :meth:`round` / :meth:`round_with_state` stay
as the eager reference implementations the pin tests compare against.

The paper's defining regime is *massively distributed* — §4 runs K=10,000
clients.  Materializing every bucket's (Kb, d) delta stack is O(K·d) peak
memory, which is exactly what breaks first at that K.  With
``EngineConfig.client_chunk`` set, rounds **stream** the client axis
instead (:meth:`round_streamed` / :meth:`round_streamed_with_state`): each
bucket's pass runs over chunk-sized client slices under ``lax.scan``,
accumulating the weighted delta sum (a (d,) vector) chunk by chunk —
O(client_chunk·d) peak delta memory — and ``compile`` traces the streamed
path inside the same single ``jax.jit``.  The per-client key split is
hoisted into the engine (:meth:`client_keys`) so chunked rounds consume
the *same* per-client randomness as the reference and differ only in
summation order (float tolerance, not bit-for-bit).

Streaming fixes the *memory* axis; ``EngineConfig.cohort`` fixes the
*compute* axis.  Under partial participation the masked paths still run
every client's pass and zero the non-participants' weights — at the
paper's ~10% participation that wastes ~90% of round flops.  The cohort
path (:meth:`round_cohort` / :meth:`round_cohort_with_state`) reuses the
round's single Bernoulli draw to *gather* only the sampled clients' rows,
weights, per-client keys, and aux-state slices into a padded
fixed-capacity bucket (static shapes under jit — size the capacity with
:func:`cohort_capacity`), runs passes + aggregation over O(C·K) clients,
and scatters dual state back.  Reweighting still sees the full weight and
mask vectors, so the unbiasedness contract is identical to the masked
reference; a capacity-overflowing draw falls back per-bucket to the
masked pass via ``lax.cond``.  ``compile``/``compile_with_state`` trace
the cohort body whenever ``cohort`` is set and participation < 1.0,
composing with ``client_chunk`` (the gathered cohort is streamed).

Streaming and cohorts bound the *compute* and *delta* memory, but the
bucket rows themselves were still materialized up front — O(n·nnz), the
last axis that breaks at the paper's thesis scale ("as many nodes as
users of the service": K=10⁶).  ``EngineConfig.virtual_data`` removes it:
the problem carries a :class:`~repro.core.problem.VirtualLayout`
(``build_virtual_problem``) whose buckets hold only (client_ids, n_k,
m_pad), and every round path **regenerates the rows it is about to
consume inside the traced body** — the streamed path materializes one
chunk's rows per ``lax.scan`` step (peak data memory
O(client_chunk·m_pad·nnz) regardless of K), the cohort path generates
rows only for the gathered cohort, and the plain paths realize one
bucket at a time.  The per-client seeding contract
(``fold_in(base, k)`` per client, ``fold_in`` per row) makes regenerated
rows bit-for-bit equal to the materialized dataset's, so virtual rounds
match materialized rounds exactly per client and to float tolerance on
iterates (the usual summation-order calibration).

Unreliable devices don't just disappear (the participation layer) — they
also send garbage.  A **fault model** (``repro.fleet.faults``) handed to
the engine corrupts each round path's deltas between the client pass and
aggregation, as a pure function of ``(seed, round_index, client_id)`` —
the wire, not the client: dual state is whatever the honest pass computed.
``EngineConfig.aggregator_guard`` is the server's defense: ``"clip"``
(per-client non-finite rejection + norm capping, folded into every path
including the streamed chunk entries) or coordinate-wise
``"trimmed_mean"`` / ``"median"`` over the materialized delta stacks
(``kernels/robust_aggregate``, plain and cohort paths only — the config
rejects combinations whose stacks are never materialized).  With
``fault_model=None`` and ``aggregator_guard=None`` every path is
bit-for-bit the pre-fault engine (no extra scan inputs, no extra traced
ops) — the parity the pin tests hold.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.problem import ClientBucket, FederatedLogReg, VirtualBucket

#: client_pass(w, bucket_index, bucket, key) -> (Kb, d) deltas w_k - w
ClientPassFn = Callable[[jax.Array, int, ClientBucket, jax.Array], jax.Array]

#: dual_pass(w, bucket_index, bucket, state_b, key) -> (deltas, new_state_b);
#: state_b is any pytree of arrays with a leading client axis (Kb, ...)
DualClientPassFn = Callable[
    [jax.Array, int, ClientBucket, Any, jax.Array], Tuple[jax.Array, Any]]

#: chunk_pass(w, bucket_index, chunk_bucket, keys) -> (chunk, d) deltas.
#: The streamed round hands the pass a chunk-sized slice of the bucket and
#: the matching slice of ``split(bucket_key, Kb)`` — the exact per-client
#: keys the unchunked pass derives internally, so chunked and reference
#: rounds differ only in summation order.
ChunkClientPassFn = Callable[
    [jax.Array, int, ClientBucket, jax.Array], jax.Array]

#: dual chunk_pass(w, bucket_index, chunk_bucket, state_chunk, keys)
#: -> (deltas, new_state_chunk)
DualChunkClientPassFn = Callable[
    [jax.Array, int, ClientBucket, Any, jax.Array], Tuple[jax.Array, Any]]

_WEIGHTINGS = ("nk", "uniform", "sum")
_SCALINGS = ("none", "diag")
_AGGREGATORS = ("dense", "pallas")
_GUARDS = ("clip", "trimmed_mean", "median")
_ORDER_STAT_GUARDS = ("trimmed_mean", "median")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Round-scheduling knobs shared by every federated algorithm."""

    participation: float = 1.0     # i.i.d. per-round client participation prob
    weighting: str = "nk"          # "nk" (n_k/n) | "uniform" (1/K) | "sum" (1)
    server_scaling: str = "none"   # "none" | "diag" (apply a_diag coordinatewise)
    aggregator: str = "dense"      # "dense" | "pallas" (scaled_aggregate kernel)
    # None -> materialize each bucket's full (Kb, d) delta stack (the
    # bit-exact reference path).  An int streams the client axis instead:
    # each bucket's pass runs over client chunks of this size via lax.scan,
    # accumulating the weighted delta *sum* (a (d,) vector) chunk by chunk,
    # so peak delta memory is O(client_chunk·d) — the paper-scale K=10,000
    # regime on a CPU box.  Chunked rounds match the reference to float
    # tolerance (summation order), not bit-for-bit.
    client_chunk: Optional[int] = None
    # None -> under partial participation, every client's pass still runs
    # and the Bernoulli draw merely zeroes non-participants' weights.  An
    # int caps the *computed* cohort instead: each bucket gathers only the
    # sampled clients, padded to a fixed per-bucket capacity
    # min(cohort, Kb, cohort_capacity(participation, Kb)) so jit shapes
    # stay static, and runs passes + aggregation over O(participation·K)
    # clients.  Size the ceiling with :func:`cohort_capacity` on the
    # largest bucket; a draw that overflows the capacity falls back to
    # the masked full-bucket pass for that bucket (lax.cond), so results
    # never depend on the capacity.  No-op at participation=1.0.
    cohort: Optional[int] = None
    # False -> buckets carry materialized rows (ClientBucket).  True -> the
    # problem was built by build_virtual_problem: buckets are VirtualBucket
    # specs and every round path regenerates the rows it consumes inside
    # the traced body through problem.virtual — one chunk (or one gathered
    # cohort) at a time, so peak data memory is independent of K.
    virtual_data: bool = False
    # None -> trust every returned delta bit-for-bit (the historical path).
    # "clip" -> per-client robustness folded into every round path: a client
    # whose delta has any non-finite coordinate is rejected (delta zeroed —
    # it counts as "returned no update" while keeping its weight in the
    # realized mass, so the reweight scalar is unchanged), and
    # guard_clip_norm caps each surviving delta's L2 norm.  Both are
    # per-client scalars, so they fold into the streamed fused_accumulate
    # chunk entries at O(chunk·d).  "trimmed_mean" / "median" ->
    # coordinate-wise order statistics over the valid (participating,
    # all-finite) clients via kernels/robust_aggregate — a bounded fraction
    # of adversarial deltas cannot move the aggregate arbitrarily.  Order
    # statistics need the materialized (K, d) stacks, so these are rejected
    # with client_chunk / virtual_data (the streamed body only ever holds
    # one chunk and a running sum — a sort cannot be folded chunk-by-chunk)
    # and with weighting="sum" (dual iterates must track the frozen dual
    # blocks through the exact plain sum); they are unweighted by
    # construction and skip participation reweighting.
    aggregator_guard: Optional[str] = None
    # L2 norm cap per client delta; requires aggregator_guard="clip".
    guard_clip_norm: Optional[float] = None
    # per-side trim fraction for aggregator_guard="trimmed_mean".
    guard_trim: float = 0.1

    @staticmethod
    def _check_optional_count(value, name: str):
        # NB: bool is a subclass of int, so isinstance(True, int) is true —
        # reject bools explicitly or cohort=True silently means cohort=1.
        if value is not None and (
                isinstance(value, bool) or not isinstance(value, int)
                or value < 1):
            raise ValueError(f"{name} must be a positive int or None")

    def __post_init__(self):
        if self.weighting not in _WEIGHTINGS:
            raise ValueError(f"weighting must be one of {_WEIGHTINGS}")
        if self.server_scaling not in _SCALINGS:
            raise ValueError(f"server_scaling must be one of {_SCALINGS}")
        if self.aggregator not in _AGGREGATORS:
            raise ValueError(f"aggregator must be one of {_AGGREGATORS}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")
        self._check_optional_count(self.client_chunk, "client_chunk")
        self._check_optional_count(self.cohort, "cohort")
        if not isinstance(self.virtual_data, bool):
            raise ValueError("virtual_data must be a bool")
        if (self.aggregator_guard is not None
                and self.aggregator_guard not in _GUARDS):
            raise ValueError(f"aggregator_guard must be one of {_GUARDS} "
                             "or None")
        if self.aggregator_guard in _ORDER_STAT_GUARDS:
            if self.client_chunk is not None:
                raise ValueError(
                    f"aggregator_guard='{self.aggregator_guard}' needs the "
                    "materialized (K, d) delta stacks; the streamed path "
                    "(client_chunk) only ever holds one chunk and a running "
                    "sum, and order statistics cannot be folded "
                    "chunk-by-chunk — use the plain or cohort path, or "
                    "aggregator_guard='clip'")
            if self.virtual_data:
                raise ValueError(
                    f"aggregator_guard='{self.aggregator_guard}' is not "
                    "available with virtual_data (virtual rounds never "
                    "materialize the full delta stacks) — use "
                    "aggregator_guard='clip'")
            if self.weighting == "sum":
                raise ValueError(
                    "order-statistic guards replace the weighted sum with "
                    "an unweighted coordinate-wise statistic; "
                    "weighting='sum' (dual methods tracking frozen dual "
                    "blocks) requires the exact plain sum — use "
                    "aggregator_guard='clip'")
        if not 0.0 <= self.guard_trim < 0.5:
            raise ValueError("guard_trim must be in [0, 0.5)")
        if self.guard_clip_norm is not None:
            if (isinstance(self.guard_clip_norm, bool)
                    or not isinstance(self.guard_clip_norm, (int, float))
                    or self.guard_clip_norm <= 0):
                raise ValueError(
                    "guard_clip_norm must be a positive number or None")
            if self.aggregator_guard != "clip":
                raise ValueError(
                    "guard_clip_norm requires aggregator_guard='clip'")


@functools.partial(jax.jit, static_argnames=("scaled",))
def _apply_server_update(w, agg, a_diag, scaled: bool):
    return w + (a_diag if scaled else 1.0) * agg


def _kernel(name: str) -> Callable:
    """Resolve a delta-native aggregation kernel for this backend — the
    Pallas entry on TPU, the identical fused jnp oracle elsewhere (the same
    auto policy as the solvers' ``use_kernel``; interpret-mode emulation is
    for the parity tests, never the hot path)."""
    if jax.default_backend() == "tpu":
        from repro.kernels import ops
        return getattr(ops, name)
    from repro.kernels import ref
    return getattr(ref, name + "_ref")


def cohort_capacity(participation: float, num_clients: int, *,
                    z: float = 6.0) -> int:
    """Static per-bucket cohort capacity for ``EngineConfig.cohort``.

    The realized cohort is Binomial(Kb, participation); a capacity of
    mean + z·σ (+1) covers the draw with overwhelming probability (z=6 ⇒
    overflow odds ~1e-9 per bucket per round), so the lax.cond fallback to
    the masked full-bucket pass is for correctness, not a path that ever
    runs in practice.  Pass the *largest* bucket's client count — the
    engine right-sizes every bucket's gather on its own to
    ``min(cohort, Kb, cohort_capacity(participation, Kb))``, so the knob
    only needs to be a safe ceiling.
    """
    if not 0.0 < participation <= 1.0:
        raise ValueError("participation must be in (0, 1]")
    if num_clients < 1:
        raise ValueError("num_clients must be >= 1")
    mean = participation * num_clients
    sd = math.sqrt(participation * (1.0 - participation) * num_clients)
    return max(1, min(num_clients, int(math.ceil(mean + z * sd)) + 1))


class RoundEngine:
    """Owns client sampling, the vmap-over-bucket client pass, and server
    aggregation.  Algorithms provide a :data:`ClientPassFn`; the engine never
    looks inside the deltas it aggregates."""

    def __init__(self, problem: FederatedLogReg, cfg: EngineConfig = EngineConfig(),
                 *, a_diag: Optional[jax.Array] = None,
                 participation_model: Optional[Any] = None,
                 fault_model: Optional[Any] = None):
        self.problem = problem
        self.cfg = cfg
        if participation_model is not None and not hasattr(
                participation_model, "masks"):
            raise ValueError(
                "participation_model must implement "
                "masks(key, round_index, offsets, sizes) — see "
                "repro.fleet.participation.ParticipationModel")
        self.participation_model = participation_model
        if fault_model is not None and not hasattr(fault_model, "apply"):
            raise ValueError(
                "fault_model must implement "
                "apply(deltas, round_index, client_ids) — see "
                "repro.fleet.faults.FaultModel")
        self.fault_model = fault_model
        if cfg.server_scaling == "diag" and a_diag is None:
            raise ValueError("server_scaling='diag' requires an a_diag")
        layout = getattr(problem, "virtual", None)
        if cfg.virtual_data and layout is None:
            raise ValueError(
                "virtual_data=True requires a problem built by "
                "build_virtual_problem (problem.virtual is the layout)")
        if layout is not None and not cfg.virtual_data:
            raise ValueError(
                "the problem carries a virtual layout (no materialized "
                "rows); set EngineConfig(virtual_data=True) to run rounds "
                "on it")
        self._virtual = layout if cfg.virtual_data else None
        self.a_diag = jnp.ones((problem.d,)) if a_diag is None else a_diag
        # per-bucket first-client index — the fold_in offset of every bucket's
        # round key, precomputed once so compiled rounds close over constants
        wi = 0
        offsets = []
        for b in problem.buckets:
            offsets.append(wi)
            wi += b.num_clients
        self._offsets = tuple(offsets)
        self._sizes = tuple(b.num_clients for b in problem.buckets)

    def _round_index_arg(self, round_index):
        """Normalize the round index the masks are drawn for.  ``None`` is
        the legacy calling convention — fine for the Bernoulli draw and any
        round-invariant model, an error for round-dependent ones (traces),
        whose masks are a function of ``(seed, r)`` by contract."""
        if round_index is None:
            if (self.participation_model is not None and
                    getattr(self.participation_model, "needs_round_index",
                            False)):
                raise ValueError(
                    "this engine's participation model is round-dependent; "
                    "pass round_index (solvers forward state.round)")
            if (self.fault_model is not None and
                    getattr(self.fault_model, "needs_round_index", True)):
                raise ValueError(
                    "this engine has a fault model; fault draws are a "
                    "function of the round by contract — pass round_index "
                    "(solvers forward state.round)")
            return jnp.asarray(0, jnp.int32)
        return jnp.asarray(round_index, jnp.int32)

    def _realize(self, bucket):
        """Materialize a virtual bucket's rows through the problem's
        layout (traceable — this is the call that runs *inside* scan/cond
        bodies so only the about-to-be-consumed rows are ever live).
        No-op on an already-materialized :class:`ClientBucket`."""
        if self._virtual is not None and isinstance(bucket, VirtualBucket):
            return self._virtual.realize(bucket)
        return bucket

    # -- fault injection & per-client guard ------------------------------- #

    def _bucket_ids(self, wi: int, num_clients: int) -> jax.Array:
        """Global client ids for the bucket whose first client is ``wi`` —
        the identity the fault model's draws fold in, so the same clients
        are corrupted identically on every round path."""
        return jnp.uint32(wi) + jnp.arange(num_clients, dtype=jnp.uint32)

    def _fault_round(self, round_index) -> Optional[jax.Array]:
        """The round index fault draws are a function of — ``None`` (and
        zero traced overhead) when no fault model is installed."""
        if self.fault_model is None:
            return None
        return self._round_index_arg(round_index)

    def _faulted(self, deltas, r, ids, live):
        """Corrupt the *returned* clients' deltas through the fault model.

        ``live`` (weights or a {0,1} mask; ``None`` = everyone) restricts
        corruption to clients actually in the round: a client that never
        reports cannot deliver a corrupted delta — and a NaN planted on a
        zero-weight row would still poison the weighted sum (0·NaN = NaN),
        so the ``jnp.where`` *selects* the honest delta instead of relying
        on the weight to cancel it."""
        if self.fault_model is None:
            return deltas
        bad = self.fault_model.apply(deltas, r, ids)
        if live is None:
            return bad
        keep = live.reshape((-1,) + (1,) * (deltas.ndim - 1)) > 0
        return jnp.where(keep, bad, deltas)

    def _order_stat(self) -> bool:
        return self.cfg.aggregator_guard in _ORDER_STAT_GUARDS

    def _guard_clip(self, deltas):
        """The "clip" guard: reject (zero) any client delta with a
        non-finite coordinate, then cap the survivors' L2 norms.  Both are
        per-client transforms of a delta block of any leading shape, which
        is what lets them fold into the streamed chunk entries."""
        if self.cfg.aggregator_guard != "clip":
            return deltas
        finite = jnp.isfinite(deltas).all(axis=-1, keepdims=True)
        safe = jnp.where(finite, deltas, jnp.zeros_like(deltas))
        cn = self.cfg.guard_clip_norm
        if cn is not None:
            nrm = jnp.sqrt((safe.astype(jnp.float32) ** 2).sum(
                axis=-1, keepdims=True))
            fac = jnp.minimum(1.0, cn / jnp.maximum(nrm, 1e-30))
            safe = safe * fac.astype(safe.dtype)
        return safe

    def _robust_apply(self, w, deltas_all, valid):
        """Order-statistic server update over the stacked (K, d) deltas:
        rows that are invalid (non-participants) or carry any non-finite
        coordinate are excluded, and the kernel's coordinate-wise trimmed
        mean / median of the rest updates the iterate."""
        finite = jnp.isfinite(deltas_all).all(axis=1)
        valid = valid & finite
        a = (self.a_diag if self.cfg.server_scaling == "diag"
             else jnp.ones_like(w))
        return _kernel("robust_aggregate")(
            w, deltas_all, valid, a, self.cfg.guard_trim,
            self.cfg.aggregator_guard).astype(w.dtype)

    # -- step 3: sampling & weighting ------------------------------------- #

    def bucket_weights(self, wi: int, num_clients: int) -> jax.Array:
        """Aggregation weights for the bucket whose first client is ``wi``."""
        if self.cfg.weighting == "uniform":
            return jnp.full((num_clients,), 1.0 / self.problem.num_clients)
        if self.cfg.weighting == "sum":
            return jnp.ones((num_clients,))
        return self.problem.client_weights[wi : wi + num_clients]

    def participation_mask(self, bucket_key: jax.Array, num_clients: int) -> jax.Array:
        """i.i.d. Bernoulli(participation) mask, 1.0 = client is in-round."""
        return (jax.random.uniform(jax.random.fold_in(bucket_key, 997),
                                   (num_clients,))
                < self.cfg.participation).astype(jnp.float32)

    def participation_masks(self, key: jax.Array,
                            round_index: Optional[Any] = None
                            ) -> Optional[List[jax.Array]]:
        """The round's per-bucket participation masks, drawn **once** from
        the round key's ``fold_in`` chain — ``None`` under full
        participation.

        This is the single draw both consumers share: state freezing in
        :meth:`round_with_state` and weight zeroing in :meth:`aggregate`
        receive the same mask list instead of each re-deriving the same
        Bernoulli draw per bucket.

        With a ``participation_model`` installed, the draw is delegated to
        ``model.masks(key, round_index, offsets, sizes)`` — trace-driven
        availability/straggler masks instead of the i.i.d. Bernoulli, same
        contract (list of per-bucket float {0,1} vectors, or ``None`` for
        full participation).
        """
        if self.participation_model is not None:
            return self.participation_model.masks(
                key, self._round_index_arg(round_index), self._offsets,
                self._sizes)
        if self.cfg.participation >= 1.0:
            return None
        return [self.participation_mask(jax.random.fold_in(key, wi),
                                        b.num_clients)
                for wi, b in zip(self._offsets, self.problem.buckets)]

    # -- step 4: aggregation ----------------------------------------------- #

    def _reweightable(self, masks) -> bool:
        """Reweighting by expected/realized mass keeps the *average*
        direction unbiased; a "sum" aggregation must stay the plain partial
        sum — for dual methods each participant's delta enters exactly once
        so the primal iterate keeps tracking the
        (frozen-for-non-participants) dual blocks, w = (1/λn)Xα.  When this
        is False the mass reductions are skipped outright instead of being
        traced as dead computation into every compiled dual-method round."""
        return masks is not None and self.cfg.weighting != "sum"

    @staticmethod
    def _reweight_scale(total_mass, expected_mass):
        """The unbiased-participation reweight scalar (one definition for
        the materialized and streamed paths)."""
        return expected_mass / jnp.maximum(total_mass, 1e-9)

    def _finish_dense(self, w, agg, scale):
        if scale is not None:
            agg = agg * scale
        return _apply_server_update(w, agg, self.a_diag,
                                    self.cfg.server_scaling == "diag")

    def aggregate(self, w: jax.Array, deltas_by_bucket: Sequence[jax.Array],
                  key: jax.Array, *,
                  masks: Optional[Sequence[jax.Array]] = None) -> jax.Array:
        """Weight, subsample, reweight, scale, and apply the client deltas.

        ``deltas_by_bucket[i]`` is the (Kb, d) output of the client pass for
        bucket i; ``key`` must be the same round key handed to the passes so
        the participation draw is tied to the round.  ``masks`` are the
        round's precomputed :meth:`participation_masks`; if omitted they are
        drawn here from the same chain (bit-identical either way).
        """
        cfg = self.cfg
        pallas = cfg.aggregator == "pallas"
        if masks is None:
            masks = self.participation_masks(key)
        if self._order_stat():
            deltas_all = jnp.concatenate(list(deltas_by_bucket), axis=0)
            if masks is not None:
                valid = jnp.concatenate(list(masks)) > 0
            else:
                valid = jnp.ones((deltas_all.shape[0],), bool)
            return self._robust_apply(w, deltas_all, valid)
        reweight = self._reweightable(masks)
        agg = jnp.zeros_like(w)
        stacked: List[jax.Array] = []
        stacked_wts: List[jax.Array] = []
        total_mass = jnp.zeros(())
        expected_mass = jnp.zeros(())
        for i, (wi, b, deltas) in enumerate(zip(self._offsets,
                                                self.problem.buckets,
                                                deltas_by_bucket)):
            deltas = self._guard_clip(deltas)
            wts = self.bucket_weights(wi, b.num_clients)
            if masks is not None:
                sel = masks[i]
                if reweight:
                    total_mass = total_mass + (wts * sel).sum()
                    expected_mass = expected_mass + wts.sum()
                wts = wts * sel
            if pallas:
                stacked.append(deltas)
                stacked_wts.append(wts)
            else:
                agg = agg + (wts[:, None] * deltas).sum(axis=0)

        scale = self._reweight_scale(total_mass, expected_mass) \
            if reweight else None

        if pallas:
            # Delta-native single HBM pass: stacked deltas go to the kernel
            # as-is, with the reweight scalar and the A epilogue folded in —
            # no (K, d) w^t + δ materialization.  Same auto policy as the
            # solvers' use_kernel: the Pallas kernel on TPU, the identical
            # fused jnp expression elsewhere (interpret-mode emulation is
            # for the parity tests, not the hot path).
            wts_all = jnp.concatenate(stacked_wts)
            deltas_all = jnp.concatenate(stacked, axis=0)
            a = self.a_diag if cfg.server_scaling == "diag" else jnp.ones_like(w)
            s = scale if scale is not None else 1.0
            return _kernel("fused_aggregate")(
                w, deltas_all, wts_all, a, s).astype(w.dtype)

        return self._finish_dense(w, agg, scale)

    # -- steps 2-4: one full round ----------------------------------------- #

    def round(self, w: jax.Array, key: jax.Array,
              client_pass: ClientPassFn, *,
              round_index: Optional[Any] = None) -> jax.Array:
        """Run the client passes over every bucket, then aggregate.

        Each bucket's pass receives ``fold_in(key, wi)`` where ``wi`` is the
        bucket's first client index — the same key the round's single
        participation draw uses for that bucket.  ``round_index`` feeds
        round-dependent participation models (availability traces) and the
        fault model's draws; the Bernoulli draw ignores it.

        With a fault model installed, each bucket's deltas are corrupted
        between the pass and aggregation — the wire, not the client.
        """
        masks = self.participation_masks(key, round_index)
        r = self._fault_round(round_index)
        deltas: List[jax.Array] = []
        for bi, (wi, b) in enumerate(zip(self._offsets, self.problem.buckets)):
            kb = jax.random.fold_in(key, wi)
            d_b = client_pass(w, bi, self._realize(b), kb)
            if self.fault_model is not None:
                d_b = self._faulted(d_b, r, self._bucket_ids(wi, b.num_clients),
                                    masks[bi] if masks is not None else None)
            deltas.append(d_b)
        return self.aggregate(w, deltas, key, masks=masks)

    def round_with_state(self, w: jax.Array, states: Sequence[Any],
                         key: jax.Array, client_pass: DualClientPassFn, *,
                         round_index: Optional[Any] = None
                         ) -> Tuple[jax.Array, List[Any]]:
        """:meth:`round` for algorithms with per-client auxiliary state.

        ``states[i]`` is bucket i's state — any pytree of arrays whose leading
        axis is the bucket's client axis (e.g. CoCoA+'s dual blocks α_k of
        shape (Kb, m_pad), or the Primal Method's g_k of shape (Kb, d)).  The
        pass receives it alongside the bucket and returns the updated state
        with the deltas; deltas flow through the same :meth:`aggregate` path
        (weighting/scaling/participation) as stateless rounds.

        Under partial participation, a client whose aggregation weight is
        zeroed by the round's Bernoulli draw also keeps its previous state —
        the round's masks are drawn once (:meth:`participation_masks`) and
        handed to both state freezing and aggregation, so primal and dual
        views never diverge.
        """
        masks = self.participation_masks(key, round_index)
        r = self._fault_round(round_index)
        deltas: List[jax.Array] = []
        new_states: List[Any] = []
        for bi, (wi, b) in enumerate(zip(self._offsets, self.problem.buckets)):
            kb = jax.random.fold_in(key, wi)
            d_b, s_b = client_pass(w, bi, self._realize(b), states[bi], kb)
            if self.fault_model is not None:
                # the wire, not the client: the delta is corrupted, the
                # client's own aux state is whatever its pass computed
                d_b = self._faulted(d_b, r, self._bucket_ids(wi, b.num_clients),
                                    masks[bi] if masks is not None else None)
            if masks is not None:
                sel = masks[bi]
                s_b = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(
                        sel.reshape((b.num_clients,) + (1,) * (new.ndim - 1))
                        > 0, new, old),
                    s_b, states[bi])
            deltas.append(d_b)
            new_states.append(s_b)
        return self.aggregate(w, deltas, key, masks=masks), new_states

    # -- the streamed round: O(client_chunk · d) peak delta memory ---------- #

    def client_keys(self, bucket_key: jax.Array, num_clients: int) -> jax.Array:
        """The bucket's per-client keys — ``split(bucket_key, Kb)``, the
        exact split every client pass historically performed internally.
        The streamed round hoists it here so a chunk-sized pass can receive
        the *same* per-client keys the unchunked pass would have used."""
        return jax.random.split(bucket_key, num_clients)

    @staticmethod
    def _pad_clients(x: jax.Array, pad: int) -> jax.Array:
        if pad == 0:
            return x
        return jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)

    def _stream_bucket(self, w, bi: int, bucket: ClientBucket, kb, wts,
                       chunk_pass, state_b=None, sel=None, keys=None,
                       ids=None, r=None):
        """Run one bucket's client pass chunk-by-chunk, returning the
        bucket's weighted delta **sum** (a (d,) vector) and — for dual-state
        passes — the updated bucket state.

        The client axis is padded to a multiple of ``client_chunk`` with
        zero-weight, n_k = 0 clients (an exact no-op in the aggregate) and
        reshaped to (num_chunks, chunk, ...); ``lax.scan`` folds the chunks
        so only one (chunk, d) delta block is ever live.

        ``keys`` overrides the per-client key derivation — the cohort path
        streams a *gathered* bucket and must hand each gathered client the
        key it would have received at its original position, not a fresh
        ``split`` over the gathered axis.

        Under ``virtual_data`` the scan carries (client_ids, n_k) instead
        of rows, and the body regenerates the chunk's rows through the
        problem's :class:`~repro.core.problem.VirtualLayout` before the
        pass — only one (chunk, m_pad, nnz) row block is ever live, so
        peak data memory is independent of K.
        """
        virtual = (self._virtual is not None
                   and isinstance(bucket, VirtualBucket))
        Kb = bucket.num_clients
        chunk = min(self.cfg.client_chunk, Kb)
        pad = (-Kb) % chunk
        nch = (Kb + pad) // chunk
        if keys is None:
            keys = self.client_keys(kb, Kb)
        if pad:
            # padded clients carry weight 0; their key is never consumed in
            # a way that matters, but must be a valid key array
            keys = jnp.concatenate(
                [keys, jnp.broadcast_to(keys[:1], (pad,) + keys.shape[1:])])

        def chunked(x):
            x = self._pad_clients(x, pad)
            return x.reshape((nch, chunk) + x.shape[1:])

        if virtual:
            # padded clients have cid 0 but n_k 0 — client_rows_padded
            # zeroes all their rows, so they are exact no-ops downstream
            xs = {
                "cid": chunked(bucket.client_ids),
                "n_k": chunked(bucket.n_k),
                "keys": keys.reshape((nch, chunk) + keys.shape[1:]),
                "wts": chunked(wts),
            }
        else:
            xs = {
                "idx": chunked(bucket.idx), "val": chunked(bucket.val),
                "y": chunked(bucket.y), "n_k": chunked(bucket.n_k),
                "keys": keys.reshape((nch, chunk) + keys.shape[1:]),
                "wts": chunked(wts),
            }
        if state_b is not None:
            xs["state"] = jax.tree_util.tree_map(chunked, state_b)
        if sel is not None:
            xs["sel"] = chunked(sel)
        if self.fault_model is not None:
            # the chunk's global client ids ride through the scan so fault
            # draws see the same identities as every other round path; the
            # xs entry only exists under a fault model, so fault-free scans
            # keep their historical structure (and bits) exactly.  Pad ids
            # are 0 but pad weights are 0, so _faulted leaves them honest.
            xs["ids"] = chunked(jnp.asarray(ids, jnp.uint32))
        fused = self.cfg.aggregator == "pallas"
        m_pad = bucket.m_pad

        def body(acc, x):
            if virtual:
                cb = self._virtual.materialize(x["cid"], x["n_k"], m_pad)
            else:
                cb = ClientBucket(x["idx"], x["val"], x["y"], x["n_k"])
            if state_b is None:
                deltas = chunk_pass(w, bi, cb, x["keys"])
                s_new = None
            else:
                deltas, s_new = chunk_pass(w, bi, cb, x["state"], x["keys"])
                if sel is not None:
                    s_new = jax.tree_util.tree_map(
                        lambda new, old: jnp.where(
                            x["sel"].reshape((chunk,) + (1,) * (new.ndim - 1))
                            > 0, new, old),
                        s_new, x["state"])
            if self.fault_model is not None:
                # live = the chunk's (already sel-zeroed) weights: only
                # clients actually contributing to the sum can be faulted
                deltas = self._faulted(deltas, r, x["ids"], x["wts"])
            deltas = self._guard_clip(deltas)
            if fused:
                # the kernel's init/acc split with an identity epilogue
                acc = _kernel("fused_accumulate")(acc, deltas, x["wts"])
            else:
                acc = acc + (x["wts"][:, None] * deltas).sum(axis=0)
            return acc, s_new

        acc, s_stack = jax.lax.scan(body, jnp.zeros_like(w), xs)
        if state_b is None:
            return acc, None
        new_state = jax.tree_util.tree_map(
            lambda a: a.reshape((nch * chunk,) + a.shape[2:])[:Kb], s_stack)
        return acc, new_state

    def _streamed_round(self, w, key, chunk_pass, states, masks, *,
                        round_index=None):
        # The keyed-chunk-pass round body: per-bucket work goes through
        # _masked_bucket, which streams when cfg.client_chunk is set and
        # otherwise runs the direct keyed pass over the (realized) bucket —
        # so this one body serves round_streamed AND round_virtual.
        cfg = self.cfg
        r = self._fault_round(round_index)
        reweight = self._reweightable(masks)
        acc = jnp.zeros_like(w)
        total_mass = jnp.zeros(())
        expected_mass = jnp.zeros(())
        new_states: Optional[List[Any]] = [] if states is not None else None
        for bi, (wi, b) in enumerate(zip(self._offsets, self.problem.buckets)):
            kb = jax.random.fold_in(key, wi)
            wts = self.bucket_weights(wi, b.num_clients)
            sel = masks[bi] if masks is not None else None
            if sel is not None:
                if reweight:
                    total_mass = total_mass + (wts * sel).sum()
                    expected_mass = expected_mass + wts.sum()
                wts = wts * sel
            acc_b, s_b = self._masked_bucket(
                w, bi, b, kb, self.client_keys(kb, b.num_clients), wts, sel,
                chunk_pass,
                state_b=states[bi] if states is not None else None,
                ids=(self._bucket_ids(wi, b.num_clients)
                     if self.fault_model is not None else None), r=r)
            acc = acc + acc_b
            if new_states is not None:
                new_states.append(s_b)
        scale = self._reweight_scale(total_mass, expected_mass) \
            if reweight else None

        if cfg.aggregator == "pallas":
            a = self.a_diag if cfg.server_scaling == "diag" else jnp.ones_like(w)
            s = scale if scale is not None else 1.0
            w_next = _kernel("fused_epilogue")(w, acc, a, s).astype(w.dtype)
        else:
            w_next = self._finish_dense(w, acc, scale)
        return w_next, new_states

    def round_streamed(self, w: jax.Array, key: jax.Array,
                       chunk_pass: ChunkClientPassFn, *,
                       round_index: Optional[Any] = None) -> jax.Array:
        """:meth:`round` with the client axis streamed in ``client_chunk``
        chunks — the weighted delta sum accumulates chunk-by-chunk and the
        (Kb, d) stacks are never materialized.  Same weighting /
        participation / scaling semantics and the same per-client key chain
        as :meth:`round`; results agree to float tolerance (summation
        order), not bit-for-bit.
        """
        if self.cfg.client_chunk is None:
            raise ValueError("round_streamed requires cfg.client_chunk")
        w_next, _ = self._streamed_round(
            w, key, chunk_pass, None,
            self.participation_masks(key, round_index),
            round_index=round_index)
        return w_next

    def round_streamed_with_state(self, w: jax.Array, states: Sequence[Any],
                                  key: jax.Array,
                                  chunk_pass: DualChunkClientPassFn, *,
                                  round_index: Optional[Any] = None
                                  ) -> Tuple[jax.Array, List[Any]]:
        """:meth:`round_with_state`, streamed.  The pass receives chunk-sized
        state slices and the frozen-state masking applies per chunk with the
        round's single Bernoulli draw; bucket states are reassembled in
        client order, so only the (chunk, d) delta block is extra memory."""
        if self.cfg.client_chunk is None:
            raise ValueError("round_streamed_with_state requires "
                             "cfg.client_chunk")
        return self._streamed_round(w, key, chunk_pass, list(states),
                                    self.participation_masks(key, round_index),
                                    round_index=round_index)

    # -- the virtual round: rows regenerated inside the traced body --------- #

    def round_virtual(self, w: jax.Array, key: jax.Array,
                      chunk_pass: ChunkClientPassFn, *,
                      round_index: Optional[Any] = None) -> jax.Array:
        """:meth:`round` over on-demand data: each bucket's rows are
        regenerated through the problem's virtual layout inside the round
        body — chunk-by-chunk under ``lax.scan`` when ``client_chunk`` is
        set (peak data memory O(client_chunk·m_pad·nnz), the K=10⁶
        regime), one whole bucket at a time otherwise.  Same weighting /
        participation / key chain as :meth:`round`; per-client quantities
        are bit-for-bit (regenerated rows ARE the materialized rows),
        iterates match to float tolerance (summation order).
        """
        if not self.cfg.virtual_data:
            raise ValueError("round_virtual requires cfg.virtual_data")
        w_next, _ = self._streamed_round(
            w, key, chunk_pass, None,
            self.participation_masks(key, round_index),
            round_index=round_index)
        return w_next

    def round_virtual_with_state(self, w: jax.Array, states: Sequence[Any],
                                 key: jax.Array,
                                 chunk_pass: DualChunkClientPassFn, *,
                                 round_index: Optional[Any] = None
                                 ) -> Tuple[jax.Array, List[Any]]:
        """:meth:`round_with_state` over on-demand data — aux state still
        lives materialized (it is O(K·m_pad), the algorithm's own memory,
        not the dataset's); only the rows are regenerated."""
        if not self.cfg.virtual_data:
            raise ValueError("round_virtual_with_state requires "
                             "cfg.virtual_data")
        return self._streamed_round(w, key, chunk_pass, list(states),
                                    self.participation_masks(key, round_index),
                                    round_index=round_index)

    # -- the cohort round: O(participation · K) client passes --------------- #

    def _bucket_accumulate(self, w, deltas, wts):
        """One bucket's weighted delta sum as a (d,) vector — the fused
        kernel's accumulate entry under ``aggregator="pallas"``, the plain
        jnp weighted sum otherwise."""
        if self.cfg.aggregator == "pallas":
            return _kernel("fused_accumulate")(jnp.zeros_like(w), deltas, wts)
        return (wts[:, None] * deltas).sum(axis=0)

    def _masked_bucket(self, w, bi: int, bucket: ClientBucket, kb, keys,
                       wtsz, sel, chunk_pass, state_b=None, ids=None, r=None):
        """The masked reference body over the *keyed* chunk-pass contract:
        every client's pass runs, zero-weighted non-participants drop out of
        the sum, and dual state freezes where ``sel`` is 0.  This is both
        the cohort path's overflow fallback and its participation=1.0 /
        cap≥Kb degenerate case, so the two lax.cond branches share one
        aggregation recipe."""
        if self.cfg.client_chunk is not None:
            return self._stream_bucket(w, bi, bucket, kb, wtsz, chunk_pass,
                                       state_b=state_b, sel=sel, keys=keys,
                                       ids=ids, r=r)
        bucket = self._realize(bucket)
        if state_b is None:
            deltas = chunk_pass(w, bi, bucket, keys)
            s_new = None
        else:
            deltas, s_new = chunk_pass(w, bi, bucket, state_b, keys)
            if sel is not None:
                s_new = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(
                        sel.reshape((bucket.num_clients,)
                                    + (1,) * (new.ndim - 1)) > 0, new, old),
                    s_new, state_b)
        if self.fault_model is not None:
            deltas = self._faulted(deltas, r, ids, wtsz)
        deltas = self._guard_clip(deltas)
        return self._bucket_accumulate(w, deltas, wtsz), s_new

    def _cohort_bucket(self, w, bi: int, bucket: ClientBucket, kb, wts, sel,
                       chunk_pass, state_b=None, ids=None, r=None):
        """One bucket's contribution with only the sampled clients computed.

        The round's Bernoulli draw ``sel`` is turned into a gather: the
        (at most ``cap``, the bucket's own right-sized static capacity —
        see below) participating clients' rows,
        weights, per-client keys, and aux-state slices move into a padded
        fixed-capacity cohort bucket (static shapes for jit), the keyed
        chunk pass runs over that O(cap) bucket, and dual state scatters
        back to its original client slots — everyone else's state is
        untouched, which *is* the freezing contract.  Padding slots carry
        weight 0 and n_k = 0 (exact no-ops in the aggregate, same trick as
        the streamed path's pad clients) and scatter out of bounds (mode
        "drop").  A draw with more participants than ``cap`` takes the
        lax.cond fallback: the masked full-bucket pass, identical to the
        no-cohort round.
        """
        Kb = bucket.num_clients
        # per-bucket static capacity: cfg.cohort is a ceiling; each bucket
        # right-sizes its own gather to its Binomial(Kb, p) draw, so small
        # buckets don't inherit the largest bucket's capacity and compute
        # nearly all of their clients anyway
        cap = min(self.cfg.cohort, Kb,
                  cohort_capacity(self.cfg.participation, Kb)
                  if self.cfg.participation < 1.0 else Kb)
        keys = self.client_keys(kb, Kb)
        wtsz = wts * sel if sel is not None else wts
        if sel is None or cap >= Kb:
            # nothing to gain from gathering — run the masked reference body
            return self._masked_bucket(w, bi, bucket, kb, keys, wtsz, sel,
                                       chunk_pass, state_b=state_b,
                                       ids=ids, r=r)
        count = jnp.count_nonzero(sel > 0)

        def cohort_branch(_):
            gidx = jnp.nonzero(sel > 0, size=cap, fill_value=0)[0]
            valid = jnp.arange(cap) < count
            if self._virtual is not None and isinstance(bucket, VirtualBucket):
                # gather only the cohort's *identities*; their rows are
                # regenerated below (realize / the streamed body) — data is
                # only ever produced for the O(cap) sampled clients
                g_bucket = VirtualBucket(
                    bucket.client_ids[gidx],
                    jnp.where(valid, bucket.n_k[gidx], 0), bucket.m_pad)
            else:
                g_bucket = ClientBucket(bucket.idx[gidx], bucket.val[gidx],
                                        bucket.y[gidx],
                                        jnp.where(valid, bucket.n_k[gidx], 0))
            g_keys = keys[gidx]
            g_wts = jnp.where(valid, wtsz[gidx], 0.0)
            # gathered global ids: fault draws fold in the client's original
            # identity, so the cohort corrupts exactly the clients the
            # masked path would (pad rows alias ids[0] but carry weight 0,
            # so _faulted leaves them honest)
            g_ids = ids[gidx] if self.fault_model is not None else None
            g_state = None if state_b is None else jax.tree_util.tree_map(
                lambda a: a[gidx], state_b)
            if self.cfg.client_chunk is not None:
                acc_b, s_new = self._stream_bucket(
                    w, bi, g_bucket, kb, g_wts, chunk_pass,
                    state_b=g_state, sel=None, keys=g_keys, ids=g_ids, r=r)
            elif state_b is None:
                deltas = chunk_pass(w, bi, self._realize(g_bucket), g_keys)
                if self.fault_model is not None:
                    deltas = self._faulted(deltas, r, g_ids, g_wts)
                acc_b = self._bucket_accumulate(w, self._guard_clip(deltas),
                                                g_wts)
                s_new = None
            else:
                deltas, s_new = chunk_pass(w, bi, self._realize(g_bucket),
                                           g_state, g_keys)
                if self.fault_model is not None:
                    deltas = self._faulted(deltas, r, g_ids, g_wts)
                acc_b = self._bucket_accumulate(w, self._guard_clip(deltas),
                                                g_wts)
            if state_b is None:
                return acc_b, None
            # scatter updated slices back to their original client slots;
            # padding rows target index Kb — out of bounds, dropped — and
            # non-gathered clients keep their old state (frozen).  Valid
            # gidx entries are unique, so the scatter is deterministic.
            scatter_idx = jnp.where(valid, gidx, Kb)
            new_state = jax.tree_util.tree_map(
                lambda old, new: old.at[scatter_idx].set(new, mode="drop"),
                state_b, s_new)
            return acc_b, new_state

        def masked_branch(_):
            return self._masked_bucket(w, bi, bucket, kb, keys, wtsz, sel,
                                       chunk_pass, state_b=state_b,
                                       ids=ids, r=r)

        return jax.lax.cond(count <= cap, cohort_branch, masked_branch, None)

    def _cohort_round(self, w, key, chunk_pass, states, masks, *,
                      round_index=None):
        """The cohort twin of :meth:`_streamed_round`: the same full-vector
        mass reductions (the reweighting contract never sees the gather —
        expected/realized mass come from the *complete* weight and mask
        vectors), with each bucket's delta sum produced by
        :meth:`_cohort_bucket` over only the sampled clients."""
        if self._order_stat():
            return self._cohort_round_robust(w, key, chunk_pass, states,
                                             masks, round_index=round_index)
        cfg = self.cfg
        r = self._fault_round(round_index)
        reweight = self._reweightable(masks)
        acc = jnp.zeros_like(w)
        total_mass = jnp.zeros(())
        expected_mass = jnp.zeros(())
        new_states: Optional[List[Any]] = [] if states is not None else None
        for bi, (wi, b) in enumerate(zip(self._offsets, self.problem.buckets)):
            kb = jax.random.fold_in(key, wi)
            wts = self.bucket_weights(wi, b.num_clients)
            sel = masks[bi] if masks is not None else None
            if sel is not None and reweight:
                total_mass = total_mass + (wts * sel).sum()
                expected_mass = expected_mass + wts.sum()
            acc_b, s_b = self._cohort_bucket(
                w, bi, b, kb, wts, sel, chunk_pass,
                state_b=states[bi] if states is not None else None,
                ids=(self._bucket_ids(wi, b.num_clients)
                     if self.fault_model is not None else None), r=r)
            acc = acc + acc_b
            if new_states is not None:
                new_states.append(s_b)
        scale = self._reweight_scale(total_mass, expected_mass) \
            if reweight else None

        if cfg.aggregator == "pallas":
            a = self.a_diag if cfg.server_scaling == "diag" else jnp.ones_like(w)
            s = scale if scale is not None else 1.0
            w_next = _kernel("fused_epilogue")(w, acc, a, s).astype(w.dtype)
        else:
            w_next = self._finish_dense(w, acc, scale)
        return w_next, new_states

    def _cohort_round_robust(self, w, key, chunk_pass, states, masks, *,
                             round_index=None):
        """The cohort body under an order-statistic guard: instead of each
        bucket folding into a weighted (d,) sum, every bucket contributes
        its (cap, d) gathered delta stack plus a validity flag per row, and
        one :meth:`_robust_apply` call takes the coordinate-wise trimmed
        mean / median across all buckets' valid rows.

        Two deliberate departures from :meth:`_cohort_bucket`:

        * **No ``lax.cond`` overflow fallback.**  The fallback's masked
          branch produces a (Kb, d) stack while the cohort branch produces
          (cap, d) — ``lax.cond`` requires equal shapes, so it cannot
          exist here.  A draw overflowing the z=6-sized capacity (odds
          ~1e-9 per bucket-round — :func:`cohort_capacity`) instead drops
          the participants beyond ``cap`` from the round: they are treated
          exactly like non-participants (state frozen, excluded from the
          statistic), a graceful degradation rather than a wrong answer.
        * **No mass reductions.**  Order statistics are unweighted and
          need no participation reweighting (the statistic is location-,
          not mass-based).
        """
        r = self._fault_round(round_index)
        stacks: List[jax.Array] = []
        valids: List[jax.Array] = []
        new_states: Optional[List[Any]] = [] if states is not None else None
        for bi, (wi, b) in enumerate(zip(self._offsets, self.problem.buckets)):
            kb = jax.random.fold_in(key, wi)
            Kb = b.num_clients
            keys = self.client_keys(kb, Kb)
            sel = masks[bi] if masks is not None else None
            ids = (self._bucket_ids(wi, Kb)
                   if self.fault_model is not None else None)
            state_b = states[bi] if states is not None else None
            cap = min(self.cfg.cohort, Kb,
                      cohort_capacity(self.cfg.participation, Kb)
                      if self.cfg.participation < 1.0 else Kb)
            if sel is None or cap >= Kb:
                # degenerate case: the full keyed pass, whole-bucket stack
                bucket = self._realize(b)
                if state_b is None:
                    deltas = chunk_pass(w, bi, bucket, keys)
                    s_new = None
                else:
                    deltas, s_new = chunk_pass(w, bi, bucket, state_b, keys)
                    if sel is not None:
                        s_new = jax.tree_util.tree_map(
                            lambda new, old: jnp.where(
                                sel.reshape((Kb,) + (1,) * (new.ndim - 1))
                                > 0, new, old),
                            s_new, state_b)
                if self.fault_model is not None:
                    deltas = self._faulted(deltas, r, ids, sel)
                stacks.append(deltas)
                valids.append(sel > 0 if sel is not None
                              else jnp.ones((Kb,), bool))
                if new_states is not None:
                    new_states.append(s_new)
                continue
            count = jnp.count_nonzero(sel > 0)
            gidx = jnp.nonzero(sel > 0, size=cap, fill_value=0)[0]
            gvalid = jnp.arange(cap) < count
            if self._virtual is not None and isinstance(b, VirtualBucket):
                g_bucket = VirtualBucket(
                    b.client_ids[gidx],
                    jnp.where(gvalid, b.n_k[gidx], 0), b.m_pad)
            else:
                g_bucket = ClientBucket(b.idx[gidx], b.val[gidx],
                                        b.y[gidx],
                                        jnp.where(gvalid, b.n_k[gidx], 0))
            g_keys = keys[gidx]
            g_ids = ids[gidx] if ids is not None else None
            if state_b is None:
                deltas = chunk_pass(w, bi, self._realize(g_bucket), g_keys)
                s_new = None
            else:
                g_state = jax.tree_util.tree_map(lambda a: a[gidx], state_b)
                deltas, s_new = chunk_pass(w, bi, self._realize(g_bucket),
                                           g_state, g_keys)
            if self.fault_model is not None:
                deltas = self._faulted(deltas, r, g_ids,
                                       gvalid.astype(jnp.float32))
            stacks.append(deltas)
            valids.append(gvalid)
            if new_states is not None:
                scatter_idx = jnp.where(gvalid, gidx, Kb)
                new_states.append(jax.tree_util.tree_map(
                    lambda old, new: old.at[scatter_idx].set(new,
                                                             mode="drop"),
                    state_b, s_new))
        w_next = self._robust_apply(w, jnp.concatenate(stacks, axis=0),
                                    jnp.concatenate(valids))
        return w_next, new_states

    def round_cohort(self, w: jax.Array, key: jax.Array,
                     chunk_pass: ChunkClientPassFn, *,
                     round_index: Optional[Any] = None) -> jax.Array:
        """:meth:`round` computing only the sampled cohort — same single
        Bernoulli draw, same weighting/reweighting/scaling semantics, same
        per-client key chain; results match the masked reference to float
        tolerance (summation order), not bit-for-bit.  At participation=1.0
        (or cap ≥ Kb) this degrades to the keyed full-bucket pass."""
        if self.cfg.cohort is None:
            raise ValueError("round_cohort requires cfg.cohort")
        w_next, _ = self._cohort_round(
            w, key, chunk_pass, None,
            self.participation_masks(key, round_index),
            round_index=round_index)
        return w_next

    def round_cohort_with_state(self, w: jax.Array, states: Sequence[Any],
                                key: jax.Array,
                                chunk_pass: DualChunkClientPassFn, *,
                                round_index: Optional[Any] = None
                                ) -> Tuple[jax.Array, List[Any]]:
        """:meth:`round_with_state` computing only the sampled cohort.  Aux
        state is gathered with the cohort and scattered back afterwards;
        non-participants' state is simply never touched, which coincides
        with the masked path's freezing bit-for-bit.  Cohort members'
        updates match the masked path to tight float tolerance (the
        overflow ``lax.cond`` compiles both branches, and XLA may round
        the per-client elementwise chain one ulp away from eager
        dispatch)."""
        if self.cfg.cohort is None:
            raise ValueError("round_cohort_with_state requires cfg.cohort")
        return self._cohort_round(w, key, chunk_pass, list(states),
                                  self.participation_masks(key, round_index),
                                  round_index=round_index)

    # -- the compiled round: O(1) dispatches per round ---------------------- #

    def _should_donate(self, donate: Optional[bool]) -> bool:
        # Donation is a no-op (with a warning) on CPU; default it off there.
        return jax.default_backend() != "cpu" if donate is None else donate

    def _require_chunk_pass(self, chunk_pass):
        if chunk_pass is None:
            raise ValueError(
                "cfg.client_chunk/cfg.cohort/cfg.virtual_data is set but no "
                "chunk_pass was supplied — streamed, cohort, and virtual "
                "rounds need the per-client-keyed chunk pass "
                "(chunk_pass(w, bi, chunk_bucket, keys, *ctx))")
        return chunk_pass

    def _use_cohort(self) -> bool:
        # Static dispatch: the gather only pays off when the draw actually
        # discards clients, so at participation=1.0 the knob is a no-op and
        # compile falls through to the streamed/materialized body.  A
        # participation model always counts as partial — its masks may drop
        # clients regardless of cfg.participation (which, with a model, is
        # the capacity-sizing bound, not the draw).
        return self.cfg.cohort is not None and (
            self.cfg.participation < 1.0
            or self.participation_model is not None)

    def compile(self, client_pass: Callable, *,
                prelude: Optional[Callable] = None,
                donate: Optional[bool] = None,
                chunk_pass: Optional[Callable] = None) -> Callable:
        """One federated round as a single compiled dispatch.

        Returns ``compiled_round(w, key) -> w_next``: the per-bucket client
        passes, the single participation draw, and the (optionally fused
        Pallas) aggregation all trace into one ``jax.jit`` over the
        precomputed ``fold_in`` offsets, with the iterate buffer donated on
        accelerator backends.

        ``prelude(w) -> tuple`` carries per-round *server* state — e.g.
        FSVRG's/DANE's full gradient, which the paper counts as its own round
        of communication.  It runs eagerly outside the jitted body (XLA
        fuses ``flat.grad`` differently under jit; keeping it out pins the
        compiled round to :meth:`round`, the reference implementation, up
        to the jit's re-association of the cross-bucket aggregation sum)
        and its results are appended to the pass's arguments:
        ``client_pass(w, bi, bucket, kb, *prelude(w))``.

        When ``cfg.client_chunk`` is set the same single ``jax.jit`` traces
        the **streamed** path (:meth:`round_streamed`) over ``chunk_pass``
        instead — peak delta memory O(client_chunk·d); :meth:`round` (and
        :meth:`reference`) stay the unchunked bit-exact reference.

        When ``cfg.cohort`` is set *and* participation < 1.0, the jitted
        body is the **cohort** path (:meth:`round_cohort`) over
        ``chunk_pass``: only the sampled clients' passes run — composed
        with ``client_chunk`` when both are set (the gathered cohort is
        streamed in chunks).

        Under ``cfg.virtual_data``, every dispatched body regenerates rows
        on demand (the cohort body generates only the gathered cohort's
        rows, the streamed body one chunk's rows per scan step); with
        neither ``cohort`` nor ``client_chunk`` set the jitted body is
        :meth:`round_virtual` over ``chunk_pass`` — bucket-at-a-time
        regeneration.
        """
        donate_args = (0,) if self._should_donate(donate) else ()

        if self._use_cohort():
            c_pass = self._require_chunk_pass(chunk_pass)

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, ctx, key, r):
                return self.round_cohort(
                    w, key,
                    lambda w_, bi, cb, ks: c_pass(w_, bi, cb, ks, *ctx),
                    round_index=r)
        elif self.cfg.client_chunk is not None:
            c_pass = self._require_chunk_pass(chunk_pass)

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, ctx, key, r):
                return self.round_streamed(
                    w, key,
                    lambda w_, bi, cb, ks: c_pass(w_, bi, cb, ks, *ctx),
                    round_index=r)
        elif self.cfg.virtual_data:
            c_pass = self._require_chunk_pass(chunk_pass)

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, ctx, key, r):
                return self.round_virtual(
                    w, key,
                    lambda w_, bi, cb, ks: c_pass(w_, bi, cb, ks, *ctx),
                    round_index=r)
        else:

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, ctx, key, r):
                return self.round(
                    w, key,
                    lambda w_, bi, b, kb: client_pass(w_, bi, b, kb, *ctx),
                    round_index=r)

        def compiled_round(w, key, round_index=None):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            return _body(w, ctx, key, self._round_index_arg(round_index))

        return compiled_round

    def reference(self, client_pass: Callable, *,
                  prelude: Optional[Callable] = None,
                  chunk_pass: Optional[Callable] = None) -> Callable:
        """The eager twin of :meth:`compile` — same calling convention,
        Python-loop dispatch through :meth:`round`.  The pin tests (and the
        round-latency benchmark's "eager dense" baseline) call this.

        Under ``cfg.virtual_data`` there are no per-bucket closures to
        reference (the rows don't exist until a round asks for them), so
        the eager path runs :meth:`round_virtual` over ``chunk_pass`` —
        bucket-at-a-time regeneration, Python-loop dispatch."""
        if self.cfg.virtual_data:
            c_pass = self._require_chunk_pass(chunk_pass)

            def reference_round(w, key, round_index=None):
                ctx = tuple(prelude(w)) if prelude is not None else ()
                return self.round_virtual(
                    w, key,
                    lambda w_, bi, cb, ks: c_pass(w_, bi, cb, ks, *ctx),
                    round_index=round_index)

            return reference_round

        def reference_round(w, key, round_index=None):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            return self.round(
                w, key, lambda w_, bi, b, kb: client_pass(w_, bi, b, kb, *ctx),
                round_index=round_index)

        return reference_round

    def compile_with_state(self, dual_pass: Callable, *,
                           prelude: Optional[Callable] = None,
                           donate: Optional[bool] = None,
                           chunk_pass: Optional[Callable] = None) -> Callable:
        """:meth:`compile` for dual-state rounds.

        Returns ``compiled_round(w, states, key) -> (w_next, new_states)``
        over a tuple-of-pytrees ``states``; both the iterate and the state
        buffers are donated on accelerator backends.  With
        ``cfg.client_chunk`` set, the jitted body is the streamed
        :meth:`round_streamed_with_state` over ``chunk_pass``; with
        ``cfg.cohort`` set under partial participation it is the cohort
        :meth:`round_cohort_with_state` (aux state gathered with the
        cohort and scattered back).
        """
        donate_args = (0, 1) if self._should_donate(donate) else ()

        if self._use_cohort():
            c_pass = self._require_chunk_pass(chunk_pass)

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, states, ctx, key, r):
                w2, new_states = self.round_cohort_with_state(
                    w, list(states), key,
                    lambda w_, bi, cb, s_c, ks: c_pass(w_, bi, cb, s_c, ks,
                                                       *ctx),
                    round_index=r)
                return w2, tuple(new_states)
        elif self.cfg.client_chunk is not None:
            c_pass = self._require_chunk_pass(chunk_pass)

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, states, ctx, key, r):
                w2, new_states = self.round_streamed_with_state(
                    w, list(states), key,
                    lambda w_, bi, cb, s_c, ks: c_pass(w_, bi, cb, s_c, ks,
                                                       *ctx),
                    round_index=r)
                return w2, tuple(new_states)
        elif self.cfg.virtual_data:
            c_pass = self._require_chunk_pass(chunk_pass)

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, states, ctx, key, r):
                w2, new_states = self.round_virtual_with_state(
                    w, list(states), key,
                    lambda w_, bi, cb, s_c, ks: c_pass(w_, bi, cb, s_c, ks,
                                                       *ctx),
                    round_index=r)
                return w2, tuple(new_states)
        else:

            @functools.partial(jax.jit, donate_argnums=donate_args)
            def _body(w, states, ctx, key, r):
                w2, new_states = self.round_with_state(
                    w, list(states), key,
                    lambda w_, bi, b, s_b, kb: dual_pass(w_, bi, b, s_b, kb,
                                                         *ctx),
                    round_index=r)
                return w2, tuple(new_states)

        def compiled_round(w, states, key, round_index=None):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            return _body(w, tuple(states), ctx, key,
                         self._round_index_arg(round_index))

        return compiled_round

    def reference_with_state(self, dual_pass: Callable, *,
                             prelude: Optional[Callable] = None,
                             chunk_pass: Optional[Callable] = None
                             ) -> Callable:
        """The eager twin of :meth:`compile_with_state` (see
        :meth:`reference` for the ``virtual_data`` dispatch)."""
        if self.cfg.virtual_data:
            c_pass = self._require_chunk_pass(chunk_pass)

            def reference_round(w, states, key, round_index=None):
                ctx = tuple(prelude(w)) if prelude is not None else ()
                w2, new_states = self.round_virtual_with_state(
                    w, list(states), key,
                    lambda w_, bi, cb, s_c, ks: c_pass(w_, bi, cb, s_c, ks,
                                                       *ctx),
                    round_index=round_index)
                return w2, tuple(new_states)

            return reference_round

        def reference_round(w, states, key, round_index=None):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            w2, new_states = self.round_with_state(
                w, list(states), key,
                lambda w_, bi, b, s_b, kb: dual_pass(w_, bi, b, s_b, kb, *ctx),
                round_index=round_index)
            return w2, tuple(new_states)

        return reference_round
