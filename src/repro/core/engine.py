"""Unified federated round engine — the paper's round template (§1, §3).

Every algorithm in this repo follows the same communication pattern:

  1. (algorithm) server broadcasts state to clients
  2. clients compute local updates in parallel         — vmap over buckets
  3. server samples/weights the participating clients  — full or i.i.d. partial
  4. server aggregates deltas and applies the update   — uniform / n_k/n /
                                                         A-scaled (Pallas)

Steps 2–4 are algorithm-independent: FSVRG (Alg. 4), naive SVRG (Alg. 3),
FedAvg, and distributed GD differ only in the *client pass* that produces the
per-client deltas ``w_k − w`` and in the weighting/scaling choices.  The
``RoundEngine`` owns steps 2–4 so algorithms supply one function instead of
hand-rolling the loop (the pre-refactor state: four divergent copies).

Aggregation is pluggable:

  * ``weighting``      — ``"nk"`` (n_k/n, the paper's mod. 2), ``"uniform"``
                          (1/K), or ``"sum"`` (weight 1 per client — the plain
                          Σ_k used by dual methods, where each delta already
                          carries its own normalization)
  * ``server_scaling`` — ``"none"`` or ``"diag"`` (A = Diag(K/ω), mod. 4)
  * ``aggregator``     — ``"dense"`` (eager jnp weighted sum, the reference
                          path) or ``"pallas"`` (one HBM pass over the stacked
                          client deltas via ``kernels.scaled_aggregate``)

Algorithms whose clients carry *auxiliary per-client state* across rounds —
CoCoA+'s dual blocks α_k, the Primal Method's perturbation vectors g_k —
use :meth:`RoundEngine.round_with_state`: the client pass receives and
returns the bucket's state alongside the deltas, and under partial
participation the engine freezes the state of exactly the clients whose
aggregation weight the same Bernoulli draw zeroed.

Partial participation samples clients i.i.d. with probability
``participation`` per round and reweights the aggregate by
(expected mass / realized mass) so the update direction stays unbiased —
the deployment reality the paper motivates in §1.2 (devices participate
only when charging / on wi-fi).  ``weighting="sum"`` is exempt from the
reweighting: dual methods need the plain sum of the participants' deltas,
matching their frozen dual blocks exactly.  Each round's Bernoulli masks
are drawn **once** (:meth:`RoundEngine.participation_masks`) and shared by
state freezing and aggregation — one draw, two consumers, bit-identical to
the historical re-derivation by construction (same ``fold_in`` chain).

Because rounds are the scarce resource (§1: "minimizing the number of
rounds of communication is the principal goal"), the per-round server work
should be a *constant number of compiled dispatches*, not a Python loop of
per-bucket calls.  :meth:`RoundEngine.compile` /
:meth:`RoundEngine.compile_with_state` return jitted round closures — the
per-bucket ``fold_in`` offsets are precomputed, the client passes and the
aggregation run inside a single ``jax.jit`` (with donated iterate/state
buffers off-CPU), and an optional eager ``prelude`` carries per-round
server state (e.g. FSVRG's full gradient — its own round of communication
in the paper, so it stays outside the jitted body and the compiled round
remains bit-identical to the eager reference).  Every solver's ``round``
calls its compiled closure; :meth:`round` / :meth:`round_with_state` stay
as the eager reference implementations the pin tests compare against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.problem import ClientBucket, FederatedLogReg

#: client_pass(w, bucket_index, bucket, key) -> (Kb, d) deltas w_k - w
ClientPassFn = Callable[[jax.Array, int, ClientBucket, jax.Array], jax.Array]

#: dual_pass(w, bucket_index, bucket, state_b, key) -> (deltas, new_state_b);
#: state_b is any pytree of arrays with a leading client axis (Kb, ...)
DualClientPassFn = Callable[
    [jax.Array, int, ClientBucket, Any, jax.Array], Tuple[jax.Array, Any]]

_WEIGHTINGS = ("nk", "uniform", "sum")
_SCALINGS = ("none", "diag")
_AGGREGATORS = ("dense", "pallas")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Round-scheduling knobs shared by every federated algorithm."""

    participation: float = 1.0     # i.i.d. per-round client participation prob
    weighting: str = "nk"          # "nk" (n_k/n) | "uniform" (1/K) | "sum" (1)
    server_scaling: str = "none"   # "none" | "diag" (apply a_diag coordinatewise)
    aggregator: str = "dense"      # "dense" | "pallas" (scaled_aggregate kernel)

    def __post_init__(self):
        if self.weighting not in _WEIGHTINGS:
            raise ValueError(f"weighting must be one of {_WEIGHTINGS}")
        if self.server_scaling not in _SCALINGS:
            raise ValueError(f"server_scaling must be one of {_SCALINGS}")
        if self.aggregator not in _AGGREGATORS:
            raise ValueError(f"aggregator must be one of {_AGGREGATORS}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")


@functools.partial(jax.jit, static_argnames=("scaled",))
def _apply_server_update(w, agg, a_diag, scaled: bool):
    return w + (a_diag if scaled else 1.0) * agg


class RoundEngine:
    """Owns client sampling, the vmap-over-bucket client pass, and server
    aggregation.  Algorithms provide a :data:`ClientPassFn`; the engine never
    looks inside the deltas it aggregates."""

    def __init__(self, problem: FederatedLogReg, cfg: EngineConfig = EngineConfig(),
                 *, a_diag: Optional[jax.Array] = None):
        self.problem = problem
        self.cfg = cfg
        if cfg.server_scaling == "diag" and a_diag is None:
            raise ValueError("server_scaling='diag' requires an a_diag")
        self.a_diag = jnp.ones((problem.d,)) if a_diag is None else a_diag
        # per-bucket first-client index — the fold_in offset of every bucket's
        # round key, precomputed once so compiled rounds close over constants
        wi = 0
        offsets = []
        for b in problem.buckets:
            offsets.append(wi)
            wi += b.num_clients
        self._offsets = tuple(offsets)

    # -- step 3: sampling & weighting ------------------------------------- #

    def bucket_weights(self, wi: int, num_clients: int) -> jax.Array:
        """Aggregation weights for the bucket whose first client is ``wi``."""
        if self.cfg.weighting == "uniform":
            return jnp.full((num_clients,), 1.0 / self.problem.num_clients)
        if self.cfg.weighting == "sum":
            return jnp.ones((num_clients,))
        return self.problem.client_weights[wi : wi + num_clients]

    def participation_mask(self, bucket_key: jax.Array, num_clients: int) -> jax.Array:
        """i.i.d. Bernoulli(participation) mask, 1.0 = client is in-round."""
        return (jax.random.uniform(jax.random.fold_in(bucket_key, 997),
                                   (num_clients,))
                < self.cfg.participation).astype(jnp.float32)

    def participation_masks(self, key: jax.Array) -> Optional[List[jax.Array]]:
        """The round's per-bucket Bernoulli masks, drawn **once** from the
        round key's ``fold_in`` chain — ``None`` under full participation.

        This is the single draw both consumers share: state freezing in
        :meth:`round_with_state` and weight zeroing in :meth:`aggregate`
        receive the same mask list instead of each re-deriving the same
        Bernoulli draw per bucket.
        """
        if self.cfg.participation >= 1.0:
            return None
        return [self.participation_mask(jax.random.fold_in(key, wi),
                                        b.num_clients)
                for wi, b in zip(self._offsets, self.problem.buckets)]

    # -- step 4: aggregation ----------------------------------------------- #

    def aggregate(self, w: jax.Array, deltas_by_bucket: Sequence[jax.Array],
                  key: jax.Array, *,
                  masks: Optional[Sequence[jax.Array]] = None) -> jax.Array:
        """Weight, subsample, reweight, scale, and apply the client deltas.

        ``deltas_by_bucket[i]`` is the (Kb, d) output of the client pass for
        bucket i; ``key`` must be the same round key handed to the passes so
        the participation draw is tied to the round.  ``masks`` are the
        round's precomputed :meth:`participation_masks`; if omitted they are
        drawn here from the same chain (bit-identical either way).
        """
        cfg = self.cfg
        pallas = cfg.aggregator == "pallas"
        if masks is None:
            masks = self.participation_masks(key)
        agg = jnp.zeros_like(w)
        stacked: List[jax.Array] = []
        stacked_wts: List[jax.Array] = []
        total_mass = jnp.zeros(())
        expected_mass = jnp.zeros(())
        for i, (wi, b, deltas) in enumerate(zip(self._offsets,
                                                self.problem.buckets,
                                                deltas_by_bucket)):
            wts = self.bucket_weights(wi, b.num_clients)
            if masks is not None:
                sel = masks[i]
                total_mass = total_mass + (wts * sel).sum()
                expected_mass = expected_mass + wts.sum()
                wts = wts * sel
            if pallas:
                stacked.append(deltas)
                stacked_wts.append(wts)
            else:
                agg = agg + (wts[:, None] * deltas).sum(axis=0)

        # Reweighting by expected/realized mass keeps the *average* direction
        # unbiased; a "sum" aggregation must stay the plain partial sum — for
        # dual methods each participant's delta enters exactly once so the
        # primal iterate keeps tracking the (frozen-for-non-participants)
        # dual blocks, w = (1/λn)Xα.
        reweight = masks is not None and cfg.weighting != "sum"
        scale = expected_mass / jnp.maximum(total_mass, 1e-9) \
            if reweight else None

        if pallas:
            # Delta-native single HBM pass: stacked deltas go to the kernel
            # as-is, with the reweight scalar and the A epilogue folded in —
            # no (K, d) w^t + δ materialization.  Same auto policy as the
            # solvers' use_kernel: the Pallas kernel on TPU, the identical
            # fused jnp expression elsewhere (interpret-mode emulation is
            # for the parity tests, not the hot path).
            wts_all = jnp.concatenate(stacked_wts)
            deltas_all = jnp.concatenate(stacked, axis=0)
            a = self.a_diag if cfg.server_scaling == "diag" else jnp.ones_like(w)
            s = scale if scale is not None else 1.0
            if jax.default_backend() == "tpu":
                from repro.kernels import ops
                return ops.fused_aggregate(
                    w, deltas_all, wts_all, a, s).astype(w.dtype)
            from repro.kernels import ref
            return ref.fused_aggregate_ref(
                w, deltas_all, wts_all, a, s).astype(w.dtype)

        if scale is not None:
            agg = agg * scale
        return _apply_server_update(w, agg, self.a_diag,
                                    cfg.server_scaling == "diag")

    # -- steps 2-4: one full round ----------------------------------------- #

    def round(self, w: jax.Array, key: jax.Array,
              client_pass: ClientPassFn) -> jax.Array:
        """Run the client passes over every bucket, then aggregate.

        Each bucket's pass receives ``fold_in(key, wi)`` where ``wi`` is the
        bucket's first client index — the same key the round's single
        participation draw uses for that bucket.
        """
        deltas: List[jax.Array] = []
        for bi, (wi, b) in enumerate(zip(self._offsets, self.problem.buckets)):
            kb = jax.random.fold_in(key, wi)
            deltas.append(client_pass(w, bi, b, kb))
        return self.aggregate(w, deltas, key,
                              masks=self.participation_masks(key))

    def round_with_state(self, w: jax.Array, states: Sequence[Any],
                         key: jax.Array, client_pass: DualClientPassFn
                         ) -> Tuple[jax.Array, List[Any]]:
        """:meth:`round` for algorithms with per-client auxiliary state.

        ``states[i]`` is bucket i's state — any pytree of arrays whose leading
        axis is the bucket's client axis (e.g. CoCoA+'s dual blocks α_k of
        shape (Kb, m_pad), or the Primal Method's g_k of shape (Kb, d)).  The
        pass receives it alongside the bucket and returns the updated state
        with the deltas; deltas flow through the same :meth:`aggregate` path
        (weighting/scaling/participation) as stateless rounds.

        Under partial participation, a client whose aggregation weight is
        zeroed by the round's Bernoulli draw also keeps its previous state —
        the round's masks are drawn once (:meth:`participation_masks`) and
        handed to both state freezing and aggregation, so primal and dual
        views never diverge.
        """
        masks = self.participation_masks(key)
        deltas: List[jax.Array] = []
        new_states: List[Any] = []
        for bi, (wi, b) in enumerate(zip(self._offsets, self.problem.buckets)):
            kb = jax.random.fold_in(key, wi)
            d_b, s_b = client_pass(w, bi, b, states[bi], kb)
            if masks is not None:
                sel = masks[bi]
                s_b = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(
                        sel.reshape((b.num_clients,) + (1,) * (new.ndim - 1))
                        > 0, new, old),
                    s_b, states[bi])
            deltas.append(d_b)
            new_states.append(s_b)
        return self.aggregate(w, deltas, key, masks=masks), new_states

    # -- the compiled round: O(1) dispatches per round ---------------------- #

    def _should_donate(self, donate: Optional[bool]) -> bool:
        # Donation is a no-op (with a warning) on CPU; default it off there.
        return jax.default_backend() != "cpu" if donate is None else donate

    def compile(self, client_pass: Callable, *,
                prelude: Optional[Callable] = None,
                donate: Optional[bool] = None) -> Callable:
        """One federated round as a single compiled dispatch.

        Returns ``compiled_round(w, key) -> w_next``: the per-bucket client
        passes, the single participation draw, and the (optionally fused
        Pallas) aggregation all trace into one ``jax.jit`` over the
        precomputed ``fold_in`` offsets, with the iterate buffer donated on
        accelerator backends.

        ``prelude(w) -> tuple`` carries per-round *server* state — e.g.
        FSVRG's/DANE's full gradient, which the paper counts as its own round
        of communication.  It runs eagerly outside the jitted body (so the
        compiled round stays bit-identical to :meth:`round`, the reference
        implementation) and its results are appended to the pass's
        arguments: ``client_pass(w, bi, bucket, kb, *prelude(w))``.
        """
        donate_args = (0,) if self._should_donate(donate) else ()

        @functools.partial(jax.jit, donate_argnums=donate_args)
        def _body(w, ctx, key):
            return self.round(
                w, key, lambda w_, bi, b, kb: client_pass(w_, bi, b, kb, *ctx))

        def compiled_round(w, key):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            return _body(w, ctx, key)

        return compiled_round

    def reference(self, client_pass: Callable, *,
                  prelude: Optional[Callable] = None) -> Callable:
        """The eager twin of :meth:`compile` — same calling convention,
        Python-loop dispatch through :meth:`round`.  The pin tests (and the
        round-latency benchmark's "eager dense" baseline) call this."""
        def reference_round(w, key):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            return self.round(
                w, key, lambda w_, bi, b, kb: client_pass(w_, bi, b, kb, *ctx))

        return reference_round

    def compile_with_state(self, dual_pass: Callable, *,
                           prelude: Optional[Callable] = None,
                           donate: Optional[bool] = None) -> Callable:
        """:meth:`compile` for dual-state rounds.

        Returns ``compiled_round(w, states, key) -> (w_next, new_states)``
        over a tuple-of-pytrees ``states``; both the iterate and the state
        buffers are donated on accelerator backends.
        """
        donate_args = (0, 1) if self._should_donate(donate) else ()

        @functools.partial(jax.jit, donate_argnums=donate_args)
        def _body(w, states, ctx, key):
            w2, new_states = self.round_with_state(
                w, list(states), key,
                lambda w_, bi, b, s_b, kb: dual_pass(w_, bi, b, s_b, kb, *ctx))
            return w2, tuple(new_states)

        def compiled_round(w, states, key):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            return _body(w, tuple(states), ctx, key)

        return compiled_round

    def reference_with_state(self, dual_pass: Callable, *,
                             prelude: Optional[Callable] = None) -> Callable:
        """The eager twin of :meth:`compile_with_state`."""
        def reference_round(w, states, key):
            ctx = tuple(prelude(w)) if prelude is not None else ()
            w2, new_states = self.round_with_state(
                w, list(states), key,
                lambda w_, bi, b, s_b, kb: dual_pass(w_, bi, b, s_b, kb, *ctx))
            return w2, tuple(new_states)

        return reference_round
