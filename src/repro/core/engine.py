"""Unified federated round engine — the paper's round template (§1, §3).

Every algorithm in this repo follows the same communication pattern:

  1. (algorithm) server broadcasts state to clients
  2. clients compute local updates in parallel         — vmap over buckets
  3. server samples/weights the participating clients  — full or i.i.d. partial
  4. server aggregates deltas and applies the update   — uniform / n_k/n /
                                                         A-scaled (Pallas)

Steps 2–4 are algorithm-independent: FSVRG (Alg. 4), naive SVRG (Alg. 3),
FedAvg, and distributed GD differ only in the *client pass* that produces the
per-client deltas ``w_k − w`` and in the weighting/scaling choices.  The
``RoundEngine`` owns steps 2–4 so algorithms supply one function instead of
hand-rolling the loop (the pre-refactor state: four divergent copies).

Aggregation is pluggable:

  * ``weighting``      — ``"nk"`` (n_k/n, the paper's mod. 2), ``"uniform"``
                          (1/K), or ``"sum"`` (weight 1 per client — the plain
                          Σ_k used by dual methods, where each delta already
                          carries its own normalization)
  * ``server_scaling`` — ``"none"`` or ``"diag"`` (A = Diag(K/ω), mod. 4)
  * ``aggregator``     — ``"dense"`` (eager jnp weighted sum, the reference
                          path) or ``"pallas"`` (one HBM pass over the stacked
                          client deltas via ``kernels.scaled_aggregate``)

Algorithms whose clients carry *auxiliary per-client state* across rounds —
CoCoA+'s dual blocks α_k, the Primal Method's perturbation vectors g_k —
use :meth:`RoundEngine.round_with_state`: the client pass receives and
returns the bucket's state alongside the deltas, and under partial
participation the engine freezes the state of exactly the clients whose
aggregation weight the same Bernoulli draw zeroed.

Partial participation samples clients i.i.d. with probability
``participation`` per round and reweights the aggregate by
(expected mass / realized mass) so the update direction stays unbiased —
the deployment reality the paper motivates in §1.2 (devices participate
only when charging / on wi-fi).  ``weighting="sum"`` is exempt from the
reweighting: dual methods need the plain sum of the participants' deltas,
matching their frozen dual blocks exactly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.problem import ClientBucket, FederatedLogReg

#: client_pass(w, bucket_index, bucket, key) -> (Kb, d) deltas w_k - w
ClientPassFn = Callable[[jax.Array, int, ClientBucket, jax.Array], jax.Array]

#: dual_pass(w, bucket_index, bucket, state_b, key) -> (deltas, new_state_b);
#: state_b is any pytree of arrays with a leading client axis (Kb, ...)
DualClientPassFn = Callable[
    [jax.Array, int, ClientBucket, Any, jax.Array], Tuple[jax.Array, Any]]

_WEIGHTINGS = ("nk", "uniform", "sum")
_SCALINGS = ("none", "diag")
_AGGREGATORS = ("dense", "pallas")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Round-scheduling knobs shared by every federated algorithm."""

    participation: float = 1.0     # i.i.d. per-round client participation prob
    weighting: str = "nk"          # "nk" (n_k/n) | "uniform" (1/K) | "sum" (1)
    server_scaling: str = "none"   # "none" | "diag" (apply a_diag coordinatewise)
    aggregator: str = "dense"      # "dense" | "pallas" (scaled_aggregate kernel)

    def __post_init__(self):
        if self.weighting not in _WEIGHTINGS:
            raise ValueError(f"weighting must be one of {_WEIGHTINGS}")
        if self.server_scaling not in _SCALINGS:
            raise ValueError(f"server_scaling must be one of {_SCALINGS}")
        if self.aggregator not in _AGGREGATORS:
            raise ValueError(f"aggregator must be one of {_AGGREGATORS}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError("participation must be in (0, 1]")


@functools.partial(jax.jit, static_argnames=("scaled",))
def _apply_server_update(w, agg, a_diag, scaled: bool):
    return w + (a_diag if scaled else 1.0) * agg


class RoundEngine:
    """Owns client sampling, the vmap-over-bucket client pass, and server
    aggregation.  Algorithms provide a :data:`ClientPassFn`; the engine never
    looks inside the deltas it aggregates."""

    def __init__(self, problem: FederatedLogReg, cfg: EngineConfig = EngineConfig(),
                 *, a_diag: Optional[jax.Array] = None):
        self.problem = problem
        self.cfg = cfg
        if cfg.server_scaling == "diag" and a_diag is None:
            raise ValueError("server_scaling='diag' requires an a_diag")
        self.a_diag = jnp.ones((problem.d,)) if a_diag is None else a_diag

    # -- step 3: sampling & weighting ------------------------------------- #

    def bucket_weights(self, wi: int, num_clients: int) -> jax.Array:
        """Aggregation weights for the bucket whose first client is ``wi``."""
        if self.cfg.weighting == "uniform":
            return jnp.full((num_clients,), 1.0 / self.problem.num_clients)
        if self.cfg.weighting == "sum":
            return jnp.ones((num_clients,))
        return self.problem.client_weights[wi : wi + num_clients]

    def participation_mask(self, bucket_key: jax.Array, num_clients: int) -> jax.Array:
        """i.i.d. Bernoulli(participation) mask, 1.0 = client is in-round."""
        return (jax.random.uniform(jax.random.fold_in(bucket_key, 997),
                                   (num_clients,))
                < self.cfg.participation).astype(jnp.float32)

    # -- step 4: aggregation ----------------------------------------------- #

    def aggregate(self, w: jax.Array, deltas_by_bucket: Sequence[jax.Array],
                  key: jax.Array) -> jax.Array:
        """Weight, subsample, reweight, scale, and apply the client deltas.

        ``deltas_by_bucket[i]`` is the (Kb, d) output of the client pass for
        bucket i; ``key`` must be the same round key handed to the passes so
        the participation draw is tied to the round.
        """
        cfg = self.cfg
        pallas = cfg.aggregator == "pallas"
        agg = jnp.zeros_like(w)
        stacked: List[jax.Array] = []
        stacked_wts: List[jax.Array] = []
        wi = 0
        total_mass = jnp.zeros(())
        expected_mass = jnp.zeros(())
        for b, deltas in zip(self.problem.buckets, deltas_by_bucket):
            kb = jax.random.fold_in(key, wi)
            wts = self.bucket_weights(wi, b.num_clients)
            if cfg.participation < 1.0:
                sel = self.participation_mask(kb, b.num_clients)
                total_mass = total_mass + (wts * sel).sum()
                expected_mass = expected_mass + wts.sum()
                wts = wts * sel
            if pallas:
                stacked.append(deltas)
                stacked_wts.append(wts)
            else:
                agg = agg + (wts[:, None] * deltas).sum(axis=0)
            wi += b.num_clients

        # Reweighting by expected/realized mass keeps the *average* direction
        # unbiased; a "sum" aggregation must stay the plain partial sum — for
        # dual methods each participant's delta enters exactly once so the
        # primal iterate keeps tracking the (frozen-for-non-participants)
        # dual blocks, w = (1/λn)Xα.
        reweight = cfg.participation < 1.0 and cfg.weighting != "sum"
        scale = expected_mass / jnp.maximum(total_mass, 1e-9) \
            if reweight else None

        if pallas:
            from repro.kernels import ops
            wts_all = jnp.concatenate(stacked_wts)
            if scale is not None:
                wts_all = wts_all * scale
            w_ks = w[None, :] + jnp.concatenate(stacked, axis=0)
            a = self.a_diag if cfg.server_scaling == "diag" else jnp.ones_like(w)
            return ops.scaled_aggregate(w, w_ks, wts_all, a).astype(w.dtype)

        if scale is not None:
            agg = agg * scale
        return _apply_server_update(w, agg, self.a_diag,
                                    cfg.server_scaling == "diag")

    # -- steps 2-4: one full round ----------------------------------------- #

    def round(self, w: jax.Array, key: jax.Array,
              client_pass: ClientPassFn) -> jax.Array:
        """Run the client passes over every bucket, then aggregate.

        Each bucket's pass receives ``fold_in(key, wi)`` where ``wi`` is the
        bucket's first client index — the same key the aggregation step uses
        for that bucket's participation draw.
        """
        deltas: List[jax.Array] = []
        wi = 0
        for bi, b in enumerate(self.problem.buckets):
            kb = jax.random.fold_in(key, wi)
            deltas.append(client_pass(w, bi, b, kb))
            wi += b.num_clients
        return self.aggregate(w, deltas, key)

    def round_with_state(self, w: jax.Array, states: Sequence[Any],
                         key: jax.Array, client_pass: DualClientPassFn
                         ) -> Tuple[jax.Array, List[Any]]:
        """:meth:`round` for algorithms with per-client auxiliary state.

        ``states[i]`` is bucket i's state — any pytree of arrays whose leading
        axis is the bucket's client axis (e.g. CoCoA+'s dual blocks α_k of
        shape (Kb, m_pad), or the Primal Method's g_k of shape (Kb, d)).  The
        pass receives it alongside the bucket and returns the updated state
        with the deltas; deltas flow through the same :meth:`aggregate` path
        (weighting/scaling/participation) as stateless rounds.

        Under partial participation, a client whose aggregation weight is
        zeroed by the round's Bernoulli draw also keeps its previous state —
        the draw is re-derived from the same ``fold_in`` chain that
        :meth:`aggregate` uses, so primal and dual views never diverge.
        """
        deltas: List[jax.Array] = []
        new_states: List[Any] = []
        wi = 0
        for bi, b in enumerate(self.problem.buckets):
            kb = jax.random.fold_in(key, wi)
            d_b, s_b = client_pass(w, bi, b, states[bi], kb)
            if self.cfg.participation < 1.0:
                sel = self.participation_mask(kb, b.num_clients)
                s_b = jax.tree_util.tree_map(
                    lambda new, old: jnp.where(
                        sel.reshape((b.num_clients,) + (1,) * (new.ndim - 1))
                        > 0, new, old),
                    s_b, states[bi])
            deltas.append(d_b)
            new_states.append(s_b)
            wi += b.num_clients
        return self.aggregate(w, deltas, key), new_states
