"""`Trainer` — the one round-loop driver every solver shares.

Before this module each benchmark/example hand-rolled its own loop, seed
schedule, and stepsize sweep per algorithm (~30 lines each in
``benchmarks/fig2_convergence.py``).  The Trainer owns all of it:

  * **Key schedule** — round r uses ``fold_in(PRNGKey(seed), r)`` with r the
    *absolute* round index from ``state.round``, so a restored checkpoint
    resumes the exact same key sequence it would have seen uninterrupted.
  * **Eval / history** — ``eval_fn(w) -> dict`` of scalars, recorded as
    Python floats every ``eval_every`` rounds (default every round; the
    final round is always evaluated, so ``history[-1]`` keeps meaning
    "final objective" for :func:`sweep` at any cadence);
    ``callback(state, r)`` for side effects.
  * **Scan fast path** — with ``scan=True`` the whole loop runs as one
    ``jit(lax.scan)`` over rounds.  Valid whenever the solver state is a
    pure pytree and ``round`` is traceable (every solver in this repo) and
    ``eval_fn`` is jax-traceable; ``callback`` and mid-run checkpointing
    are Python-side and therefore excluded.  Numerics: XLA may fuse the
    round body differently than the eager per-round path, so scan
    trajectories agree to float tolerance, not bit-for-bit — the pinning
    tests run the loop path.
  * **Checkpointing** — ``checkpoint_dir`` + ``checkpoint_every`` save the
    state pytree through :mod:`repro.checkpoint`; ``Trainer.restore``
    rebuilds a :class:`~repro.core.solver.SolverState` and ``fit(state=...)``
    resumes from it.

:func:`sweep` is the paper's retrospective stepsize-sweep protocol (run
every candidate for the full round budget, keep the best final objective),
previously a private helper inside the fig2 benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.solver import FederatedSolver, SolverState

EvalFn = Callable[[jax.Array], Dict[str, Any]]


class NonFiniteIterateError(RuntimeError):
    """The iterate went NaN/Inf mid-run.  Carries which solver and which
    round, so a campaign guard-rail can quarantine exactly that round
    instead of letting the poison silently propagate to the final
    checkpoint."""

    def __init__(self, solver_name: str, round_index: int):
        super().__init__(
            f"non-finite iterate after round {round_index} of solver "
            f"'{solver_name}' — a diverging stepsize or an unguarded "
            "fault-injected delta (see EngineConfig.aggregator_guard)")
        self.solver_name = solver_name
        self.round_index = int(round_index)


@dataclasses.dataclass
class FitResult:
    """What a training run produced: final state + per-round eval history
    (plus the solver that produced it, for hyperparam introspection)."""

    state: SolverState
    history: List[Dict[str, float]]
    solver: Optional[FederatedSolver] = None

    @property
    def w(self) -> jax.Array:
        return self.state.w


def _tuplify(node):
    """Rebuild tuples from the lists `repro.checkpoint.restore` returns."""
    if isinstance(node, (list, tuple)):
        return tuple(_tuplify(x) for x in node)
    if isinstance(node, dict):
        return {k: _tuplify(v) for k, v in node.items()}
    return node


class Trainer:
    """Drives ``solver.round`` for a fixed number of rounds.

    The per-round key is ``fold_in(PRNGKey(seed), r)`` — the single schedule
    every curve in the fig2 benchmark now derives from its ``--seed``.
    """

    def __init__(self, solver: FederatedSolver, *, rounds: int, seed: int = 0,
                 eval_fn: Optional[EvalFn] = None,
                 callback: Optional[Callable[[SolverState, int], None]] = None,
                 scan: bool = False,
                 eval_every: int = 1,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 fail_fast: bool = True):
        if scan and callback is not None:
            raise ValueError("scan=True runs the loop inside jit; Python "
                             "callbacks need the eager path")
        if scan and checkpoint_every:
            raise ValueError("scan=True runs the loop inside jit; periodic "
                             "checkpointing needs the eager path (the final "
                             "state is still saved to checkpoint_dir)")
        if checkpoint_every and not checkpoint_dir:
            raise ValueError("checkpoint_every requires a checkpoint_dir")
        if int(eval_every) < 1:
            raise ValueError("eval_every must be >= 1")
        self.solver = solver
        self.rounds = int(rounds)
        self.seed = int(seed)
        self.eval_fn = eval_fn
        self.callback = callback
        self.scan = scan
        self.eval_every = int(eval_every)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        # raise NonFiniteIterateError the round the iterate goes NaN/Inf
        # instead of silently training on garbage.  sweep() turns this off:
        # its divergent stepsize candidates are expected and discarded.
        # The scan path checks the final iterate only (the loop is one jit).
        self.fail_fast = bool(fail_fast)

    def _check_finite(self, state: SolverState, r: int) -> None:
        if self.fail_fast and not bool(jnp.isfinite(state.w).all()):
            raise NonFiniteIterateError(self.solver.name, r)

    def _is_eval_round(self, r: int) -> bool:
        """Rounds whose metrics land in history: every ``eval_every``-th
        round plus, unconditionally, the final one."""
        return (r + 1) % self.eval_every == 0 or r == self.rounds - 1

    # -- checkpointing ----------------------------------------------------- #

    def save(self, state: SolverState, path: Optional[str] = None) -> None:
        from repro import checkpoint
        path = path or self.checkpoint_dir
        checkpoint.save(path, {"w": state.w, "aux": state.aux,
                               "round": state.round},
                        step=int(state.round),
                        metadata={"solver": self.solver.name,
                                  "seed": self.seed})

    @staticmethod
    def restore(path: str) -> SolverState:
        from repro import checkpoint
        tree, info = checkpoint.restore(path)
        return SolverState(w=tree["w"], aux=_tuplify(tree.get("aux", ())),
                           round=jnp.asarray(tree.get("round", info["step"]),
                                             jnp.int32))

    # -- the round loop ---------------------------------------------------- #

    def fit(self, w0: Optional[jax.Array] = None,
            state: Optional[SolverState] = None) -> FitResult:
        """Run rounds ``state.round .. rounds-1``; fresh ``init(w0)`` state
        unless an explicit (e.g. restored) ``state`` is given."""
        if state is None:
            state = self.solver.init(w0)
        elif w0 is not None:
            raise ValueError("pass w0 or state, not both")
        start = int(state.round)
        if start >= self.rounds:
            # the "saved checkpoint never lags the returned result"
            # invariant must hold for the degenerate run too: a restored
            # state handed to a past-budget fit would otherwise return
            # without ever touching the checkpoint directory
            if self.checkpoint_dir:
                self.save(state)
            return FitResult(state=state, history=[], solver=self.solver)
        if self.scan:
            return self._fit_scan(state, start)

        base = jax.random.PRNGKey(self.seed)
        history: List[Dict[str, float]] = []
        saved_at = -1
        for r in range(start, self.rounds):
            state = self.solver.round(state, jax.random.fold_in(base, r))
            self._check_finite(state, r)
            if self.eval_fn is not None and self._is_eval_round(r):
                history.append({k: float(v)
                                for k, v in self.eval_fn(state.w).items()})
            if self.callback is not None:
                self.callback(state, r)
            if (self.checkpoint_every
                    and (r + 1) % self.checkpoint_every == 0):
                self.save(state)
                saved_at = r + 1
        # the saved checkpoint must never lag the returned result
        if self.checkpoint_dir and saved_at != self.rounds:
            self.save(state)
        return FitResult(state=state, history=history, solver=self.solver)

    def _fit_scan(self, state: SolverState, start: int) -> FitResult:
        base = jax.random.PRNGKey(self.seed)
        rs = jnp.arange(start, self.rounds)
        keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(rs)
        sparse_eval = self.eval_fn is not None and self.eval_every != 1
        if sparse_eval:
            # eval_fn runs under lax.cond on eval rounds only; off rounds
            # emit same-shaped placeholders that are discarded below
            shapes = jax.eval_shape(self.eval_fn, state.w)

            def maybe_eval(w, r):
                pred = ((r + 1) % self.eval_every == 0) | (r == self.rounds - 1)
                return jax.lax.cond(
                    pred, self.eval_fn,
                    lambda _: jax.tree.map(
                        lambda s: jnp.zeros(s.shape, s.dtype), shapes), w)

        def body(s, rk):
            r, key = rk
            s = self.solver.round(s, key)
            if sparse_eval:
                metrics = maybe_eval(s.w, r)
            else:
                metrics = self.eval_fn(s.w) if self.eval_fn is not None else {}
            return s, metrics

        final, stacked = jax.jit(
            lambda s, xs: jax.lax.scan(body, s, xs))(state, (rs, keys))
        self._check_finite(final, self.rounds - 1)
        if self.eval_fn is None:
            history: List[Dict[str, float]] = []
        else:
            recorded = [i for i, r in enumerate(range(start, self.rounds))
                        if self._is_eval_round(r)]
            history = [{k: float(v[i]) for k, v in stacked.items()}
                       for i in recorded]
        if self.checkpoint_dir:
            self.save(final)
        return FitResult(state=final, history=history, solver=self.solver)


def sweep(build_solver: Callable[[Any], FederatedSolver],
          candidates: Sequence[Any], *, rounds: int, seed: int = 0,
          eval_fn: EvalFn, objective: str = "f",
          **trainer_kw) -> Tuple[Optional[FitResult], Optional[Any]]:
    """Retrospective hyperparameter sweep (the paper's protocol).

    Runs ``build_solver(v)`` for the full round budget for every candidate
    ``v`` and keeps the run whose *final* ``history[-1][objective]`` is
    lowest (non-finite runs are discarded).  Returns
    ``(best_result, best_value)`` — ``(None, None)`` if every run diverged.
    """
    best_res, best_v, best_f = None, None, np.inf
    for v in candidates:
        # fail_fast off: a divergent candidate is part of the protocol —
        # it just loses the sweep — unless the caller opts back in
        trainer_kw.setdefault("fail_fast", False)
        res = Trainer(build_solver(v), rounds=rounds, seed=seed,
                      eval_fn=eval_fn, **trainer_kw).fit()
        if not res.history:        # degenerate budget (rounds <= start)
            continue
        f = res.history[-1][objective]
        if np.isfinite(f) and f < best_f:
            best_res, best_v, best_f = res, v, f
    return best_res, best_v
