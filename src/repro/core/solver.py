"""The `FederatedSolver` protocol — one front door for every round-based
algorithm in this repo.

The paper's central object is a *round of communication* (§1, §3); before
this module each algorithm exposed a different one (functional
``round(w, key)`` here, a mutating ``round(key)`` there, bespoke ``run``
loops everywhere).  Now every algorithm is a :class:`FederatedSolver`:

  * ``init(w0) -> SolverState`` — build the solver's full state: the
    iterate ``w``, per-client auxiliary state ``aux`` (CoCoA+'s dual blocks
    α_k, the Primal Method's perturbation vectors g_k — an empty tuple for
    stateless algorithms), and the ``round`` counter.
  * ``round(state, key) -> SolverState`` — one round of communication,
    *purely functional*: no hidden ``self.w``.  ``key`` is the round's PRNG
    key; deterministic solvers simply ignore it.
  * ``name`` / ``hyperparams`` — the string the solver registers under
    (:mod:`repro.core.registry`) and the knobs it was built with.
  * ``fit(rounds, ...)`` — convenience wrapper over
    :class:`repro.core.trainer.Trainer`, which owns the key schedule,
    eval/history, checkpointing, and the scan fast path.

:class:`SolverState` is a registered pytree, so whole states jit, scan,
and checkpoint like any other JAX value.  The contract every solver keeps:

  * ``aux`` is a (possibly empty) tuple with one entry per problem bucket,
    each a pytree of arrays with leading client axis ``(Kb, ...)`` — the
    exact shape :meth:`RoundEngine.round_with_state` threads.
  * ``round`` must not depend on Python-level mutable state, so
    ``lax.scan`` over rounds (the Trainer's fast path) and a hand-rolled
    Python loop produce the same trajectory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.problem import FederatedLogReg


@dataclasses.dataclass(frozen=True)
class SolverState:
    """Everything a solver carries between rounds, as one pytree.

    w     : (d,) the server iterate.
    aux   : per-client auxiliary state — a tuple with one pytree per
            problem bucket (leading axis = that bucket's client axis), or
            the empty tuple for stateless algorithms.
    round : int32 scalar round counter; the Trainer derives round r's key
            as ``fold_in(PRNGKey(seed), r)``, so a restored state resumes
            the exact key schedule.
    """

    w: jax.Array
    aux: Any = ()
    round: Any = 0

    def replace(self, **kw) -> "SolverState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    SolverState, data_fields=["w", "aux", "round"], meta_fields=[])


class FederatedSolver:
    """Base class / protocol for round-based federated algorithms.

    Subclasses set ``name``, implement :meth:`round`, and override
    :meth:`_init_aux` if their clients carry state across rounds.
    Constructors take the problem first: ``Solver(problem, ...)`` — the
    registry's ``make_solver(name, problem, **overrides)`` relies on it.
    """

    name: str = "solver"
    problem: FederatedLogReg

    # -- state ------------------------------------------------------------ #

    def init(self, w0: Optional[jax.Array] = None) -> SolverState:
        """Fresh solver state at iterate ``w0`` (zeros by default).

        Dual methods whose iterate is a function of the dual state
        (Appendix-A Primal/Dual) override this and reject a custom ``w0``.
        """
        w0 = jnp.zeros((self.problem.d,)) if w0 is None else w0
        return SolverState(w=w0, aux=self._init_aux(w0),
                           round=jnp.asarray(0, jnp.int32))

    def _init_aux(self, w0: jax.Array) -> Any:
        return ()

    # -- one round of communication --------------------------------------- #

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        raise NotImplementedError

    # -- introspection ----------------------------------------------------- #

    @property
    def hyperparams(self) -> Dict[str, Any]:
        """The knobs this solver was constructed with (JSON-friendly)."""
        cfg = getattr(self, "cfg", None)
        if dataclasses.is_dataclass(cfg):
            return dataclasses.asdict(cfg)
        return {}

    # -- convenience ------------------------------------------------------- #

    def fit(self, rounds: int, *, seed: int = 0, w0=None, state=None,
            eval_fn=None, **trainer_kw):
        """Run ``rounds`` rounds through the shared Trainer driver."""
        from repro.core.trainer import Trainer
        return Trainer(self, rounds=rounds, seed=seed, eval_fn=eval_fn,
                       **trainer_kw).fit(w0=w0, state=state)

    def __repr__(self) -> str:
        hp = ", ".join(f"{k}={v!r}" for k, v in self.hyperparams.items())
        return f"{type(self).__name__}({self.name}: {hp})"
