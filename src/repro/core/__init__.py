"""The paper's contribution: federated optimization algorithms.

  problem.py   — federated finite-sum problem (sparse logreg), bucketed
                 clients; build_dense_problem for ridge data on the engine
  engine.py    — unified round engine: client sampling, vmap-over-bucket
                 passes, pluggable aggregation, per-client dual-state hook
                 (shared by all algorithms)
  scaling.py   — S_k / A sparsity statistics (§3.6.1)
  fsvrg.py     — Algorithms 3 & 4 (the paper's method), on the engine
  fedavg.py    — Federated Averaging (1602.05629), on the engine
  dane.py      — Algorithm 2 (GD/SVRG local solvers, exact ridge) + the
                 Proposition-1 DANE↔SVRG construction, on the engine
  cocoa.py     — CoCoA+ and Appendix-A Algorithms 5 & 6 (Theorem 5), on the
                 engine's dual-state hook
  baselines.py — distributed GD (engine), one-shot averaging, FedAvg wrappers
  neural.py    — FSVRG/FedAvg for neural-network pytrees over the mesh
"""
from repro.core.problem import (ClientBucket, FederatedLogReg, LogRegProblem,
                                build_dense_problem, build_problem,
                                build_test_problem)
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.fsvrg import FSVRG, FSVRGConfig, naive_fsvrg_round
from repro.core.fedavg import FedAvg, FedAvgConfig
from repro.core.dane import DANE, DANEConfig, DANERidge, dane_svrg_round
from repro.core.cocoa import (CoCoAConfig, CoCoAPlus, DualMethod,
                              PrimalMethod)

__all__ = [
    "ClientBucket", "FederatedLogReg", "LogRegProblem", "build_dense_problem",
    "build_problem", "build_test_problem", "EngineConfig", "RoundEngine",
    "FSVRG", "FSVRGConfig", "naive_fsvrg_round", "FedAvg", "FedAvgConfig",
    "DANE", "DANEConfig", "DANERidge", "dane_svrg_round",
    "CoCoAConfig", "CoCoAPlus", "DualMethod", "PrimalMethod",
]
