"""The paper's contribution: federated optimization algorithms.

  problem.py   — federated finite-sum problem (sparse logreg), bucketed clients
  engine.py    — unified round engine: client sampling, vmap-over-bucket
                 passes, pluggable aggregation (shared by all algorithms)
  scaling.py   — S_k / A sparsity statistics (§3.6.1)
  fsvrg.py     — Algorithms 3 & 4 (the paper's method), on the engine
  fedavg.py    — Federated Averaging (1602.05629), on the engine
  dane.py      — Algorithm 2 + the Proposition-1 DANE↔SVRG construction
  cocoa.py     — Appendix-A Algorithms 5 & 6, Theorem 5, CoCoA+
  baselines.py — distributed GD (engine), one-shot averaging, FedAvg wrappers
  neural.py    — FSVRG/FedAvg for neural-network pytrees over the mesh
"""
from repro.core.problem import (ClientBucket, FederatedLogReg, LogRegProblem,
                                build_problem, build_test_problem)
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.fsvrg import FSVRG, FSVRGConfig, naive_fsvrg_round
from repro.core.fedavg import FedAvg, FedAvgConfig

__all__ = [
    "ClientBucket", "FederatedLogReg", "LogRegProblem", "build_problem",
    "build_test_problem", "EngineConfig", "RoundEngine",
    "FSVRG", "FSVRGConfig", "naive_fsvrg_round", "FedAvg", "FedAvgConfig",
]
