"""The paper's contribution: federated optimization algorithms.

  problem.py   — federated finite-sum problem (sparse logreg), bucketed
                 clients; build_dense_problem for ridge data on the engine
  engine.py    — unified round engine: client sampling, vmap-over-bucket
                 passes, pluggable aggregation, per-client dual-state hook
                 (shared by all algorithms)
  solver.py    — the FederatedSolver protocol: init/round over a SolverState
                 pytree (iterate + per-client aux state + round counter)
  registry.py  — string-keyed solver registry (make_solver("fedavg", prob)),
                 defaults fed from repro.configs
  trainer.py   — the shared Trainer.fit round-loop driver: key schedule,
                 eval/history, retrospective sweep, checkpointing, and the
                 jit+lax.scan fast path
  scaling.py   — S_k / A sparsity statistics (§3.6.1)
  fsvrg.py     — Algorithms 3 & 4 (the paper's method), on the engine
  fedavg.py    — Federated Averaging (1602.05629), on the engine
  dane.py      — Algorithm 2 (GD/SVRG local solvers, exact ridge) + the
                 Proposition-1 DANE↔SVRG construction, on the engine
  cocoa.py     — CoCoA+ and Appendix-A Algorithms 5 & 6 (Theorem 5), on the
                 engine's dual-state hook
  baselines.py — distributed GD (engine), one-shot averaging, FedAvg wrappers
  neural.py    — FSVRG/FedAvg for neural-network pytrees over the mesh
"""
from repro.core.problem import (ClientBucket, FederatedLogReg, LogRegProblem,
                                VirtualBucket, VirtualFlat, VirtualLayout,
                                build_dense_problem, build_problem,
                                build_test_problem, build_virtual_problem)
from repro.core.engine import EngineConfig, RoundEngine, cohort_capacity
from repro.core.solver import FederatedSolver, SolverState
from repro.core.registry import available, get_spec, make_solver, register
from repro.core.trainer import (FitResult, NonFiniteIterateError, Trainer,
                                sweep)
from repro.core.fsvrg import FSVRG, FSVRGConfig, naive_fsvrg_round
from repro.core.fedavg import FedAvg, FedAvgConfig
from repro.core.dane import DANE, DANEConfig, DANERidge, dane_svrg_round
from repro.core.cocoa import (CoCoAConfig, CoCoAPlus, DualMethod,
                              PrimalMethod)
from repro.core.baselines import DistributedGD

__all__ = [
    "ClientBucket", "FederatedLogReg", "LogRegProblem", "VirtualBucket",
    "VirtualFlat", "VirtualLayout", "build_dense_problem", "build_problem",
    "build_test_problem", "build_virtual_problem", "EngineConfig",
    "RoundEngine", "cohort_capacity",
    "FederatedSolver", "SolverState",
    "available", "get_spec", "make_solver", "register",
    "FitResult", "NonFiniteIterateError", "Trainer", "sweep",
    "FSVRG", "FSVRGConfig", "naive_fsvrg_round", "FedAvg", "FedAvgConfig",
    "DANE", "DANEConfig", "DANERidge", "dane_svrg_round",
    "CoCoAConfig", "CoCoAPlus", "DualMethod", "PrimalMethod", "DistributedGD",
]
