"""The paper's contribution: federated optimization algorithms.

  problem.py   — federated finite-sum problem (sparse logreg), bucketed clients
  scaling.py   — S_k / A sparsity statistics (§3.6.1)
  fsvrg.py     — Algorithms 3 & 4 (the paper's method)
  dane.py      — Algorithm 2 + the Proposition-1 DANE↔SVRG construction
  cocoa.py     — Appendix-A Algorithms 5 & 6, Theorem 5, CoCoA+
  baselines.py — distributed GD, one-shot averaging, FedAvg local SGD
  neural.py    — FSVRG/FedAvg for neural-network pytrees over the mesh
"""
from repro.core.problem import (ClientBucket, FederatedLogReg, LogRegProblem,
                                build_problem, build_test_problem)
from repro.core.fsvrg import FSVRG, FSVRGConfig, naive_fsvrg_round

__all__ = [
    "ClientBucket", "FederatedLogReg", "LogRegProblem", "build_problem",
    "build_test_problem", "FSVRG", "FSVRGConfig", "naive_fsvrg_round",
]
