"""Appendix-A methods and CoCoA+.

  * Algorithm 5 (Primal Method) — quadratic-perturbation method with
    perturbation vectors a_k^t = ∇F_k(w^t) − (η∇F_k(w^t) + g_k^t).
  * Algorithm 6 (Dual Method) — dual block proximal gradient ascent.
  * Theorem 5: for ridge regression the two generate identical iterates
    under w^t = (1/λn) X α^t — checked in tests/test_equivalence.py.
  * CoCoA+ [57] — the inexact version of Algorithm 6 (local SDCA instead of
    an exact block solve); used in the Fig.-2 reproduction, where the paper
    shows it converges slowly on sparse non-IID data because the safe
    aggregation parameter σ' scales with K.

Appendix-A methods assume equal n_k (as the paper does, "for simplicity");
CoCoA+ runs on the general bucketed sparse problem.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.problem import FederatedLogReg


# --------------------------------------------------------------------- #
# Appendix A, ridge regression, dense per-client data  X_k: (d, m)
# --------------------------------------------------------------------- #


def _Fk_grad_ridge(X, y, w, lam, n, K):
    """F_k(w) = (K/2n)||X^T w − y||² + (λ/2)||w||²  (eq. 12 normalization)."""
    return (K / n) * (X @ (X.T @ w - y)) + lam * w


def primal_method_init(Xs: Sequence[jax.Array], alphas0: Sequence[jax.Array],
                       lam: float, sigma: float):
    """Steps 3–5 of Algorithm 5. Returns (w0, g0 list, eta, mu)."""
    K = len(Xs)
    n = sum(int(a.shape[0]) for a in alphas0)
    eta = K / sigma
    mu = lam * (eta - 1.0)
    w0 = sum(X @ a for X, a in zip(Xs, alphas0)) / (lam * n)
    g0 = [eta * ((K / n) * (X @ a) - lam * w0) for X, a in zip(Xs, alphas0)]
    return w0, g0, eta, mu


def primal_method_round(Xs, ys, w, gs: List[jax.Array], lam, eta, mu):
    """One round of Algorithm 5 (exact local solves; ridge)."""
    K = len(Xs)
    n = sum(int(y.shape[0]) for y in ys)
    d = w.shape[0]
    w_ks = []
    for k in range(K):
        X, y = Xs[k], ys[k]
        # argmin F_k(w') − (∇F_k(w^t) − (η∇F_k(w^t) + g_k))ᵀ w' + µ/2||w'−w^t||²
        b_k = (1.0 - eta) * _Fk_grad_ridge(X, y, w, lam, n, K) - gs[k]
        # ∇F_k(w') = (K/n) X Xᵀ w' − (K/n) X y + λ w'
        H = (K / n) * (X @ X.T) + (lam + mu) * jnp.eye(d)
        rhs = (K / n) * (X @ y) + b_k + mu * w
        w_ks.append(jnp.linalg.solve(H, rhs))
    w_next = sum(w_ks) / K
    gs_next = [gs[k] + lam * eta * (w_ks[k] - w_next) for k in range(K)]
    return w_next, gs_next


def dual_method_round(Xs, ys, alphas: List[jax.Array], lam, sigma):
    """One round of Algorithm 6 (exact block solves; ridge φ_i(t)=½(t−y_i)²).

    Block subproblem (19): h_k = argmin (σ/2λn)||X_k h||² + ½||h||²
                                        − (y_k − X_kᵀw^t − α_k)ᵀ h
    """
    K = len(Xs)
    n = sum(int(a.shape[0]) for a in alphas)
    w = sum(X @ a for X, a in zip(Xs, alphas)) / (lam * n)
    new_alphas = []
    for k in range(K):
        X, y, a = Xs[k], ys[k], alphas[k]
        m = a.shape[0]
        c = y - X.T @ w - a
        M = (sigma / (lam * n)) * (X.T @ X) + jnp.eye(m)
        h = jnp.linalg.solve(M, c)
        new_alphas.append(a + h)
    return new_alphas


def dual_to_primal(Xs, alphas, lam):
    n = sum(int(a.shape[0]) for a in alphas)
    return sum(X @ a for X, a in zip(Xs, alphas)) / (lam * n)


# --------------------------------------------------------------------- #
# CoCoA+ for sparse logistic regression (local SDCA)
# --------------------------------------------------------------------- #


def _sdca_local_pass(w, alpha_b, bucket, lam, n, sigma, key):
    """One permutation pass of SDCA on each client's local dual subproblem.

    For logistic loss with y∈{−1,1} we parametrize β_i = y_i α_i ∈ (0,1);
    the scalar subproblem for coordinate i (from eq. (15)) is

        min_{β∈(0,1)}  m_i (β − β_old) + c_i (β − β_old)² + H(β),
        m_i = y_i x_iᵀ(w + (σ/λn) r),  c_i = σ||x_i||²/(2λn),
        H(β) = β log β + (1−β) log(1−β),

    solved with clipped Newton.  r = X_k u tracks this client's own updates
    within the round (the cross terms of the local block).
    """

    def one_client(idx, val, y, n_k, alpha_k, ck):
        d = w.shape[0]
        m_pad = y.shape[0]
        perm = jax.random.permutation(ck, m_pad)

        def newton_beta(beta0, mcoef, ccoef):
            def it(b, _):
                gb = mcoef + 2.0 * ccoef * (b - beta0) + jnp.log(b / (1.0 - b))
                hb = 2.0 * ccoef + 1.0 / (b * (1.0 - b))
                return jnp.clip(b - gb / hb, 1e-6, 1.0 - 1e-6), None
            b0 = jnp.clip(jax.nn.sigmoid(-mcoef), 1e-6, 1.0 - 1e-6)
            b, _ = jax.lax.scan(it, b0, None, length=12)
            return b

        def step(carry, t):
            u, r = carry
            i = perm[t]
            xi, vi, yi = idx[i], val[i], y[i]
            valid = (i < n_k).astype(jnp.float32)
            beta_old = yi * alpha_k[i]
            beta_old = jnp.clip(beta_old, 1e-6, 1.0 - 1e-6)
            xn2 = (vi * vi).sum()
            mcoef = yi * ((vi * w[xi]).sum() + (sigma / (lam * n)) * (vi * r[xi]).sum())
            ccoef = sigma * xn2 / (2.0 * lam * n)
            beta = newton_beta(beta_old, mcoef, ccoef)
            du = valid * yi * (beta - beta_old)
            u = u.at[i].add(du)
            r = r.at[xi].add(du * vi)
            return (u, r), None

        u0 = jnp.zeros((m_pad,))
        r0 = jnp.zeros((d,))
        (u, r), _ = jax.lax.scan(step, (u0, r0), jnp.arange(m_pad))
        return u, r

    keys = jax.random.split(key, bucket.num_clients)
    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y,
                                bucket.n_k, alpha_b, keys)


class CoCoAPlus:
    """CoCoA+ with γ=1 (adding) and safe σ' = γK by default."""

    def __init__(self, problem: FederatedLogReg, sigma: float | None = None):
        self.problem = problem
        self.sigma = float(sigma if sigma is not None else problem.num_clients)
        self.alphas = [jnp.zeros((b.num_clients, b.m_pad)) for b in problem.buckets]
        n = problem.flat.n
        lam = problem.flat.lam
        self.w = jnp.zeros((problem.d,))
        self._pass = [
            jax.jit(lambda w, a, key, b=b: _sdca_local_pass(
                w, a, b, lam, n, self.sigma, key))
            for b in problem.buckets
        ]

    def round(self, key):
        lam, n = self.problem.flat.lam, self.problem.flat.n
        dw = jnp.zeros_like(self.w)
        for bi, (b, pfn) in enumerate(zip(self.problem.buckets, self._pass)):
            u, r = pfn(self.w, self.alphas[bi], jax.random.fold_in(key, bi))
            self.alphas[bi] = self.alphas[bi] + u
            dw = dw + r.sum(axis=0)
        self.w = self.w + dw / (lam * n)
        return self.w
