"""CoCoA+ and the Appendix-A primal/dual methods, on the RoundEngine.

All three algorithms carry *per-client state across rounds* — exactly the
case the engine's :meth:`~repro.core.engine.RoundEngine.round_with_state`
hook exists for: the client pass receives and returns its bucket's state,
and the primal deltas flow through the ordinary aggregation path with
``weighting="sum"`` (each delta already carries its 1/(λn) normalization,
so the server update is the plain Σ_k of Algorithm 6 / CoCoA+).

  * :class:`CoCoAPlus` — CoCoA+ [arXiv:1502.03508] with γ=1 (adding) and the
    safe σ′ = γK, on the general bucketed sparse logreg problem.  State is
    the dual block α_k (Kb, m_pad) per bucket; the local solver is one
    permutation pass of SDCA whose per-coordinate subproblem (from eq. 15)
    is solved by clipped Newton — fused across the vmapped client batch by
    the Pallas kernel :func:`repro.kernels.cocoa_sdca.cocoa_sdca_update` on
    TPU, the identical jnp recursion elsewhere.  The paper's Fig. 2 shows it
    converging slowly on sparse non-IID data because σ′ scales with K.
  * :class:`PrimalMethod` — Algorithm 5: quadratic-perturbation method with
    perturbation vectors a_k^t = ∇F_k(w^t) − (η∇F_k(w^t) + g_k^t); state is
    g_k, updated from the aggregated w^{t+1} after the round.
  * :class:`DualMethod` — Algorithm 6: dual block proximal gradient ascent
    with exact block solves (eq. 19); state is α_k, and the iterate tracks
    w^t = (1/λn) X α^t incrementally through the sum-weighted deltas.
  * Theorem 5: for ridge regression Algorithms 5 and 6 generate identical
    iterates under w^t = (1/λn) X α^t — checked on the engine ports in
    tests/test_equivalence.py (both classes assume equal n_k, as the paper
    does "for simplicity", on a :func:`build_dense_problem` layout).

The pre-port list-based implementations survive verbatim in
tests/_oracles.py and pin these ports round-by-round.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.engine import EngineConfig, RoundEngine
from repro.core.problem import ClientBucket, FederatedLogReg
from repro.core.registry import register
from repro.core.solver import FederatedSolver, SolverState


def dual_to_primal(Xs, alphas, lam):
    """w = (1/λn) Σ_k X_k α_k for list-of-arrays dual blocks."""
    n = sum(int(a.shape[0]) for a in alphas)
    return sum(X @ a for X, a in zip(Xs, alphas)) / (lam * n)


# --------------------------------------------------------------------- #
# CoCoA+ for sparse logistic regression (local SDCA)
# --------------------------------------------------------------------- #


def _sdca_local_pass(w, alpha_b, bucket: ClientBucket, lam, n, sigma,
                     use_kernel, key):
    """One permutation pass of SDCA on each client's local dual subproblem.

    For logistic loss with y∈{−1,1} we parametrize β_i = y_i α_i ∈ (0,1);
    the scalar subproblem for coordinate i (from eq. (15)) is

        min_{β∈(0,1)}  m_i (β − β_old) + c_i (β − β_old)² + H(β),
        m_i = y_i x_iᵀ(w + (σ/λn) r),  c_i = σ||x_i||²/(2λn),
        H(β) = β log β + (1−β) log(1−β).

    r = X_k u tracks each client's own updates within the round (the cross
    terms of the local block).  The scan runs at the *bucket* level: at step
    t every client processes the t-th coordinate of its own permutation
    (clients are independent, so lockstep order is exactly the per-client
    sequential order), which turns the clipped-Newton β-solve into ONE
    (Kb,)-vector call per step — the fused Pallas kernel when
    ``use_kernel``, the identical jnp recursion elsewhere.
    """
    keys = jax.random.split(key, bucket.num_clients)
    return _sdca_local_pass_keyed(w, alpha_b, bucket, lam, n, sigma,
                                  use_kernel, keys)


def _sdca_local_pass_keyed(w, alpha_b, bucket: ClientBucket, lam, n, sigma,
                           use_kernel, keys):
    """:func:`_sdca_local_pass` over explicit per-client keys — the engine's
    streamed (``client_chunk``) path hands in chunk-sized bucket/state
    slices with the matching slice of the bucket's key split."""
    Kb = bucket.num_clients
    m_pad = bucket.m_pad
    d = w.shape[0]
    perms = jax.vmap(lambda ck: jax.random.permutation(ck, m_pad))(keys)

    def coeffs_one(idx, val, y, alpha_k, r, i):
        xi, vi, yi = idx[i], val[i], y[i]
        beta_old = jnp.clip(yi * alpha_k[i], 1e-6, 1.0 - 1e-6)
        xn2 = (vi * vi).sum()
        mcoef = yi * ((vi * w[xi]).sum() + (sigma / (lam * n)) * (vi * r[xi]).sum())
        ccoef = sigma * xn2 / (2.0 * lam * n)
        return beta_old, mcoef, ccoef

    def apply_one(idx, val, y, n_k, u, r, i, beta_old, beta):
        xi, vi, yi = idx[i], val[i], y[i]
        valid = (i < n_k).astype(jnp.float32)
        du = valid * yi * (beta - beta_old)
        return u.at[i].add(du), r.at[xi].add(du * vi)

    def newton_batch(beta0, mcoef, ccoef):          # all (Kb,)
        if use_kernel:
            from repro.kernels import ops
            return ops.cocoa_sdca_update(beta0, mcoef, ccoef)
        from repro.kernels import ref
        return ref.cocoa_sdca_update_ref(beta0, mcoef, ccoef)

    def step(carry, t):
        u, r = carry                               # (Kb, m_pad), (Kb, d)
        i = perms[:, t]                            # (Kb,)
        beta_old, mcoef, ccoef = jax.vmap(coeffs_one)(
            bucket.idx, bucket.val, bucket.y, alpha_b, r, i)
        beta = newton_batch(beta_old, mcoef, ccoef)
        u, r = jax.vmap(apply_one)(bucket.idx, bucket.val, bucket.y,
                                   bucket.n_k, u, r, i, beta_old, beta)
        return (u, r), None

    u0 = jnp.zeros((Kb, m_pad))
    r0 = jnp.zeros((Kb, d))
    (u, r), _ = jax.lax.scan(step, (u0, r0), jnp.arange(m_pad))
    return u, r


@dataclasses.dataclass(frozen=True)
class CoCoAConfig:
    """CoCoA+ knobs (γ is fixed at 1, the "adding" variant)."""

    sigma: Optional[float] = None  # σ': None -> the safe γK
    participation: float = 1.0     # i.i.d. per-round client participation
    aggregator: str = "dense"      # engine aggregator: "dense" | "pallas"
    # None -> auto: fused Pallas cocoa_sdca kernel on TPU, jnp elsewhere.
    use_kernel: Optional[bool] = None
    # None -> materialize each bucket's (Kb, d) delta stack; an int streams
    # the client axis in chunks of this size (see EngineConfig.client_chunk)
    client_chunk: Optional[int] = None
    # under partial participation, compute only the sampled cohort (padded
    # to this per-bucket capacity; see EngineConfig.cohort / cohort_capacity)
    cohort: Optional[int] = None
    # run on a build_virtual_problem layout: rows regenerate on demand
    # inside the round (see EngineConfig.virtual_data; auto-detected).  The
    # dual blocks α_k stay materialized — they are the algorithm's own
    # state, not the dataset's.
    virtual_data: bool = False
    # replace the Bernoulli draw with a repro.fleet participation model
    # (trace-driven availability/stragglers); `participation` then serves
    # as the model's upper-bound rate for cohort capacity sizing
    participation_model: Optional[Any] = None
    # corrupt returned deltas through a repro.fleet.faults fault model
    # (corruption hits the wire — the primal contribution — never the
    # dual blocks, which stay whatever the honest pass computed)
    fault_model: Optional[Any] = None
    # robust server aggregation.  Dual methods aggregate with
    # weighting="sum", so only "clip" composes (order-statistic guards
    # would break the w = (1/λn)Xα invariant and are a config error).
    aggregator_guard: Optional[str] = None
    guard_clip_norm: Optional[float] = None
    guard_trim: float = 0.1


class CoCoAPlus(FederatedSolver):
    """CoCoA+ with γ=1 and safe σ′ = γK by default, on the engine.

    Purely functional: the dual blocks α_k (one (Kb, m_pad) array per
    bucket) ride in ``state.aux`` and travel through
    :meth:`RoundEngine.round_with_state`; the per-client primal
    contributions X_k u_k / (λn) are the deltas, summed by the engine
    (``weighting="sum"``) into w^{t+1} = w^t + (γ/λn) Σ_k X_k u_k.  Under
    partial participation the engine freezes the dual blocks of the
    clients its Bernoulli draw left out.

    ``init()`` starts at α = 0 ⇒ w = 0; a nonzero ``w0`` would break the
    dual-primal invariant w = (1/λn) X α and is rejected."""

    name = "cocoa"

    def __init__(self, problem: FederatedLogReg, sigma: Optional[float] = None,
                 cfg: CoCoAConfig = CoCoAConfig()):
        if sigma is not None:
            cfg = dataclasses.replace(cfg, sigma=sigma)
        self.problem = problem
        self.cfg = cfg
        self.sigma = float(cfg.sigma if cfg.sigma is not None
                           else problem.num_clients)
        use_kernel = cfg.use_kernel
        if use_kernel is None:
            use_kernel = jax.default_backend() == "tpu"
        n = problem.flat.n
        lam = problem.flat.lam
        self._scale = 1.0 / (lam * n)
        virtual = cfg.virtual_data or problem.virtual is not None
        self._pass = [] if virtual else [
            jax.jit(lambda w, a, key, b=b: _sdca_local_pass(
                w, a, b, lam, n, self.sigma, use_kernel, key))
            for b in problem.buckets
        ]
        self.engine = RoundEngine(
            problem,
            EngineConfig(weighting="sum", participation=cfg.participation,
                         aggregator=cfg.aggregator,
                         client_chunk=cfg.client_chunk,
                         cohort=cfg.cohort,
                         virtual_data=virtual,
                         aggregator_guard=cfg.aggregator_guard,
                         guard_clip_norm=cfg.guard_clip_norm,
                         guard_trim=cfg.guard_trim),
            participation_model=cfg.participation_model,
            fault_model=cfg.fault_model,
        )

        def cocoa_pass(w, bi, bucket, alpha_b, kb):
            u, r = self._pass[bi](w, alpha_b, kb)
            return r * self._scale, alpha_b + u

        def cocoa_chunk_pass(w, bi, chunk_bucket, alpha_c, keys):
            u, r = _sdca_local_pass_keyed(w, alpha_c, chunk_bucket, lam, n,
                                          self.sigma, use_kernel, keys)
            return r * self._scale, alpha_c + u

        self._round_fast = self.engine.compile_with_state(
            cocoa_pass, chunk_pass=cocoa_chunk_pass)
        self._round_ref = self.engine.reference_with_state(
            cocoa_pass, chunk_pass=cocoa_chunk_pass)

    def init(self, w0: Optional[jax.Array] = None) -> SolverState:
        if w0 is not None and bool(jnp.any(w0 != 0)):
            raise ValueError("CoCoA+ starts at alpha=0 => w=0; a custom w0 "
                             "would break w = (1/lambda n) X alpha")
        return SolverState(
            w=jnp.zeros((self.problem.d,)),
            aux=tuple(jnp.zeros((b.num_clients, b.m_pad))
                      for b in self.problem.buckets),
            round=jnp.asarray(0, jnp.int32))

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        w, alphas = self._round_fast(state.w, state.aux, key,
                                     round_index=state.round)
        return SolverState(w=w, aux=alphas, round=state.round + 1)

    @property
    def hyperparams(self):
        hp = dataclasses.asdict(self.cfg)
        hp["sigma"] = self.sigma          # the resolved σ′, not the None default
        return hp


# --------------------------------------------------------------------- #
# Appendix A, ridge regression, engine-ported (equal n_k, dense buckets)
# --------------------------------------------------------------------- #


def _check_equal_sizes(problem: FederatedLogReg):
    for b in problem.buckets:
        if int(b.n_k.min()) != int(b.n_k.max()):
            raise ValueError("Appendix-A methods assume equal n_k")
    if len(problem.buckets) != 1:
        raise ValueError("Appendix-A methods assume equal n_k (one bucket)")


def _stack_alphas0(problem: FederatedLogReg,
                   alphas0: Optional[Sequence[jax.Array]]) -> jax.Array:
    """(K, m) initial dual blocks from a per-client list (zeros default)."""
    b = problem.buckets[0]
    if alphas0 is None:
        return jnp.zeros((b.num_clients, b.m_pad), b.val.dtype)
    return jnp.stack([jnp.asarray(a) for a in alphas0])


class PrimalMethod(FederatedSolver):
    """Algorithm 5 (Primal Method) with exact local solves, on the engine.

    Per-client state g_k (steps 4/9) rides through ``round_with_state``:
    the pass returns each exact subproblem solution w_k as the bucket state,
    the engine's uniform weighting forms w^{t+1} = (1/K) Σ w_k, and step 9
    (g_k ← g_k + λη(w_k − w^{t+1})) closes the round with the aggregate.

    ``problem`` must be a :func:`~repro.core.problem.build_dense_problem`
    layout with equal n_k.  ``init()`` runs steps 3–5 (w⁰ and g⁰ follow
    from ``alphas0``), so a custom ``w0`` is rejected."""

    name = "primal"

    def __init__(self, problem: FederatedLogReg, *,
                 sigma: Optional[float] = None, alphas0=None):
        _check_equal_sizes(problem)
        self.problem = problem
        K = problem.num_clients
        self.lam = float(problem.flat.lam)
        self.sigma = float(K if sigma is None else sigma)
        self.eta = K / self.sigma
        self.mu = self.lam * (self.eta - 1.0)
        self._alpha0 = _stack_alphas0(problem, alphas0)
        self.engine = RoundEngine(problem, EngineConfig(weighting="uniform"))
        # donate=False: step 9's epilogue re-reads state.aux *after* the
        # compiled dispatch, so the state buffers must survive the call.
        self._round_fast = self.engine.compile_with_state(self._primal_pass,
                                                          donate=False)

    @property
    def hyperparams(self):
        return {"sigma": self.sigma, "eta": self.eta, "mu": self.mu}

    def init(self, w0: Optional[jax.Array] = None) -> SolverState:
        if w0 is not None:
            raise ValueError("PrimalMethod's w0 is determined by alphas0 "
                             "(steps 3-5 of Algorithm 5)")
        b = self.problem.buckets[0]
        n = self.problem.flat.n
        K = self.problem.num_clients
        # steps 3-5: w^0 = (1/λn) Σ X_k α_k;  g_k^0 = η((K/n) X_k α_k − λw^0)
        xa = jnp.einsum("kmd,km->kd", b.val, self._alpha0)       # X_k α_k
        w = xa.sum(axis=0) / (self.lam * n)
        gs = self.eta * ((K / n) * xa - self.lam * w)
        return SolverState(w=w, aux=(gs,), round=jnp.asarray(0, jnp.int32))

    def _primal_pass(self, w, bi, bucket, gs_b, kb):
        lam, eta, mu = self.lam, self.eta, self.mu
        K, n = self.problem.num_clients, self.problem.flat.n

        def one_client(val, y, g_k):
            d = w.shape[0]
            X = val.T
            # argmin F_k(w') − (∇F_k(w^t) − (η∇F_k(w^t) + g_k))ᵀw'
            #        + µ/2||w'−w^t||²,  F_k as in eq. 12 ((K/n)-normalized)
            Fk = (K / n) * (X @ (X.T @ w - y)) + lam * w
            b_k = (1.0 - eta) * Fk - g_k
            H = (K / n) * (X @ X.T) + (lam + mu) * jnp.eye(d, dtype=val.dtype)
            rhs = (K / n) * (X @ y) + b_k + mu * w
            wk = jnp.linalg.solve(H, rhs)
            return wk - w, wk

        return jax.vmap(one_client)(bucket.val, bucket.y, gs_b)

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        # step 9's g update needs the aggregated w^{t+1}, so it closes the
        # round eagerly after the compiled engine dispatch.
        w_next, wks = self._round_fast(state.w, state.aux, key)
        gs = tuple(g + self.lam * self.eta * (wk - w_next)
                   for g, wk in zip(state.aux, wks))
        return SolverState(w=w_next, aux=gs, round=state.round + 1)


class DualMethod(FederatedSolver):
    """Algorithm 6 (Dual Method) with exact block solves, on the engine.

    Block subproblem (19): h_k = argmin (σ/2λn)||X_k h||² + ½||h||²
                                        − (y_k − X_kᵀw^t − α_k)ᵀ h
    State is the dual block α_k in ``state.aux``; the pass returns
    X_k h_k/(λn) as the delta, so the engine's plain sum tracks
    w^{t+1} = (1/λn) X α^{t+1} exactly.  ``init()`` derives w⁰ from
    ``alphas0``, so a custom ``w0`` is rejected."""

    name = "dual"

    def __init__(self, problem: FederatedLogReg, *,
                 sigma: Optional[float] = None, alphas0=None):
        _check_equal_sizes(problem)
        self.problem = problem
        self.lam = float(problem.flat.lam)
        self.sigma = float(problem.num_clients if sigma is None else sigma)
        self._alpha0 = _stack_alphas0(problem, alphas0)
        self.engine = RoundEngine(problem, EngineConfig(weighting="sum"))
        self._round_fast = self.engine.compile_with_state(self._dual_pass)

    @property
    def hyperparams(self):
        return {"sigma": self.sigma}

    def init(self, w0: Optional[jax.Array] = None) -> SolverState:
        if w0 is not None:
            raise ValueError("DualMethod's w0 is determined by alphas0 "
                             "(w = (1/lambda n) X alpha)")
        b = self.problem.buckets[0]
        n = self.problem.flat.n
        w = jnp.einsum("kmd,km->d", b.val, self._alpha0) / (self.lam * n)
        # hand out a copy: round 1's compiled dispatch donates the state
        # buffers off-CPU, and the cached template must survive re-inits
        return SolverState(w=w, aux=(jnp.array(self._alpha0),),
                           round=jnp.asarray(0, jnp.int32))

    def _dual_pass(self, w, bi, bucket, alpha_b, kb):
        lam, sigma = self.lam, self.sigma
        n = self.problem.flat.n

        def one_client(val, y, a):
            X = val.T
            m = a.shape[0]
            c = y - X.T @ w - a
            M = (sigma / (lam * n)) * (X.T @ X) + jnp.eye(m, dtype=val.dtype)
            h = jnp.linalg.solve(M, c)
            return (X @ h) / (lam * n), a + h

        return jax.vmap(one_client)(bucket.val, bucket.y, alpha_b)

    def round(self, state: SolverState, key: jax.Array) -> SolverState:
        w, alphas = self._round_fast(state.w, state.aux, key)
        return SolverState(w=w, aux=alphas, round=state.round + 1)


def _cocoa_defaults():
    from repro.configs import get_cocoa_config
    return {"sigma": get_cocoa_config().sigma}


@register("cocoa", defaults=_cocoa_defaults,
          description="CoCoA+ (arXiv:1502.03508, γ=1, local SDCA)")
def _make_cocoa(problem: FederatedLogReg, sigma=None, **kw) -> CoCoAPlus:
    return CoCoAPlus(problem, sigma=sigma, cfg=CoCoAConfig(**kw))


@register("primal", layout="dense",
          description="Appendix-A Algorithm 5 (Primal Method, exact solves)")
def _make_primal(problem: FederatedLogReg, **kw) -> PrimalMethod:
    return PrimalMethod(problem, **kw)


@register("dual", layout="dense",
          description="Appendix-A Algorithm 6 (Dual Method, exact solves)")
def _make_dual(problem: FederatedLogReg, **kw) -> DualMethod:
    return DualMethod(problem, **kw)
