"""FSVRG run settings for the §4 G+ logreg experiment (Fig. 2's own curve).

Algorithm 4's only free knob is the global stepsize h (the per-client
stepsize is h/n_k, mod. 1); the paper picks it retrospectively, so the
config carries both the default and the sweep grid the benchmark uses.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FSVRGRunConfig:
    name: str = "fsvrg-gplus"
    citation: str = "arXiv:1610.02527 Alg. 4"
    stepsize: float = 1.0                                # h (default outside sweeps)
    stepsize_sweep: Tuple[float, ...] = (0.3, 1.0, 3.0)  # retrospective best-h


CONFIG = FSVRGRunConfig()
