"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B] — qwen1.5-arch (MHA, kv=32)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    citation="hf:Qwen/CodeQwen1.5-7B",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    rope_theta=1000000.0,
)
