"""Phi-3.5-MoE-42B-A6.6B [hf:microsoft/Phi-3.5-MoE-instruct] — 16 experts top-2."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    moe=MoEConfig(num_experts=16, experts_per_token=2),
    moe_period=1,
)
