"""CoCoA+ run settings for the §4 G+ logreg experiment (Fig. 2's CoCoA+ curve).

Ma et al. (arXiv:1502.03508) parameterize CoCoA+ by the aggregation γ and
the subproblem parameter σ'; the safe choice for γ=1 (adding) is σ' = γK,
which is what makes the method slow on this problem — the paper's point is
exactly that σ' must scale with K=10,000 while the local SDCA pass only
sees ~216 examples.  ``sigma=None`` selects the safe γK at problem-build
time; the local solver is one SDCA permutation pass per round.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class CoCoARunConfig:
    name: str = "cocoa-gplus"
    citation: str = "arXiv:1502.03508"
    sigma: Optional[float] = None   # σ': None -> safe γK
    gamma: float = 1.0              # fixed at 1 ("adding") in this repro

CONFIG = CoCoARunConfig()
