"""Config registry: ``get_config('<arch-id>')`` and ``ARCH_IDS``."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ArchConfig, InputShape, MoEConfig, INPUT_SHAPES, SHAPES

_MODULES: Dict[str, str] = {
    "granite-20b": "granite_20b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "internvl2-1b": "internvl2_1b",
    "llama3-8b": "llama3_8b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "dbrx-132b": "dbrx_132b",
    "rwkv6-3b": "rwkv6_3b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_logreg_config():
    mod = importlib.import_module("repro.configs.gplus_logreg")
    return mod.CONFIG


def get_paper_k_config():
    """§4's K = 10,000 client count with CI-sized d/n_k (see gplus_logreg)."""
    mod = importlib.import_module("repro.configs.gplus_logreg")
    return mod.PAPER_K_CONFIG


def get_virtual_k_config(num_clients: int):
    """The virtual-data (on-demand regeneration) config at a chosen K —
    the §1.2 'as many nodes as users' regime (see gplus_logreg)."""
    mod = importlib.import_module("repro.configs.gplus_logreg")
    return mod.get_virtual_k_config(num_clients)


def get_fedavg_config():
    mod = importlib.import_module("repro.configs.fedavg_gplus")
    return mod.CONFIG


def get_dane_config():
    mod = importlib.import_module("repro.configs.dane_gplus")
    return mod.CONFIG


def get_cocoa_config():
    mod = importlib.import_module("repro.configs.cocoa_gplus")
    return mod.CONFIG


def get_fsvrg_config():
    mod = importlib.import_module("repro.configs.fsvrg_gplus")
    return mod.CONFIG


def get_gd_config():
    mod = importlib.import_module("repro.configs.gd_gplus")
    return mod.CONFIG


__all__ = [
    "ArchConfig", "InputShape", "MoEConfig", "INPUT_SHAPES", "SHAPES",
    "ARCH_IDS", "get_config", "get_logreg_config", "get_paper_k_config",
    "get_virtual_k_config",
    "get_fedavg_config", "get_dane_config", "get_cocoa_config",
    "get_fsvrg_config", "get_gd_config",
]
