"""FedAvg run settings for the §4 G+ logreg experiment.

McMahan et al. (arXiv:1602.05629) parameterize FedAvg by the client fraction
C, local epochs E, and local batch size B; this repro runs B=∞ (one
sequential permutation pass per epoch) so the knobs are E
(``local_epochs``), C (``participation``), and the local stepsize h, swept
retrospectively like every other curve in ``benchmarks/fig2_convergence.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class FedAvgRunConfig:
    name: str = "fedavg-gplus"
    citation: str = "arXiv:1602.05629"
    stepsize: float = 0.1                               # h (default outside sweeps)
    stepsize_sweep: Tuple[float, ...] = (0.1, 0.5, 2.0)  # retrospective best-h
    local_epochs: int = 2                               # E
    participation: float = 1.0                          # C


CONFIG = FedAvgRunConfig()
