"""DANE run settings for the §4 G+ logreg experiment (Fig. 2's DANE curve).

Shamir et al. (arXiv:1312.7853) analyze DANE for quadratics; on the sparse
non-IID logistic problem the paper reports it converging poorly — which the
reproduction shows too.  The logistic subproblem has no closed form, so the
local solver is ``local_steps`` GD iterations; µ > 0 is required for
stability here (µ = 0, the quadratic-case default, diverges on this data),
and the local stepsize is swept retrospectively like every other curve in
``benchmarks/fig2_convergence.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class DANERunConfig:
    name: str = "dane-gplus"
    citation: str = "arXiv:1312.7853"
    eta: float = 1.0                                    # η (eq. 10)
    mu: float = 3.0                                     # µ (eq. 10)
    local_steps: int = 25                               # GD solver iterations
    local_lr: float = 0.3                               # default outside sweeps
    local_lr_sweep: Tuple[float, ...] = (0.1, 0.3, 1.0)  # retrospective best

CONFIG = DANERunConfig()
