"""Llama-3-8B [arXiv:2407.21783] — dense GQA, 128k vocab."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    citation="arXiv:2407.21783",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
)
