"""DBRX-132B [hf:databricks/dbrx-base] — 16 experts top-4, fine-grained MoE."""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    citation="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    moe=MoEConfig(num_experts=16, experts_per_token=4),
    moe_period=1,
)
