"""InternVL2-1B [arXiv:2404.16821] — InternViT + InternLM2 (Qwen2-0.5B LM backbone).

Backbone only: the InternViT vision encoder + MLP projector is a stub;
``input_specs`` supplies precomputed patch embeddings prepended to the token
stream.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    citation="arXiv:2404.16821",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,
    head_dim=64,
    frontend="vision",
    frontend_tokens=256,       # ViT patch embeddings per image (stub)
    tie_embeddings=True,
)
