"""Distributed-GD run settings for the §4 G+ logreg experiment.

The "trivial benchmark" (teal diamonds in Fig. 2): one exact gradient step
per round of communication, stepsize picked retrospectively like every
other curve.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class GDRunConfig:
    name: str = "gd-gplus"
    citation: str = "arXiv:1610.02527 §2"
    stepsize: float = 2.0                                          # default outside sweeps
    stepsize_sweep: Tuple[float, ...] = (0.5, 2.0, 8.0, 32.0)      # retrospective best-h


CONFIG = GDRunConfig()
