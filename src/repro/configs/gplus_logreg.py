"""The paper's own experiment (§4): sparse L2-regularized logistic regression
over public Google+ posts, K=10,000 authors-as-clients.

The original data cannot be released (footnote 8 of the paper); we generate a
synthetic dataset matching the published statistics:
  n = 2,166,693 examples (scaled by ``scale``), d = 20,002 features
  (bag-of-words 20k + bias + unknown-word), n_k in [75, 9000] (power law),
  per-client feature clustering (non-IID), chronological 75/25 split.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LogRegConfig:
    name: str = "gplus-logreg"
    citation: str = "arXiv:1610.02527 §4"
    num_clients: int = 10_000
    num_features: int = 20_002
    num_examples: int = 2_166_693
    min_client_examples: int = 75
    max_client_examples: int = 9_000
    l2_reg: str = "1/n"            # lambda = 1/n, the paper's choice
    nnz_per_example: int = 60      # bag-of-words sparsity
    scale: float = 1.0             # <1 shrinks n/K proportionally for CI runs

    def scaled(self, scale: float) -> "LogRegConfig":
        K = max(8, int(self.num_clients * scale))
        f = min(1.0, scale * 10)
        n_min = max(2, int(self.min_client_examples * f))
        n_max = max(8, int(self.max_client_examples * f))
        n = max(64, int(self.num_examples * scale))
        # keep the shrunk config *feasible* for the power-law size draw
        # (K·n_min <= n <= ~0.8·K·n_max): an infeasible total saturates
        # every client at n_max and destroys the "unbalanced" property
        n = max(K * n_min, min(n, (8 * K * n_max) // 10))
        return dataclasses.replace(
            self,
            scale=scale,
            num_clients=K,
            num_examples=n,
            num_features=max(32, int(self.num_features * f)),
            min_client_examples=n_min,
            max_client_examples=n_max,
        )


CONFIG = LogRegConfig()

#: The paper-scale *client axis* on a CI box: the §4 experiment's K = 10,000
#: clients kept exact, with d and the per-client example counts shrunk so a
#: full federated round fits CPU CI.  The point of this config is the K —
#: the streamed (client_chunk) round path must handle the paper's "massively
#: distributed" regime, where materializing the (K, d) delta stack is what
#: breaks first, not the FLOPs.
PAPER_K_CONFIG = LogRegConfig(
    name="gplus-logreg-paper-k",
    num_clients=10_000,
    num_features=2_002,
    num_examples=60_000,
    min_client_examples=3,
    max_client_examples=24,
    nnz_per_example=12,
)

#: The thesis-scale client axis: "as many nodes as there are users of the
#: service" (§1.2).  d and n_k are kept small enough that a *virtual* round
#: (rows regenerated on demand inside the scan — EngineConfig.virtual_data)
#: is CPU-feasible at K up to 10⁶, while materializing the same dataset
#: at K=10⁶ would be ~4·10⁶ examples of (nnz+2)-wide rows — the regime the
#: virtual layout exists for.  Use :func:`get_virtual_k_config` to pick K.
VIRTUAL_K_CONFIG = LogRegConfig(
    name="gplus-logreg-virtual-k",
    num_clients=100_000,
    num_features=202,
    num_examples=400_000,
    min_client_examples=2,
    max_client_examples=8,
    nnz_per_example=6,
)


def get_virtual_k_config(num_clients: int) -> LogRegConfig:
    """VIRTUAL_K_CONFIG at a chosen K, total examples tracking 4·K so the
    per-client size distribution is K-independent."""
    if num_clients < 8:
        raise ValueError("num_clients must be >= 8")
    return dataclasses.replace(
        VIRTUAL_K_CONFIG,
        name=f"gplus-logreg-virtual-k{num_clients}",
        num_clients=num_clients,
        num_examples=4 * num_clients,
    )
