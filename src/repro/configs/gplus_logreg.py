"""The paper's own experiment (§4): sparse L2-regularized logistic regression
over public Google+ posts, K=10,000 authors-as-clients.

The original data cannot be released (footnote 8 of the paper); we generate a
synthetic dataset matching the published statistics:
  n = 2,166,693 examples (scaled by ``scale``), d = 20,002 features
  (bag-of-words 20k + bias + unknown-word), n_k in [75, 9000] (power law),
  per-client feature clustering (non-IID), chronological 75/25 split.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LogRegConfig:
    name: str = "gplus-logreg"
    citation: str = "arXiv:1610.02527 §4"
    num_clients: int = 10_000
    num_features: int = 20_002
    num_examples: int = 2_166_693
    min_client_examples: int = 75
    max_client_examples: int = 9_000
    l2_reg: str = "1/n"            # lambda = 1/n, the paper's choice
    nnz_per_example: int = 60      # bag-of-words sparsity
    scale: float = 1.0             # <1 shrinks n/K proportionally for CI runs

    def scaled(self, scale: float) -> "LogRegConfig":
        return dataclasses.replace(
            self,
            scale=scale,
            num_clients=max(8, int(self.num_clients * scale)),
            num_examples=max(64, int(self.num_examples * scale)),
            num_features=max(32, int(self.num_features * min(1.0, scale * 10))),
            min_client_examples=max(2, int(self.min_client_examples * min(1.0, scale * 10))),
            max_client_examples=max(8, int(self.max_client_examples * min(1.0, scale * 10))),
        )


CONFIG = LogRegConfig()
