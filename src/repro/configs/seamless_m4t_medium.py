"""SeamlessM4T-medium [arXiv:2308.11596] — enc-dec multimodal (audio).

Backbone only: the mel-spectrogram + conv feature extractor frontend is a
stub; ``input_specs`` supplies precomputed frame embeddings (d_model) for the
encoder. 12 encoder + 12 decoder layers, MHA (kv=16).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec_audio",
    citation="arXiv:2308.11596",
    num_layers=12,             # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    frontend_tokens=1024,      # encoder frames per utterance (stub)
)
