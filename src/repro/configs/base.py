"""Architecture configuration system.

Every assigned architecture gets one ``<id>.py`` module exporting CONFIG, an
:class:`ArchConfig` with the exact published hyper-parameters (source cited in
``citation``).  ``reduced()`` derives the CPU-smoke-test variant (2 layers,
d_model<=512, <=4 experts) of the same family.

Input shapes are global (pre-sharding); ``input_specs`` in
``repro.launch.dryrun`` turns them into ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    # DBRX-style fine-grained experts keep d_ff per expert; router is top-k.
    router_jitter: float = 0.0
    load_balance_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool.

    ``family`` in {dense, moe, ssm, hybrid, encdec_audio, vlm}.
    For encdec/vlm/audio the *frontend* is a stub: inputs arrive as
    precomputed frame/patch embeddings (see DESIGN.md carve-out).
    """

    name: str
    family: str
    citation: str

    num_layers: int
    d_model: int
    num_heads: int           # 0 for attention-free (rwkv)
    num_kv_heads: int        # GQA kv heads; == num_heads for MHA
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default d_model // num_heads
    moe: Optional[MoEConfig] = None
    sliding_window: Optional[int] = None    # SWA window (h2o-danube)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_style: str = "swiglu"   # 'swiglu' (3 mats) | 'gelu' (2 mats, GPT-style)

    # --- hybrid (jamba) ---
    attn_period: int = 0        # 1 attention layer every `attn_period` layers
    moe_period: int = 0         # MoE MLP every `moe_period` layers (else dense)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # --- rwkv6 ---
    attention_free: bool = False
    rwkv_head_dim: int = 64

    # --- encoder-decoder (seamless) ---
    encoder_layers: int = 0

    # --- modality frontend stub ---
    frontend: Optional[str] = None   # 'audio' | 'vision' | None
    frontend_tokens: int = 0         # number of embedding tokens the stub emits

    def __post_init__(self):
        if self.head_dim is None and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------ #
    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k decode (O(seq) or windowed state)."""
        return self.attention_free or self.attn_period > 0 or self.sliding_window is not None

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        for i in range(L):
            per_layer += self._layer_params(i)
        enc = 0
        if self.encoder_layers:
            for i in range(self.encoder_layers):
                enc += self._attn_params() + self._dense_mlp_params() + 2 * d
        return emb + per_layer + enc + d  # final norm

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb + d
        for i in range(L):
            total += self._layer_params(i, active_only=True)
        if self.encoder_layers:
            for i in range(self.encoder_layers):
                total += self._attn_params() + self._dense_mlp_params() + 2 * d
        return total

    # -- helpers ------------------------------------------------------- #
    def _attn_params(self) -> int:
        hd = self.head_dim or (self.d_model // max(self.num_heads, 1))
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        return q + kv + o

    def _dense_mlp_params(self) -> int:
        mats = 3 if self.mlp_style == "swiglu" else 2
        return mats * self.d_model * self.d_ff

    def _mamba_params(self) -> int:
        d_inner = self.mamba_expand * self.d_model
        return (
            2 * self.d_model * d_inner            # in_proj (x, z)
            + d_inner * self.mamba_d_conv         # conv
            + d_inner * (2 * self.mamba_d_state + 1 + self.mamba_d_state)  # x->B,C,dt + A
            + d_inner * self.d_model              # out_proj
        )

    def _rwkv_params(self) -> int:
        d = self.d_model
        return 4 * d * d + 2 * d * self.d_ff + 10 * d  # r,k,v,o + ffn + mixes/decay

    def _layer_params(self, i: int, active_only: bool = False) -> int:
        d = self.d_model
        norms = 2 * d
        if self.attention_free:
            return self._rwkv_params() + norms
        if self.attn_period > 0:  # jamba-style hybrid
            mixer = self._attn_params() if (i % self.attn_period == self.attn_period - 1) else self._mamba_params()
        else:
            mixer = self._attn_params()
        if self.moe is not None and (self.moe_period == 0 or i % self.moe_period == self.moe_period - 1):
            n_e = self.moe.experts_per_token if active_only else self.moe.num_experts
            mlp = n_e * self._dense_mlp_params() + d * self.moe.num_experts
        else:
            mlp = self._dense_mlp_params()
        return mixer + mlp + norms

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, 2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = 0 if self.attention_free else max(2, min(self.num_heads, 4))
        kv = 0 if self.attention_free else max(1, min(self.num_kv_heads, heads))
        hd = 0 if self.attention_free else d // heads
        moe = None
        if self.moe is not None:
            moe = MoEConfig(num_experts=4, experts_per_token=min(2, self.moe.experts_per_token))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2 if self.attn_period == 0 else self.attn_period,  # keep 1 hybrid block
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd if heads else None,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            moe=moe,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_tokens=min(self.frontend_tokens, 16) if self.frontend_tokens else 0,
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4096, 256, "train"),
    InputShape("prefill_32k", 32768, 32, "prefill"),
    InputShape("decode_32k", 32768, 128, "decode"),
    InputShape("long_500k", 524288, 1, "decode"),
)

SHAPES = {s.name: s for s in INPUT_SHAPES}
