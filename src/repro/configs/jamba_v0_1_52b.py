"""Jamba-v0.1-52B [arXiv:2403.19887] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32 layers in 4 blocks of 8; one attention layer per block (position 7), the
rest Mamba; MoE MLP every other layer (period 2).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    citation="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, experts_per_token=2),
    attn_period=8,
    moe_period=2,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)
