"""Granite-20B-Code [arXiv:2405.04324] — llama-arch, code; GQA with 1 KV head (MQA)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    citation="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_style="gelu",   # GPT-BigCode-style 2-matrix MLP (d_ff = 4*d_model)
)
