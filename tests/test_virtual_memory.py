"""The bounded-memory claim behind virtual data, pinned at the XLA level.

A virtual round's data memory must be O(client_chunk · m_pad · nnz) —
*independent of K*.  Rather than sampling RSS (noisy, allocator-dependent),
we ask the compiler: ``compiled.memory_analysis()`` reports the exact temp
scratch the round executable reserves, and ``jax.live_arrays()`` shows
every buffer the process retains after a real execution.  The pin is a
*slope*: growing K by 4x may not grow the round's scratch by more than a
few bytes per added client (the O(K) participation mask and weight vectors
are allowed; the O(K·m_pad·nnz) row data is not).

The K=10⁶ end-to-end round (the §1.2 "as many nodes as users" regime —
materialized rows would be ~200 MB, the virtual round holds ~2 MB of
scratch) runs under ``-m slow``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_virtual_k_config
from repro.core import build_virtual_problem
from repro.core.engine import EngineConfig, RoundEngine
from repro.data.synthetic import virtual_dataset

_K_SMALL, _K_BIG = 10_000, 40_000
_CHUNK = 1024

#: materialized row bytes per client at the virtual-K config: ~4 examples
#: of (nnz idx i32 + nnz val f32 + y f32) with nnz=6
_ROW_BYTES_PER_CLIENT = 4 * (6 * 4 + 6 * 4 + 4)


def _gd_chunk_pass(w, bi, cb, keys):
    """A data-shaped local step (one gradient step per client) — enough to
    force the round body to regenerate and consume every chunk's rows."""
    nkf = jnp.maximum(cb.n_k.astype(jnp.float32), 1.0)
    z = (cb.val * w[cb.idx]).sum(axis=2)
    g_sc = -cb.y * jax.nn.sigmoid(-cb.y * z) / nkf[:, None]
    g = jax.vmap(lambda i, s, v: jnp.zeros_like(w).at[i].add(s[:, None] * v))(
        cb.idx, g_sc, cb.val)
    return -0.1 * g


@functools.lru_cache(maxsize=3)
def _compiled_round(K, chunk):
    """(compiled round, problem) for the virtual-K config at ``K`` —
    cached so the scratch-slope and live-buffer tests share the (expensive)
    trace+compile."""
    vds = virtual_dataset(get_virtual_k_config(K), seed=0)
    pv = build_virtual_problem(vds)
    eng = RoundEngine(pv, EngineConfig(virtual_data=True, client_chunk=chunk))
    w = jnp.zeros(pv.d)
    key = jax.random.PRNGKey(0)
    compiled = jax.jit(
        lambda w_, k_: eng.round_virtual(w_, k_, _gd_chunk_pass)
    ).lower(w, key).compile()
    return compiled, pv


def test_virtual_round_scratch_does_not_scale_with_k():
    """The compiled round's temp scratch may not grow with K: 4x the
    clients, at most a few bytes of extra scratch per added client (vs
    ~200 B/client that materialized rows would cost)."""
    small, _ = _compiled_round(_K_SMALL, _CHUNK)
    big, _ = _compiled_round(_K_BIG, _CHUNK)
    ma_s, ma_b = small.memory_analysis(), big.memory_analysis()
    slope = (ma_b.temp_size_in_bytes - ma_s.temp_size_in_bytes) \
        / (_K_BIG - _K_SMALL)
    assert slope < 8.0, (
        f"round scratch grows {slope:.1f} B/client "
        f"({ma_s.temp_size_in_bytes} -> {ma_b.temp_size_in_bytes})")
    # the executable itself holds chunk-sized scratch, not K-sized data
    assert ma_b.temp_size_in_bytes < 16 * 2**20
    # w and the PRNG key in, w out — no O(K) round arguments
    assert ma_b.argument_size_in_bytes < 16 * 2**10
    assert ma_b.output_size_in_bytes < 16 * 2**10


def test_virtual_round_live_buffers_bounded():
    """After actually running a round at K=40k, nothing K·row-sized stays
    live: the biggest retained buffers are the O(K) client metadata vectors
    (sizes/weights, ≤8 B/client), never regenerated row data."""
    compiled, pv = _compiled_round(_K_BIG, _CHUNK)
    w = jnp.zeros(pv.d)
    before = {id(a) for a in jax.live_arrays()}
    out = jax.block_until_ready(compiled(w, jax.random.PRNGKey(1)))
    assert np.isfinite(np.asarray(out)).all()
    cap = 8 * _K_BIG   # int64 per-client metadata is the legal maximum
    # delta, not absolute: other tests' session fixtures (materialized
    # datasets) legitimately hold larger buffers in a full pytest run
    big = [a.nbytes for a in jax.live_arrays()
           if id(a) not in before and a.nbytes > cap]
    assert not big, f"new live buffers above {cap} B from a virtual round: " \
                    f"{big}"
    # and the bound we beat: materialized rows at this K
    assert cap < _ROW_BYTES_PER_CLIENT * _K_BIG // 4


@pytest.mark.slow
def test_virtual_round_e2e_k_one_million():
    """The headline: a full federated round over K=10⁶ clients on this CPU
    box, rows regenerated on demand — bounded scratch, finite iterate, and
    no megabyte-scale row buffer ever retained."""
    K = 1_000_000
    compiled, pv = _compiled_round(K, 2048)
    ma = compiled.memory_analysis()
    assert ma.temp_size_in_bytes < 32 * 2**20
    w = jnp.zeros(pv.d)
    before = {id(a) for a in jax.live_arrays()}
    out = jax.block_until_ready(compiled(w, jax.random.PRNGKey(2)))
    assert np.isfinite(np.asarray(out)).all()
    assert float(jnp.abs(out).max()) > 0.0
    # materialized rows would be ~200 MB here; the round retains nothing
    # beyond per-client metadata scale
    big = [a.nbytes for a in jax.live_arrays()
           if id(a) not in before and a.nbytes > 16 * K]
    assert not big, f"new live buffers above 16 B/client at K=1e6: {big}"
