"""End-to-end behaviour: the full federated pipeline (data → problem →
FSVRG → evaluation) reproduces the paper's qualitative Fig.-2 ordering at CI
scale, and the checkpointing substrate round-trips exactly.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_logreg_config
from repro.core import FSVRG, FSVRGConfig, build_problem, build_test_problem
from repro.core.baselines import one_shot_average, run_gd
from repro.data.synthetic import generate


def test_end_to_end_fig2_ordering():
    """At equal round budget: FSVRG < GD(best lr) in objective, and both
    produce a usable model (test error < predict-constant baseline)."""
    cfg = get_logreg_config().scaled(0.002)
    ds = generate(cfg, seed=0)
    prob = build_problem(ds)
    te = build_test_problem(ds)
    rounds = 10

    w_f = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(rounds, seed=0).w

    best_gd_f = np.inf
    for lr in (0.5, 2.0, 8.0):
        w_g, _ = run_gd(prob, jnp.zeros(prob.d), rounds, lr)
        best_gd_f = min(best_gd_f, float(prob.flat.loss(w_g)))

    f_fsvrg = float(prob.flat.loss(w_f))
    assert f_fsvrg < best_gd_f, (f_fsvrg, best_gd_f)

    # test error better than the majority-class constant predictor
    const_err = min(float((te.y == 1).mean()), float((te.y == -1).mean()))
    fsvrg_err = float(te.error_rate(w_f))
    assert fsvrg_err < const_err, (fsvrg_err, const_err)


def test_one_shot_averaging_is_not_enough():
    """[107]-style one-shot averaging plateaus above FSVRG's objective —
    the paper's argument for why single-round schemes fail on non-IID data."""
    cfg = get_logreg_config().scaled(0.002)
    ds = generate(cfg, seed=2)
    prob = build_problem(ds)

    w_os = one_shot_average(prob, jnp.zeros(prob.d), jax.random.PRNGKey(0),
                            stepsize=0.5, epochs=12)
    w_f = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(10, seed=0).w
    assert float(prob.flat.loss(w_f)) < float(prob.flat.loss(w_os))


def test_checkpoint_roundtrip():
    from repro.checkpoint import restore, save
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("internvl2-1b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ckpt")
        save(path, params, step=7, metadata={"arch": cfg.name})
        restored, meta = restore(path)
        assert meta["step"] == 7 and meta["metadata"]["arch"] == cfg.name
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
