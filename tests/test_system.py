"""End-to-end behaviour: the full federated pipeline (data → problem →
FSVRG → evaluation) reproduces the paper's qualitative Fig.-2 ordering at CI
scale, and the checkpointing substrate round-trips exactly.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_logreg_config
from repro.core import FSVRG, FSVRGConfig, build_problem, build_test_problem
from repro.core.baselines import one_shot_average, run_gd
from repro.data.synthetic import generate


def test_end_to_end_fig2_ordering():
    """At equal round budget: FSVRG < GD(best lr) in objective, and both
    produce a usable model (test error < predict-constant baseline)."""
    cfg = get_logreg_config().scaled(0.002)
    ds = generate(cfg, seed=0)
    prob = build_problem(ds)
    te = build_test_problem(ds)
    rounds = 10

    w_f = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(rounds, seed=0).w

    best_gd_f = np.inf
    for lr in (0.5, 2.0, 8.0):
        w_g, _ = run_gd(prob, jnp.zeros(prob.d), rounds, lr)
        best_gd_f = min(best_gd_f, float(prob.flat.loss(w_g)))

    f_fsvrg = float(prob.flat.loss(w_f))
    assert f_fsvrg < best_gd_f, (f_fsvrg, best_gd_f)

    # test error better than the majority-class constant predictor
    const_err = min(float((te.y == 1).mean()), float((te.y == -1).mean()))
    fsvrg_err = float(te.error_rate(w_f))
    assert fsvrg_err < const_err, (fsvrg_err, const_err)


def test_one_shot_averaging_is_not_enough():
    """[107]-style one-shot averaging plateaus above FSVRG's objective —
    the paper's argument for why single-round schemes fail on non-IID data."""
    cfg = get_logreg_config().scaled(0.002)
    ds = generate(cfg, seed=2)
    prob = build_problem(ds)

    w_os = one_shot_average(prob, jnp.zeros(prob.d), jax.random.PRNGKey(0),
                            stepsize=0.5, epochs=12)
    w_f = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(10, seed=0).w
    assert float(prob.flat.loss(w_f)) < float(prob.flat.loss(w_os))


def test_checkpoint_roundtrip():
    from repro.checkpoint import restore, save
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("internvl2-1b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ckpt")
        save(path, params, step=7, metadata={"arch": cfg.name})
        restored, meta = restore(path)
        assert meta["step"] == 7 and meta["metadata"]["arch"] == cfg.name
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_roundtrip_bare_array():
    """A bare-array pytree: the root IS the leaf (keystr "") — the v1
    string-path reconstruction indexed an empty key list and crashed."""
    from repro.checkpoint import restore, save

    w = jnp.arange(12.0).reshape(3, 4)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ckpt")
        save(path, w, step=3)
        restored, meta = restore(path)
        assert meta["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored), np.asarray(w))


def test_checkpoint_roundtrip_int_keyed_dict():
    """An int-keyed dict must come back as a dict, not a list — the keystr
    for dict key 0 and list index 0 are both "[0]", so only the structured
    v2 key paths can tell them apart."""
    from repro.checkpoint import restore, save

    # NB: keys must not mix types at one level (jax sorts dict keys), so
    # the int-keyed dicts live under string-keyed parents
    tree = {"ints": {0: jnp.zeros(2), 2: jnp.ones(3)},  # non-contiguous
            "nested": [jnp.full(2, 5.0), {1: jnp.full(1, 7.0)}]}
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ckpt")
        save(path, tree)
        restored, _ = restore(path)
        assert isinstance(restored["ints"], dict)
        assert set(restored["ints"]) == {0, 2}
        np.testing.assert_array_equal(np.asarray(restored["ints"][2]),
                                      np.ones(3))
        assert isinstance(restored["nested"], list)
        assert isinstance(restored["nested"][1], dict)
        np.testing.assert_array_equal(np.asarray(restored["nested"][1][1]),
                                      np.full(1, 7.0))


def test_checkpoint_v1_manifest_still_restores():
    """Legacy manifests (no key_paths) restore through the string-path
    parser, int-index dicts listified as they always were."""
    import json

    from repro.checkpoint import restore, save

    tree = {"a": [jnp.zeros(2), jnp.ones(2)], "b": jnp.full(3, 2.0)}
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ckpt")
        save(path, tree, step=1)
        mpath = os.path.join(path, "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        del manifest["key_paths"]          # downgrade to the v1 format
        manifest["format_version"] = 1
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        restored, meta = restore(path)
        assert meta["step"] == 1
        np.testing.assert_array_equal(np.asarray(restored["a"][1]), np.ones(2))
        np.testing.assert_array_equal(np.asarray(restored["b"]),
                                      np.full(3, 2.0))
