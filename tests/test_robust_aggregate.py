"""robust_aggregate (the order-statistic aggregator-guard kernel) vs
pure-numpy order statistics and the jnp oracle in kernels/ref.py.

Deterministic sweeps only — unlike test_kernels.py this module must run
without hypothesis (the aggregator guard is load-bearing for the fault-
tolerance contract, so its parity coverage can't hinge on an optional dev
dependency); the hypothesis shape/seed sweep lives in test_kernels.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def test_robust_aggregate_matches_numpy_order_stats():
    """Kernel AND ref against a plain numpy oracle: the median / trimmed
    mean are taken over exactly the valid rows (odd count, so the median
    is literally the middle element), scaled by a_diag."""
    rng = np.random.default_rng(0)
    K, d = 9, 300
    deltas = rng.normal(size=(K, d)).astype(np.float32)
    valid = np.array([1, 1, 1, 0, 1, 1, 0, 1, 1], np.int32)   # m = 7
    w = rng.normal(size=d).astype(np.float32)
    a = (np.abs(rng.normal(size=d)) + 0.5).astype(np.float32)
    rows = deltas[valid > 0]
    expect_med = w + a * np.median(rows, axis=0)
    # trim=0.2, m=7: lo = floor(0.2*7) = 1, hi = 7-1 = 6 -> mean of ranks 1..5
    expect_tm = w + a * np.sort(rows, axis=0)[1:6].mean(axis=0)
    for mode, expect in (("median", expect_med), ("trimmed_mean", expect_tm)):
        for fn in (ops.robust_aggregate, ref.robust_aggregate_ref):
            out = fn(jnp.asarray(w), jnp.asarray(deltas), jnp.asarray(valid),
                     jnp.asarray(a), 0.2, mode)
            np.testing.assert_allclose(np.asarray(out), expect,
                                       rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("K,d,trim,rate", [
    (1, 1, 0.0, 1.0), (2, 127, 0.1, 0.5), (16, 128, 0.25, 0.7),
    (24, 1000, 0.49, 0.3), (7, 4097, 0.1, 1.0),
])
def test_robust_aggregate_matches_ref_across_shapes(K, d, trim, rate):
    """Padding/grid edges (d below, at, and past d_block multiples) and
    degenerate valid counts all agree with the jnp oracle."""
    ks = jax.random.split(jax.random.PRNGKey(K * 7919 + d), 4)
    wt = jax.random.normal(ks[0], (d,))
    deltas = jax.random.normal(ks[1], (K, d))
    valid = jax.random.bernoulli(ks[2], rate, (K,))
    a = jnp.abs(jax.random.normal(ks[3], (d,))) + 0.5
    for mode in ("trimmed_mean", "median"):
        out_k = ops.robust_aggregate(wt, deltas, valid, a, trim, mode)
        out_r = ref.robust_aggregate_ref(wt, deltas, valid, a, trim, mode)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-5, atol=1e-5)


def test_robust_aggregate_all_invalid_is_identity():
    """No surviving client -> zero update (not NaN from an empty mean)."""
    d = 257
    w = jax.random.normal(jax.random.PRNGKey(3), (d,))
    deltas = jnp.full((4, d), jnp.nan)
    valid = jnp.zeros((4,), jnp.int32)
    for mode in ("trimmed_mean", "median"):
        out = ops.robust_aggregate(w, deltas, valid, jnp.ones((d,)),
                                   0.1, mode)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


def test_robust_aggregate_bounds_poisoned_update():
    """The point of the guard: with a minority of rows driven to huge
    (but finite, so no engine pre-exclusion) values and marked valid, the
    trimmed mean stays within the honest rows' coordinate-wise range —
    the sort itself must bury the outliers outside the rank window."""
    rng = np.random.default_rng(1)
    K, d = 11, 200
    deltas = rng.normal(size=(K, d)).astype(np.float32)
    deltas[0] = 1e30
    deltas[1] = -1e30
    w = np.zeros(d, np.float32)
    a = np.ones(d, np.float32)
    out = np.asarray(ops.robust_aggregate(
        jnp.asarray(w), jnp.asarray(deltas), jnp.ones((K,), jnp.int32),
        jnp.asarray(a), 0.2, "trimmed_mean"))
    honest = deltas[2:]
    assert (out >= honest.min(axis=0) - 1e-5).all()
    assert (out <= honest.max(axis=0) + 1e-5).all()


def test_robust_aggregate_validation():
    w = jnp.zeros(8)
    deltas = jnp.zeros((2, 8))
    valid = jnp.ones((2,), jnp.int32)
    with pytest.raises(ValueError, match="mode"):
        ops.robust_aggregate(w, deltas, valid, jnp.ones(8), 0.1, "mean")
    with pytest.raises(ValueError, match="trim"):
        ops.robust_aggregate(w, deltas, valid, jnp.ones(8), 0.5,
                             "trimmed_mean")
