"""The campaign runner's crash-survival contract (repro.fleet.campaign)
and the atomic checkpoint writes it stands on.

Contracts:

1. Atomic saves — a crash *during* ``checkpoint.save`` (payload write or
   manifest write) leaves the previous checkpoint fully restorable; the
   manifest is the commit point and is written last.
2. EventLog — resume truncation drops exactly the re-running rounds of
   one cell; a torn trailing line (mid-write kill) is discarded on load.
3. Campaign resume — an interrupted + resumed campaign produces
   bit-identical final iterates and deterministic event views vs an
   uninterrupted run, including across a drift-epoch boundary.
4. Payload checksums — a flipped byte in the arrays file raises
   ChecksumError instead of restoring garbage; pre-v3 manifests without
   the crc still restore.
5. Guard-rails — a NaN-poisoning burst diverges an unguarded campaign;
   the rollback rail quarantines exactly the poisoned round and still
   converges; resume bit-identity holds *across* a rollback; persistent
   faults abort with CampaignDiverged instead of looping forever.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro import checkpoint
from repro.checkpoint import checkpoint as ckpt_mod
from repro.core import NonFiniteIterateError
from repro.fleet import (CampaignDiverged, CampaignSpec, DeltaFaults,
                         EventLog, FleetTrace, RoundEvent,
                         deterministic_view, run_campaign, summarize_events)


# --------------------------------------------------------------------- #
# 1. atomic checkpoint saves
# --------------------------------------------------------------------- #


def _tree(v):
    return {"w": np.arange(4, dtype=np.float32) * v,
            "round": np.int32(v)}


def test_checkpoint_interrupted_payload_write_keeps_previous(tmp_path,
                                                             monkeypatch):
    d = str(tmp_path / "ck")
    checkpoint.save(d, _tree(1), step=1)

    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_mod.np, "savez", boom)
    with pytest.raises(OSError):
        checkpoint.save(d, _tree(2), step=2)
    monkeypatch.undo()
    tree, info = checkpoint.restore(d)
    assert info["step"] == 1
    np.testing.assert_array_equal(tree["w"], _tree(1)["w"])


def test_checkpoint_interrupted_before_manifest_keeps_previous(tmp_path,
                                                               monkeypatch):
    """Kill between the payload write and the manifest replace: the new
    arrays file exists on disk but the manifest — the commit point —
    still names the old one, and restore returns step 1."""
    d = str(tmp_path / "ck")
    checkpoint.save(d, _tree(1), step=1)
    real_replace = os.replace

    def replace_except_manifest(src, dst):
        if os.path.basename(dst) == "manifest.json":
            raise OSError("killed before commit")
        return real_replace(src, dst)

    monkeypatch.setattr(ckpt_mod.os, "replace", replace_except_manifest)
    with pytest.raises(OSError):
        checkpoint.save(d, _tree(2), step=2)
    monkeypatch.undo()
    tree, info = checkpoint.restore(d)
    assert info["step"] == 1
    np.testing.assert_array_equal(tree["w"], _tree(1)["w"])


def test_checkpoint_completed_save_cleans_stale_payloads(tmp_path):
    d = str(tmp_path / "ck")
    checkpoint.save(d, _tree(1), step=1)
    checkpoint.save(d, _tree(2), step=2)
    payloads = [f for f in os.listdir(d) if f.endswith(".npz")]
    assert payloads == ["arrays-000000002.npz"]
    tree, info = checkpoint.restore(d)
    assert info["step"] == 2
    np.testing.assert_array_equal(tree["w"], _tree(2)["w"])


def test_checkpoint_restores_legacy_arrays_npz(tmp_path):
    """Pre-atomic checkpoints (plain arrays.npz, no arrays_file key in the
    manifest) must still restore."""
    d = str(tmp_path / "ck")
    checkpoint.save(d, _tree(3), step=3)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    os.rename(os.path.join(d, manifest.pop("arrays_file")),
              os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    tree, info = checkpoint.restore(d)
    assert info["step"] == 3
    np.testing.assert_array_equal(tree["w"], _tree(3)["w"])


# --------------------------------------------------------------------- #
# 2. the event log
# --------------------------------------------------------------------- #


def _ev(cell, r, f=None):
    return RoundEvent(cell=cell, round=r, drawn=10, realized=9,
                      stragglers=1, f=f, wall_s=0.5)


def test_eventlog_truncate_drops_only_rerun_rounds(tmp_path):
    log = EventLog(str(tmp_path / "ev.jsonl"))
    for r in range(4):
        log.append(_ev("a", r))
    log.append(_ev("b", 0))
    log.truncate("a", 2)
    events = log.load()
    assert [(e["cell"], e["round"]) for e in events] == [
        ("a", 0), ("a", 1), ("b", 0)]


def test_eventlog_drops_torn_tail(tmp_path):
    log = EventLog(str(tmp_path / "ev.jsonl"))
    log.append(_ev("a", 0))
    log.append(_ev("a", 1))
    with open(log.path, "a") as f:
        f.write('{"cell": "a", "round": 2, "drawn"')   # killed mid-write
    assert [e["round"] for e in log.load()] == [0, 1]
    log.truncate("a", 1)   # the rewrite also discards the torn tail
    assert [e["round"] for e in log.load()] == [0]


def test_deterministic_view_strips_timing_only():
    e = json.loads(_ev("a", 1, f=0.5).to_json())
    v = deterministic_view(e)
    assert "wall_s" not in v and "peak_rss_mb" not in v
    assert v["f"] == 0.5 and v["round"] == 1


def test_summarize_events_rollup():
    events = [json.loads(_ev("a", r, f=(1.0 - 0.1 * r) if r % 2 else None)
                         .to_json()) for r in range(4)]
    s = summarize_events(events)["a"]
    assert s["rounds"] == 4 and s["straggler_total"] == 4
    assert [p["round"] for p in s["convergence"]] == [1, 3]
    assert s["final_f"] == pytest.approx(0.7)


# --------------------------------------------------------------------- #
# 3. campaign resume bit-identity
# --------------------------------------------------------------------- #

SPEC = CampaignSpec(
    algos=("gd", "fedavg"), rounds=3, seed=0, scale=0.002, model="trace",
    trace=FleetTrace(seed=5, base=0.5, amplitude=0.3, period=7.0,
                     burst_prob=0.3, burst_frac=0.5, straggler_rate=0.25),
    eval_every=2, checkpoint_every=1)


def _run_pair(spec, tmp_path, stop_after):
    d_ref = str(tmp_path / "ref")
    d_run = str(tmp_path / "run")
    s_ref = run_campaign(spec, d_ref, verbose=False)
    r = run_campaign(spec, d_run, stop_after=stop_after, verbose=False)
    assert r.get("interrupted")
    s_run = run_campaign(spec, d_run, verbose=False)
    ev_ref = [deterministic_view(e)
              for e in EventLog(os.path.join(d_ref, "events.jsonl")).load()]
    ev_run = [deterministic_view(e)
              for e in EventLog(os.path.join(d_run, "events.jsonl")).load()]
    return s_ref, s_run, ev_ref, ev_run


@pytest.mark.slow
def test_campaign_interrupt_resume_bit_identical(tmp_path):
    """Crash after the first cell plus one round of the second: the resume
    must skip the completed cell, land mid-cell on the other, and the
    final iterates and event stream must match the uninterrupted run."""
    s_ref, s_run, ev_ref, ev_run = _run_pair(SPEC, tmp_path,
                                             stop_after=SPEC.rounds + 1)
    assert ev_ref == ev_run
    assert len(ev_ref) == len(SPEC.algos) * SPEC.rounds
    for a in SPEC.algos:
        np.testing.assert_array_equal(
            np.asarray(s_ref["finals"][a]["w"]),
            np.asarray(s_run["finals"][a]["w"]))


@pytest.mark.slow
def test_campaign_resume_across_drift_epoch(tmp_path):
    """The interruption lands exactly on a drift-epoch boundary; resume
    must rebuild the correct epoch's dataset from the absolute round."""
    spec = CampaignSpec(
        algos=("gd",), rounds=4, seed=0, scale=0.002, model="trace",
        trace=SPEC.trace, drift_every=2, drift_w_scale=0.8,
        drift_resample=True, eval_every=4, checkpoint_every=1)
    s_ref, s_run, ev_ref, ev_run = _run_pair(spec, tmp_path, stop_after=2)
    assert ev_ref == ev_run
    np.testing.assert_array_equal(np.asarray(s_ref["finals"]["gd"]["w"]),
                                  np.asarray(s_run["finals"]["gd"]["w"]))


@pytest.mark.slow
def test_campaign_summary_written_and_events_counted(tmp_path):
    d = str(tmp_path / "c")
    spec = CampaignSpec(algos=("gd",), rounds=2, seed=0, scale=0.002,
                        model="bernoulli", participation=0.5,
                        eval_every=1, checkpoint_every=1)
    run_campaign(spec, d, verbose=False)
    with open(os.path.join(d, "summary.json")) as f:
        summary = json.load(f)
    cell = summary["cells"]["gd"]
    assert cell["rounds"] == 2
    assert cell["straggler_total"] == 0          # bernoulli: no stragglers
    assert len(cell["convergence"]) == 2
    assert summary["spec"]["model"] == "bernoulli"


# --------------------------------------------------------------------- #
# 4. payload checksums
# --------------------------------------------------------------------- #


def test_checkpoint_checksum_detects_corruption(tmp_path):
    d = str(tmp_path / "ck")
    checkpoint.save(d, _tree(1), step=1)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == 3 and "payload_crc32" in manifest
    path = os.path.join(d, manifest["arrays_file"])
    with open(path, "rb") as f:
        blob = bytearray(f.read())
    blob[len(blob) // 2] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(checkpoint.ChecksumError, match="crc32"):
        checkpoint.restore(d)


def test_checkpoint_pre_v3_manifest_without_crc_restores(tmp_path):
    """A v2 manifest (no payload_crc32) must restore unverified — old
    checkpoints on disk stay readable after the upgrade."""
    d = str(tmp_path / "ck")
    checkpoint.save(d, _tree(4), step=4)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    del manifest["payload_crc32"]
    manifest["format_version"] = 2
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    tree, info = checkpoint.restore(d)
    assert info["step"] == 4
    np.testing.assert_array_equal(tree["w"], _tree(4)["w"])


# --------------------------------------------------------------------- #
# 5. fault telemetry fields + guard-rails
# --------------------------------------------------------------------- #


def test_round_event_fault_fields_roundtrip_and_rollup():
    e = RoundEvent(cell="a", round=0, drawn=5, realized=5, stragglers=0,
                   faults_injected=3, clients_rejected=2, rollbacks=1,
                   f=1.0, wall_s=0.1)
    d = json.loads(e.to_json())
    assert (d["faults_injected"], d["clients_rejected"],
            d["rollbacks"]) == (3, 2, 1)
    events = [d, json.loads(_ev("a", 1).to_json())]
    s = summarize_events(events)["a"]
    assert s["faults_injected_total"] == 3
    assert s["clients_rejected_total"] == 2
    assert s["rollbacks"] == 1


def test_summarize_handles_pre_fault_schema():
    """Event logs written before the fault fields existed still roll up."""
    events = [json.loads(_ev("a", r).to_json()) for r in range(2)]
    for e in events:
        for k in ("faults_injected", "clients_rejected", "rollbacks"):
            e.pop(k)
    s = summarize_events(events)["a"]
    assert s["faults_injected_total"] == 0 and s["rollbacks"] == 0


# one cell, full participation, a NaN-poisoning burst at round 4 under the
# rollback rail: deterministic, so every test below sees the same story
FAULTY = CampaignSpec(
    algos=("gd",), rounds=14, seed=0, scale=0.002, model="full",
    eval_every=1, checkpoint_every=2,
    faults=DeltaFaults(seed=1, nan_rate=0.35, start_round=4, stop_round=5),
    guard="rollback")


@pytest.mark.slow
def test_campaign_unguarded_nan_faults_diverge(tmp_path):
    spec = dataclasses.replace(FAULTY, guard="none")
    with pytest.raises(NonFiniteIterateError):
        run_campaign(spec, str(tmp_path / "c"), verbose=False)


@pytest.mark.slow
def test_campaign_rollback_rail_quarantines_and_converges(tmp_path):
    """The rail quarantines exactly the poisoned round and the cell still
    lands near the fault-free objective (it legitimately runs one fewer
    effective round, hence the loose tolerance)."""
    clean = dataclasses.replace(FAULTY, faults=None, guard="none")
    s_ref = run_campaign(clean, str(tmp_path / "ref"), verbose=False)
    s = run_campaign(FAULTY, str(tmp_path / "run"), verbose=False)
    cell = s["cells"]["gd"]
    assert cell["rollbacks"] >= 1
    assert cell["faults_injected_total"] >= 1
    ref_f = s_ref["cells"]["gd"]["final_f"]
    assert np.isfinite(cell["final_f"])
    assert abs(cell["final_f"] - ref_f) <= 0.1 * ref_f
    with open(os.path.join(str(tmp_path / "run"), "cells", "gd",
                           "guard.json")) as f:
        guard = json.load(f)
    assert guard["quarantined"] == [4] and guard["total"] >= 1


@pytest.mark.slow
def test_campaign_clip_guard_prevents_rollbacks(tmp_path):
    """The engine-level clip guard rejects the poisoned deltas outright:
    no divergence, no rollback, and the rejected clients are counted."""
    spec = dataclasses.replace(FAULTY, guard="clip")
    s = run_campaign(spec, str(tmp_path / "c"), verbose=False)
    cell = s["cells"]["gd"]
    assert cell["rollbacks"] == 0
    assert cell["clients_rejected_total"] >= 1
    assert np.isfinite(cell["final_f"])


@pytest.mark.slow
def test_campaign_resume_across_rollback_bit_identical(tmp_path):
    """Kill the campaign after the rollback has fired; the resumed run
    must replay the quarantine decision from guard.json and match the
    uninterrupted run bit-for-bit."""
    s_ref, s_run, ev_ref, ev_run = _run_pair(FAULTY, tmp_path, stop_after=7)
    assert ev_ref == ev_run
    np.testing.assert_array_equal(np.asarray(s_ref["finals"]["gd"]["w"]),
                                  np.asarray(s_run["finals"]["gd"]["w"]))


@pytest.mark.slow
def test_campaign_persistent_faults_abort(tmp_path):
    """Faults that never stop: quarantining cannot restore progress, so
    the rail gives up with CampaignDiverged instead of looping forever."""
    spec = dataclasses.replace(
        FAULTY, rounds=8, max_rollbacks=1,
        faults=DeltaFaults(seed=1, nan_rate=0.5, start_round=2))
    with pytest.raises(CampaignDiverged) as ei:
        run_campaign(spec, str(tmp_path / "c"), verbose=False)
    assert ei.value.cell == "gd" and ei.value.rollbacks >= 2
