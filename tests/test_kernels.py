"""Per-kernel validation: hypothesis sweeps over shapes/dtypes, allclose
against the pure-jnp oracle in kernels/ref.py (interpret=True on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.slow
@settings(deadline=None, max_examples=30)
@given(
    d=st.integers(1, 5000),
    h=st.floats(1e-4, 10.0),
    seed=st.integers(0, 2**30),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_fsvrg_update_matches_ref(d, h, seed, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    w = jax.random.normal(ks[0], (d,), dtype)
    s = jnp.abs(jax.random.normal(ks[1], (d,), dtype)) + 0.1
    gn = jax.random.normal(ks[2], (d,), dtype)
    go = jax.random.normal(ks[3], (d,), dtype)
    gb = jax.random.normal(ks[4], (d,), dtype)
    out_k = ops.fsvrg_update(w, s, gn, go, gb, h)
    out_r = ref.fsvrg_update_ref(w, s, gn, go, gb, h)
    assert out_k.dtype == w.dtype
    tol = 1e-6 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol * (1.0 + 10 * h))


@pytest.mark.slow
@settings(deadline=None, max_examples=20)
@given(
    K=st.integers(1, 24),
    d=st.integers(1, 3000),
    seed=st.integers(0, 2**30),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_scaled_aggregate_matches_ref(K, d, seed, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    wt = jax.random.normal(ks[0], (d,), dtype)
    wks = jax.random.normal(ks[1], (K, d), dtype)
    wts = jax.nn.softmax(jax.random.normal(ks[2], (K,)))
    a = jnp.abs(jax.random.normal(ks[3], (d,))) + 0.5
    out_k = ops.scaled_aggregate(wt, wks, wts, a)
    out_r = ref.scaled_aggregate_ref(wt, wks, wts, a)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("block_rows", [8, 64, 256])
def test_fsvrg_update_block_shapes(block_rows):
    d = 1000
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    args = [jax.random.normal(k, (d,)) for k in ks]
    out = ops.fsvrg_update(*args, 0.3, block_rows=block_rows)
    expect = ref.fsvrg_update_ref(*args, 0.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-5, atol=1e-6)


@pytest.mark.parametrize("k_block,d_block", [(2, 128), (8, 512), (16, 1024)])
def test_scaled_aggregate_block_shapes(k_block, d_block):
    K, d = 10, 999
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    wt = jax.random.normal(ks[0], (d,))
    wks = jax.random.normal(ks[1], (K, d))
    wts = jnp.full((K,), 1.0 / K)
    a = jnp.ones((d,))
    out = ops.scaled_aggregate(wt, wks, wts, a, k_block=k_block, d_block=d_block)
    expect = ref.scaled_aggregate_ref(wt, wks, wts, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
@settings(deadline=None, max_examples=20)
@given(
    K=st.integers(1, 24),
    d=st.integers(1, 3000),
    seed=st.integers(0, 2**30),
    mode=st.sampled_from(["trimmed_mean", "median"]),
    trim=st.floats(0.0, 0.49),
)
def test_robust_aggregate_matches_ref(K, d, seed, mode, trim):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    wt = jax.random.normal(ks[0], (d,))
    deltas = jax.random.normal(ks[1], (K, d))
    valid = jax.random.bernoulli(ks[2], 0.7, (K,))
    a = jnp.abs(jax.random.normal(ks[3], (d,))) + 0.5
    out_k = ops.robust_aggregate(wt, deltas, valid, a, trim, mode)
    out_r = ref.robust_aggregate_ref(wt, deltas, valid, a, trim, mode)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_kernel_equals_fsvrg_inner_loop_semantics():
    """The fused kernel is exactly Alg. 4 line 8 for one step."""
    d = 257
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    w, s, gn, go, gb = [jax.random.normal(k, (d,)) for k in ks]
    h = 0.7
    manual = w - h * (s * (gn - go) + gb)
    out = ops.fsvrg_update(w, s, gn, go, gb, h)
    np.testing.assert_allclose(np.asarray(out), np.asarray(manual), rtol=1e-5)
