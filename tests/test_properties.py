"""The paper's §3.1 desirable algorithmic properties (A)–(D), plus
hypothesis property tests of the system's invariants (scaling statistics,
data pipeline, aggregation algebra).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import FSVRG, FSVRGConfig, build_problem
from repro.core import scaling
from repro.core.problem import LogRegProblem


def _dense_problem_from_clients(client_rows, d, lam=0.01, seed=0):
    """Build a FederatedLogReg from explicit per-client (idx,val,y) rows."""
    from repro.data.synthetic import FederatedDataset

    idx = np.concatenate([c[0] for c in client_rows])
    val = np.concatenate([c[1] for c in client_rows])
    y = np.concatenate([c[2] for c in client_rows])
    sizes = np.array([len(c[2]) for c in client_rows], np.int32)
    client_of = np.repeat(np.arange(len(client_rows)), sizes)
    ds = FederatedDataset(
        idx=idx.astype(np.int32), val=val.astype(np.float32),
        y=y.astype(np.float32), client_of=client_of.astype(np.int32),
        client_sizes=sizes, num_features=d,
        test_idx=idx[:1], test_val=val[:1], test_y=y[:1],
        test_client_of=client_of[:1])
    return build_problem(ds, lam=lam)


def _random_clients(rng, K, nk, d, nnz, feature_pool=None):
    out = []
    for _ in range(K):
        pool = feature_pool if feature_pool is not None else np.arange(d)
        idx = rng.choice(pool, size=(nk, nnz))
        val = np.ones((nk, nnz), np.float32)
        w = rng.standard_normal(d)
        marg = val * w[idx]
        y = np.where(rng.random(nk) < 1 / (1 + np.exp(-marg.sum(1))), 1.0, -1.0)
        out.append((idx, val, y))
    return out


# ------------------------------------------------------------------ #
# Property (A): initialized at the optimum, the algorithm stays there.
# ------------------------------------------------------------------ #


def test_property_A_fixed_point_at_optimum(small_problem):
    prob = small_problem
    # find near-optimum by many GD steps
    w = jnp.zeros(prob.d)
    g = jax.jit(prob.flat.grad)
    for _ in range(4000):
        w = w - 2.0 * g(w)
    gn = float(jnp.linalg.norm(g(w)))
    assert gn < 1e-4, gn

    solver = FSVRG(prob, FSVRGConfig(stepsize=1.0))
    w2 = solver.round(solver.init(w), jax.random.PRNGKey(0)).w
    # movement is bounded by the residual gradient scale: each local step
    # moves ~h_k*|∇f|, amplified at most K/omega by the A-scaling
    drift = float(jnp.linalg.norm(w2 - w))
    K = prob.num_clients
    assert drift < 5 * K * gn + 1e-6, (drift, gn)


# ------------------------------------------------------------------ #
# Property (B): all data on one node -> O(1) rounds (one SVRG pass).
# ------------------------------------------------------------------ #


def test_property_B_single_node_converges_fast():
    rng = np.random.default_rng(0)
    clients = _random_clients(rng, K=1, nk=256, d=16, nnz=8)
    prob = _dense_problem_from_clients(clients, d=16, lam=0.05)
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))
    # optimum
    w_star = jnp.zeros(prob.d)
    for _ in range(2000):
        w_star = w_star - 0.5 * prob.flat.grad(w_star)
    f_star = float(prob.flat.loss(w_star))

    # best stepsize retrospectively (the paper's protocol)
    def one_round_f(h):
        solver = FSVRG(prob, FSVRGConfig(stepsize=h))
        return float(prob.flat.loss(
            solver.round(solver.init(), jax.random.PRNGKey(1)).w))

    f1 = min(one_round_f(h) for h in (1.0, 3.0, 10.0))
    # one round closes most of the gap to optimal
    assert (f0 - f1) > 0.8 * (f0 - f_star), (f0, f1, f_star)


# ------------------------------------------------------------------ #
# Property (C): feature-disjoint clients -> ~1 round (A-scaling at work).
# ------------------------------------------------------------------ #


def test_property_C_decomposable_problem():
    rng = np.random.default_rng(2)
    K, d_each, nnz = 4, 8, 4
    d = K * d_each
    clients = []
    for k in range(K):
        pool = np.arange(k * d_each, (k + 1) * d_each)
        clients += _random_clients(rng, 1, 128, d, nnz, feature_pool=pool)
    prob = _dense_problem_from_clients(clients, d=d, lam=0.05)

    w_star = jnp.zeros(prob.d)
    for _ in range(2000):
        w_star = w_star - 0.5 * prob.flat.grad(w_star)
    f_star = float(prob.flat.loss(w_star))
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))

    def gap(h, **kw):
        solver = FSVRG(prob, FSVRGConfig(stepsize=h, **kw))
        return float(prob.flat.loss(
            solver.round(solver.init(), jax.random.PRNGKey(0)).w)) - f_star

    # A = K/omega recovers most of the gap in one round...
    gap_scaled = min(gap(h) for h in (1.0, 3.0))
    assert gap_scaled < 0.35 * (f0 - f_star), (gap_scaled, f0 - f_star)
    # ...and beats plain averaging at the SAME stepsize.  (With fully
    # disjoint features A = K·I, so an unconstrained stepsize sweep could
    # absorb A into h — the per-h comparison is the meaningful one.)
    for h in (1.0, 3.0):
        assert gap(h) < gap(h, use_A=False) + 1e-9, h


# ------------------------------------------------------------------ #
# Property (D): identical client datasets -> one round ~ one SVRG pass.
# ------------------------------------------------------------------ #


def test_property_D_identical_clients():
    rng = np.random.default_rng(3)
    base = _random_clients(rng, 1, 128, 16, 8)[0]
    clients = [base] * 4
    prob = _dense_problem_from_clients(clients, d=16, lam=0.05)

    w_star = jnp.zeros(prob.d)
    for _ in range(2000):
        w_star = w_star - 0.5 * prob.flat.grad(w_star)
    f_star = float(prob.flat.loss(w_star))
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))

    def one_round_f(h):
        solver = FSVRG(prob, FSVRGConfig(stepsize=h))
        return float(prob.flat.loss(
            solver.round(solver.init(), jax.random.PRNGKey(0)).w))

    f1 = min(one_round_f(h) for h in (1.0, 3.0, 10.0))
    assert (f0 - f1) > 0.8 * (f0 - f_star), (f0, f1, f_star)


# ------------------------------------------------------------------ #
# hypothesis: invariants of the scaling statistics (§3.6.1)
# ------------------------------------------------------------------ #


@pytest.mark.slow
@settings(deadline=None, max_examples=25)
@given(st.integers(2, 6), st.integers(4, 32), st.integers(8, 24), st.integers(2, 6),
       st.integers(0, 10_000))
def test_scaling_stats_invariants(K, nk, d, nnz, seed):
    rng = np.random.default_rng(seed)
    clients = _random_clients(rng, K, nk, d, min(nnz, d))
    prob = _dense_problem_from_clients(clients, d=d)

    om = np.asarray(scaling.omega(prob))
    assert om.shape == (d,)
    assert (om >= 0).all() and (om <= K).all()

    a = np.asarray(scaling.aggregation_diag(prob))
    # a^j = K/omega^j in [1, K] on covered coords, exactly 1 elsewhere
    covered = om > 0
    assert np.allclose(a[covered], K / om[covered])
    assert (a[covered] >= 1.0 - 1e-6).all() and (a[covered] <= K + 1e-6).all()
    assert np.allclose(a[~covered], 1.0)

    phi = np.asarray(scaling.global_feature_counts(prob.flat)) / prob.flat.n
    assert phi.min() >= 0 and phi.max() <= 1.0 + 1e-6

    b = prob.buckets[0]
    s0 = np.asarray(scaling.s_k_diag(jnp.asarray(phi), b.idx[0], b.val[0], b.n_k[0]))
    assert (s0 > 0).all()
    # features the client never sees scale by exactly 1
    seen = np.zeros(d, bool)
    seen[np.asarray(b.idx[0]).reshape(-1)[np.asarray(b.val[0]).reshape(-1) != 0]] = True
    assert np.allclose(s0[~seen], 1.0)


@pytest.mark.slow
@settings(deadline=None, max_examples=20)
@given(st.integers(2, 5), st.integers(0, 10_000))
def test_client_weights_sum_to_one(K, seed):
    rng = np.random.default_rng(seed)
    clients = _random_clients(rng, K, int(rng.integers(4, 40)), 16, 4)
    prob = _dense_problem_from_clients(clients, d=16)
    assert abs(float(prob.client_weights.sum()) - 1.0) < 1e-5


# ------------------------------------------------------------------ #
# hypothesis: flat loss/grad consistency (autodiff oracle)
# ------------------------------------------------------------------ #


@settings(deadline=None, max_examples=15)
@given(st.integers(4, 64), st.integers(4, 24), st.integers(1, 6), st.integers(0, 9999))
def test_grad_matches_autodiff(n, d, nnz, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, d, size=(n, nnz)), jnp.int32)
    val = jnp.asarray(rng.standard_normal((n, nnz)), jnp.float32)
    y = jnp.asarray(np.where(rng.random(n) < 0.5, 1.0, -1.0), jnp.float32)
    prob = LogRegProblem(idx=idx, val=val, y=y, lam=0.1, num_features=d)
    w = jnp.asarray(rng.standard_normal(d), jnp.float32)
    g1 = prob.grad(w)
    g2 = jax.grad(prob.loss)(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4, atol=2e-5)
