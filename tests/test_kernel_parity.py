"""Interpret-mode Pallas parity at fixed non-multiple-of-block sizes.

Unlike test_kernels.py (hypothesis sweeps, skipped where hypothesis is not
installed), this module has no optional dependencies — CPU-only CI always
exercises every Pallas kernel path against the kernels/ref.py oracles, at
sizes that force ragged padding of the (rows, 128) / (K_BLOCK, D_BLOCK)
grids (d=1000 and 999, K=5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DTYPES = (jnp.float32, jnp.bfloat16)


def _tol(dtype):
    return 1e-6 if dtype == jnp.float32 else 0.05


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("d", [1, 127, 1000])
def test_fsvrg_update_parity(d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    w, s, gn, go, gb = [jax.random.normal(k, (d,), dtype) for k in ks]
    h = 0.7
    out = ops.fsvrg_update(w, s, gn, go, gb, h)
    expect = ref.fsvrg_update_ref(w, s, gn, go, gb, h)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 10)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("d", [1, 127, 1000])
def test_fedavg_update_parity(d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    w = jax.random.normal(ks[0], (d,), dtype)
    g = jax.random.normal(ks[1], (d,), dtype)
    h, lam = 0.3, 0.05
    out = ops.fedavg_update(w, g, h, lam)
    expect = ref.fedavg_update_ref(w, g, h, lam)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 10)


def test_fedavg_update_zero_stepsize_is_noop():
    """h=0 must be an exact no-op — the padded-slot masking contract."""
    w = jax.random.normal(jax.random.PRNGKey(2), (1000,))
    g = jax.random.normal(jax.random.PRNGKey(3), (1000,))
    out = ops.fedavg_update(w, g, 0.0, 0.05)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


def test_fedavg_update_semantics():
    """The fused kernel is exactly one regularized SGD step."""
    d = 257
    w = jax.random.normal(jax.random.PRNGKey(4), (d,))
    g = jax.random.normal(jax.random.PRNGKey(5), (d,))
    h, lam = 0.2, 0.1
    manual = w - h * (g + lam * w)
    out = ops.fedavg_update(w, g, h, lam)
    np.testing.assert_allclose(np.asarray(out), np.asarray(manual),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("d", [1, 127, 1000])
def test_dane_update_parity(d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    w, g, a, wt = [jax.random.normal(k, (d,), dtype) for k in ks]
    lr, lam, mu = 0.4, 0.03, 0.2
    out = ops.dane_update(w, g, a, wt, lr, lam, mu)
    expect = ref.dane_update_ref(w, g, a, wt, lr, lam, mu)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 10)


def test_dane_update_zero_stepsize_is_noop():
    """lr=0 must be an exact no-op (the masking contract shared with
    fedavg_update)."""
    ks = jax.random.split(jax.random.PRNGKey(8), 4)
    w, g, a, wt = [jax.random.normal(k, (1000,)) for k in ks]
    out = ops.dane_update(w, g, a, wt, 0.0, 0.05, 0.3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(w))


def test_dane_update_semantics():
    """The fused kernel is exactly one GD step on DANE's local subproblem:
    w − lr(∇F_k(w) − a_k + µ(w − w^t)) with ∇F_k split as g + λw."""
    d = 257
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    w, g, a, wt = [jax.random.normal(k, (d,)) for k in ks]
    lr, lam, mu = 0.2, 0.1, 0.4
    manual = w - lr * ((g + lam * w) - a + mu * (w - wt))
    out = ops.dane_update(w, g, a, wt, lr, lam, mu)
    np.testing.assert_allclose(np.asarray(out), np.asarray(manual),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("d", [1, 127, 1000])
def test_cocoa_sdca_parity(d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    beta0 = jax.random.uniform(ks[0], (d,), minval=0.05, maxval=0.95).astype(dtype)
    m = jax.random.normal(ks[1], (d,), dtype)
    c = (jnp.abs(jax.random.normal(ks[2], (d,))) * 0.5).astype(dtype)
    out = ops.cocoa_sdca_update(beta0, m, c)
    expect = ref.cocoa_sdca_update_ref(beta0, m, c)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=_tol(dtype), atol=_tol(dtype) * 10)
    # solutions live strictly inside the (0,1) dual box
    outf = np.asarray(out, np.float32)
    assert outf.min() > 0.0 and outf.max() < 1.0


def test_cocoa_sdca_solves_scalar_subproblem():
    """The Newton solve really minimizes m(β−β₀)+c(β−β₀)²+H(β): the
    stationarity residual at the returned β is ~0 for interior solutions."""
    d = 321
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    beta0 = jax.random.uniform(ks[0], (d,), minval=0.2, maxval=0.8)
    m = jax.random.normal(ks[1], (d,)) * 0.5
    c = jnp.abs(jax.random.normal(ks[2], (d,))) * 0.5
    b = ops.cocoa_sdca_update(beta0, m, c)
    resid = m + 2.0 * c * (b - beta0) + jnp.log(b / (1.0 - b))
    interior = (np.asarray(b) > 1e-4) & (np.asarray(b) < 1.0 - 1e-4)
    assert interior.mean() > 0.9
    np.testing.assert_allclose(np.asarray(resid)[interior], 0.0, atol=1e-4)


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("K,d", [(5, 1000), (1, 999), (5, 1)])
def test_scaled_aggregate_parity(K, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    wt = jax.random.normal(ks[0], (d,), dtype)
    wks = jax.random.normal(ks[1], (K, d), dtype)
    wts = jax.nn.softmax(jax.random.normal(ks[2], (K,)))
    a = jnp.abs(jax.random.normal(ks[3], (d,))) + 0.5
    out = ops.scaled_aggregate(wt, wks, wts, a)
    expect = ref.scaled_aggregate_ref(wt, wks, wts, a)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def _wkv_inputs(seed, BH, S, D, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (BH, S, D), dtype)
    k = jax.random.normal(ks[1], (BH, S, D), dtype)
    v = jax.random.normal(ks[2], (BH, S, D), dtype)
    # decay in (0, 1), concentrated near 1 like trained RWKV-6 decays
    w = jnp.exp(-jnp.exp(-6.0 + jax.random.normal(ks[3], (BH, S, D)))).astype(dtype)
    u = (jax.random.normal(ks[4], (BH, D)) * 0.1).astype(dtype)
    return r, k, v, w, u


@pytest.mark.parametrize("BH,S,D", [(1, 32, 8), (2, 64, 8), (1, 128, 32)])
def test_wkv6_parity(BH, S, D):
    r, k, v, w, u = _wkv_inputs(3, BH, S, D)
    out, state = ops.wkv6(r, k, v, w, u)
    out_ref, state_ref = ref.wkv6_ref(r, k, v, w, u)
    assert out.dtype == r.dtype and state.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               rtol=3e-4, atol=3e-4)


def test_wkv6_parity_nontrivial_chunking():
    # S = 2 chunks: the inter-chunk state handoff must match the oracle
    r, k, v, w, u = _wkv_inputs(4, 2, 64, 16)
    out_c32, state_c32 = ops.wkv6(r, k, v, w, u, chunk=32)
    out_ref, state_ref = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out_c32), np.asarray(out_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(state_c32), np.asarray(state_ref),
                               rtol=3e-4, atol=3e-4)
