"""FederatedSolver protocol, registry, and Trainer driver.

Pins ``Trainer.fit`` against the pre-redesign hand-rolled fig2 round loops
(kept verbatim in tests/_oracles.py) for FSVRG, FedAvg, DANE, and CoCoA+ —
the loop structure, key schedule, state threading, and history capture
must all survive the API redesign.  The oracles drive the *eager*
reference round while ``Trainer`` drives each solver's compiled closure,
so the iterate/history pins are a tight float tolerance (the whole-round
jit may re-associate the cross-bucket aggregation sum — see
test_fused_round.py); per-client dual blocks stay bit-for-bit.  Also
covers the registry round-trip (every registered name constructs, runs 2
rounds, and yields a valid SolverState pytree), the jit+lax.scan fast
path, the checkpoint save/resume cycle, and the retrospective sweep
protocol.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _oracles
from repro.core import (NonFiniteIterateError, SolverState, Trainer,
                        available, build_dense_problem, get_spec, make_solver,
                        sweep)


def _eval(prob):
    """jax-traceable eval (works in both the loop and the scan path); the
    Trainer converts the recorded values to Python floats."""
    def eval_fn(w):
        return {"f": prob.flat.loss(w)}
    return eval_fn


def _eval_floats(prob):
    """What the pre-redesign fig2 loops recorded: eager Python floats."""
    ev = _eval(prob)
    return lambda w: {k: float(v) for k, v in ev(w).items()}


# --------------------------------------------------------------------- #
# Trainer vs the pre-redesign fig2 loops
# --------------------------------------------------------------------- #


def _assert_history_close(hist, hist_ref):
    assert len(hist) == len(hist_ref)
    for rec, rec_ref in zip(hist, hist_ref):
        assert rec.keys() == rec_ref.keys()
        for k in rec:
            np.testing.assert_allclose(rec[k], rec_ref[k],
                                       rtol=1e-5, atol=1e-8)


def test_trainer_pins_fig2_fsvrg_loop(tiny_problem):
    prob = tiny_problem
    ev = _eval(prob)
    w_ref, hist_ref = _oracles.fig2_fsvrg_loop(prob, 1.0, 3, seed=1,
                                               eval_fn=_eval_floats(prob))
    res = Trainer(make_solver("fsvrg", prob, stepsize=1.0), rounds=3, seed=1,
                  eval_fn=ev).fit()
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-8)
    _assert_history_close(res.history, hist_ref)


def test_trainer_pins_fig2_fedavg_loop(tiny_problem):
    prob = tiny_problem
    ev = _eval(prob)
    w_ref, hist_ref = _oracles.fig2_fedavg_loop(prob, 0.5, 2, 3, seed=2,
                                                eval_fn=_eval_floats(prob))
    res = Trainer(make_solver("fedavg", prob, stepsize=0.5, local_epochs=2),
                  rounds=3, seed=2, eval_fn=ev).fit()
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-8)
    _assert_history_close(res.history, hist_ref)


def test_trainer_pins_fig2_dane_loop(tiny_problem):
    prob = tiny_problem
    ev = _eval(prob)
    kw = dict(eta=1.0, mu=3.0, local_steps=5, local_lr=0.3)
    w_ref, hist_ref = _oracles.fig2_dane_loop(prob, 3, seed=4,
                                              eval_fn=_eval_floats(prob), **kw)
    res = Trainer(make_solver("dane", prob, **kw), rounds=3, seed=4,
                  eval_fn=ev).fit()
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-8)
    _assert_history_close(res.history, hist_ref)


def test_trainer_pins_fig2_cocoa_loop(tiny_problem):
    """Iterates AND final dual blocks: the functional SolverState threading
    must reproduce the pre-redesign mutable-class trajectory (dual blocks
    exactly — per-client state never crosses the aggregation sum)."""
    prob = tiny_problem
    ev = _eval(prob)
    w_ref, alphas_ref, hist_ref = _oracles.fig2_cocoa_loop(
        prob, 3, seed=0, eval_fn=_eval_floats(prob))
    res = Trainer(make_solver("cocoa", prob), rounds=3, seed=0,
                  eval_fn=ev).fit()
    np.testing.assert_allclose(np.asarray(res.w), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-8)
    _assert_history_close(res.history, hist_ref)
    assert len(res.state.aux) == len(alphas_ref)
    for a_eng, a_ref in zip(res.state.aux, alphas_ref):
        np.testing.assert_array_equal(np.asarray(a_eng), np.asarray(a_ref))


# --------------------------------------------------------------------- #
# registry round-trip
# --------------------------------------------------------------------- #


def _dense_ridge_problem(K=3, m=8, d=5, lam=0.1, seed=0):
    rng = np.random.default_rng(seed)
    Xs = [jnp.asarray(rng.standard_normal((d, m)), jnp.float32)
          for _ in range(K)]
    ys = [jnp.asarray(rng.standard_normal(m), jnp.float32) for _ in range(K)]
    return build_dense_problem(Xs, ys, lam)


def test_registry_round_trip(tiny_problem):
    """Every registered name constructs with its config defaults, runs 2
    rounds through the Trainer, and produces a valid, finite SolverState
    pytree with the round counter advanced."""
    names = available()
    assert len(names) >= 8, names
    dense = _dense_ridge_problem()
    for name in names:
        spec = get_spec(name)
        problem = tiny_problem if spec.layout == "sparse" else dense
        solver = make_solver(name, problem)
        assert solver.name == name
        assert isinstance(solver.hyperparams, dict)
        res = solver.fit(2, seed=0)
        state = res.state
        assert isinstance(state, SolverState)
        assert int(state.round) == 2, name
        assert state.w.shape == (problem.d,)
        # a valid pytree: flatten/unflatten round-trips, all leaves finite
        leaves, treedef = jax.tree_util.tree_flatten(state)
        assert all(np.isfinite(np.asarray(l)).all() for l in leaves), name
        state2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_array_equal(np.asarray(state2.w),
                                      np.asarray(state.w))


def test_registry_unknown_name_and_overrides(tiny_problem):
    with pytest.raises(KeyError):
        make_solver("bogus", tiny_problem)
    solver = make_solver("fedavg", tiny_problem, stepsize=0.7)
    assert solver.hyperparams["stepsize"] == 0.7
    # defaults still come from the config for keys not overridden
    from repro.configs import get_fedavg_config
    assert solver.hyperparams["local_epochs"] == get_fedavg_config().local_epochs


def test_cocoa_rejects_nonzero_w0(tiny_problem):
    solver = make_solver("cocoa", tiny_problem)
    with pytest.raises(ValueError):
        solver.init(jnp.ones(tiny_problem.d))


# --------------------------------------------------------------------- #
# scan fast path
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("name", ["fedavg", "cocoa"])
def test_scan_fast_path_matches_loop(tiny_problem, name):
    """jit + lax.scan over rounds == the eager per-round loop, for a
    stateless and a dual-state solver (float tolerance: XLA may fuse the
    scanned round body differently)."""
    prob = tiny_problem
    ev = _eval(prob)
    loop = Trainer(make_solver(name, prob), rounds=3, seed=0,
                   eval_fn=ev).fit()
    scan = Trainer(make_solver(name, prob), rounds=3, seed=0, eval_fn=ev,
                   scan=True).fit()
    np.testing.assert_allclose(np.asarray(scan.w), np.asarray(loop.w),
                               rtol=1e-6, atol=1e-7)
    assert int(scan.state.round) == int(loop.state.round) == 3
    assert len(scan.history) == len(loop.history)
    for a, b in zip(scan.history, loop.history):
        np.testing.assert_allclose(a["f"], b["f"], rtol=1e-6)


def test_scan_rejects_python_callback(tiny_problem):
    with pytest.raises(ValueError):
        Trainer(make_solver("fedavg", tiny_problem), rounds=2, scan=True,
                callback=lambda s, r: None)


# --------------------------------------------------------------------- #
# checkpoint save / restore / resume
# --------------------------------------------------------------------- #


def test_checkpoint_resume_is_bit_identical(tiny_problem, tmp_path):
    """fit 2 rounds + save, restore, fit to 4 == one uninterrupted 4-round
    run — the absolute-round key schedule makes resumption exact (dual
    state included)."""
    prob = tiny_problem
    ckpt = str(tmp_path / "cocoa")
    solver = make_solver("cocoa", prob)
    Trainer(solver, rounds=2, seed=0, checkpoint_dir=ckpt).fit()

    restored = Trainer.restore(ckpt)
    assert int(restored.round) == 2
    resumed = Trainer(solver, rounds=4, seed=0).fit(state=restored)
    straight = Trainer(make_solver("cocoa", prob), rounds=4, seed=0).fit()
    for a, b in zip(jax.tree_util.tree_leaves(resumed.state),
                    jax.tree_util.tree_leaves(straight.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_final_checkpoint_never_lags_result(tiny_problem, tmp_path):
    """checkpoint_every that does not divide rounds must still leave the
    *final* state on disk, not the last periodic save."""
    ckpt = str(tmp_path / "gd")
    res = Trainer(make_solver("gd", tiny_problem), rounds=3, seed=0,
                  checkpoint_dir=ckpt, checkpoint_every=2).fit()
    restored = Trainer.restore(ckpt)
    assert int(restored.round) == 3
    np.testing.assert_array_equal(np.asarray(restored.w), np.asarray(res.w))


def test_scan_rejects_periodic_checkpointing(tiny_problem, tmp_path):
    with pytest.raises(ValueError):
        Trainer(make_solver("gd", tiny_problem), rounds=4, scan=True,
                checkpoint_dir=str(tmp_path / "x"), checkpoint_every=2)


def test_fit_past_round_budget_is_noop(tiny_problem):
    solver = make_solver("gd", tiny_problem)
    res = Trainer(solver, rounds=2, seed=0).fit()
    again = Trainer(solver, rounds=2, seed=0).fit(state=res.state)
    assert again.history == []
    np.testing.assert_array_equal(np.asarray(again.w), np.asarray(res.w))


def test_fit_past_round_budget_still_checkpoints(tiny_problem, tmp_path):
    """The degenerate start >= rounds return must uphold the "saved
    checkpoint never lags the returned result" invariant: a restored state
    handed to a past-budget fit with checkpoint_dir set used to return
    without ever writing the directory."""
    solver = make_solver("gd", tiny_problem)
    res = Trainer(solver, rounds=2, seed=0).fit()
    ckpt = str(tmp_path / "late")
    again = Trainer(solver, rounds=2, seed=0,
                    checkpoint_dir=ckpt).fit(state=res.state)
    restored = Trainer.restore(ckpt)
    assert int(restored.round) == int(again.state.round) == 2
    np.testing.assert_array_equal(np.asarray(restored.w), np.asarray(again.w))


# --------------------------------------------------------------------- #
# retrospective sweep
# --------------------------------------------------------------------- #


def test_sweep_picks_best_final_objective(tiny_problem):
    prob = tiny_problem
    ev = _eval(prob)
    candidates = (0.3, 1.0)
    res, best = sweep(lambda h: make_solver("fsvrg", prob, stepsize=h),
                      candidates, rounds=2, seed=0, eval_fn=ev)
    finals = {
        h: Trainer(make_solver("fsvrg", prob, stepsize=h), rounds=2, seed=0,
                   eval_fn=ev).fit().history[-1]["f"]
        for h in candidates
    }
    assert best == min(finals, key=finals.get)
    assert res.history[-1]["f"] == finals[best]


# --------------------------------------------------------------------- #
# eval cadence (eval_every)
# --------------------------------------------------------------------- #


def test_eval_every_records_subset_of_dense_history(tiny_problem):
    """eval_every=k keeps exactly the rounds (r+1) % k == 0 plus the final
    round, with values identical to the every-round history's entries."""
    prob = tiny_problem
    ev = _eval(prob)
    dense = Trainer(make_solver("gd", prob), rounds=7, seed=0,
                    eval_fn=ev).fit()
    sparse = Trainer(make_solver("gd", prob), rounds=7, seed=0,
                     eval_fn=ev, eval_every=3).fit()
    # rounds 2, 5 (cadence) + 6 (final)
    assert len(sparse.history) == 3
    expect = [dense.history[2], dense.history[5], dense.history[6]]
    for rec, rec_ref in zip(sparse.history, expect):
        assert rec == rec_ref
    np.testing.assert_array_equal(np.asarray(sparse.w), np.asarray(dense.w))


def test_eval_every_final_round_always_recorded(tiny_problem):
    """A cadence that never divides the budget still records the final
    round — history[-1] keeps meaning 'final objective' (the sweep
    contract)."""
    prob = tiny_problem
    res = Trainer(make_solver("gd", prob), rounds=4, seed=0,
                  eval_fn=_eval(prob), eval_every=10).fit()
    assert len(res.history) == 1
    ref = Trainer(make_solver("gd", prob), rounds=4, seed=0,
                  eval_fn=_eval(prob)).fit()
    assert res.history[0] == ref.history[-1]


@pytest.mark.parametrize("eval_every", [2, 5])
def test_eval_every_scan_matches_loop(tiny_problem, eval_every):
    prob = tiny_problem
    ev = _eval(prob)
    loop = Trainer(make_solver("gd", prob), rounds=6, seed=0,
                   eval_fn=ev, eval_every=eval_every).fit()
    scan = Trainer(make_solver("gd", prob), rounds=6, seed=0,
                   eval_fn=ev, eval_every=eval_every, scan=True).fit()
    assert len(scan.history) == len(loop.history)
    for rec, rec_ref in zip(scan.history, loop.history):
        np.testing.assert_allclose(rec["f"], rec_ref["f"],
                                   rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(np.asarray(scan.w), np.asarray(loop.w),
                               rtol=1e-5, atol=1e-8)


def test_eval_every_validation(tiny_problem):
    with pytest.raises(ValueError):
        Trainer(make_solver("gd", tiny_problem), rounds=2, eval_every=0)


def test_eval_every_sweep_still_picks_best(tiny_problem):
    """The sweep keys off history[-1], which eval_every preserves."""
    prob = tiny_problem
    ev = _eval(prob)
    res_d, best_d = sweep(lambda h: make_solver("gd", prob, stepsize=h),
                          (0.5, 2.0), rounds=3, seed=0, eval_fn=ev)
    res_s, best_s = sweep(lambda h: make_solver("gd", prob, stepsize=h),
                          (0.5, 2.0), rounds=3, seed=0, eval_fn=ev,
                          eval_every=2)
    assert best_s == best_d
    assert res_s.history[-1] == res_d.history[-1]


# --------------------------------------------------------------------- #
# fail-fast on non-finite iterates
# --------------------------------------------------------------------- #


class _DivergeAt:
    """Protocol-minimal solver whose iterate goes NaN at a given round —
    traceable, so it drives both the eager loop and the scan path."""

    name = "diverge-stub"
    hyperparams = {}

    def __init__(self, bad_round):
        self.bad_round = bad_round

    def init(self, w0=None):
        w = jnp.zeros(3) if w0 is None else w0
        return SolverState(w=w, aux=(), round=jnp.asarray(0, jnp.int32))

    def round(self, state, key):
        bad = state.round == self.bad_round
        w = jnp.where(bad, jnp.full_like(state.w, jnp.nan), state.w + 1.0)
        return SolverState(w=w, aux=(), round=state.round + 1)


def test_fail_fast_raises_the_round_the_iterate_goes_nan():
    """The error names the solver and the exact round — what the campaign
    guard-rail quarantines."""
    with pytest.raises(NonFiniteIterateError) as ei:
        Trainer(_DivergeAt(2), rounds=5, seed=0).fit()
    assert ei.value.solver_name == "diverge-stub"
    assert ei.value.round_index == 2


def test_fail_fast_off_lets_the_run_finish():
    res = Trainer(_DivergeAt(2), rounds=5, seed=0, fail_fast=False).fit()
    assert int(res.state.round) == 5
    assert not bool(jnp.isfinite(res.w).all())


def test_fail_fast_scan_path_checks_final_iterate():
    with pytest.raises(NonFiniteIterateError):
        Trainer(_DivergeAt(3), rounds=5, seed=0, scan=True).fit()
