"""Pre-port list-based DANE / CoCoA+ / Appendix-A implementations.

These are the standalone (Python-lists-of-per-client-arrays, hand-rolled
round loop) code paths that the engine ports in ``repro.core.dane`` /
``repro.core.cocoa`` replaced.  They are kept verbatim here as *oracles*:
tests/test_dane_cocoa_engine.py pins each engine port against its oracle
round-by-round.  The only deliberate deviation is ``cocoa_round_list``,
whose per-bucket key is ``fold_in(key, wi)`` (wi = the bucket's first
client index) to match the RoundEngine key contract — the pre-port class
used the bucket's position, which pins nothing but its own loop.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core.dane import ridge_grad
from repro.core.problem import FederatedLogReg


# --------------------------------------------------------------------- #
# exact DANE for ridge regression (dense per-client data)
# --------------------------------------------------------------------- #


def dane_round_ridge(Xs: Sequence[jax.Array], ys: Sequence[jax.Array], w, lam,
                     eta: float = 1.0, mu: float = 0.0):
    """One exact DANE round on ridge. Xs[k]: (d, n_k)."""
    K = len(Xs)
    n = sum(int(y.shape[0]) for y in ys)
    # ∇f(w^t) = Σ (n_k/n) ∇F_k(w^t)
    full_grad = sum((ys[k].shape[0] / n) * ridge_grad(Xs[k], ys[k], w, lam)
                    for k in range(K))
    d = w.shape[0]
    w_next = jnp.zeros_like(w)
    for k in range(K):
        X, y = Xs[k], ys[k]
        m = y.shape[0]
        a_k = ridge_grad(X, y, w, lam) - eta * full_grad
        # (H_k + µI) w = c_k + a_k + µ w^t,  H_k = XXᵀ/m + λI, c_k = Xy/m
        H = X @ X.T / m + (lam + mu) * jnp.eye(d)
        rhs = X @ y / m + a_k + mu * w
        w_next = w_next + jnp.linalg.solve(H, rhs) / K
    return w_next


# --------------------------------------------------------------------- #
# inexact DANE for logistic regression (GD local solver)
# --------------------------------------------------------------------- #


def dane_round_logreg_gd(problem: FederatedLogReg, w, *, eta: float = 1.0,
                         mu: float = 0.0, local_steps: int = 50,
                         local_lr: float = 1.0):
    """DANE with a GD local solver, on the bucketed sparse problem."""
    flat = problem.flat
    full_grad = flat.grad(w)
    lam = flat.lam
    agg = jnp.zeros_like(w)
    wi = 0
    for b in problem.buckets:

        def one_client(idx, val, y, n_k):
            d = w.shape[0]
            nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
            valid = (jnp.arange(y.shape[0]) < n_k).astype(jnp.float32)

            def Fk_grad(wk):
                z = y * (val * wk[idx]).sum(axis=1)
                gs = -y * jax.nn.sigmoid(-y * z) * valid / nkf
                return jnp.zeros((d,)).at[idx].add(gs[:, None] * val) + lam * wk

            a_k = Fk_grad(w) - eta * full_grad

            def gd_step(wk, _):
                g = Fk_grad(wk) - a_k + mu * (wk - w)
                return wk - local_lr * g, None

            wk, _ = jax.lax.scan(gd_step, w, None, length=local_steps)
            return wk

        wks = jax.vmap(one_client)(b.idx, b.val, b.y, b.n_k)   # (Kb, d)
        agg = agg + wks.sum(axis=0)
        wi += b.num_clients
    return agg / problem.num_clients


# --------------------------------------------------------------------- #
# CoCoA+ (list-based alphas, hand-rolled round loop, per-client SDCA scan)
# --------------------------------------------------------------------- #


def _sdca_local_pass_list(w, alpha_b, bucket, lam, n, sigma, key):
    """The pre-rewrite SDCA local pass: vmap over clients, each running its
    own sequential scan with a *scalar* Newton solve per coordinate —
    verbatim from the pre-port CoCoAPlus.  Kept independent of
    ``repro.core.cocoa._sdca_local_pass`` so the lockstep bucket-scan
    rewrite there is pinned against genuinely separate code."""

    def one_client(idx, val, y, n_k, alpha_k, ck):
        d = w.shape[0]
        m_pad = y.shape[0]
        perm = jax.random.permutation(ck, m_pad)

        def newton_beta(beta0, mcoef, ccoef):
            def it(b, _):
                gb = mcoef + 2.0 * ccoef * (b - beta0) + jnp.log(b / (1.0 - b))
                hb = 2.0 * ccoef + 1.0 / (b * (1.0 - b))
                return jnp.clip(b - gb / hb, 1e-6, 1.0 - 1e-6), None
            b0 = jnp.clip(jax.nn.sigmoid(-mcoef), 1e-6, 1.0 - 1e-6)
            b, _ = jax.lax.scan(it, b0, None, length=12)
            return b

        def step(carry, t):
            u, r = carry
            i = perm[t]
            xi, vi, yi = idx[i], val[i], y[i]
            valid = (i < n_k).astype(jnp.float32)
            beta_old = yi * alpha_k[i]
            beta_old = jnp.clip(beta_old, 1e-6, 1.0 - 1e-6)
            xn2 = (vi * vi).sum()
            mcoef = yi * ((vi * w[xi]).sum() + (sigma / (lam * n)) * (vi * r[xi]).sum())
            ccoef = sigma * xn2 / (2.0 * lam * n)
            beta = newton_beta(beta_old, mcoef, ccoef)
            du = valid * yi * (beta - beta_old)
            u = u.at[i].add(du)
            r = r.at[xi].add(du * vi)
            return (u, r), None

        u0 = jnp.zeros((m_pad,))
        r0 = jnp.zeros((d,))
        (u, r), _ = jax.lax.scan(step, (u0, r0), jnp.arange(m_pad))
        return u, r

    keys = jax.random.split(key, bucket.num_clients)
    return jax.vmap(one_client)(bucket.idx, bucket.val, bucket.y,
                                bucket.n_k, alpha_b, keys)


def cocoa_round_list(problem: FederatedLogReg, w, alphas: List[jax.Array],
                     key, sigma: float):
    """The pre-port CoCoAPlus.round body: per-bucket SDCA pass (the
    pre-rewrite per-client scan above), list alphas, dw accumulated by
    hand, w ← w + dw/(λn)."""
    lam, n = problem.flat.lam, problem.flat.n
    dw = jnp.zeros_like(w)
    new_alphas = []
    wi = 0
    for bi, b in enumerate(problem.buckets):
        u, r = _sdca_local_pass_list(w, alphas[bi], b, lam, n, sigma,
                                     jax.random.fold_in(key, wi))
        new_alphas.append(alphas[bi] + u)
        dw = dw + r.sum(axis=0)
        wi += b.num_clients
    return w + dw / (lam * n), new_alphas


# --------------------------------------------------------------------- #
# Appendix A, ridge regression, dense per-client data  X_k: (d, m)
# --------------------------------------------------------------------- #


def _Fk_grad_ridge(X, y, w, lam, n, K):
    """F_k(w) = (K/2n)||X^T w − y||² + (λ/2)||w||²  (eq. 12 normalization)."""
    return (K / n) * (X @ (X.T @ w - y)) + lam * w


def primal_method_init(Xs: Sequence[jax.Array], alphas0: Sequence[jax.Array],
                       lam: float, sigma: float):
    """Steps 3–5 of Algorithm 5. Returns (w0, g0 list, eta, mu)."""
    K = len(Xs)
    n = sum(int(a.shape[0]) for a in alphas0)
    eta = K / sigma
    mu = lam * (eta - 1.0)
    w0 = sum(X @ a for X, a in zip(Xs, alphas0)) / (lam * n)
    g0 = [eta * ((K / n) * (X @ a) - lam * w0) for X, a in zip(Xs, alphas0)]
    return w0, g0, eta, mu


def primal_method_round(Xs, ys, w, gs: List[jax.Array], lam, eta, mu):
    """One round of Algorithm 5 (exact local solves; ridge)."""
    K = len(Xs)
    n = sum(int(y.shape[0]) for y in ys)
    d = w.shape[0]
    w_ks = []
    for k in range(K):
        X, y = Xs[k], ys[k]
        # argmin F_k(w') − (∇F_k(w^t) − (η∇F_k(w^t) + g_k))ᵀ w' + µ/2||w'−w^t||²
        b_k = (1.0 - eta) * _Fk_grad_ridge(X, y, w, lam, n, K) - gs[k]
        # ∇F_k(w') = (K/n) X Xᵀ w' − (K/n) X y + λ w'
        H = (K / n) * (X @ X.T) + (lam + mu) * jnp.eye(d)
        rhs = (K / n) * (X @ y) + b_k + mu * w
        w_ks.append(jnp.linalg.solve(H, rhs))
    w_next = sum(w_ks) / K
    gs_next = [gs[k] + lam * eta * (w_ks[k] - w_next) for k in range(K)]
    return w_next, gs_next


def dual_method_round(Xs, ys, alphas: List[jax.Array], lam, sigma):
    """One round of Algorithm 6 (exact block solves; ridge φ_i(t)=½(t−y_i)²).

    Block subproblem (19): h_k = argmin (σ/2λn)||X_k h||² + ½||h||²
                                        − (y_k − X_kᵀw^t − α_k)ᵀ h
    """
    K = len(Xs)
    n = sum(int(a.shape[0]) for a in alphas)
    w = sum(X @ a for X, a in zip(Xs, alphas)) / (lam * n)
    new_alphas = []
    for k in range(K):
        X, y, a = Xs[k], ys[k], alphas[k]
        m = a.shape[0]
        c = y - X.T @ w - a
        M = (sigma / (lam * n)) * (X.T @ X) + jnp.eye(m)
        h = jnp.linalg.solve(M, c)
        new_alphas.append(a + h)
    return new_alphas


# --------------------------------------------------------------------- #
# pre-redesign fig2 round loops (Trainer pinning oracles)
# --------------------------------------------------------------------- #
#
# Before the FederatedSolver/Trainer redesign, benchmarks/fig2_convergence.py
# hand-rolled one round loop per algorithm: construct the solver, then
# ``for r: w = round(w, fold_in(PRNGKey(seed), r)); hist.append(eval(w))``
# (CoCoA+ additionally threaded its mutable dual blocks).  These functions
# keep those loop bodies verbatim — inlining each pre-redesign ``round``
# implementation at the engine level — parametrized on the seed, so
# tests/test_trainer.py can pin ``Trainer.fit`` against them bit-for-bit.
# The vmapped client passes are shared with the live solvers on purpose:
# the passes themselves are pinned against the fully independent list-based
# oracles above; what these loops pin is the *driver* — key schedule, round
# ordering, state threading, and history capture.


def _round_key(seed: int, r: int):
    return jax.random.fold_in(jax.random.PRNGKey(seed), r)


def fig2_fsvrg_loop(problem: FederatedLogReg, h: float, rounds: int,
                    seed: int, eval_fn):
    """The pre-redesign fig2 FSVRG curve: fresh solver, hand-rolled loop."""
    from repro.core import FSVRG, FSVRGConfig

    solver = FSVRG(problem, FSVRGConfig(stepsize=h))
    w = jnp.zeros(problem.d)
    hist = []
    for r in range(rounds):
        # verbatim pre-redesign FSVRG.round(w, key) body
        full_grad = problem.flat.grad(w)

        def fsvrg_pass(w_, bi, bucket, kb, fg=full_grad):
            return solver._passes[bi](w_, fg, phi=solver.phi, key=kb)

        w = solver.engine.round(w, _round_key(seed, r), fsvrg_pass)
        hist.append(eval_fn(w))
    return w, hist


def fig2_fedavg_loop(problem: FederatedLogReg, h: float, local_epochs: int,
                     rounds: int, seed: int, eval_fn):
    """The pre-redesign fig2 FedAvg curve."""
    from repro.core import FedAvg, FedAvgConfig

    solver = FedAvg(problem, FedAvgConfig(stepsize=h,
                                          local_epochs=local_epochs))
    w = jnp.zeros(problem.d)
    hist = []
    for r in range(rounds):
        # verbatim pre-redesign FedAvg.round(w, key) body
        w = solver.engine.round(
            w, _round_key(seed, r),
            lambda w_, bi, bucket, kb: solver._passes[bi](w_, key=kb))
        hist.append(eval_fn(w))
    return w, hist


def fig2_dane_loop(problem: FederatedLogReg, rounds: int, seed: int, eval_fn,
                   **dane_kw):
    """The pre-redesign fig2 DANE curve (GD local solver)."""
    from repro.core import DANE, DANEConfig

    solver = DANE(problem, DANEConfig(**dane_kw))
    w = jnp.zeros(problem.d)
    hist = []
    for r in range(rounds):
        # verbatim pre-redesign DANE.round(w, key) body
        full_grad = problem.flat.grad(w)

        def dane_pass(w_, bi, bucket, kb, fg=full_grad):
            return solver._passes[bi](w_, fg, key=kb)

        w = solver.engine.round(w, _round_key(seed, r), dane_pass)
        hist.append(eval_fn(w))
    return w, hist


def fig2_cocoa_loop(problem: FederatedLogReg, rounds: int, seed: int,
                    eval_fn, sigma=None):
    """The pre-redesign fig2 CoCoA+ curve: the mutable-class round body
    (dual blocks threaded by hand through round_with_state)."""
    from repro.core.cocoa import CoCoAPlus

    solver = CoCoAPlus(problem, sigma=sigma)
    w = jnp.zeros(problem.d)
    alphas = [jnp.zeros((b.num_clients, b.m_pad)) for b in problem.buckets]
    hist = []
    for r in range(rounds):
        # verbatim pre-redesign CoCoAPlus.round(key) body, de-mutabilized
        def cocoa_pass(w_, bi, bucket, alpha_b, kb):
            u, dr = solver._pass[bi](w_, alpha_b, kb)
            return dr * solver._scale, alpha_b + u

        w, alphas = solver.engine.round_with_state(
            w, alphas, _round_key(seed, r), cocoa_pass)
        hist.append(eval_fn(w))
    return w, alphas, hist
