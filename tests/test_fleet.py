"""The fleet simulation layer (repro.fleet): traces + participation models.

Contracts:

1. Trace determinism — any round's masks are a pure function of
   ``(trace.seed, round, client_ids)``: bit-identical across
   regeneration, under jit, and invariant to how the client axis is
   batched (a slice of the fleet's mask == the mask of the slice), which
   is what makes chunk/cohort rounds see the same fleet.
2. BernoulliParticipation is a bit-exact pin of the engine's historical
   draw — installing it changes nothing, down to the last bit.
3. Trace-driven rounds: plain vs streamed (chunk) vs cohort parity under
   a round-dependent model; round-dependent models reject mask requests
   without a round index.
4. Dropout-after-compute — a straggler (available but not returned)
   is indistinguishable from a never-sampled client: replaying the
   trace's ``returned`` mask through FixedParticipation reproduces the
   trace round bit-for-bit, and dual-state freezing covers stragglers.
5. Solver plumbing: registry solvers accept ``participation_model`` and
   thread ``state.round`` into the compiled round (no retrace per round).
6. Distribution drift (repro.data.synthetic.drifted_dataset): epoch 0 is
   the identity, epochs are deterministic, shapes are drift-invariant.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Trainer, make_solver
from repro.core.engine import EngineConfig, RoundEngine
from repro.fleet import (BernoulliParticipation, FixedParticipation,
                         FleetTrace, TraceParticipation, availability_rate,
                         fleet_masks)

TRACE = FleetTrace(seed=5, base=0.5, amplitude=0.3, period=7.0,
                   burst_prob=0.3, burst_frac=0.5, straggler_rate=0.25)


def _keyed_deltas(w, bucket, keys):
    def one(n_k, ck):
        return ((jax.random.uniform(ck, w.shape) - 0.5)
                * (1.0 + 0.1 * n_k.astype(jnp.float32)))
    return jax.vmap(one)(bucket.n_k, keys)


def _passes():
    def client_pass(w, bi, b, kb):
        return _keyed_deltas(w, b, jax.random.split(kb, b.num_clients))

    def chunk_pass(w, bi, cb, keys):
        return _keyed_deltas(w, cb, keys)

    return client_pass, chunk_pass


# --------------------------------------------------------------------- #
# 1. trace determinism
# --------------------------------------------------------------------- #


def test_fleet_masks_bit_identical_across_regeneration():
    ids = jnp.arange(64, dtype=jnp.uint32)
    for r in (0, 3, 11):
        a = fleet_masks(TRACE, r, ids)
        b = fleet_masks(TRACE, r, ids)
        c = jax.jit(lambda rr: fleet_masks(TRACE, rr, ids))(jnp.int32(r))
        np.testing.assert_array_equal(np.asarray(a.available),
                                      np.asarray(b.available))
        np.testing.assert_array_equal(np.asarray(a.returned),
                                      np.asarray(b.returned))
        np.testing.assert_array_equal(np.asarray(a.available),
                                      np.asarray(c.available))
        np.testing.assert_array_equal(np.asarray(a.returned),
                                      np.asarray(c.returned))


def test_fleet_masks_batch_shape_invariant():
    """The mask of a client depends only on its global id — computing the
    fleet whole or in arbitrary slices gives the same bits (the property
    chunk/cohort rounds rely on)."""
    K = 50
    ids = jnp.arange(K, dtype=jnp.uint32)
    whole = fleet_masks(TRACE, 4, ids)
    for lo, hi in ((0, 7), (7, 30), (30, 50), (13, 14)):
        part = fleet_masks(TRACE, 4, ids[lo:hi])
        np.testing.assert_array_equal(np.asarray(whole.available)[lo:hi],
                                      np.asarray(part.available))
        np.testing.assert_array_equal(np.asarray(whole.returned)[lo:hi],
                                      np.asarray(part.returned))


def test_availability_rate_bounds_and_diurnal_variation():
    ids = jnp.arange(200, dtype=jnp.uint32)
    rates = np.stack([np.asarray(availability_rate(TRACE, r, ids))
                      for r in range(14)])
    assert (rates >= 0.0).all() and (rates <= 1.0).all()
    # the sinusoid must actually move the per-client rate across rounds
    assert rates.std(axis=0).max() > 0.05


def test_returned_is_subset_of_available():
    ids = jnp.arange(300, dtype=jnp.uint32)
    m = fleet_masks(TRACE, 2, ids)
    av, ret = np.asarray(m.available), np.asarray(m.returned)
    assert ((ret == 1) <= (av == 1)).all()
    assert (av - ret).sum() > 0  # straggler_rate=0.25: someone straggled
    quiet = dataclasses.replace(TRACE, straggler_rate=0.0)
    m0 = fleet_masks(quiet, 2, ids)
    np.testing.assert_array_equal(np.asarray(m0.available),
                                  np.asarray(m0.returned))


def test_trace_validation():
    with pytest.raises(ValueError):
        FleetTrace(base=0.0)
    with pytest.raises(ValueError):
        FleetTrace(base=0.3, amplitude=0.4)   # rate floor <= 0
    with pytest.raises(ValueError):
        FleetTrace(straggler_rate=1.0)


# --------------------------------------------------------------------- #
# 2. BernoulliParticipation pins the engine draw
# --------------------------------------------------------------------- #


def test_bernoulli_model_bit_identical_to_engine_draw(small_problem):
    prob = small_problem
    p = 0.4
    eng = RoundEngine(prob, EngineConfig(participation=p))
    eng_m = RoundEngine(prob, EngineConfig(participation=p),
                        participation_model=BernoulliParticipation(p))
    client_pass, _ = _passes()
    w = jnp.zeros(prob.d)
    for r in range(3):
        key = jax.random.PRNGKey(30 + r)
        for a, b in zip(eng.participation_masks(key),
                        eng_m.participation_masks(key)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(eng.round(w, key, client_pass)),
            np.asarray(eng_m.round(w, key, client_pass)))


# --------------------------------------------------------------------- #
# 3. trace-driven rounds across engine paths
# --------------------------------------------------------------------- #


def test_round_dependent_model_requires_round_index(small_problem):
    eng = RoundEngine(small_problem, EngineConfig(participation=0.8),
                      participation_model=TraceParticipation(TRACE))
    client_pass, _ = _passes()
    with pytest.raises(ValueError, match="round"):
        eng.round(jnp.zeros(small_problem.d), jax.random.PRNGKey(0),
                  client_pass)


@pytest.mark.parametrize("r", [0, 5])
def test_trace_round_chunk_and_cohort_parity(small_problem, r):
    """One fleet, three engine paths: the plain masked round, the streamed
    (client_chunk) round, and the gathered cohort round all see the same
    trace masks — outputs agree to the same float tolerance as the
    Bernoulli paths (chunked/cohort accumulation reorders the sum)."""
    prob = small_problem
    model = TraceParticipation(TRACE)
    cap = TRACE.max_rate()
    kw = dict(participation=cap)
    eng = RoundEngine(prob, EngineConfig(**kw), participation_model=model)
    eng_ch = RoundEngine(prob, EngineConfig(client_chunk=3, **kw),
                         participation_model=model)
    eng_co = RoundEngine(prob, EngineConfig(cohort=6, **kw),
                         participation_model=model)
    client_pass, chunk_pass = _passes()
    w = jax.random.normal(jax.random.PRNGKey(1), (prob.d,)) * 0.1
    key = jax.random.PRNGKey(40 + r)
    out = eng.round(w, key, client_pass, round_index=r)
    out_ch = eng_ch.round_streamed(w, key, chunk_pass, round_index=r)
    out_co = eng_co.round_cohort(w, key, chunk_pass, round_index=r)
    np.testing.assert_allclose(np.asarray(out_ch), np.asarray(out),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_co), np.asarray(out),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# 4. dropout-after-compute semantics
# --------------------------------------------------------------------- #


def test_straggler_equals_removed_delta(small_problem):
    """The trace round must equal a round whose participant set is exactly
    the returned clients — a straggler's computed-but-dropped delta leaves
    no trace in the aggregate (zero weight ≡ removed), and differs from
    the availability-only round whenever someone straggled."""
    prob = small_problem
    model = TraceParticipation(TRACE)
    r = 2
    key = jax.random.PRNGKey(50)
    offsets = tuple(int(w) for w in np.cumsum(
        [0] + [b.num_clients for b in prob.buckets])[:-1])
    sizes = tuple(b.num_clients for b in prob.buckets)
    avail, returned = model.mask_components(key, jnp.int32(r), offsets, sizes)
    assert sum(float((a - b).sum()) for a, b in zip(avail, returned)) > 0

    kw = dict(participation=TRACE.max_rate())
    client_pass, _ = _passes()
    w = jnp.zeros(prob.d)
    eng_tr = RoundEngine(prob, EngineConfig(**kw), participation_model=model)
    eng_ret = RoundEngine(prob, EngineConfig(**kw),
                          participation_model=FixedParticipation(
                              tuple(returned)))
    eng_av = RoundEngine(prob, EngineConfig(**kw),
                         participation_model=FixedParticipation(tuple(avail)))
    out_tr = eng_tr.round(w, key, client_pass, round_index=r)
    out_ret = eng_ret.round(w, key, client_pass, round_index=r)
    out_av = eng_av.round(w, key, client_pass, round_index=r)
    np.testing.assert_array_equal(np.asarray(out_tr), np.asarray(out_ret))
    assert (np.asarray(out_tr) != np.asarray(out_av)).any()


def test_straggler_state_frozen(small_problem):
    """Dual-state freezing covers stragglers: every client whose delta
    did not return — never-available AND available-but-straggling — keeps
    its state bit-for-bit."""
    prob = small_problem
    model = TraceParticipation(TRACE)
    eng = RoundEngine(prob, EngineConfig(weighting="sum",
                                         participation=TRACE.max_rate()),
                      participation_model=model)

    def dual_pass(w, bi, b, s_b, kb):
        deltas = _keyed_deltas(w, b, jax.random.split(kb, b.num_clients))
        return deltas, s_b + deltas[:, :3]

    states = [jnp.ones((b.num_clients, 3)) for b in prob.buckets]
    r, key = 2, jax.random.PRNGKey(50)
    offsets = tuple(int(w) for w in np.cumsum(
        [0] + [b.num_clients for b in prob.buckets])[:-1])
    sizes = tuple(b.num_clients for b in prob.buckets)
    _, returned = model.mask_components(key, jnp.int32(r), offsets, sizes)
    _, new_states = eng.round_with_state(jnp.zeros(prob.d), states, key,
                                         dual_pass, round_index=r)
    changed_any = False
    for ret, s_old, s_new in zip(returned, states, new_states):
        gone = np.asarray(ret) <= 0
        np.testing.assert_array_equal(np.asarray(s_new)[gone],
                                      np.asarray(s_old)[gone])
        changed_any |= bool(
            (np.asarray(s_new)[~gone] != np.asarray(s_old)[~gone]).any())
    assert changed_any  # someone returned and their state moved


# --------------------------------------------------------------------- #
# 5. solver plumbing
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("name", ["gd", "fedavg", "fsvrg", "cocoa", "dane"])
def test_solvers_accept_trace_model(small_problem, name):
    """Every Fig.-2 solver runs under a trace model through the Trainer
    (which feeds state.round into the compiled round), and two identical
    fits are bit-identical."""
    model = TraceParticipation(TRACE)
    kw = dict(participation=TRACE.max_rate(), participation_model=model)

    def fit():
        solver = make_solver(name, small_problem, **kw)
        return Trainer(solver, rounds=3, seed=0).fit()

    w1, w2 = fit().w, fit().w
    assert np.isfinite(np.asarray(w1)).all()
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


def test_trace_model_ignores_solver_seed(small_problem):
    """The fleet is the fleet: masks are a function of (trace.seed, r),
    not of the solver's round key."""
    model = TraceParticipation(TRACE)
    eng = RoundEngine(small_problem,
                      EngineConfig(participation=TRACE.max_rate()),
                      participation_model=model)
    m1 = eng.participation_masks(jax.random.PRNGKey(0), round_index=4)
    m2 = eng.participation_masks(jax.random.PRNGKey(999), round_index=4)
    for a, b in zip(m1, m2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# 6. distribution drift
# --------------------------------------------------------------------- #


def test_drift_epoch_zero_is_identity(small_virtual_dataset):
    from repro.data.synthetic import drifted_dataset

    vds = small_virtual_dataset
    assert drifted_dataset(vds, 0, w_true_scale=0.5,
                           resample_clients=True) is vds


def test_drift_deterministic_and_shape_invariant(small_virtual_dataset):
    from repro.data.synthetic import drifted_dataset, materialize_dataset

    vds = small_virtual_dataset
    d1 = materialize_dataset(drifted_dataset(vds, 2, w_true_scale=0.8,
                                             resample_clients=True))
    d2 = materialize_dataset(drifted_dataset(vds, 2, w_true_scale=0.8,
                                             resample_clients=True))
    base = materialize_dataset(vds)
    np.testing.assert_array_equal(d1.y, d2.y)
    np.testing.assert_array_equal(np.asarray(d1.val), np.asarray(d2.val))
    # same shapes and client partition, different data
    assert d1.y.shape == base.y.shape and d1.idx.shape == base.idx.shape
    np.testing.assert_array_equal(d1.client_sizes, base.client_sizes)
    assert (d1.y != base.y).any() or (np.asarray(d1.idx)
                                      != np.asarray(base.idx)).any()


def test_drift_epochs_differ(small_virtual_dataset):
    from repro.data.synthetic import drifted_dataset, materialize_dataset

    vds = small_virtual_dataset
    d1 = materialize_dataset(drifted_dataset(vds, 1, resample_clients=True))
    d2 = materialize_dataset(drifted_dataset(vds, 2, resample_clients=True))
    assert (d1.y != d2.y).any() or (np.asarray(d1.idx)
                                    != np.asarray(d2.idx)).any()


def test_drift_w_scale_only_relabels(small_virtual_dataset):
    """Concept drift (w_true rescale) moves labels, not features."""
    from repro.data.synthetic import drifted_dataset, materialize_dataset

    vds = small_virtual_dataset
    base = materialize_dataset(vds)
    dr = materialize_dataset(drifted_dataset(vds, 3, w_true_scale=0.5))
    np.testing.assert_array_equal(np.asarray(base.idx), np.asarray(dr.idx))
    np.testing.assert_array_equal(np.asarray(base.val), np.asarray(dr.val))
