"""The fleet simulation layer (repro.fleet): traces + participation models.

Contracts:

1. Trace determinism — any round's masks are a pure function of
   ``(trace.seed, round, client_ids)``: bit-identical across
   regeneration, under jit, and invariant to how the client axis is
   batched (a slice of the fleet's mask == the mask of the slice), which
   is what makes chunk/cohort rounds see the same fleet.
2. BernoulliParticipation is a bit-exact pin of the engine's historical
   draw — installing it changes nothing, down to the last bit.
3. Trace-driven rounds: plain vs streamed (chunk) vs cohort parity under
   a round-dependent model; round-dependent models reject mask requests
   without a round index.
4. Dropout-after-compute — a straggler (available but not returned)
   is indistinguishable from a never-sampled client: replaying the
   trace's ``returned`` mask through FixedParticipation reproduces the
   trace round bit-for-bit, and dual-state freezing covers stragglers.
5. Solver plumbing: registry solvers accept ``participation_model`` and
   thread ``state.round`` into the compiled round (no retrace per round).
6. Distribution drift (repro.data.synthetic.drifted_dataset): epoch 0 is
   the identity, epochs are deterministic, shapes are drift-invariant.
7. Fault injection (repro.fleet.faults): fault draws follow the same
   purity/batch-shape-invariance contract as the masks; every engine path
   corrupts the same clients identically; NaN poisoning breaks an
   unguarded round and every ``aggregator_guard`` restores a finite one.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Trainer, make_solver
from repro.core.engine import EngineConfig, RoundEngine
from repro.fleet import (BernoulliParticipation, DeltaFaults,
                         FixedParticipation, FleetTrace, TraceParticipation,
                         availability_rate, fault_counts, fleet_masks)

TRACE = FleetTrace(seed=5, base=0.5, amplitude=0.3, period=7.0,
                   burst_prob=0.3, burst_frac=0.5, straggler_rate=0.25)


def _keyed_deltas(w, bucket, keys):
    def one(n_k, ck):
        return ((jax.random.uniform(ck, w.shape) - 0.5)
                * (1.0 + 0.1 * n_k.astype(jnp.float32)))
    return jax.vmap(one)(bucket.n_k, keys)


def _passes():
    def client_pass(w, bi, b, kb):
        return _keyed_deltas(w, b, jax.random.split(kb, b.num_clients))

    def chunk_pass(w, bi, cb, keys):
        return _keyed_deltas(w, cb, keys)

    return client_pass, chunk_pass


# --------------------------------------------------------------------- #
# 1. trace determinism
# --------------------------------------------------------------------- #


def test_fleet_masks_bit_identical_across_regeneration():
    ids = jnp.arange(64, dtype=jnp.uint32)
    for r in (0, 3, 11):
        a = fleet_masks(TRACE, r, ids)
        b = fleet_masks(TRACE, r, ids)
        c = jax.jit(lambda rr: fleet_masks(TRACE, rr, ids))(jnp.int32(r))
        np.testing.assert_array_equal(np.asarray(a.available),
                                      np.asarray(b.available))
        np.testing.assert_array_equal(np.asarray(a.returned),
                                      np.asarray(b.returned))
        np.testing.assert_array_equal(np.asarray(a.available),
                                      np.asarray(c.available))
        np.testing.assert_array_equal(np.asarray(a.returned),
                                      np.asarray(c.returned))


def test_fleet_masks_batch_shape_invariant():
    """The mask of a client depends only on its global id — computing the
    fleet whole or in arbitrary slices gives the same bits (the property
    chunk/cohort rounds rely on)."""
    K = 50
    ids = jnp.arange(K, dtype=jnp.uint32)
    whole = fleet_masks(TRACE, 4, ids)
    for lo, hi in ((0, 7), (7, 30), (30, 50), (13, 14)):
        part = fleet_masks(TRACE, 4, ids[lo:hi])
        np.testing.assert_array_equal(np.asarray(whole.available)[lo:hi],
                                      np.asarray(part.available))
        np.testing.assert_array_equal(np.asarray(whole.returned)[lo:hi],
                                      np.asarray(part.returned))


def test_availability_rate_bounds_and_diurnal_variation():
    ids = jnp.arange(200, dtype=jnp.uint32)
    rates = np.stack([np.asarray(availability_rate(TRACE, r, ids))
                      for r in range(14)])
    assert (rates >= 0.0).all() and (rates <= 1.0).all()
    # the sinusoid must actually move the per-client rate across rounds
    assert rates.std(axis=0).max() > 0.05


def test_returned_is_subset_of_available():
    ids = jnp.arange(300, dtype=jnp.uint32)
    m = fleet_masks(TRACE, 2, ids)
    av, ret = np.asarray(m.available), np.asarray(m.returned)
    assert ((ret == 1) <= (av == 1)).all()
    assert (av - ret).sum() > 0  # straggler_rate=0.25: someone straggled
    quiet = dataclasses.replace(TRACE, straggler_rate=0.0)
    m0 = fleet_masks(quiet, 2, ids)
    np.testing.assert_array_equal(np.asarray(m0.available),
                                  np.asarray(m0.returned))


def test_trace_validation():
    with pytest.raises(ValueError):
        FleetTrace(base=0.0)
    with pytest.raises(ValueError):
        FleetTrace(base=0.3, amplitude=0.4)   # rate floor <= 0
    with pytest.raises(ValueError):
        FleetTrace(straggler_rate=1.0)


# --------------------------------------------------------------------- #
# 2. BernoulliParticipation pins the engine draw
# --------------------------------------------------------------------- #


def test_bernoulli_model_bit_identical_to_engine_draw(small_problem):
    prob = small_problem
    p = 0.4
    eng = RoundEngine(prob, EngineConfig(participation=p))
    eng_m = RoundEngine(prob, EngineConfig(participation=p),
                        participation_model=BernoulliParticipation(p))
    client_pass, _ = _passes()
    w = jnp.zeros(prob.d)
    for r in range(3):
        key = jax.random.PRNGKey(30 + r)
        for a, b in zip(eng.participation_masks(key),
                        eng_m.participation_masks(key)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(eng.round(w, key, client_pass)),
            np.asarray(eng_m.round(w, key, client_pass)))


# --------------------------------------------------------------------- #
# 3. trace-driven rounds across engine paths
# --------------------------------------------------------------------- #


def test_round_dependent_model_requires_round_index(small_problem):
    eng = RoundEngine(small_problem, EngineConfig(participation=0.8),
                      participation_model=TraceParticipation(TRACE))
    client_pass, _ = _passes()
    with pytest.raises(ValueError, match="round"):
        eng.round(jnp.zeros(small_problem.d), jax.random.PRNGKey(0),
                  client_pass)


@pytest.mark.parametrize("r", [0, 5])
def test_trace_round_chunk_and_cohort_parity(small_problem, r):
    """One fleet, three engine paths: the plain masked round, the streamed
    (client_chunk) round, and the gathered cohort round all see the same
    trace masks — outputs agree to the same float tolerance as the
    Bernoulli paths (chunked/cohort accumulation reorders the sum)."""
    prob = small_problem
    model = TraceParticipation(TRACE)
    cap = TRACE.max_rate()
    kw = dict(participation=cap)
    eng = RoundEngine(prob, EngineConfig(**kw), participation_model=model)
    eng_ch = RoundEngine(prob, EngineConfig(client_chunk=3, **kw),
                         participation_model=model)
    eng_co = RoundEngine(prob, EngineConfig(cohort=6, **kw),
                         participation_model=model)
    client_pass, chunk_pass = _passes()
    w = jax.random.normal(jax.random.PRNGKey(1), (prob.d,)) * 0.1
    key = jax.random.PRNGKey(40 + r)
    out = eng.round(w, key, client_pass, round_index=r)
    out_ch = eng_ch.round_streamed(w, key, chunk_pass, round_index=r)
    out_co = eng_co.round_cohort(w, key, chunk_pass, round_index=r)
    np.testing.assert_allclose(np.asarray(out_ch), np.asarray(out),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_co), np.asarray(out),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# 4. dropout-after-compute semantics
# --------------------------------------------------------------------- #


def test_straggler_equals_removed_delta(small_problem):
    """The trace round must equal a round whose participant set is exactly
    the returned clients — a straggler's computed-but-dropped delta leaves
    no trace in the aggregate (zero weight ≡ removed), and differs from
    the availability-only round whenever someone straggled."""
    prob = small_problem
    model = TraceParticipation(TRACE)
    r = 2
    key = jax.random.PRNGKey(50)
    offsets = tuple(int(w) for w in np.cumsum(
        [0] + [b.num_clients for b in prob.buckets])[:-1])
    sizes = tuple(b.num_clients for b in prob.buckets)
    avail, returned = model.mask_components(key, jnp.int32(r), offsets, sizes)
    assert sum(float((a - b).sum()) for a, b in zip(avail, returned)) > 0

    kw = dict(participation=TRACE.max_rate())
    client_pass, _ = _passes()
    w = jnp.zeros(prob.d)
    eng_tr = RoundEngine(prob, EngineConfig(**kw), participation_model=model)
    eng_ret = RoundEngine(prob, EngineConfig(**kw),
                          participation_model=FixedParticipation(
                              tuple(returned)))
    eng_av = RoundEngine(prob, EngineConfig(**kw),
                         participation_model=FixedParticipation(tuple(avail)))
    out_tr = eng_tr.round(w, key, client_pass, round_index=r)
    out_ret = eng_ret.round(w, key, client_pass, round_index=r)
    out_av = eng_av.round(w, key, client_pass, round_index=r)
    np.testing.assert_array_equal(np.asarray(out_tr), np.asarray(out_ret))
    assert (np.asarray(out_tr) != np.asarray(out_av)).any()


def test_straggler_state_frozen(small_problem):
    """Dual-state freezing covers stragglers: every client whose delta
    did not return — never-available AND available-but-straggling — keeps
    its state bit-for-bit."""
    prob = small_problem
    model = TraceParticipation(TRACE)
    eng = RoundEngine(prob, EngineConfig(weighting="sum",
                                         participation=TRACE.max_rate()),
                      participation_model=model)

    def dual_pass(w, bi, b, s_b, kb):
        deltas = _keyed_deltas(w, b, jax.random.split(kb, b.num_clients))
        return deltas, s_b + deltas[:, :3]

    states = [jnp.ones((b.num_clients, 3)) for b in prob.buckets]
    r, key = 2, jax.random.PRNGKey(50)
    offsets = tuple(int(w) for w in np.cumsum(
        [0] + [b.num_clients for b in prob.buckets])[:-1])
    sizes = tuple(b.num_clients for b in prob.buckets)
    _, returned = model.mask_components(key, jnp.int32(r), offsets, sizes)
    _, new_states = eng.round_with_state(jnp.zeros(prob.d), states, key,
                                         dual_pass, round_index=r)
    changed_any = False
    for ret, s_old, s_new in zip(returned, states, new_states):
        gone = np.asarray(ret) <= 0
        np.testing.assert_array_equal(np.asarray(s_new)[gone],
                                      np.asarray(s_old)[gone])
        changed_any |= bool(
            (np.asarray(s_new)[~gone] != np.asarray(s_old)[~gone]).any())
    assert changed_any  # someone returned and their state moved


# --------------------------------------------------------------------- #
# 5. solver plumbing
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("name", ["gd", "fedavg", "fsvrg", "cocoa", "dane"])
def test_solvers_accept_trace_model(small_problem, name):
    """Every Fig.-2 solver runs under a trace model through the Trainer
    (which feeds state.round into the compiled round), and two identical
    fits are bit-identical."""
    model = TraceParticipation(TRACE)
    kw = dict(participation=TRACE.max_rate(), participation_model=model)

    def fit():
        solver = make_solver(name, small_problem, **kw)
        return Trainer(solver, rounds=3, seed=0).fit()

    w1, w2 = fit().w, fit().w
    assert np.isfinite(np.asarray(w1)).all()
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


def test_trace_model_ignores_solver_seed(small_problem):
    """The fleet is the fleet: masks are a function of (trace.seed, r),
    not of the solver's round key."""
    model = TraceParticipation(TRACE)
    eng = RoundEngine(small_problem,
                      EngineConfig(participation=TRACE.max_rate()),
                      participation_model=model)
    m1 = eng.participation_masks(jax.random.PRNGKey(0), round_index=4)
    m2 = eng.participation_masks(jax.random.PRNGKey(999), round_index=4)
    for a, b in zip(m1, m2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# 6. distribution drift
# --------------------------------------------------------------------- #


def test_drift_epoch_zero_is_identity(small_virtual_dataset):
    from repro.data.synthetic import drifted_dataset

    vds = small_virtual_dataset
    assert drifted_dataset(vds, 0, w_true_scale=0.5,
                           resample_clients=True) is vds


def test_drift_deterministic_and_shape_invariant(small_virtual_dataset):
    from repro.data.synthetic import drifted_dataset, materialize_dataset

    vds = small_virtual_dataset
    d1 = materialize_dataset(drifted_dataset(vds, 2, w_true_scale=0.8,
                                             resample_clients=True))
    d2 = materialize_dataset(drifted_dataset(vds, 2, w_true_scale=0.8,
                                             resample_clients=True))
    base = materialize_dataset(vds)
    np.testing.assert_array_equal(d1.y, d2.y)
    np.testing.assert_array_equal(np.asarray(d1.val), np.asarray(d2.val))
    # same shapes and client partition, different data
    assert d1.y.shape == base.y.shape and d1.idx.shape == base.idx.shape
    np.testing.assert_array_equal(d1.client_sizes, base.client_sizes)
    assert (d1.y != base.y).any() or (np.asarray(d1.idx)
                                      != np.asarray(base.idx)).any()


def test_drift_epochs_differ(small_virtual_dataset):
    from repro.data.synthetic import drifted_dataset, materialize_dataset

    vds = small_virtual_dataset
    d1 = materialize_dataset(drifted_dataset(vds, 1, resample_clients=True))
    d2 = materialize_dataset(drifted_dataset(vds, 2, resample_clients=True))
    assert (d1.y != d2.y).any() or (np.asarray(d1.idx)
                                    != np.asarray(d2.idx)).any()


def test_drift_w_scale_only_relabels(small_virtual_dataset):
    """Concept drift (w_true rescale) moves labels, not features."""
    from repro.data.synthetic import drifted_dataset, materialize_dataset

    vds = small_virtual_dataset
    base = materialize_dataset(vds)
    dr = materialize_dataset(drifted_dataset(vds, 3, w_true_scale=0.5))
    np.testing.assert_array_equal(np.asarray(base.idx), np.asarray(dr.idx))
    np.testing.assert_array_equal(np.asarray(base.val), np.asarray(dr.val))


# --------------------------------------------------------------------- #
# 7. fault injection
# --------------------------------------------------------------------- #

# finite corruptions only (sign / scale / replay) — rounds stay comparable
# across engine paths; NaN poisoning gets its own tests below
FAULTS = DeltaFaults(seed=9, sign_rate=0.2, scale_rate=0.15,
                     scale_factor=5.0, replay_rate=0.15, replay_window=2)
NAN_FAULTS = DeltaFaults(seed=2, nan_rate=0.3)


def test_fault_kinds_deterministic_and_jit_stable():
    ids = jnp.arange(200, dtype=jnp.uint32)
    for r in (0, 3):
        k1 = FAULTS.kinds(r, ids)
        k2 = jax.jit(FAULTS.kinds)(jnp.int32(r), ids)
        np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    # different rounds draw different fault sets
    assert (np.asarray(FAULTS.kinds(0, ids))
            != np.asarray(FAULTS.kinds(3, ids))).any()
    # no nan_rate configured -> the poison kind never fires
    assert 1 not in set(np.unique(np.asarray(FAULTS.kinds(1, ids))))


def test_fault_kinds_batch_shape_invariant():
    """A slice of the fleet's kinds == the kinds of the slice — the same
    invariance the masks have, so chunk/cohort rounds corrupt the same
    clients as the plain round."""
    ids = jnp.arange(120, dtype=jnp.uint32)
    whole = np.asarray(FAULTS.kinds(2, ids))
    for lo, hi in ((0, 7), (7, 64), (64, 120)):
        np.testing.assert_array_equal(
            np.asarray(FAULTS.kinds(2, ids[lo:hi])), whole[lo:hi])


def test_fault_apply_batch_shape_invariant():
    ids = jnp.arange(50, dtype=jnp.uint32)
    deltas = jax.random.normal(jax.random.PRNGKey(0), (50, 33))
    whole = np.asarray(FAULTS.apply(deltas, 4, ids))
    assert (whole != np.asarray(deltas)).any()
    for lo, hi in ((0, 13), (13, 50)):
        np.testing.assert_array_equal(
            np.asarray(FAULTS.apply(deltas[lo:hi], 4, ids[lo:hi])),
            whole[lo:hi])


def test_fault_window_gating():
    f = dataclasses.replace(FAULTS, start_round=3, stop_round=5)
    ids = jnp.arange(100, dtype=jnp.uint32)
    assert not np.asarray(f.kinds(2, ids)).any()
    assert np.asarray(f.kinds(3, ids)).any()
    assert not np.asarray(f.kinds(5, ids)).any()
    # inside the window the draws match the ungated model bit-for-bit
    np.testing.assert_array_equal(np.asarray(f.kinds(4, ids)),
                                  np.asarray(FAULTS.kinds(4, ids)))


def test_fault_validation():
    with pytest.raises(ValueError, match="sum"):
        DeltaFaults(nan_rate=0.6, sign_rate=0.6)
    with pytest.raises(ValueError, match="nan_rate"):
        DeltaFaults(nan_rate=1.5)
    with pytest.raises(ValueError, match="replay_window"):
        DeltaFaults(replay_window=0)
    with pytest.raises(ValueError, match="stop_round"):
        DeltaFaults(start_round=4, stop_round=4)


def test_fault_spec_round_trip():
    f = DeltaFaults.from_spec("nan=0.01,sign=0.05,scale-factor=7,"
                              "start=3,stop=9,seed=2")
    assert f == DeltaFaults(seed=2, nan_rate=0.01, sign_rate=0.05,
                            scale_factor=7.0, start_round=3, stop_round=9)
    with pytest.raises(ValueError, match="knob"):
        DeltaFaults.from_spec("nans=0.1")


def test_fault_counts_matches_kinds():
    """fault_counts is telemetry's recomputable view: it must agree with
    counting the kinds over the returned clients directly, and a client
    that never reports is never counted."""
    f = DeltaFaults(seed=7, nan_rate=0.2, sign_rate=0.2)
    ids = jnp.arange(64, dtype=jnp.uint32)
    mask = (ids % 3 != 0).astype(jnp.float32)    # the returned-weight view
    inj, poi = fault_counts(f, 1, ids, mask)
    k = np.asarray(f.kinds(1, ids))
    live = np.asarray(mask) > 0
    assert int(inj) == int((live & (k != 0)).sum()) > 0
    assert int(poi) == int((live & (k == 1)).sum()) > 0
    inj0, poi0 = fault_counts(None, 1, ids, mask)
    assert int(inj0) == 0 and int(poi0) == 0


@pytest.mark.parametrize("r", [0, 4])
def test_faulted_round_paths_parity(small_problem, r):
    """One fault model, three engine paths: plain, streamed, and cohort
    rounds corrupt the same clients identically (global-id draws, not
    batch positions), to the same tolerance as the honest parity test —
    and the faults demonstrably changed the round."""
    prob = small_problem
    model = TraceParticipation(TRACE)
    kw = dict(participation=TRACE.max_rate())
    eng = RoundEngine(prob, EngineConfig(**kw), participation_model=model,
                      fault_model=FAULTS)
    eng_ch = RoundEngine(prob, EngineConfig(client_chunk=3, **kw),
                         participation_model=model, fault_model=FAULTS)
    eng_co = RoundEngine(prob, EngineConfig(cohort=6, **kw),
                         participation_model=model, fault_model=FAULTS)
    client_pass, chunk_pass = _passes()
    w = jax.random.normal(jax.random.PRNGKey(1), (prob.d,)) * 0.1
    key = jax.random.PRNGKey(70 + r)
    out = eng.round(w, key, client_pass, round_index=r)
    out_ch = eng_ch.round_streamed(w, key, chunk_pass, round_index=r)
    out_co = eng_co.round_cohort(w, key, chunk_pass, round_index=r)
    np.testing.assert_allclose(np.asarray(out_ch), np.asarray(out),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_co), np.asarray(out),
                               rtol=1e-5, atol=1e-6)
    honest = RoundEngine(prob, EngineConfig(**kw),
                         participation_model=model)
    out_h = honest.round(w, key, client_pass, round_index=r)
    assert (np.asarray(out) != np.asarray(out_h)).any()


def test_zero_rate_fault_model_is_identity(small_problem):
    """Installing an all-zero-rate fault model changes nothing, down to
    the last bit — the no-faults analogue of the Bernoulli pin."""
    prob = small_problem
    client_pass, chunk_pass = _passes()
    w = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(7)
    f0 = DeltaFaults(seed=3)
    eng = RoundEngine(prob, EngineConfig(participation=0.5))
    eng_f = RoundEngine(prob, EngineConfig(participation=0.5),
                        fault_model=f0)
    np.testing.assert_array_equal(
        np.asarray(eng.round(w, key, client_pass, round_index=0)),
        np.asarray(eng_f.round(w, key, client_pass, round_index=0)))
    eng_ch = RoundEngine(prob, EngineConfig(participation=0.5,
                                            client_chunk=3))
    eng_chf = RoundEngine(prob, EngineConfig(participation=0.5,
                                             client_chunk=3),
                          fault_model=f0)
    np.testing.assert_array_equal(
        np.asarray(eng_ch.round_streamed(w, key, chunk_pass,
                                         round_index=0)),
        np.asarray(eng_chf.round_streamed(w, key, chunk_pass,
                                          round_index=0)))


def test_fault_model_requires_round_index(small_problem):
    eng = RoundEngine(small_problem, EngineConfig(participation=0.8),
                      fault_model=FAULTS)
    client_pass, _ = _passes()
    with pytest.raises(ValueError, match="fault"):
        eng.round(jnp.zeros(small_problem.d), jax.random.PRNGKey(0),
                  client_pass)


def test_nan_faults_break_unguarded_round_and_every_guard_recovers(
        small_problem):
    """NaN poisoning propagates through the unguarded weighted sum; each
    aggregator_guard arm ("clip" rejection, trimmed mean, median) yields a
    finite round from the same poisoned deltas, and the streamed clip
    round matches the plain clip round."""
    prob = small_problem
    client_pass, chunk_pass = _passes()
    w = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(11)
    out = RoundEngine(prob, EngineConfig(), fault_model=NAN_FAULTS).round(
        w, key, client_pass, round_index=0)
    assert not bool(jnp.isfinite(out).all())
    guarded = {}
    for g in ("clip", "trimmed_mean", "median"):
        out_g = RoundEngine(prob, EngineConfig(aggregator_guard=g),
                            fault_model=NAN_FAULTS).round(
            w, key, client_pass, round_index=0)
        assert bool(jnp.isfinite(out_g).all()), g
        guarded[g] = np.asarray(out_g)
    out_ch = RoundEngine(prob, EngineConfig(aggregator_guard="clip",
                                            client_chunk=3),
                         fault_model=NAN_FAULTS).round_streamed(
        w, key, chunk_pass, round_index=0)
    np.testing.assert_allclose(np.asarray(out_ch), guarded["clip"],
                               rtol=1e-5, atol=1e-6)


def test_guard_clip_rejects_nonfinite_and_caps_norms(small_problem):
    eng = RoundEngine(small_problem,
                      EngineConfig(aggregator_guard="clip",
                                   guard_clip_norm=0.5))
    d = small_problem.d
    big = jnp.ones((d,))                      # ||big|| = sqrt(d) >> 0.5
    small = jnp.full((d,), 1e-3 / np.sqrt(d))
    deltas = jnp.stack([jnp.full((d,), jnp.nan), big, small])
    safe = np.asarray(eng._guard_clip(deltas))
    np.testing.assert_array_equal(safe[0], np.zeros(d))
    assert np.linalg.norm(safe[1]) == pytest.approx(0.5, rel=1e-5)
    np.testing.assert_allclose(safe[2], np.asarray(small), rtol=1e-6)


def test_guard_config_validation():
    with pytest.raises(ValueError, match="aggregator_guard"):
        EngineConfig(aggregator_guard="mean")
    with pytest.raises(ValueError, match="client_chunk"):
        EngineConfig(aggregator_guard="trimmed_mean", client_chunk=4)
    with pytest.raises(ValueError, match="virtual"):
        EngineConfig(aggregator_guard="median", virtual_data=True)
    with pytest.raises(ValueError, match="sum"):
        EngineConfig(aggregator_guard="median", weighting="sum")
    with pytest.raises(ValueError, match="guard_trim"):
        EngineConfig(aggregator_guard="trimmed_mean", guard_trim=0.5)
    with pytest.raises(ValueError, match="guard_clip_norm"):
        EngineConfig(aggregator_guard="clip", guard_clip_norm=0.0)
    with pytest.raises(ValueError, match="clip"):
        EngineConfig(guard_clip_norm=1.0)
