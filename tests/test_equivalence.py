"""The paper's two equivalence theorems, tested to float tolerance on the
engine-ported implementations.

  * Proposition 1 (§3.5): DANE(η=1, µ=0) with one SVRG epoch as the local
    solver generates the same iterates as naive Federated SVRG (Alg. 3).
  * Theorem 5 (App. A): for ridge regression the Primal Method (Alg. 5) and
    the Dual Method (Alg. 6) are equivalent under w = (1/λn)Xα.

Both sides of each equivalence run on the RoundEngine (the list-based
pre-port implementations are pinned separately in
tests/test_dane_cocoa_engine.py), so these tests also guard the engine's
key schedule: a change to the fold_in chain breaks Prop. 1 immediately.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _x64():
    """f64 for machine-precision equivalence checks — scoped to this module
    so the f32 model tests elsewhere are unaffected."""
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


from repro.core import (DANERidge, DualMethod, PrimalMethod,
                        build_dense_problem, naive_fsvrg_round)
from repro.core.cocoa import dual_to_primal
from repro.core.dane import dane_svrg_round, ridge_grad


@pytest.mark.parametrize("stepsize,m", [(0.05, 10), (0.2, 25)])
def test_proposition_1_dane_svrg_equals_naive_fsvrg(tiny_problem, stepsize, m):
    prob = tiny_problem
    w = jax.random.normal(jax.random.PRNGKey(7), (prob.d,)) * 0.2
    key = jax.random.PRNGKey(11)
    w_alg3 = naive_fsvrg_round(prob, w, key, stepsize=stepsize, m=m)
    w_dane = dane_svrg_round(prob, w, key, stepsize=stepsize, m=m)
    np.testing.assert_allclose(np.asarray(w_alg3), np.asarray(w_dane),
                               rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("sigma", [1.0, 2.0, 4.0])
def test_theorem_5_primal_dual_equivalence(sigma):
    rng = np.random.default_rng(0)
    K, m, d, lam = 4, 12, 8, 0.1
    Xs = [jnp.asarray(rng.standard_normal((d, m))) for _ in range(K)]
    ys = [jnp.asarray(rng.standard_normal(m)) for _ in range(K)]
    alphas0 = [jnp.asarray(rng.standard_normal(m)) for _ in range(K)]

    dense = build_dense_problem(Xs, ys, lam)
    primal = PrimalMethod(dense, sigma=sigma, alphas0=alphas0)
    dual = DualMethod(dense, sigma=sigma, alphas0=alphas0)
    sp, sd = primal.init(), dual.init()
    key = jax.random.PRNGKey(0)
    for _ in range(6):
        sd = dual.round(sd, key)
        sp = primal.round(sp, key)
        np.testing.assert_allclose(np.asarray(sp.w), np.asarray(sd.w),
                                   rtol=1e-9, atol=1e-11)
        # the dual iterate really is (1/λn) X α for the current dual blocks
        alphas = list(sd.aux[0])
        np.testing.assert_allclose(
            np.asarray(sd.w), np.asarray(dual_to_primal(Xs, alphas, lam)),
            rtol=1e-9, atol=1e-11)


def test_dual_method_converges_to_ridge_optimum():
    rng = np.random.default_rng(1)
    K, m, d, lam = 3, 10, 6, 0.2
    Xs = [jnp.asarray(rng.standard_normal((d, m))) for _ in range(K)]
    ys = [jnp.asarray(rng.standard_normal(m)) for _ in range(K)]
    n = K * m
    X = jnp.concatenate(Xs, axis=1)
    y = jnp.concatenate(ys)
    # closed-form ridge optimum of (1/2n)||X^T w - y||^2 + lam/2 ||w||^2
    w_star = jnp.linalg.solve(X @ X.T / n + lam * jnp.eye(d), X @ y / n)

    solver = DualMethod(build_dense_problem(Xs, ys, lam), sigma=float(K))
    state = solver.init()
    key = jax.random.PRNGKey(0)
    for _ in range(200):
        state = solver.round(state, key)
    np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_star),
                               rtol=1e-5, atol=1e-7)


def test_dane_exact_solves_identical_data_in_one_round():
    """Property (D) for DANE (§3.4): identical local datasets, η=1, µ=0 —
    the local subproblem becomes the global one, solved exactly in 1 round."""
    rng = np.random.default_rng(2)
    d, m, lam = 6, 20, 0.1
    X = jnp.asarray(rng.standard_normal((d, m)))
    y = jnp.asarray(rng.standard_normal(m))
    Xs, ys = [X] * 4, [y] * 4
    w0 = jnp.asarray(rng.standard_normal(d))
    solver = DANERidge(build_dense_problem(Xs, ys, lam), eta=1.0, mu=0.0)
    w1 = solver.round(solver.init(w0), jax.random.PRNGKey(0)).w
    gnorm = float(jnp.linalg.norm(ridge_grad(X, y, w1, lam)))
    assert gnorm < 1e-8, gnorm


def test_dane_property_A_fixed_point():
    rng = np.random.default_rng(3)
    d, m, lam = 5, 16, 0.1
    Xs = [jnp.asarray(rng.standard_normal((d, m))) for _ in range(3)]
    ys = [jnp.asarray(rng.standard_normal(m)) for _ in range(3)]
    n = 3 * m
    X = jnp.concatenate(Xs, axis=1)
    y = jnp.concatenate(ys)
    w_star = jnp.linalg.solve(X @ X.T / n + lam * jnp.eye(d), X @ y / n)
    solver = DANERidge(build_dense_problem(Xs, ys, lam), eta=1.0, mu=0.5)
    w1 = solver.round(solver.init(w_star), jax.random.PRNGKey(0)).w
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w_star), rtol=1e-8)
