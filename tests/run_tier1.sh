#!/usr/bin/env sh
# Tier-1 verify entrypoint (see ROADMAP.md).  Extra args pass through to
# pytest, e.g.:  tests/run_tier1.sh -m "not slow"
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
