#!/usr/bin/env sh
# Tier-1 verify entrypoint (see ROADMAP.md).  Extra args pass through to
# pytest, e.g.:  tests/run_tier1.sh -m "not slow"
set -e
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export PYTHONPATH

# Collection guard: a collection error must fail the run loudly on its own —
# the seed suite's hypothesis ImportError masked two real test failures.
python -m pytest --collect-only -q > /dev/null

# Static contracts (before anything executes): fedlint enforces the
# bit-stability / key-discipline / kernel-oracle / round-path / tracer-leak
# rules (FED001-FED005, docs/ARCHITECTURE.md "Static contracts"); exits
# nonzero on any unsuppressed, unbaselined finding.  The JSON report is a
# CI artifact (tier1.yml).  Stdlib-only — no install needed.
python -m repro.analysis src benchmarks tests \
    --json "${FEDLINT_JSON:-fedlint_report.json}"

# Generic lint: ruff (pinned in requirements-dev.txt; ruff.toml).  The
# container image may not ship it — CI installs and runs it; locally the
# step is skipped with a notice rather than failing on a missing tool.
if command -v ruff > /dev/null 2>&1; then
    ruff check src benchmarks tests
else
    echo "run_tier1: ruff not installed; skipping generic lint (CI runs it)" >&2
fi

# Benchmark smoke: the fig2 --algo wiring must run end-to-end (tiny config,
# 2 rounds, truncated OPT) so engine/benchmark plumbing can't rot silently.
# dane covers the registry sweep path; fedavg covers the single-solver
# Trainer driver path (the same make_solver/fit route the examples and the
# README quickstart use; the lax.scan fast path is covered by
# tests/test_trainer.py).
python benchmarks/fig2_convergence.py --algo dane --rounds 2 --scale 0.001 \
    --opt-iters 50 > /dev/null
python benchmarks/fig2_convergence.py --algo fedavg --rounds 2 --scale 0.001 \
    --opt-iters 50 --seed 1 > /dev/null

# Round-latency harness smoke: every timing path (eager dense / compiled /
# compiled fused) must run end-to-end and emit valid JSON, so the perf
# trajectory tooling can't rot.  Writes to a scratch file — the committed
# BENCH_round.json is the measured trajectory, not a smoke artifact.
python benchmarks/bench_round.py --smoke \
    --json "${BENCH_ROUND_JSON:-BENCH_round.smoke.json}" > /dev/null

# Paper-scale client-axis smoke: one budget-guarded K=10,000 streamed-round
# config (2 algorithms, 2 rounds, 1 repeat — ~20 s on a CPU box), so the
# chunked path is exercised at the paper's actual K on every CI run.
python benchmarks/bench_round.py --smoke --paper-k \
    --json "${BENCH_PAPERK_JSON:-BENCH_round.paperk.smoke.json}" > /dev/null

# Cohort-round smoke: budget-guarded K=10,000 partial-participation sweep
# (p=0.1 only, 2 rounds, 1 repeat) timing the cohort-gathered round
# against the masked streamed round, so the gather/scatter path is
# exercised at the paper's K and participation on every CI run.
python benchmarks/bench_round.py --smoke --participation-sweep \
    --json "${BENCH_COHORT_JSON:-BENCH_round.cohort.smoke.json}" > /dev/null

# Virtual-data smoke: budget-guarded K=10,000 virtual rounds (gd + fedavg,
# 2 rounds, 1 repeat) — rows regenerated on demand inside the compiled
# round, with the live-buffer/RSS memory columns — so the bounded-memory
# client-axis path is exercised on every CI run.
python benchmarks/bench_round.py --smoke --virtual \
    --json "${BENCH_VIRTUAL_JSON:-BENCH_round.virtual.smoke.json}" > /dev/null

# Campaign smoke: budget-guarded fleet campaign (2 cells x 3 rounds, tiny
# scale) with a FORCED mid-run crash + resume — exits nonzero unless the
# resumed run's final iterates and event stream are bit-identical to the
# uninterrupted one, so the kill-resume contract is verified on every CI
# run.  Scratch paths only (runs/ is gitignored).
python benchmarks/campaign.py --smoke \
    --out "${CAMPAIGN_SMOKE_DIR:-runs/campaign_smoke}" > /dev/null

# Fault-injection smoke: a NaN-poisoning burst mid-campaign under the
# rollback guard-rail — exits nonzero unless the rail records >= 1
# rollback (quarantining the poisoned round) and the cell still converges
# to a finite objective, so the fault-tolerance path is exercised on
# every CI run.
python benchmarks/campaign.py --fault-smoke \
    --out "${CAMPAIGN_FAULT_SMOKE_DIR:-runs/campaign_fault_smoke}" > /dev/null

exec python -m pytest -x -q "$@"
