"""Chunk-parallel WKV vs the sequential oracle (hillclimb pair 3 change)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.models.rwkv import _wkv_chunked, _wkv_sequential


def _random_inputs(key, B, S, Hn, D, decay_strength=1.0):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (B, S, Hn, D))
    k = jax.random.normal(ks[1], (B, S, Hn, D))
    v = jax.random.normal(ks[2], (B, S, Hn, D))
    # w = exp(-exp(decay)) in (0,1); decay around -6 (the init) ± spread
    decay = -6.0 + decay_strength * jax.random.normal(ks[3], (B, S, Hn, D))
    w = jnp.exp(-jnp.exp(decay))
    u = jax.random.normal(ks[4], (Hn, D)) * 0.1
    s0 = jax.random.normal(ks[5], (B, Hn, D, D)) * 0.1
    return r, k, v, w, u, s0


@settings(deadline=None, max_examples=12)
@given(st.integers(1, 2), st.sampled_from([32, 64, 96]), st.integers(1, 3),
       st.sampled_from([8, 16]), st.integers(0, 2**29))
def test_chunked_matches_sequential(B, S, Hn, D, seed):
    r, k, v, w, u, s0 = _random_inputs(jax.random.PRNGKey(seed), B, S, Hn, D)
    out_c, s_c = _wkv_chunked(r, k, v, w, u, s0, chunk=32)
    out_s, s_s = _wkv_sequential(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s),
                               rtol=2e-4, atol=2e-4)


def test_chunked_strong_decay_stable():
    """Strongly decaying channels stress the c_t normalization."""
    r, k, v, w, u, s0 = _random_inputs(jax.random.PRNGKey(0), 1, 64, 2, 8,
                                       decay_strength=3.0)
    out_c, s_c = _wkv_chunked(r, k, v, w, u, s0, chunk=32)
    out_s, s_s = _wkv_sequential(r, k, v, w, u, s0)
    assert bool(jnp.isfinite(out_c).all())
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                               rtol=5e-3, atol=5e-3)


def test_chunked_gradients_match():
    r, k, v, w, u, s0 = _random_inputs(jax.random.PRNGKey(1), 1, 64, 1, 8)

    def loss_c(r, k, v, w):
        out, _ = _wkv_chunked(r, k, v, w, u, s0, chunk=32)
        return (out ** 2).sum()

    def loss_s(r, k, v, w):
        out, _ = _wkv_sequential(r, k, v, w, u, s0)
        return (out ** 2).sum()

    g_c = jax.grad(loss_c, (0, 1, 2, 3))(r, k, v, w)
    g_s = jax.grad(loss_s, (0, 1, 2, 3))(r, k, v, w)
    for a, b in zip(g_c, g_s):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_decode_uses_sequential_o1_state():
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("rwkv6-3b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 100)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, tok, cache)
    assert bool(jnp.isfinite(logits).all())
