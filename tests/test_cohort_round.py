"""The cohort round path — compute only the sampled clients.

Contracts:

1. Same round key ⇒ same participant set: the cohort engine's Bernoulli
   draw is bit-identical to the masked engine's (the gather consumes no
   randomness), so the two paths see exactly the same cohort.
2. Engine-level cohort-vs-masked parity across the full knob cross —
   weighting × aggregator × client_chunk × cohort capacity — for both
   stateless and dual-state rounds.  The gather hands each sampled client
   the per-client key and weight of its original position, so the update
   matches the masked reference up to summation order (float tolerance).
   Cohort members' dual state matches to tight float tolerance — not
   bit-for-bit, because the overflow lax.cond forces both branches
   through XLA, which may FMA-contract the per-client elementwise chain
   differently from the eager reference's op-by-op dispatch (1-ulp).
3. Non-participants' dual state is frozen — on the cohort path it is never
   touched at all, which must coincide with the masked path's
   jnp.where-freezing bit-for-bit.
4. A draw that overflows the static capacity takes the per-bucket lax.cond
   fallback to the masked pass — results never depend on the capacity.
5. Solver-level parity: every sparse solver config (FSVRG/FedAvg/GD/DANE/
   CoCoA+) plumbs ``cohort`` into its compiled round.
6. ``cohort_capacity`` sizes the static bucket so overflow is a z-sigma
   tail event; at participation=1.0 the knob is a compile-time no-op.
7. Over *virtual* data the gather moves client identities and rows
   regenerate inside the pass: cohort rounds (stateless, dual-state, and
   the forced-overflow fallback) are bit-identical to materialized cohort
   rounds on the same key.
8. A cohort FedAvg round completes at the paper's K = 10,000 and matches
   the masked round (slow-marked).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_paper_k_config
from repro.core import CoCoAConfig, CoCoAPlus, FSVRG, FSVRGConfig, \
    build_problem, cohort_capacity, make_solver
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.fedavg import FedAvg, FedAvgConfig
from repro.data.synthetic import generate


# --------------------------------------------------------------------- #
# keyed synthetic passes (same idiom as test_chunked_round: uniform, not
# normal — erfinv can differ by an ulp across batch shapes, which would
# spoil the exact per-client state comparisons)
# --------------------------------------------------------------------- #


def _keyed_deltas(w, bucket, keys):
    def one(n_k, ck):
        return ((jax.random.uniform(ck, w.shape) - 0.5)
                * (1.0 + 0.1 * n_k.astype(jnp.float32)))
    return jax.vmap(one)(bucket.n_k, keys)


def _passes():
    def client_pass(w, bi, b, kb):
        return _keyed_deltas(w, b, jax.random.split(kb, b.num_clients))

    def chunk_pass(w, bi, cb, keys):
        return _keyed_deltas(w, cb, keys)

    return client_pass, chunk_pass


def _dual_passes():
    def keyed(w, bucket, state_b, keys):
        deltas = _keyed_deltas(w, bucket, keys)
        return deltas, state_b + deltas[:, :3]

    def dual_pass(w, bi, b, s_b, kb):
        return keyed(w, b, s_b, jax.random.split(kb, b.num_clients))

    def dual_chunk_pass(w, bi, cb, s_c, keys):
        return keyed(w, cb, s_c, keys)

    return dual_pass, dual_chunk_pass


# --------------------------------------------------------------------- #
# 1. same key ⇒ same participant set
# --------------------------------------------------------------------- #


def test_cohort_engine_draws_identical_masks(small_problem):
    """The gather must reuse the round's single Bernoulli draw, not
    re-derive one: masks from the cohort and masked engines are
    bit-identical for the same round key."""
    prob = small_problem
    eng_m = RoundEngine(prob, EngineConfig(participation=0.3))
    eng_c = RoundEngine(prob, EngineConfig(participation=0.3, cohort=3))
    for r in range(4):
        key = jax.random.PRNGKey(r)
        for a, b in zip(eng_m.participation_masks(key),
                        eng_c.participation_masks(key)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# 2. engine-level cohort-vs-masked parity
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("cohort", [2, 4, None])  # None -> cohort_capacity
@pytest.mark.parametrize("chunk", [None, 3])
@pytest.mark.parametrize("weighting", ["nk", "uniform", "sum"])
@pytest.mark.parametrize("aggregator", ["dense", "pallas"])
def test_cohort_round_matches_masked_reference(small_problem, cohort, chunk,
                                               weighting, aggregator):
    prob = small_problem
    p = 0.4
    if cohort is None:
        cohort = cohort_capacity(p, max(b.num_clients for b in prob.buckets))
    a_diag = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (prob.d,))) + 0.5
    kw = dict(weighting=weighting, participation=p, server_scaling="diag",
              aggregator=aggregator, client_chunk=chunk)
    eng_ref = RoundEngine(prob, EngineConfig(**kw), a_diag=a_diag)
    eng_coh = RoundEngine(prob, EngineConfig(cohort=cohort, **kw),
                          a_diag=a_diag)
    client_pass, chunk_pass = _passes()
    w = jax.random.normal(jax.random.PRNGKey(1), (prob.d,)) * 0.1
    for r in range(3):   # several keys: small caps hit both cond branches
        key = jax.random.PRNGKey(10 + r)
        out_ref = eng_ref.round(w, key, client_pass)
        out_coh = eng_coh.round_cohort(w, key, chunk_pass)
        np.testing.assert_allclose(np.asarray(out_coh), np.asarray(out_ref),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("cohort", [1, 3])
@pytest.mark.parametrize("chunk", [None, 2])
def test_cohort_round_with_state_matches_reference(small_problem, cohort,
                                                   chunk):
    """Dual-state gather/scatter: the iterate matches to float tolerance;
    per-client state matches the masked path — bitwise for clients outside
    the draw (never touched on either path), tight float tolerance for
    participants (the lax.cond branches are XLA-compiled, which may round
    the per-client elementwise chain one ulp away from eager dispatch)."""
    prob = small_problem
    kw = dict(weighting="sum", participation=0.4, client_chunk=chunk)
    eng_ref = RoundEngine(prob, EngineConfig(**kw))
    eng_coh = RoundEngine(prob, EngineConfig(cohort=cohort, **kw))
    dual_pass, dual_chunk_pass = _dual_passes()
    states = [jnp.arange(b.num_clients * 3, dtype=jnp.float32)
              .reshape(b.num_clients, 3) for b in prob.buckets]
    w = jnp.zeros(prob.d)
    for r in range(3):
        key = jax.random.PRNGKey(20 + r)
        masks = eng_ref.participation_masks(key)
        w_ref, st_ref = eng_ref.round_with_state(w, states, key, dual_pass)
        w_coh, st_coh = eng_coh.round_cohort_with_state(w, states, key,
                                                        dual_chunk_pass)
        np.testing.assert_allclose(np.asarray(w_coh), np.asarray(w_ref),
                                   rtol=1e-5, atol=1e-5)
        for sel, s_c, s_r in zip(masks, st_coh, st_ref):
            out = np.asarray(sel) <= 0
            np.testing.assert_array_equal(np.asarray(s_c)[out],
                                          np.asarray(s_r)[out])
            np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                                       rtol=1e-5, atol=1e-5)


def test_cohort_freezes_nonparticipant_state(small_problem):
    """Clients outside the draw keep their previous state bit-for-bit —
    the scatter never writes their slots."""
    prob = small_problem
    eng = RoundEngine(prob, EngineConfig(weighting="sum", participation=0.3,
                                         cohort=4))
    _, dual_chunk_pass = _dual_passes()
    states = [jnp.ones((b.num_clients, 3)) for b in prob.buckets]
    key = jax.random.PRNGKey(7)
    masks = eng.participation_masks(key)
    _, new_states = eng.round_cohort_with_state(jnp.zeros(prob.d), states,
                                                key, dual_chunk_pass)
    changed_any = False
    for sel, s_old, s_new in zip(masks, states, new_states):
        sel = np.asarray(sel) > 0
        np.testing.assert_array_equal(np.asarray(s_new)[~sel],
                                      np.asarray(s_old)[~sel])
        changed_any |= bool(
            (np.asarray(s_new)[sel] != np.asarray(s_old)[sel]).any())
    assert changed_any  # the draw picked someone and their state moved


def test_cohort_overflow_falls_back_to_masked(small_problem):
    """Capacity 1 at participation 0.9: nearly every draw overflows, so the
    lax.cond fallback carries the round — and still matches the masked
    reference (results must never depend on the capacity)."""
    prob = small_problem
    kw = dict(participation=0.9)
    eng_ref = RoundEngine(prob, EngineConfig(**kw))
    eng_coh = RoundEngine(prob, EngineConfig(cohort=1, **kw))
    client_pass, chunk_pass = _passes()
    w = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(11)
    np.testing.assert_allclose(
        np.asarray(eng_coh.round_cohort(w, key, chunk_pass)),
        np.asarray(eng_ref.round(w, key, client_pass)),
        rtol=1e-5, atol=1e-5)


def test_cohort_compile_dispatch_and_errors(small_problem):
    prob = small_problem
    client_pass, chunk_pass = _passes()
    # round_cohort without the knob
    eng = RoundEngine(prob, EngineConfig(participation=0.5))
    with pytest.raises(ValueError):
        eng.round_cohort(jnp.zeros(prob.d), jax.random.PRNGKey(0), chunk_pass)
    # compile on a cohort engine needs the keyed chunk pass
    eng_c = RoundEngine(prob, EngineConfig(participation=0.5, cohort=3))
    with pytest.raises(ValueError):
        eng_c.compile(client_pass)
    # at participation=1.0 the knob is a static no-op: compiled rounds are
    # bit-identical to the plain engine's
    eng_full = RoundEngine(prob, EngineConfig(cohort=3))
    w = jax.random.normal(jax.random.PRNGKey(4), (prob.d,)) * 0.1
    key = jax.random.PRNGKey(5)
    out_plain = RoundEngine(prob, EngineConfig()).compile(
        client_pass, chunk_pass=chunk_pass)(w, key)
    out_noop = eng_full.compile(client_pass, chunk_pass=chunk_pass)(w, key)
    np.testing.assert_array_equal(np.asarray(out_noop), np.asarray(out_plain))
    # compiled cohort round == eager cohort round (tight float tolerance —
    # the whole-round jit may re-associate the cross-bucket sum)
    out_eager = eng_c.round_cohort(w, key, chunk_pass)
    out_comp = eng_c.compile(client_pass, chunk_pass=chunk_pass)(w, key)
    np.testing.assert_allclose(np.asarray(out_comp), np.asarray(out_eager),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# 5. solver-level parity: cohort plumbs through every sparse config
# --------------------------------------------------------------------- #


def test_fedavg_cohort_matches_masked(small_problem):
    prob = small_problem
    key = jax.random.PRNGKey(0)
    a = FedAvg(prob, FedAvgConfig(stepsize=0.1, participation=0.3))
    b = FedAvg(prob, FedAvgConfig(stepsize=0.1, participation=0.3, cohort=4))
    np.testing.assert_allclose(np.asarray(b.round(b.init(), key).w),
                               np.asarray(a.round(a.init(), key).w),
                               rtol=1e-5, atol=1e-6)


def test_fsvrg_cohort_fused_chunked_matches_masked(small_problem):
    """FSVRG with diag scaling through the fused cohort path, composed with
    client_chunk — 2 rounds, so the cohort iterate feeds the next draw."""
    prob = small_problem
    kw = dict(stepsize=1.0, participation=0.3)
    a = FSVRG(prob, FSVRGConfig(**kw))
    b = FSVRG(prob, FSVRGConfig(aggregator="pallas", client_chunk=3,
                                cohort=4, **kw))
    sa, sb = a.init(), b.init()
    base = jax.random.PRNGKey(1)
    for r in range(2):
        kr = jax.random.fold_in(base, r)
        sa, sb = a.round(sa, kr), b.round(sb, kr)
    np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                               rtol=1e-5, atol=1e-6)


def test_cocoa_cohort_matches_masked(tiny_problem):
    """Dual-state solver end-to-end: iterate to float tolerance, dual
    blocks gathered, updated, scattered back — tight tolerance (the cond
    branches' XLA rounding; see the module docstring), and the blocks must
    stay consistent with the iterate over consecutive rounds."""
    prob = tiny_problem
    a = CoCoAPlus(prob, cfg=CoCoAConfig(participation=0.5))
    b = CoCoAPlus(prob, cfg=CoCoAConfig(participation=0.5, cohort=3))
    key = jax.random.PRNGKey(2)
    sa, sb = a.init(), b.init()
    for r in range(2):
        kr = jax.random.fold_in(key, r)
        sa, sb = a.round(sa, kr), b.round(sb, kr)
    np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                               rtol=1e-5, atol=1e-6)
    for x, y in zip(sa.aux, sb.aux):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6)


def test_registry_plumbs_cohort(small_problem):
    for algo, kw in (("gd", {"participation": 0.5}),
                     ("dane", {"participation": 0.5}),
                     ("dane", {"participation": 0.5, "local_solver": "svrg",
                               "mu": 0.0})):
        a = make_solver(algo, small_problem, **kw)
        b = make_solver(algo, small_problem, cohort=4, **kw)
        key = jax.random.PRNGKey(3)
        sa = a.round(a.init(), key)
        sb = b.round(b.init(), key)
        np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                                   rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# 6. capacity sizing
# --------------------------------------------------------------------- #


def test_cohort_capacity_bounds_and_monotonicity():
    assert cohort_capacity(1.0, 1000) == 1000          # clipped to K
    assert cohort_capacity(0.1, 1) == 1
    c = cohort_capacity(0.1, 10_000)
    assert 1000 < c < 1300, c                          # mean + 6σ headroom
    assert cohort_capacity(0.3, 10_000) > c            # monotone in p
    assert cohort_capacity(0.1, 20_000) > c            # monotone in K
    with pytest.raises(ValueError):
        cohort_capacity(0.0, 100)
    with pytest.raises(ValueError):
        cohort_capacity(0.1, 0)


def test_cohort_capacity_covers_the_draw(small_problem):
    """At the recommended z, realized cohorts fit the capacity for every
    bucket over many rounds (the cond fallback is a tail event)."""
    prob = small_problem
    p = 0.3
    eng = RoundEngine(prob, EngineConfig(participation=p))
    for b in prob.buckets:
        cap = cohort_capacity(p, b.num_clients)
        assert cap <= b.num_clients
    caps = [cohort_capacity(p, b.num_clients) for b in prob.buckets]
    for r in range(50):
        masks = eng.participation_masks(jax.random.PRNGKey(r))
        for cap, m in zip(caps, masks):
            assert int((np.asarray(m) > 0).sum()) <= cap


# --------------------------------------------------------------------- #
# 7. cohort over virtual data: gather identities, regenerate rows
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("cohort,chunk", [(2, None), (4, 2)])
def test_cohort_virtual_matches_materialized_cohort(cohort, chunk):
    """The cohort gather moves VirtualBucket *identities* (client ids, n_k,
    keys, weights); rows regenerate inside the pass — so the virtual cohort
    round must be bit-identical to the materialized cohort round on the
    same key (same draw, same gather, same rows)."""
    from test_virtual_data import _keyed_data_passes, _pair
    _, _, pm, pv = _pair()
    kw = dict(participation=0.4, weighting="nk", client_chunk=chunk)
    eng_m = RoundEngine(pm, EngineConfig(cohort=cohort, **kw))
    eng_v = RoundEngine(pv, EngineConfig(virtual_data=True, cohort=cohort,
                                         **kw))
    _, chunk_pass = _keyed_data_passes(pm.flat.lam)
    w = jax.random.uniform(jax.random.PRNGKey(8), (pm.d,)) * 0.1
    for r in range(2):
        key = jax.random.PRNGKey(30 + r)
        np.testing.assert_array_equal(
            np.asarray(eng_v.round_cohort(w, key, chunk_pass)),
            np.asarray(eng_m.round_cohort(w, key, chunk_pass)))


def test_cohort_virtual_overflow_falls_back_to_masked():
    """Forced capacity overflow on virtual data: capacity 1 at
    participation 0.9 sends (nearly) every bucket down the lax.cond
    fallback, which realizes the *whole* bucket from the virtual layout —
    still bit-equal to the materialized cohort round, and matching the
    masked reference to float tolerance (capacity must never change
    results, virtual or not)."""
    from test_virtual_data import _keyed_data_passes, _pair
    _, _, pm, pv = _pair()
    kw = dict(participation=0.9)
    eng_m = RoundEngine(pm, EngineConfig(cohort=1, **kw))
    eng_v = RoundEngine(pv, EngineConfig(virtual_data=True, cohort=1, **kw))
    eng_ref = RoundEngine(pm, EngineConfig(**kw))
    client_pass, chunk_pass = _keyed_data_passes(pm.flat.lam)
    w = jnp.zeros(pm.d)
    key = jax.random.PRNGKey(12)
    out_v = eng_v.round_cohort(w, key, chunk_pass)
    np.testing.assert_array_equal(
        np.asarray(out_v),
        np.asarray(eng_m.round_cohort(w, key, chunk_pass)))
    np.testing.assert_allclose(
        np.asarray(out_v),
        np.asarray(eng_ref.round(w, key, client_pass)),
        rtol=1e-5, atol=1e-5)


def test_cohort_virtual_dual_state_matches_materialized():
    """Dual state on the virtual cohort path: aux blocks gather/scatter
    materialized while rows regenerate — iterate and every per-client state
    slot bit-equal to the materialized cohort round."""
    from test_virtual_data import _keyed_data_passes, _pair
    _, _, pm, pv = _pair()
    kw = dict(weighting="sum", participation=0.4, cohort=3)
    eng_m = RoundEngine(pm, EngineConfig(**kw))
    eng_v = RoundEngine(pv, EngineConfig(virtual_data=True, **kw))
    _, chunk_pass = _keyed_data_passes(pm.flat.lam)

    def dual_chunk_pass(w, bi, cb, s_c, keys):
        deltas = chunk_pass(w, bi, cb, keys)
        return deltas, s_c + deltas[:, :3]

    states = [jnp.arange(b.num_clients * 3, dtype=jnp.float32)
              .reshape(b.num_clients, 3) for b in pm.buckets]
    key = jax.random.PRNGKey(13)
    w_m, st_m = eng_m.round_cohort_with_state(jnp.zeros(pm.d), states, key,
                                              dual_chunk_pass)
    w_v, st_v = eng_v.round_cohort_with_state(jnp.zeros(pv.d), states, key,
                                              dual_chunk_pass)
    np.testing.assert_array_equal(np.asarray(w_v), np.asarray(w_m))
    for a, b in zip(st_v, st_m):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------- #
# 8. the paper's K = 10,000, cohort-gathered
# --------------------------------------------------------------------- #


@pytest.mark.slow
def test_paper_scale_k10000_fedavg_cohort_round():
    """One FedAvg round at the §4 client count with 10% participation,
    cohort-gathered + streamed: the compiled round runs over ~1,000
    computed clients instead of 10,000 and matches the masked streamed
    round on the same key."""
    cfg = get_paper_k_config()
    ds = generate(cfg, seed=0)
    prob = build_problem(ds, max_bucket_rows=20_000)
    p = 0.1
    cap = cohort_capacity(p, max(b.num_clients for b in prob.buckets))
    masked = make_solver("fedavg", prob, client_chunk=256, participation=p)
    cohort = make_solver("fedavg", prob, client_chunk=256, participation=p,
                         cohort=cap)
    key = jax.random.PRNGKey(0)
    sm = masked.round(masked.init(), key)
    sc = cohort.round(cohort.init(), key)
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))
    f1 = float(prob.flat.loss(sc.w))
    assert np.isfinite(f1) and f1 < f0, (f1, f0)
    np.testing.assert_allclose(np.asarray(sc.w), np.asarray(sm.w),
                               rtol=1e-4, atol=1e-6)
