"""Synthetic data generator: every §1.2 federated characteristic must
actually hold in the generated data (massively distributed, non-IID,
unbalanced, sparse), plus bucketing integrity.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs import get_logreg_config
from repro.core import build_problem
from repro.core.baselines import majority_baseline_error
from repro.data.synthetic import generate


@pytest.fixture(scope="module")
def ds():
    return generate(get_logreg_config().scaled(0.003), seed=1)


def test_unbalanced(ds):
    sizes = ds.client_sizes
    assert sizes.max() >= 3 * sizes.min()


def test_sparse(ds):
    nnz_frac = (ds.val != 0).sum() / (ds.num_examples * ds.num_features)
    assert nnz_frac < 0.2


def test_bias_and_unknown_word_every_example(ds):
    assert (ds.idx[:, 0] == 0).all()
    assert (ds.val[:, 0] == 1).all()
    assert (ds.idx[:, 1] == 1).all()


def test_noniid_feature_clustering(ds):
    """Most features appear on a minority of clients (paper Fig. 1: >88% of
    features on <10% of nodes at full scale; scaled threshold here)."""
    K = ds.num_clients
    d = ds.num_features
    seen = np.zeros((K, d), bool)
    start = 0
    for k, nk in enumerate(ds.client_sizes):
        rows = ds.idx[start : start + nk]
        vals = ds.val[start : start + nk]
        seen[k, rows[vals != 0]] = True
        start += nk
    omega = seen.sum(axis=0)
    covered = omega[omega > 0]
    frac_rare = (covered < 0.5 * K).mean()
    assert frac_rare > 0.5, frac_rare


def test_per_client_majority_beats_chance(ds):
    """Label skew per client: majority-vote beats the global label rate
    (the paper's 17.14% vs 33.16% structure)."""
    err_majority = majority_baseline_error(ds.y, ds.client_of, ds.test_y,
                                           ds.test_client_of)
    global_label = 1.0 if (ds.y > 0).mean() >= 0.5 else -1.0
    err_global_const = float((ds.test_y != global_label).mean())
    assert err_majority < err_global_const


def test_bucketing_preserves_examples(ds):
    prob = build_problem(ds)
    n_bucketed = sum(int(b.n_k.sum()) for b in prob.buckets)
    assert n_bucketed == ds.num_examples
    assert abs(float(prob.client_weights.sum()) - 1.0) < 1e-5
    # padded rows are all-zero valued
    for b in prob.buckets:
        m_pad = b.m_pad
        for j in range(b.num_clients):
            nk = int(b.n_k[j])
            assert (np.asarray(b.val[j, nk:]) == 0).all()


@settings(deadline=None, max_examples=5)
@given(st.integers(0, 100))
def test_generation_deterministic(seed):
    cfg = get_logreg_config().scaled(0.0008)
    a = generate(cfg, seed=seed)
    b = generate(cfg, seed=seed)
    assert (a.idx == b.idx).all() and (a.y == b.y).all()
    assert (a.client_sizes == b.client_sizes).all()


def test_train_test_split_per_client(ds):
    # ~75/25 per client
    total = ds.client_sizes.sum() + len(ds.test_y)
    frac = ds.client_sizes.sum() / total
    assert 0.6 < frac < 0.9
