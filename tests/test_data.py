"""Synthetic data generator: every §1.2 federated characteristic must
actually hold in the generated data (massively distributed, non-IID,
unbalanced, sparse), plus bucketing integrity, the Σ n_k pin of the
size-renormalization fix, and the error-rate tie-break regression.

``hypothesis`` is an *optional* dev dep (requirements-dev.txt): only the
fuzzed determinism test needs it, so it alone degrades to a fixed-seed
parametrization instead of skipping the whole module.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

from repro.configs import get_logreg_config
from repro.configs.gplus_logreg import LogRegConfig
from repro.core import build_problem
from repro.core.baselines import majority_baseline_error
from repro.data.synthetic import (_power_law_sizes, generate,
                                  train_split_sizes)


DS_SCALE, DS_SEED = 0.003, 1


@pytest.fixture(scope="module")
def ds():
    return generate(get_logreg_config().scaled(DS_SCALE), seed=DS_SEED)


def test_unbalanced(ds):
    sizes = ds.client_sizes
    assert sizes.max() >= 3 * sizes.min()


def test_sparse(ds):
    nnz_frac = (ds.val != 0).sum() / (ds.num_examples * ds.num_features)
    assert nnz_frac < 0.2


def test_bias_and_unknown_word_every_example(ds):
    assert (ds.idx[:, 0] == 0).all()
    assert (ds.val[:, 0] == 1).all()
    assert (ds.idx[:, 1] == 1).all()


def test_noniid_feature_clustering():
    """Most features appear on a minority of clients (paper Fig. 1: >88% of
    features on <10% of nodes at full scale; scaled threshold here).  The
    statistic sharpens with scale — at 0.003 the shrunken feature space is
    almost fully shared — so this test generates its own 0.005 dataset."""
    ds = generate(get_logreg_config().scaled(0.005), seed=0)
    K = ds.num_clients
    d = ds.num_features
    seen = np.zeros((K, d), bool)
    start = 0
    for k, nk in enumerate(ds.client_sizes):
        rows = ds.idx[start : start + nk]
        vals = ds.val[start : start + nk]
        seen[k, rows[vals != 0]] = True
        start += nk
    omega = seen.sum(axis=0)
    covered = omega[omega > 0]
    frac_rare = (covered < 0.5 * K).mean()
    assert frac_rare > 0.5, frac_rare


def test_per_client_majority_beats_chance(ds):
    """Label skew per client: majority-vote beats the global label rate
    (the paper's 17.14% vs 33.16% structure)."""
    err_majority = majority_baseline_error(ds.y, ds.client_of, ds.test_y,
                                           ds.test_client_of)
    global_label = 1.0 if (ds.y > 0).mean() >= 0.5 else -1.0
    err_global_const = float((ds.test_y != global_label).mean())
    assert err_majority < err_global_const


def test_bucketing_preserves_examples(ds):
    prob = build_problem(ds)
    n_bucketed = sum(int(b.n_k.sum()) for b in prob.buckets)
    assert n_bucketed == ds.num_examples
    assert abs(float(prob.client_weights.sum()) - 1.0) < 1e-5
    # padded rows are all-zero valued
    for b in prob.buckets:
        m_pad = b.m_pad
        for j in range(b.num_clients):
            nk = int(b.n_k[j])
            assert (np.asarray(b.val[j, nk:]) == 0).all()


def _check_generation_deterministic(seed, K=16, d=40, nnz=5, n_span=(2, 8)):
    """Same (cfg, seed) twice -> the same dataset, bit for bit — across the
    config axes, not just the PRNG seed.  K/d stay on small values (the
    generator pads rows/params to fixed blocks, so compiles are shared)."""
    cfg = LogRegConfig(num_clients=K, num_features=d,
                       num_examples=4 * K, nnz_per_example=nnz,
                       min_client_examples=n_span[0],
                       max_client_examples=n_span[1])
    a = generate(cfg, seed=seed)
    b = generate(cfg, seed=seed)
    assert (a.idx == b.idx).all() and (a.y == b.y).all()
    assert (a.val == b.val).all()
    assert (a.test_idx == b.test_idx).all() and (a.test_y == b.test_y).all()
    assert (a.client_sizes == b.client_sizes).all()


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=10, derandomize=True)
    @given(seed=st.integers(0, 2**16),
           K=st.sampled_from([8, 16]),
           d=st.sampled_from([40, 57]),
           nnz=st.sampled_from([3, 5]),
           n_span=st.sampled_from([(1, 6), (2, 8)]))
    def test_generation_deterministic(seed, K, d, nnz, n_span):
        _check_generation_deterministic(seed, K, d, nnz, n_span)
else:
    @pytest.mark.parametrize("seed,K,d,nnz,n_span", [
        (0, 16, 40, 5, (2, 8)),
        (31, 8, 57, 3, (1, 6)),
        (100, 16, 57, 5, (1, 6)),
        (2**15, 8, 40, 3, (2, 8)),
    ])
    def test_generation_deterministic(seed, K, d, nnz, n_span):
        _check_generation_deterministic(seed, K, d, nnz, n_span)


def test_train_test_split_per_client(ds):
    # ~75/25 per client
    total = ds.client_sizes.sum() + len(ds.test_y)
    frac = ds.client_sizes.sum() / total
    assert 0.6 < frac < 0.9


# --------------------------------------------------------------------- #
# size renormalization: Σ n_k must track the configured total
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("K,n_total,n_min,n_max", [
    (10_000, 2_166_693, 75, 9_000),      # the paper's §4 statistics, exact
    (137, 30_000, 75, 9_000),
    (50, 11_000, 10, 400),
])
def test_power_law_sizes_hit_configured_total(K, n_total, n_min, n_max):
    """Pre-fix, the clip after normalization silently dropped the tail's
    mass and Σ n_k drifted far under the configured total; renormalizing
    (largest-remainder style) pins it within 1% — here, exactly."""
    sizes = _power_law_sizes(np.random.default_rng(0), K, n_total,
                             n_min, n_max)
    assert sizes.min() >= n_min and sizes.max() <= n_max
    assert abs(int(sizes.sum()) - n_total) <= 0.01 * n_total


def test_power_law_sizes_saturate_infeasible_totals():
    """Totals outside [K·n_min, K·n_max] pin to the nearest feasible sum."""
    rng = np.random.default_rng(1)
    assert (_power_law_sizes(rng, 10, 10_000, 2, 90) == 90).all()
    assert (_power_law_sizes(rng, 10, 5, 2, 90) == 2).all()


def test_generated_total_tracks_config(ds):
    """End-to-end: train + test example counts realize cfg.num_examples
    within 1% (the generator's Σ n_k pin through the 75/25 split)."""
    cfg = get_logreg_config().scaled(DS_SCALE)
    total = int(ds.client_sizes.sum()) + len(ds.test_y)
    assert abs(total - cfg.num_examples) <= 0.01 * cfg.num_examples


# --------------------------------------------------------------------- #
# error-rate tie-break regression
# --------------------------------------------------------------------- #


def test_error_rate_zero_margin_predicts_plus_one(ds):
    """An all-zero iterate gives every example a zero margin; the old
    jnp.sign-based error rate counted those as wrong for BOTH classes
    (sign(0) == 0 matches neither label -> error 1.0).  Ties now break
    deterministically to +1, so the error is exactly the −1 label mass."""
    prob = build_problem(ds)
    err = float(prob.flat.error_rate(jnp.zeros(prob.d)))
    expect = float((np.asarray(prob.flat.y) == -1).mean())
    assert abs(err - expect) < 1e-6
    assert err < 1.0  # the old behavior


# --------------------------------------------------------------------- #
# the 75/25 split never starves a splittable client of test examples
# --------------------------------------------------------------------- #


def test_split_gives_every_multi_example_client_a_test_example():
    """Every client with n_k >= 2 keeps >= 1 train AND >= 1 test example;
    an n_k == 1 client puts its only example in train (documented).  The
    old max(1, floor(0.75 n_k)) consumed n_k == 1 clients whole — and this
    guard must hold at the generator's minimum (n_min as low as 1)."""
    import dataclasses
    cfg = dataclasses.replace(get_logreg_config().scaled(0.002),
                              min_client_examples=1)
    ds = generate(cfg, seed=5)
    tr = np.bincount(ds.client_of, minlength=ds.num_clients)
    te = np.bincount(ds.test_client_of, minlength=ds.num_clients)
    total = tr + te
    assert (tr >= 1).all()
    assert (te[total >= 2] >= 1).all(), "zero-test client with n_k >= 2"
    assert (te[total == 1] == 0).all() and (tr[total == 1] == 1).all()


def test_train_split_sizes_rule():
    """The shared split helper element-by-element against the documented
    rule: train = max(1, floor(0.75 n)) capped at n − 1 for n >= 2, and a
    lone example goes to train.  Both generate() and the virtual layout
    route through this one function, so this is the single place the
    train/test boundary can regress."""
    n = np.arange(1, 101)
    tr = train_split_sizes(n)
    expect = np.minimum(np.maximum(1, (0.75 * n).astype(np.int64)),
                        np.maximum(n - 1, 1))
    np.testing.assert_array_equal(tr, expect)
    assert tr[0] == 1                      # n=1: train keeps the example
    assert (tr[1:] <= n[1:] - 1).all()     # n>=2: never starves test
    assert (tr >= 1).all()
    assert tr.dtype == np.int64


def test_generate_client_sizes_follow_split_rule(ds):
    """End-to-end pin: the dataset's per-client train sizes ARE
    train_split_sizes of the full per-client counts."""
    tr = np.bincount(ds.client_of, minlength=ds.num_clients)
    te = np.bincount(ds.test_client_of, minlength=ds.num_clients)
    np.testing.assert_array_equal(ds.client_sizes, train_split_sizes(tr + te))
