"""FedAvg subsystem: reduction to sequential SGD in the single-client case
(vs a plain-numpy reference), objective decrease on the unbalanced synthetic
problem, and jnp-vs-Pallas-kernel local-step parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedAvg, FedAvgConfig, build_problem
from repro.core.baselines import fedavg_round


def _single_client_problem(n=24, d=11, nnz=4, lam=0.05, seed=0):
    from repro.data.synthetic import FederatedDataset

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, d, size=(n, nnz)).astype(np.int32)
    val = rng.standard_normal((n, nnz)).astype(np.float32)
    y = np.where(rng.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    ds = FederatedDataset(
        idx=idx, val=val, y=y,
        client_of=np.zeros(n, np.int32),
        client_sizes=np.array([n], np.int32), num_features=d,
        test_idx=idx[:1], test_val=val[:1], test_y=y[:1],
        test_client_of=np.zeros(1, np.int32))
    return ds, build_problem(ds, lam=lam)


def test_single_client_one_epoch_is_sequential_sgd():
    """K=1, E=1 FedAvg == plain sequential SGD over the round's permutation,
    against a ~10-line numpy reference, to <=1e-5 in f32."""
    lam, h = 0.05, 0.2
    ds, prob = _single_client_problem(lam=lam)
    n = ds.num_examples
    key = jax.random.PRNGKey(7)

    solver = FedAvg(prob, FedAvgConfig(stepsize=h, local_epochs=1))
    w_fed = solver.round(solver.init(), key).w

    # reproduce the engine's key chain to recover the visit order
    kb = jax.random.fold_in(key, 0)                       # bucket key (wi=0)
    ck = jax.random.split(kb, 1)[0]                       # client key
    ek = jax.random.split(ck, 1)[0]                       # epoch key
    perm = np.asarray(jax.random.permutation(ek, n))

    # numpy reference: sequential SGD on the regularized logreg objective
    w = np.zeros(prob.d, np.float64)
    for i in perm:
        z = (ds.val[i].astype(np.float64) * w[ds.idx[i]]).sum()
        g_sc = -ds.y[i] / (1.0 + np.exp(ds.y[i] * z))
        g = np.zeros(prob.d, np.float64)
        np.add.at(g, ds.idx[i], g_sc * ds.val[i])
        w = (1.0 - h * lam) * w - h * g

    np.testing.assert_allclose(np.asarray(w_fed), w, rtol=1e-5, atol=1e-5)


def test_objective_decreases_on_unbalanced_clients(small_problem):
    """K>1 unbalanced clients: each of 10 FedAvg rounds strictly decreases
    the regularized objective on the synthetic federated problem."""
    prob = small_problem
    sizes = np.concatenate([np.asarray(b.n_k) for b in prob.buckets])
    assert sizes.max() > 2 * sizes.min()      # the data really is unbalanced

    solver = FedAvg(prob, FedAvgConfig(stepsize=0.05, local_epochs=1))
    state = solver.init()
    f_prev = float(prob.flat.loss(state.w))
    key = jax.random.PRNGKey(0)
    for r in range(10):
        state = solver.round(state, jax.random.fold_in(key, r))
        f = float(prob.flat.loss(state.w))
        assert f < f_prev, (r, f_prev, f)
        f_prev = f


def test_kernel_path_matches_jnp_path(tiny_problem):
    """use_kernel=True (fused Pallas fedavg_update, interpret on CPU) and the
    inline jnp expression produce the same round."""
    prob = tiny_problem
    w0 = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(5)
    s_j = FedAvg(prob, FedAvgConfig(stepsize=0.1, local_epochs=2,
                                    use_kernel=False))
    s_k = FedAvg(prob, FedAvgConfig(stepsize=0.1, local_epochs=2,
                                    use_kernel=True))
    w_j = s_j.round(s_j.init(w0), key).w
    w_k = s_k.round(s_k.init(w0), key).w
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_j),
                               rtol=1e-6, atol=1e-6)


def test_partial_participation_round_runs(small_problem):
    prob = small_problem
    solver = FedAvg(prob, FedAvgConfig(stepsize=0.05, local_epochs=1,
                                       participation=0.5))
    state = solver.init()
    f0 = float(prob.flat.loss(state.w))
    key = jax.random.PRNGKey(1)
    for r in range(4):
        state = solver.round(state, jax.random.fold_in(key, r))
    assert float(prob.flat.loss(state.w)) < f0


def test_legacy_wrapper_delegates(tiny_problem):
    """baselines.fedavg_round keeps its original signature and key schedule."""
    prob = tiny_problem
    w0 = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(2)
    w1 = fedavg_round(prob, w0, key, stepsize=0.1, epochs=2)
    solver = FedAvg(prob, FedAvgConfig(stepsize=0.1, local_epochs=2))
    w2 = solver.round(solver.init(w0), key).w
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
