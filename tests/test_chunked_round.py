"""The streamed (client_chunk) round path — the paper-scale client axis.

Contracts:

1. The chunk-accumulating ``fused_aggregate`` entries (``fused_accumulate``
   + ``fused_epilogue``) compose to exactly the one-shot kernel's oracle.
2. Engine-level chunked-vs-unchunked parity across the full knob cross —
   weighting × participation × aggregator × client_chunk ∈ {1, 3, K} — on
   the ragged real bucket layout, for both stateless and dual-state rounds
   (including the frozen-state masking).  Chunked rounds consume the same
   per-client keys as the reference (the split is hoisted into
   ``RoundEngine.client_keys``), so they agree to float tolerance — the
   only difference is summation order.
3. Solver-level parity: a solver built with ``client_chunk`` dispatches the
   streamed compiled round and matches the unchunked build.
4. ``build_problem(max_bucket_rows=...)`` splits oversized buckets without
   changing any client's data, order, or weight.
5. A full FedAvg round completes at the paper's K = 10,000 (slow-marked).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_paper_k_config
from repro.core import CoCoAConfig, CoCoAPlus, FSVRG, FSVRGConfig, \
    build_problem, make_solver
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.fedavg import FedAvg, FedAvgConfig
from repro.data.synthetic import generate
from repro.kernels import ops, ref


# --------------------------------------------------------------------- #
# 1. the chunk-accumulating kernel entries
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("K,d", [(5, 1000), (1, 999), (13, 257)])
def test_fused_accumulate_matches_oracle(K, d):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    acc = jax.random.normal(ks[0], (d,))
    deltas = jax.random.normal(ks[1], (K, d))
    wts = jax.nn.softmax(jax.random.normal(ks[2], (K,)))
    out = ops.fused_accumulate(acc, deltas, wts)
    expect = ref.fused_accumulate_ref(acc, deltas, wts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_fused_epilogue_matches_oracle():
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    w = jax.random.normal(ks[0], (777,))
    acc = jax.random.normal(ks[1], (777,))
    a = jnp.abs(jax.random.normal(ks[2], (777,))) + 0.5
    out = ops.fused_epilogue(w, acc, a, 1.7)
    expect = ref.fused_epilogue_ref(w, acc, a, 1.7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_chunked_accumulation_composes_to_one_shot_kernel():
    """Folding the delta stack through fused_accumulate chunk-by-chunk and
    closing with fused_epilogue == the one-shot fused_aggregate oracle —
    the init/acc/epilogue split really is a refactor of the same kernel."""
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    K, d, chunk = 12, 515, 5
    wt = jax.random.normal(ks[0], (d,))
    deltas = jax.random.normal(ks[1], (K, d))
    wts = jax.nn.softmax(jax.random.normal(ks[2], (K,)))
    a = jnp.abs(jax.random.normal(ks[3], (d,))) + 0.5
    acc = jnp.zeros((d,))
    for c0 in range(0, K, chunk):
        acc = ops.fused_accumulate(acc, deltas[c0:c0 + chunk],
                                   wts[c0:c0 + chunk])
    out = ops.fused_epilogue(wt, acc, a, 1.3)
    expect = ref.fused_aggregate_ref(wt, deltas, wts, a, 1.3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- #
# 2. engine-level chunked-vs-unchunked parity
# --------------------------------------------------------------------- #


def _keyed_deltas(w, bucket, keys):
    """A synthetic per-client-keyed pass: each client's delta is a function
    of its own key and n_k only, so chunked and unchunked runs must agree
    up to summation order.  Uses ``uniform`` (pure bit manipulation) rather
    than ``normal`` — erfinv can differ by an ulp across batch shapes, which
    would spoil the exact per-client state comparison."""
    def one(n_k, ck):
        return ((jax.random.uniform(ck, w.shape) - 0.5)
                * (1.0 + 0.1 * n_k.astype(jnp.float32)))
    return jax.vmap(one)(bucket.n_k, keys)


def _passes():
    def client_pass(w, bi, b, kb):
        return _keyed_deltas(w, b, jax.random.split(kb, b.num_clients))

    def chunk_pass(w, bi, cb, keys):
        return _keyed_deltas(w, cb, keys)

    return client_pass, chunk_pass


@pytest.mark.parametrize("chunk", [1, 3, None])  # None -> K (>= every Kb)
@pytest.mark.parametrize("weighting", ["nk", "uniform", "sum"])
@pytest.mark.parametrize("participation", [1.0, 0.5])
@pytest.mark.parametrize("aggregator", ["dense", "pallas"])
def test_streamed_round_matches_reference(small_problem, chunk, weighting,
                                          participation, aggregator):
    prob = small_problem
    chunk = prob.num_clients if chunk is None else chunk
    a_diag = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (prob.d,))) + 0.5
    kw = dict(weighting=weighting, participation=participation,
              server_scaling="diag", aggregator=aggregator)
    eng_ref = RoundEngine(prob, EngineConfig(**kw), a_diag=a_diag)
    eng_chk = RoundEngine(prob, EngineConfig(client_chunk=chunk, **kw),
                          a_diag=a_diag)
    client_pass, chunk_pass = _passes()
    w = jax.random.normal(jax.random.PRNGKey(1), (prob.d,)) * 0.1
    key = jax.random.PRNGKey(3)
    out_ref = eng_ref.round(w, key, client_pass)
    out_chk = eng_chk.round_streamed(w, key, chunk_pass)
    np.testing.assert_allclose(np.asarray(out_chk), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunk", [1, 3])
@pytest.mark.parametrize("participation", [1.0, 0.5])
def test_streamed_round_with_state_matches_reference(small_problem, chunk,
                                                     participation):
    """Dual-state streaming: deltas, state threading, and the frozen-state
    masking under the round's single Bernoulli draw all match the unchunked
    reference (state updates are per-client, so they match exactly)."""
    prob = small_problem
    kw = dict(weighting="sum", participation=participation)
    eng_ref = RoundEngine(prob, EngineConfig(**kw))
    eng_chk = RoundEngine(prob, EngineConfig(client_chunk=chunk, **kw))

    def keyed(w, bucket, state_b, keys):
        deltas = _keyed_deltas(w, bucket, keys)
        return deltas, state_b + deltas[:, :3]

    def dual_pass(w, bi, b, s_b, kb):
        return keyed(w, b, s_b, jax.random.split(kb, b.num_clients))

    def dual_chunk_pass(w, bi, cb, s_c, keys):
        return keyed(w, cb, s_c, keys)

    states = [jnp.zeros((b.num_clients, 3)) for b in prob.buckets]
    w = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(5)
    w_ref, st_ref = eng_ref.round_with_state(w, states, key, dual_pass)
    w_chk, st_chk = eng_chk.round_streamed_with_state(w, states, key,
                                                      dual_chunk_pass)
    np.testing.assert_allclose(np.asarray(w_chk), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)
    for s_c, s_r in zip(st_chk, st_ref):
        np.testing.assert_array_equal(np.asarray(s_c), np.asarray(s_r))


def test_engine_config_rejects_bool_counts():
    """isinstance(True, int) is true in Python, so client_chunk=True used to
    slip through the positive-int guard and silently mean chunk size 1; the
    same hole would have applied to cohort=True."""
    with pytest.raises(ValueError):
        EngineConfig(client_chunk=True)
    with pytest.raises(ValueError):
        EngineConfig(cohort=True)
    with pytest.raises(ValueError):
        EngineConfig(cohort=0)
    with pytest.raises(ValueError):
        EngineConfig(client_chunk=False)
    cfg = EngineConfig(client_chunk=1, cohort=1)  # real ints still pass
    assert cfg.client_chunk == 1 and cfg.cohort == 1


def test_streamed_round_requires_chunk_and_pass(small_problem):
    with pytest.raises(ValueError):
        EngineConfig(client_chunk=0)
    eng = RoundEngine(small_problem, EngineConfig())
    with pytest.raises(ValueError):
        eng.round_streamed(jnp.zeros(small_problem.d), jax.random.PRNGKey(0),
                           lambda w, bi, cb, ks: None)
    eng_chk = RoundEngine(small_problem, EngineConfig(client_chunk=2))
    with pytest.raises(ValueError):
        eng_chk.compile(lambda w, bi, b, kb: None)  # no chunk_pass supplied


# --------------------------------------------------------------------- #
# 3. solver-level parity: client_chunk plumbs through the compiled round
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("chunk", [1, 3, None])
@pytest.mark.parametrize("participation", [1.0, 0.5])
def test_fedavg_chunked_matches_unchunked(small_problem, chunk,
                                          participation):
    prob = small_problem
    chunk = prob.num_clients if chunk is None else chunk
    key = jax.random.PRNGKey(0)
    a = FedAvg(prob, FedAvgConfig(stepsize=0.1, participation=participation))
    b = FedAvg(prob, FedAvgConfig(stepsize=0.1, participation=participation,
                                  client_chunk=chunk))
    sa = a.round(a.init(), key)
    sb = b.round(b.init(), key)
    np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                               rtol=1e-5, atol=1e-6)


def test_fsvrg_chunked_fused_matches_unchunked(small_problem):
    """FSVRG with diag server scaling through the chunked *fused* path
    (fused_accumulate per chunk + fused_epilogue) == the dense unchunked
    build — over 2 rounds, so the streamed iterate feeds the next round."""
    prob = small_problem
    a = FSVRG(prob, FSVRGConfig(stepsize=1.0))
    b = FSVRG(prob, FSVRGConfig(stepsize=1.0, client_chunk=4,
                                aggregator="pallas"))
    sa, sb = a.init(), b.init()
    base = jax.random.PRNGKey(1)
    for r in range(2):
        kr = jax.random.fold_in(base, r)
        sa, sb = a.round(sa, kr), b.round(sb, kr)
    np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("participation", [1.0, 0.5])
def test_cocoa_chunked_matches_unchunked(tiny_problem, participation):
    """Dual-state solver: iterate AND dual blocks agree (blocks exactly —
    per-client state never crosses the chunked reduction)."""
    prob = tiny_problem
    a = CoCoAPlus(prob, cfg=CoCoAConfig(participation=participation))
    b = CoCoAPlus(prob, cfg=CoCoAConfig(participation=participation,
                                        client_chunk=3))
    key = jax.random.PRNGKey(2)
    sa = a.round(a.init(), key)
    sb = b.round(b.init(), key)
    np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                               rtol=1e-5, atol=1e-7)
    for x, y in zip(sa.aux, sb.aux):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_registry_plumbs_client_chunk(small_problem):
    for algo, kw in (("gd", {}), ("dane", {}),
                     ("dane", {"local_solver": "svrg", "mu": 0.0})):
        a = make_solver(algo, small_problem, **kw)
        b = make_solver(algo, small_problem, client_chunk=5, **kw)
        key = jax.random.PRNGKey(3)
        sa = a.round(a.init(), key)
        sb = b.round(b.init(), key)
        np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                                   rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# 4. max_bucket_rows grouping equivalence
# --------------------------------------------------------------------- #


def _client_rows(prob):
    """Per-client (n_k, idx, val, y) in bucket-concatenated order."""
    out = []
    for b in prob.buckets:
        for j in range(b.num_clients):
            nk = int(b.n_k[j])
            out.append((nk, np.asarray(b.idx[j, :nk]),
                        np.asarray(b.val[j, :nk]), np.asarray(b.y[j, :nk])))
    return out


def test_max_bucket_rows_preserves_clients(small_dataset):
    ds = small_dataset
    base = build_problem(ds)
    cap = 6 * int(ds.client_sizes.max())     # force several splits
    capped = build_problem(ds, max_bucket_rows=cap)
    assert len(capped.buckets) > len(base.buckets)
    for b in capped.buckets:
        assert b.num_clients == 1 or b.num_clients * b.m_pad <= cap
    rows_base, rows_capped = _client_rows(base), _client_rows(capped)
    assert len(rows_base) == len(rows_capped) == ds.num_clients
    for (n0, i0, v0, y0), (n1, i1, v1, y1) in zip(rows_base, rows_capped):
        assert n0 == n1
        np.testing.assert_array_equal(i0, i1)
        np.testing.assert_array_equal(v0, v1)
        np.testing.assert_array_equal(y0, y1)
    np.testing.assert_array_equal(np.asarray(base.client_weights),
                                  np.asarray(capped.client_weights))


def test_max_bucket_rows_none_is_identity(small_dataset):
    base = build_problem(small_dataset)
    same = build_problem(small_dataset, max_bucket_rows=None)
    assert len(base.buckets) == len(same.buckets)
    for a, b in zip(base.buckets, same.buckets):
        np.testing.assert_array_equal(np.asarray(a.n_k), np.asarray(b.n_k))


# --------------------------------------------------------------------- #
# 5. the paper's K = 10,000
# --------------------------------------------------------------------- #


@pytest.mark.slow
def test_paper_scale_k10000_fedavg_round():
    """One full FedAvg round at the §4 client count, streamed: K = 10,000,
    bounded per-bucket host memory, O(client_chunk·d) peak delta memory —
    and the round makes progress."""
    cfg = get_paper_k_config()
    assert cfg.num_clients == 10_000
    ds = generate(cfg, seed=0)
    assert ds.num_clients == 10_000
    prob = build_problem(ds, max_bucket_rows=20_000)
    assert all(b.num_clients == 1 or b.num_clients * b.m_pad <= 20_000
               for b in prob.buckets)
    solver = make_solver("fedavg", prob, client_chunk=256)
    state = solver.init()
    f0 = float(prob.flat.loss(state.w))
    state = solver.round(state, jax.random.PRNGKey(0))
    f1 = float(prob.flat.loss(state.w))
    assert np.isfinite(f1) and f1 < f0, (f1, f0)
