"""Sharding rule engine tests + a real (1x1-mesh) sharded train step, and
hypothesis checks that every rule respects divisibility.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.sharding import rules


class FakeMesh:
    """Duck-typed mesh for rule testing without touching jax device state."""

    def __init__(self, shape_map):
        self.axis_names = tuple(shape_map)
        self.shape = dict(shape_map)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_attention_rules():
    spec = rules.spec_for_param("layers/pos0/attn/wq", (32, 4096, 4096), MESH)
    assert spec == P(None, "data", "model")
    spec = rules.spec_for_param("layers/pos0/attn/wo", (32, 4096, 4096), MESH)
    assert spec == P(None, "model", "data")


def test_divisibility_guard():
    # seamless vocab 256206 is not 16-divisible -> replicated rows
    spec = rules.spec_for_param("embed", (256206, 1024), MESH)
    assert spec == P(None, None)
    spec = rules.spec_for_param("embed", (128256, 4096), MESH)
    assert spec == P("model", None)


def test_moe_expert_parallel():
    spec = rules.spec_for_param("layers/pos0/moe/w_gate", (32, 16, 4096, 6400), MESH)
    assert spec == P(None, "model", "data", None)


def test_norms_replicated():
    spec = rules.spec_for_param("layers/pos0/norm1", (32, 4096), MESH)
    assert spec == P()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_every_param_gets_valid_spec(arch):
    """Every full-config parameter receives a spec whose sharded dims divide."""
    from repro.models import build_model

    cfg = get_config(arch)
    model = build_model(cfg, jnp.bfloat16)
    p_specs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(p_specs)
    n_sharded = 0
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        spec = rules.spec_for_param(path, leaf.shape, MESH)
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if entry is not None:
                axes = entry if isinstance(entry, tuple) else (entry,)
                size = int(np.prod([MESH.shape[a] for a in axes]))
                assert dim % size == 0, (arch, path, leaf.shape, spec)
                n_sharded += 1
    assert n_sharded > 0, f"{arch}: no parameter sharded at all"


def test_batch_spec_client_axis():
    spec = rules.batch_spec((4, 1, 64, 4096), MESH, client_axis=True)
    assert spec == P(None, None, ("data",), None)
    spec = rules.batch_spec((256, 4096), MESH)
    assert spec == P(("data",), None)
    spec = rules.batch_spec((256, 4096), MESH_POD)
    assert spec == P(("pod", "data"), None)


def test_cache_spec_kv_sequence_parallel():
    spec = rules.cache_spec("pos0/k", (32, 128, 32768, 8, 128), MESH)
    assert spec == P(None, ("data",), "model", None, None)
    # long_500k B=1: everything shards the sequence
    spec = rules.cache_spec("pos0/k", (4, 1, 524288, 8, 128), MESH)
    assert spec == P(None, None, ("data", "model"), None, None)


def test_cache_spec_recurrent_state():
    spec = rules.cache_spec("pos0/ssm", (4, 128, 8192, 16), MESH)
    assert spec == P(None, ("data",), "model", None)


def test_sharded_train_step_runs_on_host_mesh():
    """End-to-end: reduced arch + rule-derived shardings on a 1x1 mesh."""
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model, make_batch
    from repro.configs.base import InputShape
    from repro.core.neural import FedNeuralConfig, make_fsvrg_round, make_client_batches

    mesh = make_host_mesh()
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, InputShape("t", 32, 4, "train"), dtype=jnp.float32)
    cb = make_client_batches(batch, num_clients=2, local_steps=1)

    # jax.set_mesh only exists in newer jax; on 0.4.x the Mesh itself is the
    # context manager (shardings below are explicit, the context is belt&braces)
    mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with mesh_ctx:
        in_sh = (rules.params_shardings(params, mesh),
                 rules.batch_shardings(cb, mesh, client_axis=True))
        step = jax.jit(make_fsvrg_round(model, FedNeuralConfig(stepsize=0.3)),
                       in_shardings=in_sh)
        new_params, metrics = step(params, cb)
    l0 = model.loss(params, batch)[0]
    l1 = model.loss(new_params, batch)[0]
    assert float(l1) < float(l0)
    assert bool(jnp.isfinite(metrics["full_grad_norm"]))
