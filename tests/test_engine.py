"""RoundEngine: the unified round loop must reproduce the pre-refactor
hand-rolled FSVRG loop bit-for-bit, partial-participation reweighting must
keep the aggregated update unbiased, and the pluggable aggregation paths
(dense jnp vs Pallas scaled_aggregate) must agree."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FSVRG, FSVRGConfig
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.fsvrg import _client_pass


def _prerefactor_fsvrg_round(problem, w, key, cfg, phi, a_diag, passes,
                             apply_fn):
    """Verbatim copy of the seed FSVRG.round body (pre-RoundEngine), kept
    here as the bit-for-bit oracle for the engine refactor."""
    full_grad = problem.flat.grad(w)
    agg = jnp.zeros_like(w)
    wi = 0
    total_mass = jnp.zeros(())
    expected_mass = jnp.zeros(())
    for b, pass_fn in zip(problem.buckets, passes):
        kb = jax.random.fold_in(key, wi)
        deltas = pass_fn(w, full_grad, phi=phi, key=kb)
        if cfg.naive or not cfg.use_weighted_agg:
            wts = jnp.full((b.num_clients,), 1.0 / problem.num_clients)
        else:
            wts = problem.client_weights[wi : wi + b.num_clients]
        if cfg.participation < 1.0:
            sel = (jax.random.uniform(jax.random.fold_in(kb, 997),
                                      (b.num_clients,))
                   < cfg.participation).astype(jnp.float32)
            total_mass = total_mass + (wts * sel).sum()
            expected_mass = expected_mass + wts.sum()
            wts = wts * sel
        agg = agg + (wts[:, None] * deltas).sum(axis=0)
        wi += b.num_clients
    if cfg.participation < 1.0:
        agg = agg * (expected_mass / jnp.maximum(total_mass, 1e-9))
    scale = a_diag if (cfg.use_A and not cfg.naive) else 1.0
    return apply_fn(w, agg, scale)


@pytest.mark.parametrize("participation", [1.0, 0.5])
def test_fsvrg_on_engine_matches_prerefactor_trajectory(tiny_problem, participation):
    """3 rounds of engine-backed FSVRG == the seed round loop, bit-for-bit.

    The engine's *eager reference* round is the bit-exact pin surface (the
    refactor must not change a single ulp of the round template); the
    compiled round that ``solver.round`` dispatches is checked against the
    same oracle at tight tolerance — whole-round jit may associate the
    multi-bucket aggregation differently (see test_fused_round.py).
    """
    prob = tiny_problem
    cfg = FSVRGConfig(stepsize=1.0, participation=participation)
    solver = FSVRG(prob, cfg)

    passes = [
        jax.jit(functools.partial(_client_pass, bucket=b, lam=prob.flat.lam,
                                  cfg=cfg))
        for b in prob.buckets
    ]
    apply_fn = jax.jit(lambda w, agg, scale: w + scale * agg)

    state = solver.init()
    w_eager = jnp.zeros(prob.d)
    w_ref = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(0)
    for r in range(3):
        kr = jax.random.fold_in(key, r)
        state = solver.round(state, kr)
        w_eager = solver._round_ref(w_eager, kr)
        w_ref = _prerefactor_fsvrg_round(prob, w_ref, kr, cfg, solver.phi,
                                         solver.a_diag, passes, apply_fn)
        np.testing.assert_array_equal(np.asarray(w_eager), np.asarray(w_ref))
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_ref),
                                   rtol=1e-5, atol=1e-8)


def test_partial_participation_reweighting_unbiased(small_problem):
    """With fixed client deltas, the mean over participation draws of the
    reweighted aggregate matches the full-participation aggregate — the
    (expected mass / realized mass) correction keeps the round direction
    unbiased in expectation."""
    prob = small_problem
    w = jnp.zeros(prob.d)
    rng = np.random.default_rng(0)
    deltas = [
        jnp.asarray(rng.standard_normal((b.num_clients, prob.d)), jnp.float32)
        for b in prob.buckets
    ]

    eng_full = RoundEngine(prob, EngineConfig())
    ref_dir = eng_full.aggregate(w, deltas, jax.random.PRNGKey(0)) - w

    eng_p = RoundEngine(prob, EngineConfig(participation=0.75))
    one_draw = jax.jit(lambda key: eng_p.aggregate(w, deltas, key) - w)
    N = 800
    acc = jnp.zeros_like(w)
    base = jax.random.PRNGKey(42)
    for i in range(N):
        acc = acc + one_draw(jax.random.fold_in(base, i))
    mean_dir = acc / N

    rel = float(jnp.linalg.norm(mean_dir - ref_dir)
                / jnp.linalg.norm(ref_dir))
    assert rel < 0.08, rel


def test_pallas_aggregator_matches_dense(small_problem):
    """aggregator='pallas' (scaled_aggregate kernel over the stacked deltas)
    == the dense jnp weighted sum, for both scaling modes."""
    prob = small_problem
    w = jax.random.normal(jax.random.PRNGKey(1), (prob.d,)) * 0.1
    rng = np.random.default_rng(1)
    deltas = [
        jnp.asarray(rng.standard_normal((b.num_clients, prob.d)), jnp.float32)
        for b in prob.buckets
    ]
    a_diag = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (prob.d,))) + 0.5
    key = jax.random.PRNGKey(3)

    for eng_kw in ({}, {"server_scaling": "diag"},
                   {"participation": 0.5},
                   {"weighting": "uniform", "server_scaling": "diag"}):
        dense = RoundEngine(prob, EngineConfig(**eng_kw), a_diag=a_diag)
        pallas = RoundEngine(prob, EngineConfig(aggregator="pallas", **eng_kw),
                             a_diag=a_diag)
        out_d = dense.aggregate(w, deltas, key)
        out_p = pallas.aggregate(w, deltas, key)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=1e-5, atol=1e-5)


def test_distributed_gd_on_engine_matches_flat_gd(tiny_problem):
    """The engine-ported GD (per-client mean gradients, n_k/n aggregation)
    equals the flat single-gradient round up to f32 association."""
    from repro.core.baselines import DistributedGD, gd_round

    prob = tiny_problem
    w_flat = jnp.zeros(prob.d)
    solver = DistributedGD(prob, stepsize=2.0)
    state = solver.init()
    for _ in range(3):
        w_flat = gd_round(prob, w_flat, 2.0)
        state = solver.round(state, jax.random.PRNGKey(0))
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_flat),
                                   rtol=1e-5, atol=1e-6)


def test_sum_weighting_is_plain_delta_sum(small_problem):
    """weighting='sum' (the dual-method aggregation) applies weight 1 per
    client: the round update is exactly Σ_k δ_k."""
    prob = small_problem
    w = jnp.zeros(prob.d)
    rng = np.random.default_rng(2)
    deltas = [
        jnp.asarray(rng.standard_normal((b.num_clients, prob.d)), jnp.float32)
        for b in prob.buckets
    ]
    eng = RoundEngine(prob, EngineConfig(weighting="sum"))
    out = eng.aggregate(w, deltas, jax.random.PRNGKey(0))
    expect = sum(d.sum(axis=0) for d in deltas)
    np.testing.assert_allclose(np.asarray(out - w), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_round_with_state_threads_and_masks_state(small_problem):
    """round_with_state hands each bucket its own state, returns the pass's
    update, and under partial participation freezes exactly the clients the
    aggregation draw zeroes."""
    prob = small_problem
    w = jnp.zeros(prob.d)
    states = [jnp.zeros((b.num_clients, 3)) for b in prob.buckets]

    def pass_fn(w, bi, bucket, state_b, kb):
        deltas = jnp.zeros((bucket.num_clients, prob.d))
        return deltas, state_b + 1.0

    # full participation: every client's state advances
    eng = RoundEngine(prob, EngineConfig())
    _, new_states = eng.round_with_state(w, states, jax.random.PRNGKey(0),
                                         pass_fn)
    for s in new_states:
        np.testing.assert_array_equal(np.asarray(s), 1.0)

    # partial participation: non-participants keep their state, and the
    # frozen set is exactly the complement of the engine's Bernoulli draw
    eng_p = RoundEngine(prob, EngineConfig(participation=0.5))
    key = jax.random.PRNGKey(1)
    _, new_states = eng_p.round_with_state(w, states, key, pass_fn)
    wi = 0
    advanced = frozen = 0
    for b, s in zip(prob.buckets, new_states):
        sel = np.asarray(eng_p.participation_mask(
            jax.random.fold_in(key, wi), b.num_clients))
        np.testing.assert_array_equal(np.asarray(s)[sel == 1.0], 1.0)
        np.testing.assert_array_equal(np.asarray(s)[sel == 0.0], 0.0)
        advanced += int(sel.sum())
        frozen += int((1 - sel).sum())
        wi += b.num_clients
    assert advanced > 0 and frozen > 0


def test_engine_config_validation(tiny_problem):
    with pytest.raises(ValueError):
        EngineConfig(weighting="bogus")
    with pytest.raises(ValueError):
        EngineConfig(participation=0.0)
    with pytest.raises(ValueError):
        RoundEngine(tiny_problem, EngineConfig(server_scaling="diag"))


#: every documented invalid knob combination and the message it must carry —
#: the runtime twin of the FED004 static check (every knob is either
#: threaded through all round paths or rejected here, loudly)
_INVALID_CONFIGS = [
    (dict(weighting="bogus"), "weighting must be one of"),
    (dict(server_scaling="block"), "server_scaling must be one of"),
    (dict(aggregator="sparse"), "aggregator must be one of"),
    (dict(participation=0.0), r"participation must be in \(0, 1\]"),
    (dict(participation=1.5), r"participation must be in \(0, 1\]"),
    (dict(participation=-0.25), r"participation must be in \(0, 1\]"),
    # bool is a subclass of int: client_chunk=True must not mean chunk=1
    (dict(client_chunk=True), "client_chunk must be a positive int"),
    (dict(client_chunk=0), "client_chunk must be a positive int"),
    (dict(client_chunk=-4), "client_chunk must be a positive int"),
    (dict(client_chunk=2.5), "client_chunk must be a positive int"),
    (dict(cohort=True), "cohort must be a positive int"),
    (dict(cohort=0), "cohort must be a positive int"),
    (dict(cohort=-1), "cohort must be a positive int"),
    (dict(virtual_data=1), "virtual_data must be a bool"),
    (dict(virtual_data=None), "virtual_data must be a bool"),
    (dict(aggregator_guard="huber"), "aggregator_guard must be one of"),
    # order-statistic guards need the materialized (K, d) stacks
    (dict(aggregator_guard="trimmed_mean", client_chunk=8), "materialized"),
    (dict(aggregator_guard="median", client_chunk=8), "materialized"),
    (dict(aggregator_guard="trimmed_mean", virtual_data=True), "virtual"),
    (dict(aggregator_guard="median", virtual_data=True), "virtual"),
    # ... and replace the weighted sum dual methods rely on
    (dict(aggregator_guard="trimmed_mean", weighting="sum"),
     "exact plain sum"),
    (dict(aggregator_guard="median", weighting="sum"), "exact plain sum"),
    (dict(guard_trim=-0.1), r"guard_trim must be in \[0, 0.5\)"),
    (dict(guard_trim=0.5), r"guard_trim must be in \[0, 0.5\)"),
    (dict(guard_trim=0.7), r"guard_trim must be in \[0, 0.5\)"),
    (dict(guard_clip_norm=0.0), "guard_clip_norm must be a positive number"),
    (dict(guard_clip_norm=-1.0), "guard_clip_norm must be a positive number"),
    (dict(guard_clip_norm=True), "guard_clip_norm must be a positive number"),
    (dict(guard_clip_norm=1.0), "requires aggregator_guard='clip'"),
    (dict(guard_clip_norm=1.0, aggregator_guard="median"),
     "requires aggregator_guard='clip'"),
]


@pytest.mark.parametrize(
    "kwargs,match", _INVALID_CONFIGS,
    ids=["-".join(f"{k}={v}" for k, v in kw.items())
         for kw, _ in _INVALID_CONFIGS])
def test_engine_config_validation_matrix(kwargs, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kwargs)


@pytest.mark.parametrize("kwargs", [
    dict(),
    dict(participation=0.5, cohort=4),
    dict(client_chunk=8, virtual_data=True),
    dict(aggregator_guard="trimmed_mean", guard_trim=0.2),
    dict(aggregator_guard="median", participation=0.3),
    dict(aggregator_guard="clip", guard_clip_norm=5.0, client_chunk=8),
    dict(aggregator_guard="clip", virtual_data=True),
])
def test_engine_config_valid_combinations(kwargs):
    EngineConfig(**kwargs)  # must not raise
