"""Structural HLO cost analyzer: validated against XLA's cost_analysis on
loop-free graphs, and against analytic counts on scanned graphs (where
XLA's analysis is known to under-report by the trip count).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.utils.hlo_cost import analyze_text, _parse_shape


def _xla_cost(compiled):
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca


def test_matches_xla_on_loop_free_graph():
    def g(a, b):
        return jnp.tanh(a @ b).sum()

    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    comp = jax.jit(g).lower(a, b).compile()
    xla = _xla_cost(comp)
    mine = analyze_text(comp.as_text())
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.02
    assert abs(mine.bytes - xla["bytes accessed"]) / xla["bytes accessed"] < 0.02


def test_scan_flops_scale_with_trip_count():
    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    analytic_per_step = 2 * 128**3
    flops = {}
    for trips in (3, 12):
        ws = jax.ShapeDtypeStruct((trips, 128, 128), jnp.float32)
        comp = jax.jit(f).lower(x, ws).compile()
        flops[trips] = analyze_text(comp.as_text()).flops
        assert abs(flops[trips] - trips * analytic_per_step) / (
            trips * analytic_per_step) < 0.05, (trips, flops[trips])
    # and XLA's own analysis does NOT scale (the reason this module exists)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    assert _xla_cost(comp)["flops"] < 0.5 * flops[12]


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    mine = analyze_text(comp.as_text())
    analytic = 5 * 4 * 2 * 64**3
    assert abs(mine.flops - analytic) / analytic < 0.1, mine.flops


def test_tuple_shape_parsing():
    s = _parse_shape("(s32[], f32[128,128]{1,0}, f32[7,128,128]{2,1,0})")
    assert s.parts is not None and len(s.parts) == 3
    assert s.parts[1].dims == (128, 128)
    assert s.bytes == 4 + 128 * 128 * 4 + 7 * 128 * 128 * 4


def test_shape_bytes():
    assert _parse_shape("bf16[4,8]").bytes == 64
    assert _parse_shape("pred[10]").bytes == 10
    assert _parse_shape("f32[]").bytes == 4


def test_collectives_inside_loops_multiplied():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (dry-run only)")
