"""Pallas WKV6 kernel vs the sequential oracle: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.kernels.wkv6 import wkv6
from repro.models.rwkv import _wkv_sequential


def _inputs(seed, B, S, Hn, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, S, Hn, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hn, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hn, D), dtype)
    w = jnp.exp(-jnp.exp(-6.0 + jax.random.normal(ks[3], (B, S, Hn, D)))).astype(dtype)
    u = (jax.random.normal(ks[4], (Hn, D)) * 0.1).astype(dtype)
    return r, k, v, w, u


def _flat(t, B, Hn, S, D):
    return t.transpose(0, 2, 1, 3).reshape(B * Hn, S, D)


@settings(deadline=None, max_examples=10)
@given(st.integers(1, 2), st.sampled_from([32, 64, 128]), st.integers(1, 2),
       st.sampled_from([8, 32]), st.integers(0, 2**28))
def test_wkv6_kernel_matches_oracle(B, S, Hn, D, seed):
    r, k, v, w, u = _inputs(seed, B, S, Hn, D, jnp.float32)
    s0 = jnp.zeros((B, Hn, D, D))
    out_ref, s_ref = _wkv_sequential(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u.astype(jnp.float32), s0)
    out_k, s_k = wkv6(_flat(r, B, Hn, S, D), _flat(k, B, Hn, S, D),
                      _flat(v, B, Hn, S, D), _flat(w, B, Hn, S, D),
                      jnp.tile(u, (B, 1)), interpret=True)
    out_k = out_k.reshape(B, Hn, S, D).transpose(0, 2, 1, 3)
    s_k = s_k.reshape(B, Hn, D, D)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)


def test_wkv6_kernel_bf16_inputs():
    B, S, Hn, D = 1, 64, 1, 16
    r, k, v, w, u = _inputs(0, B, S, Hn, D, jnp.bfloat16)
    out_k, s_k = wkv6(_flat(r, B, Hn, S, D), _flat(k, B, Hn, S, D),
                      _flat(v, B, Hn, S, D), _flat(w, B, Hn, S, D),
                      jnp.tile(u, (B, 1)), interpret=True)
    assert out_k.dtype == jnp.bfloat16
    assert bool(jnp.isfinite(out_k.astype(jnp.float32)).all())
    s0 = jnp.zeros((B, Hn, D, D))
    out_ref, _ = _wkv_sequential(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w.astype(jnp.float32), u.astype(jnp.float32), s0)
    np.testing.assert_allclose(np.asarray(out_k.astype(jnp.float32)),
                               np.asarray(out_ref.reshape(B, S, Hn, D)
                                          .transpose(0, 2, 1, 3)
                                          .reshape(B * Hn, S, D)),
                               rtol=0.08, atol=0.08)


def test_wkv6_rejects_ragged_seq():
    r = jnp.zeros((1, 33, 8))
    with pytest.raises(ValueError):
        wkv6(r, r, r, r, jnp.zeros((1, 8)), interpret=True)
