"""fedlint (src/repro/analysis): fire + no-fire fixtures for every FED rule,
mutation fixtures seeding violations into copies of real modules, and the
CLI surface (exit codes, JSON report, suppressions, baseline).

The in-memory fixtures pin each rule's positive and negative space; the
mutation fixtures are the acceptance check that the pass would actually
catch a regression in the *real* modules it guards (a bit-unstable sampler
slipped into synthetic.py, a dead EngineConfig knob, a kernel without an
oracle, ...).
"""
import ast
import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.analysis.core import (
    Finding,
    RepoContext,
    SourceFile,
    run_context,
)

REPO = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# helpers


def _ctx(files):
    parsed = {}
    for path, src in files.items():
        parsed[path] = SourceFile(path, src, ast.parse(src), src.splitlines())
    return RepoContext(parsed)


def lint(files, baseline=None):
    return run_context(_ctx(files), baseline)


def rules_fired(files):
    return sorted({f.rule for f in lint(files).active})


def run_cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=str(cwd), env=env, capture_output=True, text=True)


# ---------------------------------------------------------------------------
# FED001 — bit-unstable primitives in regeneration-critical modules


def test_fed001_fires_on_normal_in_data():
    report = lint({"src/repro/data/gen.py": (
        "import jax\n"
        "def f(key):\n"
        "    return jax.random.normal(key, (3,))\n")})
    assert [f.rule for f in report.active] == ["FED001"]
    assert "bit-stable" in report.active[0].message


@pytest.mark.parametrize("call", [
    "jr.gamma(key, 2.0)",                     # import alias
    "random.beta(key, 1.0, 1.0)",             # from jax import random
    "dirichlet(key, alpha)",                  # from jax.random import ...
])
def test_fed001_fires_across_import_spellings(call):
    src = ("import jax\n"
           "import jax.random as jr\n"
           "from jax import random\n"
           "from jax.random import dirichlet\n"
           f"def f(key, alpha):\n    return {call}\n")
    assert "FED001" in rules_fired({"src/repro/fleet/traces.py": src})


def test_fed001_no_fire_on_inversion_samplers():
    src = ("import jax\n"
           "def f(key):\n"
           "    a = jax.random.uniform(jax.random.fold_in(key, 0), (3,))\n"
           "    b = jax.random.gumbel(jax.random.fold_in(key, 1), (3,))\n"
           "    c = jax.random.exponential(jax.random.fold_in(key, 2), (3,))\n"
           "    return a + b + c\n")
    assert rules_fired({"src/repro/data/gen.py": src}) == []


def test_fed001_no_fire_outside_scoped_modules():
    # model initializers may use normal: weights are checkpointed, never
    # regenerated from shape
    src = ("import jax\n"
           "def init(key):\n"
           "    return jax.random.normal(key, (4, 4))\n")
    assert "FED001" not in rules_fired({"src/repro/models/layers.py": src})


# ---------------------------------------------------------------------------
# FED002 — key discipline


def test_fed002_fires_on_key_reuse():
    src = ("import jax\n"
           "def f(key):\n"
           "    a = jax.random.uniform(key, (3,))\n"
           "    b = jax.random.uniform(key, (3,))\n"
           "    return a + b\n")
    report = lint({"src/repro/core/x.py": src})
    assert [f.rule for f in report.active] == ["FED002"]
    assert report.active[0].line == 4


def test_fed002_fires_on_sample_after_split():
    src = ("import jax\n"
           "def f(key):\n"
           "    ks = jax.random.split(key, 4)\n"
           "    bad = jax.random.uniform(key, (3,))\n"
           "    return ks, bad\n")
    assert "FED002" in rules_fired({"src/repro/core/x.py": src})


def test_fed002_fires_on_duplicate_constant_tag():
    src = ("import jax\n"
           "def f(key):\n"
           "    a = jax.random.fold_in(key, 7)\n"
           "    b = jax.random.fold_in(key, 7)\n"
           "    return a, b\n")
    report = lint({"src/repro/core/x.py": src})
    assert any("repeats the fold_in" in f.message for f in report.active)


def test_fed002_fires_on_raw_key_sampling_in_library_code():
    src = ("import jax\n"
           "def f():\n"
           "    return jax.random.uniform(jax.random.PRNGKey(0), (3,))\n")
    assert "FED002" in rules_fired({"src/repro/core/x.py": src})


def test_fed002_raw_key_sampling_allowed_in_tests():
    src = ("import jax\n"
           "def test_x():\n"
           "    return jax.random.uniform(jax.random.PRNGKey(0), (3,))\n")
    assert rules_fired({"tests/test_x.py": src}) == []


def test_fed002_fires_on_loop_carried_consumption():
    src = ("import jax\n"
           "def f(key, n):\n"
           "    out = 0.0\n"
           "    for i in range(n):\n"
           "        out += jax.random.uniform(key, ())\n"
           "    return out\n")
    assert "FED002" in rules_fired({"src/repro/core/x.py": src})


def test_fed002_no_fire_on_fold_in_fanout():
    # the repo's core idiom: many fold_ins with distinct tags off one key
    src = ("import jax\n"
           "ROWS = 2\n"
           "def f(key, k, p):\n"
           "    ck = jax.random.fold_in(key, k)\n"
           "    a = jax.random.uniform(jax.random.fold_in(ck, 0), (3,))\n"
           "    b = jax.random.gumbel(jax.random.fold_in(ck, 1), (3,))\n"
           "    rk = jax.random.fold_in(jax.random.fold_in(ck, ROWS), p)\n"
           "    c = jax.random.uniform(rk, (3,))\n"
           "    return a, b, c\n")
    assert rules_fired({"src/repro/data/gen.py": src}) == []


def test_fed002_no_fire_on_rebinding():
    # fan out sub-keys with fold_in, sample each binding exactly once
    src = ("import jax\n"
           "def f(key, r):\n"
           "    key = jax.random.fold_in(key, r)\n"
           "    k0 = jax.random.fold_in(key, 0)\n"
           "    a = jax.random.uniform(k0, ())\n"
           "    key = jax.random.fold_in(key, 1)\n"
           "    b = jax.random.uniform(key, ())\n"
           "    return a, b\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


def test_fed002_fires_on_fold_in_from_sampled_key():
    # JAX guidance: a key is spent once a sampler consumes it — deriving
    # more streams from it afterwards is the reuse FED002 exists to catch
    src = ("import jax\n"
           "def f(key):\n"
           "    a = jax.random.uniform(key, ())\n"
           "    k2 = jax.random.fold_in(key, 1)\n"
           "    return a, k2\n")
    assert "FED002" in rules_fired({"src/repro/core/x.py": src})


def test_fed002_no_fire_on_branch_exclusive_consumption():
    # fsvrg's one_client: the same key feeds randint OR permutation,
    # never both on one execution path
    src = ("import jax\n"
           "def f(ck, naive, m):\n"
           "    if naive:\n"
           "        idx = jax.random.randint(ck, (4,), 0, m)\n"
           "    else:\n"
           "        idx = jax.random.permutation(ck, m)\n"
           "    return idx\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


def test_fed002_fires_on_reuse_after_both_branches_consume():
    src = ("import jax\n"
           "def f(ck, naive, m):\n"
           "    if naive:\n"
           "        idx = jax.random.randint(ck, (4,), 0, m)\n"
           "    else:\n"
           "        idx = jax.random.permutation(ck, m)\n"
           "    extra = jax.random.uniform(ck, ())\n"
           "    return idx, extra\n")
    assert "FED002" in rules_fired({"src/repro/core/x.py": src})


def test_fed002_no_fire_on_split_unpack():
    src = ("import jax\n"
           "def f(key):\n"
           "    k1, k2 = jax.random.split(key)\n"
           "    return jax.random.uniform(k1, ()), jax.random.uniform(k2, ())\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


def test_fed002_no_fire_on_same_site_rederivation_in_loop():
    # bench_round warmup: fold_in(key, 0) at one site inside a loop is
    # intentional re-derivation, not a stream collision
    src = ("import jax\n"
           "def f(key, fns, w):\n"
           "    for fn in fns:\n"
           "        fn(w, jax.random.fold_in(key, 0))\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


# ---------------------------------------------------------------------------
# FED003 — kernel / oracle / registration / parity-test triangle


_KERNEL_OK = {
    "src/repro/kernels/mykern.py": "def mykern(x):\n    return x\n",
    "src/repro/kernels/ref.py": "def mykern_ref(x):\n    return x\n",
    "src/repro/kernels/ops.py": ("from repro.kernels.mykern import mykern\n"),
    "tests/test_mykern.py": ("def test_parity():\n"
                             "    assert mykern is not None and "
                             "mykern_ref is not None\n"),
}


def test_fed003_no_fire_on_complete_triangle():
    assert rules_fired(_KERNEL_OK) == []


def test_fed003_fires_on_missing_oracle():
    files = dict(_KERNEL_OK)
    files["src/repro/kernels/ref.py"] = "def other_ref(x):\n    return x\n"
    report = lint(files)
    assert any(f.rule == "FED003" and "no 'mykern_ref' oracle" in f.message
               for f in report.active)


def test_fed003_fires_on_missing_ops_registration():
    files = dict(_KERNEL_OK)
    files["src/repro/kernels/ops.py"] = "# nothing registered\n"
    report = lint(files)
    assert any(f.rule == "FED003" and "ops.py" in f.message
               for f in report.active)


def test_fed003_fires_on_missing_parity_test():
    files = dict(_KERNEL_OK)
    files["tests/test_mykern.py"] = "def test_unrelated():\n    pass\n"
    report = lint(files)
    assert any(f.rule == "FED003" and "parity" in f.message
               for f in report.active)


def test_fed003_test_check_skipped_without_test_files():
    files = {k: v for k, v in _KERNEL_OK.items() if not k.startswith("tests/")}
    assert rules_fired(files) == []


def test_fed003_private_helpers_exempt():
    files = dict(_KERNEL_OK)
    files["src/repro/kernels/mykern.py"] += "def _helper(x):\n    return x\n"
    assert rules_fired(files) == []


# ---------------------------------------------------------------------------
# FED004 — EngineConfig round-path completeness (synthetic engine fixtures)


def _engine_src(*, extra_field="", extra_post="", beta_paths=True,
                drop_path=False):
    paths = ["round", "round_with_state", "round_streamed",
             "round_streamed_with_state", "round_cohort",
             "round_cohort_with_state", "round_virtual",
             "round_virtual_with_state"]
    if drop_path:
        paths = paths[:-1]
    body = [
        "import dataclasses",
        "",
        "@dataclasses.dataclass(frozen=True)",
        "class EngineConfig:",
        "    alpha: float = 1.0",
        "    beta: int = 2",
        extra_field,
        "",
        "    def __post_init__(self):",
        "        if self.alpha < 0:",
        "            raise ValueError('alpha must be >= 0')",
        extra_post,
        "",
        "class RoundEngine:",
        "    def __init__(self, cfg):",
        "        self.cfg = cfg",
        "",
        "    def _common(self, w):",
        "        return w * self.cfg.alpha",
    ]
    for i, p in enumerate(paths):
        uses_beta = beta_paths or p == "round"
        extra = " + self.cfg.beta" if uses_beta else ""
        body += ["", f"    def {p}(self, w):",
                 f"        return self._common(w){extra}"]
    return "\n".join(line for line in body if line is not None) + "\n"


def test_fed004_no_fire_when_all_fields_threaded():
    files = {"src/repro/core/engine.py": _engine_src()}
    assert rules_fired(files) == []


def test_fed004_fires_on_dead_knob():
    files = {"src/repro/core/engine.py":
             _engine_src(extra_field="    gamma: float = 0.5")}
    report = lint(files)
    assert any(f.rule == "FED004" and "gamma" in f.message
               and "never read" in f.message for f in report.active)


def test_fed004_fires_on_partially_threaded_unvalidated_knob():
    files = {"src/repro/core/engine.py": _engine_src(beta_paths=False)}
    report = lint(files)
    assert any(f.rule == "FED004" and "EngineConfig.beta" in f.message
               and "silently no-ops" in f.message for f in report.active)


def test_fed004_validation_excuses_partial_threading():
    files = {"src/repro/core/engine.py": _engine_src(
        beta_paths=False,
        extra_post=("        if self.beta < 0:\n"
                    "            raise ValueError('beta must be >= 0')"))}
    assert rules_fired(files) == []


def test_fed004_fires_on_missing_round_path():
    files = {"src/repro/core/engine.py": _engine_src(drop_path=True)}
    report = lint(files)
    assert any(f.rule == "FED004"
               and "round_virtual_with_state" in f.message
               for f in report.active)


def test_fed004_real_engine_is_clean():
    path = REPO / "src/repro/core/engine.py"
    files = {"src/repro/core/engine.py": path.read_text()}
    assert rules_fired(files) == []


# ---------------------------------------------------------------------------
# FED005 — tracer leaks in jitted bodies


_JIT_HEADER = "import functools\nimport jax\nimport jax.numpy as jnp\n"


def test_fed005_fires_on_if_while_casts_item():
    src = _JIT_HEADER + (
        "@jax.jit\n"
        "def f(w):\n"
        "    if w.sum() > 0:\n"
        "        w = -w\n"
        "    while w[0] > 0:\n"
        "        w = w - 1\n"
        "    a = float(w[0])\n"
        "    b = bool(w[1])\n"
        "    c = w.max().item()\n"
        "    return w, a, b, c\n")
    report = lint({"src/repro/core/x.py": src})
    lines = sorted(f.line for f in report.active)
    assert [f.rule for f in report.active] == ["FED005"] * 5
    assert lines == [6, 8, 10, 11, 12]


def test_fed005_fires_on_ternary_and_jit_lambda():
    src = _JIT_HEADER + (
        "g = jax.jit(lambda w: w if w.sum() > 0 else -w)\n")
    assert "FED005" in rules_fired({"src/repro/core/x.py": src})


def test_fed005_fires_inside_nested_def():
    src = _JIT_HEADER + (
        "@functools.partial(jax.jit, donate_argnums=(0,))\n"
        "def f(w):\n"
        "    def body(x):\n"
        "        if x[0] > 0:\n"
        "            return -x\n"
        "        return x\n"
        "    return body(w)\n")
    assert "FED005" in rules_fired({"src/repro/core/x.py": src})


def test_fed005_no_fire_on_static_argnames():
    src = _JIT_HEADER + (
        "@functools.partial(jax.jit, static_argnames=('mode',))\n"
        "def f(w, mode):\n"
        "    if mode == 'fast':\n"
        "        w = w * 2\n"
        "    return w\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


def test_fed005_no_fire_on_sanitizers():
    src = _JIT_HEADER + (
        "@jax.jit\n"
        "def f(w, masks):\n"
        "    if masks is None:\n"
        "        return w\n"
        "    if w.shape[0] > 2 and w.ndim == 1:\n"
        "        w = w * 2\n"
        "    if isinstance(w, tuple):\n"
        "        return w[0]\n"
        "    if len(masks) > 1:\n"
        "        w = w + 1\n"
        "    return jnp.where(w > 0, w, -w)\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


def test_fed005_no_fire_outside_jit():
    src = _JIT_HEADER + (
        "def f(w):\n"
        "    if w.sum() > 0:\n"
        "        return -w\n"
        "    return w\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


# ---------------------------------------------------------------------------
# suppressions + baseline (engine mechanics)


_FED001_BAD = ("import jax\n"
               "def f(key):\n"
               "    return jax.random.normal(key, (3,))\n")


def test_suppression_with_reason_is_honored():
    src = _FED001_BAD.replace(
        "jax.random.normal(key, (3,))",
        "jax.random.normal(key, (3,))  "
        "# fedlint: disable=FED001 -- fixture: documented exception")
    report = lint({"src/repro/data/gen.py": src})
    assert report.active == [] and len(report.suppressed) == 1


def test_suppression_on_preceding_comment_line():
    src = ("import jax\n"
           "def f(key):\n"
           "    # fedlint: disable=FED001 -- fixture: documented exception\n"
           "    return jax.random.normal(key, (3,))\n")
    report = lint({"src/repro/data/gen.py": src})
    assert report.active == [] and len(report.suppressed) == 1


def test_suppression_without_reason_rejected():
    src = _FED001_BAD.replace(
        "jax.random.normal(key, (3,))",
        "jax.random.normal(key, (3,))  # fedlint: disable=FED001")
    report = lint({"src/repro/data/gen.py": src})
    assert sorted(f.rule for f in report.active) == ["FED000", "FED001"]


def test_suppression_for_wrong_rule_does_not_mask():
    src = _FED001_BAD.replace(
        "jax.random.normal(key, (3,))",
        "jax.random.normal(key, (3,))  # fedlint: disable=FED003 -- wrong rule")
    report = lint({"src/repro/data/gen.py": src})
    assert any(f.rule == "FED001" for f in report.active)


def test_disable_mentioned_in_docstring_is_inert():
    src = ('"""Docs quoting `# fedlint: disable=FED001` must not count."""\n'
           "X = 1\n")
    assert rules_fired({"src/repro/core/x.py": src}) == []


def test_baseline_grandfathers_findings():
    report = lint({"src/repro/data/gen.py": _FED001_BAD})
    fp = {f.fingerprint for f in report.active}
    again = lint({"src/repro/data/gen.py": _FED001_BAD}, baseline=fp)
    assert again.active == [] and len(again.baselined) == 1


# ---------------------------------------------------------------------------
# mutation fixtures: seed a violation into a copy of a REAL module and
# assert the CLI catches it (non-zero exit) — the acceptance criterion


def _mutations():
    return {
        "FED001": ("src/repro/data/synthetic.py",
                   "jax.random.gumbel(", "jax.random.normal(", 1),
        "FED002": ("src/repro/fleet/traces.py", None, (
            "\n\ndef _seeded_violation(key):\n"
            "    a = jax.random.uniform(key, (3,))\n"
            "    b = jax.random.uniform(key, (3,))\n"
            "    return a + b\n"), None),
        "FED003": ("src/repro/kernels/ref.py",
                   "def wkv6_ref(", "def wkv6_oracle(", 1),
        "FED004": ("src/repro/core/engine.py",
                   "    participation: float = 1.0",
                   "    participation: float = 1.0\n"
                   "    seeded_dead_knob: float = 0.5", 1),
        "FED005": ("src/repro/kernels/wkv6.py",
                   "    nc = S // chunk",
                   "    if r.sum() > 0:\n        pass\n"
                   "    nc = S // chunk", 1),
    }


@pytest.mark.parametrize("rule", sorted(_mutations()))
def test_mutation_fixture_is_caught(rule, tmp_path):
    target, old, new, expect_count = _mutations()[rule]
    # mirror the modules each rule needs to see into a scratch tree
    needed = {
        "src/repro/data/synthetic.py",
        "src/repro/fleet/traces.py",
        "src/repro/core/engine.py",
        "src/repro/kernels/wkv6.py",
        "src/repro/kernels/ref.py",
        "src/repro/kernels/ops.py",
    }
    for rel in needed:
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        dst.write_text((REPO / rel).read_text())
    mutant = tmp_path / target
    src = mutant.read_text()
    if old is None:
        src = src + new
    else:
        assert src.count(old) >= expect_count, (
            f"mutation anchor {old!r} vanished from {target} — update the "
            f"fixture")
        src = src.replace(old, new, 1)
    mutant.write_text(src)

    clean = run_cli(["src", "--no-baseline"], cwd=REPO)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    mutated = run_cli(["src", "--no-baseline"], cwd=tmp_path)
    assert mutated.returncode == 1, mutated.stdout + mutated.stderr
    assert rule in mutated.stdout


# ---------------------------------------------------------------------------
# CLI surface


def test_cli_clean_tree_exits_zero_with_json(tmp_path):
    report_path = tmp_path / "report.json"
    res = run_cli(["src", "benchmarks", "tests", "--no-baseline",
                   "--json", str(report_path)], cwd=REPO)
    assert res.returncode == 0, res.stdout + res.stderr
    data = json.loads(report_path.read_text())
    assert data["summary"]["active"] == 0
    assert data["files_scanned"] > 50


def test_cli_missing_path_is_usage_error():
    res = run_cli(["no/such/dir"], cwd=REPO)
    assert res.returncode == 2


def test_cli_list_rules():
    res = run_cli(["--list-rules"], cwd=REPO)
    assert res.returncode == 0
    for rid in ("FED001", "FED002", "FED003", "FED004", "FED005"):
        assert rid in res.stdout


def test_cli_update_baseline_roundtrip(tmp_path):
    tree = tmp_path / "src" / "repro" / "data"
    tree.mkdir(parents=True)
    (tree / "gen.py").write_text(_FED001_BAD)
    first = run_cli(["src", "--no-baseline"], cwd=tmp_path)
    assert first.returncode == 1
    upd = run_cli(["src", "--update-baseline"], cwd=tmp_path)
    assert upd.returncode == 0
    assert json.loads((tmp_path / "fedlint_baseline.json").read_text())[
        "fingerprints"]
    second = run_cli(["src"], cwd=tmp_path)
    assert second.returncode == 0, second.stdout + second.stderr
    assert "1 baselined" in second.stdout


def test_finding_fingerprint_is_line_free():
    a = Finding("FED001", "p.py", 3, "msg")
    b = Finding("FED001", "p.py", 99, "msg")
    assert a.fingerprint == b.fingerprint
