"""Engine-ported DANE / CoCoA+ / Appendix-A methods pinned against the
pre-port list-based implementations (tests/_oracles.py), plus jnp-vs-Pallas
kernel-path parity for the two new fused local-step kernels.

The dense-ridge pins run under f64 so "the same math, reassociated by the
engine's weighted aggregation" is distinguishable from a real drift: the
tolerances are at the f64 noise floor, orders of magnitude below any
algorithmic difference.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _oracles
from repro.core import (CoCoAConfig, CoCoAPlus, DANE, DANEConfig, DANERidge,
                        DualMethod, PrimalMethod, build_dense_problem)
from repro.core.cocoa import dual_to_primal


@pytest.fixture()
def x64():
    """f64 for the dense-ridge machine-precision pins (function-scoped so the
    f32 sparse-problem tests and session fixtures are unaffected)."""
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def _ridge_data(K=4, m=12, d=8, seed=0):
    rng = np.random.default_rng(seed)
    Xs = [jnp.asarray(rng.standard_normal((d, m))) for _ in range(K)]
    ys = [jnp.asarray(rng.standard_normal(m)) for _ in range(K)]
    return Xs, ys


# --------------------------------------------------------------------- #
# DANE
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("eta,mu", [(1.0, 0.0), (0.7, 0.5)])
def test_dane_ridge_engine_pins_list_oracle(x64, eta, mu):
    """3 rounds of engine DANERidge == the pre-port dane_round_ridge loop,
    at the f64 noise floor."""
    Xs, ys = _ridge_data()
    lam = 0.1
    solver = DANERidge(build_dense_problem(Xs, ys, lam), eta=eta, mu=mu)
    w_ref = jnp.asarray(np.random.default_rng(1).standard_normal(8))
    state = solver.init(w_ref)
    for _ in range(3):
        state = solver.round(state, jax.random.PRNGKey(0))
        w_ref = _oracles.dane_round_ridge(Xs, ys, w_ref, lam, eta=eta, mu=mu)
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_ref),
                                   rtol=1e-12, atol=1e-13)


def test_dane_gd_engine_pins_list_oracle(tiny_problem):
    """Engine DANE (GD local solver) == the pre-port hand-rolled loop on the
    sparse bucketed problem, over 2 chained rounds (f32 tolerance — the
    engine reassociates the uniform average as w + Σ(w_k − w)/K)."""
    prob = tiny_problem
    cfg = DANEConfig(eta=1.0, mu=0.3, local_steps=10, local_lr=0.3)
    solver = DANE(prob, cfg)
    state = solver.init()
    w_ref = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(0)
    for r in range(2):
        kr = jax.random.fold_in(key, r)
        state = solver.round(state, kr)
        w_ref = _oracles.dane_round_logreg_gd(
            prob, w_ref, eta=cfg.eta, mu=cfg.mu, local_steps=cfg.local_steps,
            local_lr=cfg.local_lr)
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_ref),
                                   rtol=2e-5, atol=2e-6)


def test_dane_gd_kernel_path_matches_jnp(tiny_problem):
    """use_kernel=True (fused Pallas dane_update, interpret on CPU) and the
    inline jnp expression produce the same round (to f32 tolerance — the
    jnp path folds the Python-float scalar prefactors in double precision,
    the kernel chains them in f32)."""
    prob = tiny_problem
    w0 = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(5)
    cfg = dict(eta=1.0, mu=0.3, local_steps=5, local_lr=0.3)
    s_j = DANE(prob, DANEConfig(use_kernel=False, **cfg))
    s_k = DANE(prob, DANEConfig(use_kernel=True, **cfg))
    w_j = s_j.round(s_j.init(w0), key).w
    w_k = s_k.round(s_k.init(w0), key).w
    np.testing.assert_allclose(np.asarray(w_k), np.asarray(w_j),
                               rtol=1e-4, atol=1e-6)


def test_dane_config_validation():
    with pytest.raises(ValueError):
        DANEConfig(local_solver="newton")


# --------------------------------------------------------------------- #
# CoCoA+
# --------------------------------------------------------------------- #


def test_cocoa_engine_pins_list_oracle(tiny_problem):
    """3 rounds of engine CoCoA+ == the pre-port list-based loop: iterates
    AND dual blocks (the round_with_state plumbing must not touch α_k
    beyond the pass's own update)."""
    prob = tiny_problem
    solver = CoCoAPlus(prob)
    state = solver.init()
    w_ref = jnp.zeros(prob.d)
    alphas_ref = [jnp.zeros((b.num_clients, b.m_pad)) for b in prob.buckets]
    for r in range(3):
        key = jax.random.PRNGKey(r)
        state = solver.round(state, key)
        w_ref, alphas_ref = _oracles.cocoa_round_list(prob, w_ref, alphas_ref,
                                                      key, solver.sigma)
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_ref),
                                   rtol=1e-5, atol=1e-7)
        for a_eng, a_ref in zip(state.aux, alphas_ref):
            np.testing.assert_allclose(np.asarray(a_eng), np.asarray(a_ref),
                                       rtol=1e-5, atol=1e-7)


def test_cocoa_kernel_path_matches_jnp(tiny_problem):
    """use_kernel=True (fused Pallas cocoa_sdca Newton solve, interpret on
    CPU) matches the inline jnp recursion."""
    prob = tiny_problem
    c_j = CoCoAPlus(prob, cfg=CoCoAConfig(use_kernel=False))
    c_k = CoCoAPlus(prob, cfg=CoCoAConfig(use_kernel=True))
    st_j, st_k = c_j.init(), c_k.init()
    for r in range(2):
        st_j = c_j.round(st_j, jax.random.PRNGKey(r))
        st_k = c_k.round(st_k, jax.random.PRNGKey(r))
    np.testing.assert_allclose(np.asarray(st_k.w), np.asarray(st_j.w),
                               rtol=1e-6, atol=1e-7)


def test_cocoa_partial_participation_freezes_left_out_duals(tiny_problem):
    """Under participation<1, exactly the clients the engine's Bernoulli
    draw left out keep their dual blocks, and the primal iterate keeps
    tracking the dual blocks — w = (1/λn) Σ_k X_k α_k — because the "sum"
    weighting takes the plain partial sum (no unbiasedness reweighting)."""
    prob = tiny_problem
    solver = CoCoAPlus(prob, cfg=CoCoAConfig(participation=0.5))
    key = jax.random.PRNGKey(3)
    state0 = solver.init()
    state = solver.round(state0, key)
    wi = 0
    num_frozen = 0
    for bi, b in enumerate(prob.buckets):
        kb = jax.random.fold_in(key, wi)
        sel = np.asarray(solver.engine.participation_mask(kb, b.num_clients))
        changed = np.abs(np.asarray(state.aux[bi])
                         - np.asarray(state0.aux[bi])).max(axis=1) > 0
        # left-out clients must be frozen; participants (with data) update
        assert not changed[sel == 0.0].any()
        num_frozen += int((sel == 0.0).sum())
        wi += b.num_clients
    assert num_frozen > 0  # the draw actually left someone out

    state = solver.round(state, jax.random.PRNGKey(4))
    state = solver.round(state, jax.random.PRNGKey(5))
    lam, n = prob.flat.lam, prob.flat.n
    xa = jnp.zeros(prob.d)
    for b, a in zip(prob.buckets, state.aux):
        xa = xa.at[b.idx].add(a[:, :, None] * b.val)
    np.testing.assert_allclose(np.asarray(state.w),
                               np.asarray(xa / (lam * n)),
                               rtol=1e-5, atol=1e-6)


def test_cocoa_pallas_aggregator_matches_dense(tiny_problem):
    """CoCoA+'s sum-weighted deltas through aggregator='pallas'
    (scaled_aggregate) == the dense path."""
    prob = tiny_problem
    c_d = CoCoAPlus(prob, cfg=CoCoAConfig(aggregator="dense"))
    c_p = CoCoAPlus(prob, cfg=CoCoAConfig(aggregator="pallas"))
    st_d, st_p = c_d.init(), c_p.init()
    for r in range(2):
        st_d = c_d.round(st_d, jax.random.PRNGKey(r))
        st_p = c_p.round(st_p, jax.random.PRNGKey(r))
    np.testing.assert_allclose(np.asarray(st_p.w), np.asarray(st_d.w),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------- #
# Appendix-A primal/dual methods
# --------------------------------------------------------------------- #


def test_primal_method_engine_pins_list_oracle(x64):
    Xs, ys = _ridge_data(seed=4)
    lam, sigma = 0.1, 2.0
    rng = np.random.default_rng(5)
    alphas0 = [jnp.asarray(rng.standard_normal(12)) for _ in range(4)]
    solver = PrimalMethod(build_dense_problem(Xs, ys, lam), sigma=sigma,
                          alphas0=alphas0)
    state = solver.init()
    w, gs, eta, mu = _oracles.primal_method_init(Xs, alphas0, lam, sigma)
    np.testing.assert_allclose(np.asarray(state.w), np.asarray(w),
                               rtol=1e-12, atol=1e-13)
    for _ in range(4):
        state = solver.round(state, jax.random.PRNGKey(0))
        w, gs = _oracles.primal_method_round(Xs, ys, w, gs, lam, eta, mu)
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w),
                                   rtol=1e-11, atol=1e-12)
        np.testing.assert_allclose(np.asarray(state.aux[0]),
                                   np.asarray(jnp.stack(gs)),
                                   rtol=1e-11, atol=1e-12)


def test_dual_method_engine_pins_list_oracle(x64):
    Xs, ys = _ridge_data(seed=6)
    lam, sigma = 0.1, 4.0
    rng = np.random.default_rng(7)
    alphas0 = [jnp.asarray(rng.standard_normal(12)) for _ in range(4)]
    solver = DualMethod(build_dense_problem(Xs, ys, lam), sigma=sigma,
                        alphas0=alphas0)
    state = solver.init()
    alphas = list(alphas0)
    for _ in range(4):
        state = solver.round(state, jax.random.PRNGKey(0))
        alphas = _oracles.dual_method_round(Xs, ys, alphas, lam, sigma)
        np.testing.assert_allclose(
            np.asarray(state.aux[0]), np.asarray(jnp.stack(alphas)),
            rtol=1e-11, atol=1e-12)
        # the engine's incremental w tracks (1/λn) X α exactly
        np.testing.assert_allclose(
            np.asarray(state.w), np.asarray(dual_to_primal(Xs, alphas, lam)),
            rtol=1e-11, atol=1e-12)


def test_appendix_a_rejects_unequal_sizes(x64):
    rng = np.random.default_rng(8)
    Xs = [jnp.asarray(rng.standard_normal((5, m))) for m in (6, 9)]
    ys = [jnp.asarray(rng.standard_normal(m)) for m in (6, 9)]
    alphas0 = [jnp.asarray(rng.standard_normal(m)) for m in (6, 9)]
    with pytest.raises(ValueError):
        PrimalMethod(build_dense_problem(Xs, ys, 0.1), sigma=2.0,
                     alphas0=alphas0)
