"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; only repro.launch.dryrun forces 512 placeholder devices.
"""
import pytest


@pytest.fixture(scope="session")
def tiny_dataset():
    from repro.configs import get_logreg_config
    from repro.data.synthetic import generate

    return generate(get_logreg_config().scaled(0.001), seed=3)


@pytest.fixture(scope="session")
def tiny_problem(tiny_dataset):
    from repro.core import build_problem

    return build_problem(tiny_dataset)


@pytest.fixture(scope="session")
def small_dataset():
    from repro.configs import get_logreg_config
    from repro.data.synthetic import generate

    return generate(get_logreg_config().scaled(0.002), seed=0)


@pytest.fixture(scope="session")
def small_problem(small_dataset):
    from repro.core import build_problem

    return build_problem(small_dataset)


@pytest.fixture(scope="session")
def small_virtual_dataset():
    """The virtual twin of ``small_dataset`` — same cfg, same seed, so the
    regenerated rows are bit-for-bit the materialized ones."""
    from repro.configs import get_logreg_config
    from repro.data.synthetic import virtual_dataset

    return virtual_dataset(get_logreg_config().scaled(0.002), seed=0)


@pytest.fixture(scope="session")
def small_virtual_problem(small_virtual_dataset):
    from repro.core import build_virtual_problem

    return build_virtual_problem(small_virtual_dataset)
