"""The virtual-data property layer: on-demand per-client regeneration must be
*indistinguishable* from the materialized dataset.

The contract under test (see data/synthetic.py's seeding-contract docstring
and ARCHITECTURE.md "Virtual data"):

1. ``make_client_batch(vds, k)`` is bit-for-bit row-slice ``k`` of
   ``generate`` on the same cfg/seed — for EVERY client, train and test
   halves, and any chronological prefix (a prefix is always a prefix).
2. ``VirtualDataset.client_rows_padded`` reproduces the engine's padded
   bucket layout bitwise (idx 0 / val 0 / y 1 padding included).
3. ``build_virtual_problem`` mirrors ``build_problem``: same bucket
   grouping, same n_k, same client order, same weights — which is what
   makes virtual rounds key-compatible with materialized ones.
4. Engine rounds over virtual data match materialized rounds **bit-for-bit**
   across the knob cross (client_chunk × cohort × participation × weighting
   × aggregator): regenerated rows are the materialized rows, and the
   traced round body is the same computation.
5. Solver-level parity: GD/FedAvg/CoCoA+ iterates are bit-equal;
   FSVRG/DANE match to tight float tolerance (their eager prelude computes
   the full gradient through VirtualFlat's streamed scatter, whose
   summation order differs from the materialized flat view by ulps).
6. ``VirtualFlat`` is a faithful flat view: loss/error_rate/feature_counts/
   omega exact, grad to tight tolerance (scatter order only).

Engine- and solver-level properties run on a dedicated *tiny* problem pair
(small m_pad keeps the eager per-round tracing cheap enough to fuzz); the
exhaustive every-client data pin runs on the shared ``small_dataset``
fixture scale, where buckets are big enough to be representative.

``hypothesis`` is an optional dev dep: each fuzzed property degrades to a
seeded-draw loop with the same example count.  ``VIRTUAL_PT_EXAMPLES``
budget-guards the count (default 200 locally; CI sets it lower).
"""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - env-dependent
    HAVE_HYPOTHESIS = False

from repro.configs.gplus_logreg import LogRegConfig
from repro.core import (CoCoAConfig, CoCoAPlus, FSVRG, FSVRGConfig,
                        build_problem, build_virtual_problem, make_solver)
from repro.core import scaling
from repro.core.engine import EngineConfig, RoundEngine
from repro.core.problem import VirtualBucket
from repro.data.synthetic import (generate, make_client_batch,
                                  train_split_sizes, virtual_dataset)

#: total drawn examples for the fuzzed data-parity property (the heavier
#: round-parity fuzz runs a fraction of this; see _N_ROUND)
N_EXAMPLES = int(os.environ.get("VIRTUAL_PT_EXAMPLES", "200"))
#: round-parity draws are ~10s each (an eager round re-traces the whole
#: regeneration graph per call), so the round fuzz runs a small fraction of
#: the data-parity width; the deterministic tests above the fuzz already
#: pin the main knob combinations.
_N_ROUND = max(6, N_EXAMPLES // 32)


def _fuzz(check, n_examples):
    """One decorator for both worlds: a real hypothesis ``@given`` over a
    case seed when available, a seeded-draw loop of the same example count
    otherwise (so the property still runs at full width without the dep)."""
    if HAVE_HYPOTHESIS:
        @settings(max_examples=n_examples, deadline=None, derandomize=True)
        @given(st.integers(0, 2**31 - 1))
        def test(case_seed):
            check(case_seed)
    else:
        def test():
            rng = np.random.default_rng(20260808)
            for _ in range(n_examples):
                check(int(rng.integers(0, 2**31 - 1)))
    test.__doc__ = check.__doc__
    return test


#: the engine/solver property-test scale: multi-bucket but tiny m_pad, so
#: eager round tracing stays cheap enough to run dozens of knob draws
_TINY = LogRegConfig(name="virtual-pt", num_clients=12, num_features=64,
                     num_examples=60, min_client_examples=2,
                     max_client_examples=10, nnz_per_example=6)


@functools.lru_cache(maxsize=1)
def _pair():
    """(materialized ds, virtual twin, materialized problem, virtual
    problem) at the tiny property-test scale — module-cached instead of a
    fixture so the hypothesis-wrapped properties can reach it too."""
    ds = generate(_TINY, seed=0)
    vds = virtual_dataset(_TINY, seed=0)
    return ds, vds, build_problem(ds), build_virtual_problem(vds)


def _client_row_slices(ds, vds):
    """Per-client (train_slice, test_slice) into the split arrays — both
    splits are client-contiguous in client order by construction."""
    tr = np.asarray(vds.client_sizes, np.int64)
    te = np.asarray(vds.full_sizes, np.int64) - tr
    tr_off = np.concatenate([[0], np.cumsum(tr)[:-1]])
    te_off = np.concatenate([[0], np.cumsum(te)[:-1]])
    return [(slice(int(tr_off[k]), int(tr_off[k] + tr[k])),
             slice(int(te_off[k]), int(te_off[k] + te[k])))
            for k in range(ds.num_clients)]


# --------------------------------------------------------------------- #
# 1. make_client_batch == generate row slices — every client, both splits
# --------------------------------------------------------------------- #


def test_make_client_batch_matches_generate_every_client(
        small_dataset, small_virtual_dataset):
    ds, vds = small_dataset, small_virtual_dataset
    assert ds.num_clients == vds.num_clients
    np.testing.assert_array_equal(ds.client_sizes, vds.client_sizes)
    for k, (trs, tes) in enumerate(_client_row_slices(ds, vds)):
        idx, val, y = (np.asarray(a) for a in make_client_batch(vds, k))
        tr = int(vds.client_sizes[k])
        np.testing.assert_array_equal(idx[:tr], ds.idx[trs], err_msg=f"k={k}")
        np.testing.assert_array_equal(val[:tr], ds.val[trs], err_msg=f"k={k}")
        np.testing.assert_array_equal(y[:tr], ds.y[trs], err_msg=f"k={k}")
        np.testing.assert_array_equal(idx[tr:], ds.test_idx[tes])
        np.testing.assert_array_equal(val[tr:], ds.test_val[tes])
        np.testing.assert_array_equal(y[tr:], ds.test_y[tes])


def _check_data_parity(case_seed):
    """One fuzzed case: a fresh tiny (cfg, seed) pair, then bitwise
    regeneration parity for a drawn client, prefix, and padded batch.

    The generation *seed*, total example count, drawn client/prefix/subset
    all vary freely; the jit-static axes (d, nnz, K, size bounds, batch
    shapes) come from small discrete grids so 200 examples reuse a bounded
    set of row-regeneration compilations instead of paying XLA per draw.
    """
    rng = np.random.default_rng(case_seed)
    d, nnz = [(33, 4), (48, 6)][int(rng.integers(0, 2))]
    K = int(rng.choice([8, 12]))
    n_min, n_max = [(1, 5), (3, 9)][int(rng.integers(0, 2))]
    cfg = LogRegConfig(
        num_clients=K, num_features=d,
        num_examples=int(rng.integers(K * n_min, K * n_max + 1)),
        min_client_examples=n_min, max_client_examples=n_max,
        nnz_per_example=nnz)
    seed = int(rng.integers(0, 2**16))

    ds = generate(cfg, seed=seed)
    vds = virtual_dataset(cfg, seed=seed)
    np.testing.assert_array_equal(ds.client_sizes,
                                  train_split_sizes(vds.full_sizes))

    # one drawn client, full rows == the ds slices, bitwise
    k = int(rng.integers(0, K))
    trs, tes = _client_row_slices(ds, vds)[k]
    idx, val, y = (np.asarray(a) for a in make_client_batch(vds, k))
    tr = int(vds.client_sizes[k])
    np.testing.assert_array_equal(idx[:tr], ds.idx[trs])
    np.testing.assert_array_equal(val[:tr], ds.val[trs])
    np.testing.assert_array_equal(y[:tr], ds.y[trs])
    np.testing.assert_array_equal(idx[tr:], ds.test_idx[tes])
    np.testing.assert_array_equal(val[tr:], ds.test_val[tes])
    np.testing.assert_array_equal(y[tr:], ds.test_y[tes])

    # a chronological prefix is a prefix (row keys don't depend on num_rows)
    r = min(int(rng.choice([1, 2, 3])), int(vds.full_sizes[k]))
    pidx, pval, py = (np.asarray(a) for a in make_client_batch(vds, k, r))
    np.testing.assert_array_equal(pidx, idx[:r])
    np.testing.assert_array_equal(pval, val[:r])
    np.testing.assert_array_equal(py, y[:r])

    # a drawn client batch in the engine's padded layout, bitwise vs the
    # padded train slices (idx 0 / val 0 / y 1 past n_k)
    size = min(K, int(rng.choice([3, 8])))
    ids = rng.choice(K, size=size, replace=False).astype(np.int32)
    n_k = np.asarray(vds.client_sizes, np.int64)[ids]
    m_pad = int(n_k.max() + rng.choice([0, 2]))
    bidx, bval, by = (np.asarray(a) for a in vds.client_rows_padded(
        jnp.asarray(ids), jnp.asarray(n_k.astype(np.int32)), m_pad))
    slices = _client_row_slices(ds, vds)
    for j, k in enumerate(ids):
        m = int(n_k[j])
        trs, _ = slices[int(k)]
        np.testing.assert_array_equal(bidx[j, :m], ds.idx[trs])
        np.testing.assert_array_equal(bval[j, :m], ds.val[trs])
        np.testing.assert_array_equal(by[j, :m], ds.y[trs])
        assert (bidx[j, m:] == 0).all() and (bval[j, m:] == 0).all()
        assert (by[j, m:] == 1.0).all()


test_virtual_matches_generate_fuzzed = _fuzz(_check_data_parity, N_EXAMPLES)


# --------------------------------------------------------------------- #
# 2-3. the virtual problem mirrors the materialized one
# --------------------------------------------------------------------- #


def test_virtual_problem_mirrors_materialized_layout():
    _, _, pm, pv = _pair()
    assert pv.virtual is not None and pm.virtual is None
    assert len(pv.buckets) == len(pm.buckets) > 1
    assert pv.num_clients == pm.num_clients
    assert pv.d == pm.d and pv.flat.n == pm.flat.n
    assert pv.flat.lam == pm.flat.lam
    np.testing.assert_array_equal(np.asarray(pv.client_weights),
                                  np.asarray(pm.client_weights))
    for bm, bv in zip(pm.buckets, pv.buckets):
        assert isinstance(bv, VirtualBucket)
        assert bv.m_pad == bm.m_pad and bv.num_clients == bm.num_clients
        np.testing.assert_array_equal(np.asarray(bv.n_k), np.asarray(bm.n_k))


def test_virtual_layout_realize_matches_materialized_buckets():
    """layout.realize(virtual bucket) IS the materialized bucket, bitwise —
    the row-level pin behind every round-parity property below."""
    _, _, pm, pv = _pair()
    for bm, vb in zip(pm.buckets, pv.buckets):
        cb = pv.virtual.realize(vb)
        np.testing.assert_array_equal(np.asarray(cb.idx), np.asarray(bm.idx))
        np.testing.assert_array_equal(np.asarray(cb.val), np.asarray(bm.val))
        np.testing.assert_array_equal(np.asarray(cb.y), np.asarray(bm.y))
        np.testing.assert_array_equal(np.asarray(cb.n_k), np.asarray(bm.n_k))


def test_engine_virtual_config_guards():
    _, _, pm, pv = _pair()
    # a virtual problem without the flag, and the flag without a layout
    with pytest.raises(ValueError):
        RoundEngine(pv, EngineConfig())
    with pytest.raises(ValueError):
        RoundEngine(pm, EngineConfig(virtual_data=True))
    with pytest.raises(ValueError):
        EngineConfig(virtual_data=1)
    eng_m = RoundEngine(pm, EngineConfig())
    with pytest.raises(ValueError):
        eng_m.round_virtual(jnp.zeros(pm.d), jax.random.PRNGKey(0),
                            lambda *a: None)
    eng_v = RoundEngine(pv, EngineConfig(virtual_data=True))
    with pytest.raises(ValueError):   # compile needs the keyed chunk pass
        eng_v.compile(lambda *a: None)


# --------------------------------------------------------------------- #
# 4. engine rounds: virtual == materialized, bit-for-bit
# --------------------------------------------------------------------- #


def _keyed_data_passes(lam):
    """A cheap *data- and key-consuming* keyed pass pair: one vectorized
    local gradient step plus a keyed perturbation (no per-row scan, so
    eager round tracing stays fast enough to fuzz).  ``chunk_pass`` is the
    virtual/streamed/cohort contract; ``client_pass`` its split-key twin
    for the materialized reference round."""

    def chunk_pass(w, bi, cb, keys):
        def one(idx, val, y, n_k, ck):
            nkf = jnp.maximum(n_k.astype(jnp.float32), 1.0)
            z = (val * w[idx]).sum(axis=1)
            g_sc = -y * jax.nn.sigmoid(-y * z) / nkf   # padded rows: val==0
            g = jnp.zeros_like(w).at[idx].add(g_sc[:, None] * val)
            r = jax.random.uniform(ck, w.shape) - 0.5
            return -0.5 * (g + lam * w) + 0.01 * r
        return jax.vmap(one)(cb.idx, cb.val, cb.y, cb.n_k, keys)

    def client_pass(w, bi, b, kb):
        return chunk_pass(w, bi, b, jax.random.split(kb, b.num_clients))

    return client_pass, chunk_pass


def test_virtual_chunk_pass_deltas_bitwise():
    """Per-client deltas from regenerated rows are bit-equal to deltas from
    materialized rows, bucket by bucket."""
    _, _, pm, pv = _pair()
    _, chunk_pass = _keyed_data_passes(pm.flat.lam)
    w = jax.random.uniform(jax.random.PRNGKey(3), (pm.d,)) * 0.1
    for bi, (bm, vb) in enumerate(zip(pm.buckets, pv.buckets)):
        keys = jax.random.split(jax.random.PRNGKey(bi), bm.num_clients)
        d_m = chunk_pass(w, bi, bm, keys)
        d_v = chunk_pass(w, bi, pv.virtual.realize(vb), keys)
        np.testing.assert_array_equal(np.asarray(d_v), np.asarray(d_m))


def _check_round_parity(case_seed):
    """One fuzzed knob draw: the same round key through the materialized
    engine and the virtual engine, on the matching path shape, must produce
    the identical iterate — bitwise, because the regenerated rows and the
    per-client key chain are both identical."""
    rng = np.random.default_rng(case_seed)
    _, _, pm, pv = _pair()
    chunk = [None, 1, 2, 3, 5][int(rng.integers(0, 5))]
    participation = [1.0, 0.5, 0.3][int(rng.integers(0, 3))]
    weighting = ["nk", "uniform", "sum"][int(rng.integers(0, 3))]
    aggregator = ["dense", "pallas"][int(rng.integers(0, 2))]
    cohort = [None, 2, 4][int(rng.integers(0, 3))]
    kw = dict(participation=participation, weighting=weighting,
              aggregator=aggregator, client_chunk=chunk, cohort=cohort)
    eng_m = RoundEngine(pm, EngineConfig(**kw))
    eng_v = RoundEngine(pv, EngineConfig(virtual_data=True, **kw))
    _, chunk_pass = _keyed_data_passes(pm.flat.lam)
    key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
    w = jax.random.uniform(jax.random.fold_in(key, 1), (pm.d,)) * 0.1

    if cohort is not None and participation < 1.0:
        out_m = eng_m.round_cohort(w, key, chunk_pass)
        out_v = eng_v.round_cohort(w, key, chunk_pass)
    elif chunk is not None:
        out_m = eng_m.round_streamed(w, key, chunk_pass)
        out_v = eng_v.round_virtual(w, key, chunk_pass)
    else:
        # chunk=None virtual rounds run the keyed whole-bucket body; the
        # matching materialized twin is the same _streamed_round shape
        # (plain round's stacked aggregation differs by summation order,
        # which is round-vs-round_streamed's documented tolerance, pinned
        # by the engine's own tests).
        out_m = eng_m._streamed_round(w, key, chunk_pass, None,
                                      eng_m.participation_masks(key))[0]
        out_v = eng_v.round_virtual(w, key, chunk_pass)
    np.testing.assert_array_equal(
        np.asarray(out_v), np.asarray(out_m),
        err_msg=f"chunk={chunk} p={participation} weighting={weighting} "
                f"agg={aggregator} cohort={cohort}")


test_virtual_round_matches_materialized_fuzzed = _fuzz(_check_round_parity,
                                                       _N_ROUND)


def test_virtual_round_with_state_matches_materialized():
    """Dual-state virtual rounds: deltas from regenerated rows, aux state
    carried materialized — iterate and state bit-equal to the materialized
    engine under partial participation (same freezing draw)."""
    _, _, pm, pv = _pair()
    kw = dict(weighting="sum", participation=0.5, client_chunk=2)
    eng_m = RoundEngine(pm, EngineConfig(**kw))
    eng_v = RoundEngine(pv, EngineConfig(virtual_data=True, **kw))
    _, chunk_pass = _keyed_data_passes(pm.flat.lam)

    def dual_chunk_pass(w, bi, cb, s_c, keys):
        deltas = chunk_pass(w, bi, cb, keys)
        return deltas, s_c + deltas[:, :3]

    states = [jnp.arange(b.num_clients * 3, dtype=jnp.float32)
              .reshape(b.num_clients, 3) for b in pm.buckets]
    w = jnp.zeros(pm.d)
    key = jax.random.PRNGKey(9)
    w_m, st_m = eng_m.round_streamed_with_state(w, states, key,
                                                dual_chunk_pass)
    w_v, st_v = eng_v.round_virtual_with_state(w, states, key,
                                               dual_chunk_pass)
    np.testing.assert_array_equal(np.asarray(w_v), np.asarray(w_m))
    for a, b in zip(st_v, st_m):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_virtual_compiled_matches_eager():
    """compile() on a virtual engine (no chunk, chunked, cohort) tracks the
    eager round_virtual to tight float tolerance (whole-round jit may
    re-associate the cross-bucket sum)."""
    _, _, _, pv = _pair()
    _, chunk_pass = _keyed_data_passes(pv.flat.lam)
    w = jax.random.uniform(jax.random.PRNGKey(5), (pv.d,)) * 0.1
    key = jax.random.PRNGKey(6)
    # the no-knob compile path is already pinned end-to-end by the gd solver
    # parity case; keep the two structurally distinct paths here
    for kw in (dict(client_chunk=3), dict(participation=0.4, cohort=4)):
        eng = RoundEngine(pv, EngineConfig(virtual_data=True, **kw))
        eager = (eng.round_cohort(w, key, chunk_pass)
                 if eng._use_cohort()
                 else eng.round_virtual(w, key, chunk_pass))
        compiled = eng.compile(None, chunk_pass=lambda w_, bi, cb, ks:
                               chunk_pass(w_, bi, cb, ks))(w, key)
        np.testing.assert_allclose(np.asarray(compiled), np.asarray(eager),
                                   rtol=1e-5, atol=1e-6, err_msg=str(kw))


# --------------------------------------------------------------------- #
# 5. solver-level parity across all five algorithms
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("algo,kw,exact", [
    ("gd", {}, True),
    ("gd", {"client_chunk": 4}, True),
    ("fedavg", {"participation": 0.5, "client_chunk": 4}, True),
    ("fedavg", {"participation": 0.3, "cohort": 8}, True),
    # FSVRG/DANE preludes compute the full gradient through VirtualFlat's
    # streamed scatter — summation order differs from the materialized flat
    # view by ulps, which the local scans then amplify to ~1e-7 on w.
    ("fsvrg", {}, False),
    ("dane", {}, False),
])
def test_solver_virtual_matches_materialized(algo, kw, exact):
    _, _, pm, pv = _pair()
    if algo == "fsvrg":
        a, b = _fsvrg_pair()
    else:
        a = make_solver(algo, pm, **kw)
        b = make_solver(algo, pv, **kw)
    sa, sb = a.init(), b.init()
    base = jax.random.PRNGKey(1)
    for r in range(2):
        kr = jax.random.fold_in(base, r)
        sa, sb = a.round(sa, kr), b.round(sb, kr)
    if exact:
        np.testing.assert_array_equal(np.asarray(sb.w), np.asarray(sa.w))
    else:
        np.testing.assert_allclose(np.asarray(sb.w), np.asarray(sa.w),
                                   rtol=1e-5, atol=1e-6)


def test_cocoa_virtual_matches_materialized():
    """Dual-state solver end-to-end: CoCoA+'s α blocks initialize over
    VirtualBucket shapes and stay materialized; iterate and blocks are
    bit-equal to the materialized run."""
    _, _, pm, pv = _pair()
    a = CoCoAPlus(pm, cfg=CoCoAConfig(client_chunk=2))
    b = CoCoAPlus(pv, cfg=CoCoAConfig(client_chunk=2))
    key = jax.random.PRNGKey(2)
    sa, sb = a.init(), b.init()
    for r in range(2):
        kr = jax.random.fold_in(key, r)
        sa, sb = a.round(sa, kr), b.round(sb, kr)
    np.testing.assert_array_equal(np.asarray(sb.w), np.asarray(sa.w))
    for x, y in zip(sa.aux, sb.aux):
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@functools.lru_cache(maxsize=1)
def _fsvrg_pair():
    """(materialized, virtual) FSVRG solvers on the tiny pair — cached so
    the iterate-parity and scaling-parity tests share one construction
    (the constructor compiles the streamed count/φ pipeline)."""
    _, _, pm, pv = _pair()
    return FSVRG(pm, FSVRGConfig()), FSVRG(pv, FSVRGConfig())


def test_fsvrg_virtual_scalings_exact():
    """FSVRG's φ and A come from streamed feature counts on the virtual
    path — integer-sum quantities, so they must be exactly equal."""
    a, b = _fsvrg_pair()
    np.testing.assert_array_equal(np.asarray(b.phi), np.asarray(a.phi))
    np.testing.assert_array_equal(np.asarray(b.a_diag), np.asarray(a.a_diag))


# --------------------------------------------------------------------- #
# 6. VirtualFlat is a faithful flat view
# --------------------------------------------------------------------- #


def test_virtual_flat_matches_materialized_flat():
    pm, pv = _pair()[2:]
    fm, fv = pm.flat, pv.flat
    assert fv.n == fm.n and fv.num_features == fm.num_features
    w = jax.random.uniform(jax.random.PRNGKey(7), (fm.num_features,)) * 0.2
    # loss/error_rate: identical masked per-row terms, scalar reductions
    np.testing.assert_allclose(float(fv.loss(w)), float(fm.loss(w)),
                               rtol=1e-6)
    # same integer error count; the /n normalizations round differently
    np.testing.assert_allclose(float(fv.error_rate(w)),
                               float(fm.error_rate(w)), rtol=1e-6)
    # grad: same per-row scalars, scatter order differs -> tight tolerance
    np.testing.assert_allclose(np.asarray(fv.grad(w)), np.asarray(fm.grad(w)),
                               rtol=1e-5, atol=2e-6)
    # counts are integer sums: exact
    np.testing.assert_array_equal(
        np.asarray(fv.feature_counts()),
        np.asarray(scaling.global_feature_counts(fm)))
    np.testing.assert_array_equal(
        np.asarray(fv.omega()), np.asarray(scaling.omega(pm)))
    np.testing.assert_array_equal(
        np.asarray(scaling.omega(pv)), np.asarray(scaling.omega(pm)))
    with pytest.raises(NotImplementedError):
        fv.margins(w)
