"""Per-architecture smoke tests: reduced variant (2 layers, d_model<=512,
<=4 experts), one forward/train step on CPU, assert output shapes + no NaNs.
Also checks prefill↔incremental-decode consistency per family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.models import build_model, make_batch

TRAIN_SHAPE = InputShape("smoke-train", 64, 2, "train")
PREFILL_SHAPE = InputShape("smoke-prefill", 32, 2, "prefill")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 8 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, TRAIN_SHAPE, dtype=jnp.float32)

    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, loss)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), arch

    # one SGD step must decrease the (full-batch) loss at lr -> small
    # (0.05 overshoots on the stiffest reduced configs, e.g. jamba; a
    # descent direction only guarantees decrease for small enough lr)
    def loss_after_step(lr):
        new_params = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                                  params, grads)
        return float(model.loss(new_params, batch)[0])

    losses2 = [loss_after_step(lr) for lr in (0.05, 0.005)]
    assert min(losses2) < float(loss), (arch, float(loss), losses2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode_consistency(arch):
    """Logits from prefill(tokens) == logits after feeding tokens one at a
    time through decode_step from an empty cache."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, PREFILL_SHAPE, jax.random.PRNGKey(2), dtype=jnp.float32)

    logits_pre, cache_pre = jax.jit(model.prefill)(params, batch)
    assert bool(jnp.isfinite(logits_pre).all()), arch
    assert logits_pre.shape == (PREFILL_SHAPE.global_batch, cfg.vocab_size)

    if cfg.family == "encdec_audio":
        # incremental decode continues from the prefill cache
        tok = jnp.argmax(logits_pre, -1)[:, None]
        logits_next, _ = jax.jit(model.decode_step)(params, tok, cache_pre)
        assert bool(jnp.isfinite(logits_next).all())
        return

    if cfg.family == "vlm":
        # scratch-decode path doesn't carry the image prefix; just check
        # continuation from the prefill cache
        tok = jnp.argmax(logits_pre, -1)[:, None]
        logits_next, _ = jax.jit(model.decode_step)(params, tok, cache_pre)
        assert bool(jnp.isfinite(logits_next).all())
        return

    toks = batch["tokens"]
    B, S = toks.shape
    cache = model.init_cache(B, S + 4)
    step = jax.jit(model.decode_step)
    logits = None
    for t in range(S):
        logits, cache = step(params, toks[:, t : t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_pre),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache_matches_full_history():
    """SWA (h2o-danube family): decode with the window ring-buffer cache
    must match full attention restricted to the window."""
    cfg = get_config("h2o-danube-1.8b").reduced()
    assert cfg.sliding_window is not None
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(3))
    W = cfg.sliding_window
    S = W * 2  # force wrap-around
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, S), 0, cfg.vocab_size)

    # reference: prefill on the full sequence (flash attention applies the
    # window mask directly)
    batch = {"tokens": toks, "labels": toks, "mask": jnp.ones_like(toks, jnp.float32)}
    logits_ref, _ = jax.jit(model.prefill)(params, batch)

    cache = model.init_cache(2, S)
    assert cache["pos0"]["k"].shape[2] == W  # ring buffer, not full length
    step = jax.jit(model.decode_step)
    logits = None
    for t in range(S):
        logits, cache = step(params, toks[:, t : t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_ref),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_decode_state_is_o1():
    cfg = get_config("rwkv6-3b").reduced()
    model = build_model(cfg, jnp.float32)
    cache = model.init_cache(2, 1_000_000)
    # no leaf scales with the sequence length
    for leaf in jax.tree.leaves(cache):
        assert leaf.size < 4_000_000, leaf.shape


def test_jamba_hybrid_structure():
    from repro.models import transformer as T
    cfg = get_config("jamba-v0.1-52b")
    P = T.pattern_period(cfg)
    assert P == 8
    kinds = [T.layer_kind(cfg, j) for j in range(P)]
    assert sum(1 for m, _ in kinds if m == "attn") == 1      # 1:7 interleave
    assert sum(1 for _, m in kinds if m == "moe") == 4       # every other layer
