"""Behavioral tests of the FSVRG algorithm family on the synthetic
federated problem: convergence, ablations of the four §3.6.2 modifications,
robustness to the non-IID distribution (the paper's FSVRG vs FSVRGR).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_logreg_config
from repro.core import FSVRG, FSVRGConfig, build_problem
from repro.core.baselines import fedavg_round, run_gd
from repro.core.cocoa import CoCoAPlus
from repro.data.synthetic import generate


def _optimum(prob, iters=4000, lr=1.5):
    w = jnp.zeros(prob.d)
    g = jax.jit(prob.flat.grad)
    for _ in range(iters):
        w = w - lr * g(w)
    return w


def test_fsvrg_converges_on_federated_problem(small_problem):
    prob = small_problem
    w_star = _optimum(prob)
    f_star = float(prob.flat.loss(w_star))
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))

    f10 = np.inf
    for h in (3.0, 10.0):   # best stepsize retrospectively (paper protocol)
        w = FSVRG(prob, FSVRGConfig(stepsize=h)).fit(10, seed=0).w
        f10 = min(f10, float(prob.flat.loss(w)))
    # 10 rounds close >=60% of the optimality gap
    assert (f0 - f10) > 0.6 * (f0 - f_star), (f0, f10, f_star)


def test_fsvrg_beats_gd_per_round(small_problem):
    prob = small_problem
    rounds = 8
    w_f = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(rounds, seed=0).w
    best_gd = np.inf
    for lr in (0.5, 2.0, 8.0):
        w_g, _ = run_gd(prob, jnp.zeros(prob.d), rounds, lr)
        best_gd = min(best_gd, float(prob.flat.loss(w_g)))
    assert float(prob.flat.loss(w_f)) < best_gd


def test_scaling_ablation_helps_on_noniid(small_problem):
    """S/A scaling should not hurt — and typically helps — on clustered
    non-IID sparse data (the paper's central claim)."""
    prob = small_problem
    rounds = 6
    w_full = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(rounds, seed=1).w
    w_plain = FSVRG(prob, FSVRGConfig(stepsize=1.0, use_S=False,
                                      use_A=False)).fit(rounds, seed=1).w
    f_full = float(prob.flat.loss(w_full))
    f_plain = float(prob.flat.loss(w_plain))
    assert f_full <= f_plain * 1.02, (f_full, f_plain)


def test_fsvrg_robust_to_reshuffling():
    """FSVRG on clustered vs randomly reshuffled data (FSVRGR, Fig. 2 red):
    per the paper the difference should be subtle."""
    cfg = get_logreg_config().scaled(0.002)
    ds = generate(cfg, seed=5)
    prob = build_problem(ds)

    # reshuffle example->client assignment, keep sizes
    rng = np.random.default_rng(0)
    perm = rng.permutation(ds.num_examples)
    import dataclasses
    ds_r = dataclasses.replace(ds, idx=ds.idx[perm], val=ds.val[perm], y=ds.y[perm])
    prob_r = build_problem(ds_r)

    rounds = 6
    w1 = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(rounds, seed=0).w
    w2 = FSVRG(prob_r, FSVRGConfig(stepsize=1.0)).fit(rounds, seed=0).w
    f1 = float(prob.flat.loss(w1))
    f2 = float(prob_r.flat.loss(w2))
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))
    # both make substantial progress; gap between them is small
    assert f1 < 0.95 * f0 and f2 < 0.95 * f0
    assert abs(f1 - f2) < 0.25 * (f0 - min(f1, f2)), (f1, f2)


def test_cocoa_plus_runs_and_improves(small_problem):
    prob = small_problem
    solver = CoCoAPlus(prob)
    state = solver.init()
    f0 = float(prob.flat.loss(state.w))
    for r in range(3):
        state = solver.round(state, jax.random.PRNGKey(r))
    f3 = float(prob.flat.loss(state.w))
    assert f3 < f0, (f0, f3)


def test_fedavg_round_improves(small_problem):
    prob = small_problem
    w0 = jnp.zeros(prob.d)
    f0 = float(prob.flat.loss(w0))
    w1 = fedavg_round(prob, w0, jax.random.PRNGKey(0), stepsize=0.05)
    assert float(prob.flat.loss(w1)) < f0


def test_unbalanced_weighted_aggregation_matters(small_problem):
    """n_k/n weighting (mod. 2) vs uniform 1/K on heavily unbalanced data."""
    prob = small_problem
    sizes = np.concatenate([np.asarray(b.n_k) for b in prob.buckets])
    assert sizes.max() > 2 * sizes.min()      # the data really is unbalanced
    w_w = FSVRG(prob, FSVRGConfig(stepsize=1.0)).fit(5, seed=2).w
    w_u = FSVRG(prob, FSVRGConfig(stepsize=1.0,
                                  use_weighted_agg=False)).fit(5, seed=2).w
    # weighted aggregation should not be materially worse
    assert float(prob.flat.loss(w_w)) <= float(prob.flat.loss(w_u)) * 1.05
