"""Delta-native fused aggregation + the compiled round path.

Four contracts from the round-hot-path fusion:

1. ``fused_aggregate`` (the delta-native Pallas kernel, reweight scalar and
   A epilogue folded in) matches its jnp oracle at ragged (K, d) sizes.
2. The engine's dense and fused aggregation paths agree across every
   ``weighting`` mode, ``participation < 1``, and ``server_scaling="diag"``.
3. The round's participation masks are drawn once
   (``RoundEngine.participation_masks``) and are bit-identical to the
   historical per-consumer re-derivation.
4. The compiled round (``RoundEngine.compile`` / ``compile_with_state``)
   pins against the reference ``round`` / ``round_with_state`` — through
   the FSVRG and CoCoA+ solvers, whose ``round`` dispatches the compiled
   closure.  The whole-round jit is free to re-associate the multi-bucket
   ``agg + Σ`` chain (it is bit-identical on single-bucket problems, where
   there is nothing to re-associate), so the iterate pin is a tight float
   tolerance; everything per-client — deltas, dual-state blocks, the
   participation draw — stays exact.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CoCoAConfig, CoCoAPlus, FSVRG, FSVRGConfig
from repro.core.engine import EngineConfig, RoundEngine
from repro.kernels import ops, ref

DTYPES = (jnp.float32, jnp.bfloat16)


# --------------------------------------------------------------------- #
# 1. kernel parity at ragged sizes
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
@pytest.mark.parametrize("scale", [1.0, 1.73])
@pytest.mark.parametrize("K,d", [(5, 1000), (1, 999), (5, 1), (13, 257)])
def test_fused_aggregate_parity(K, d, scale, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    wt = jax.random.normal(ks[0], (d,), dtype)
    deltas = jax.random.normal(ks[1], (K, d), dtype)
    wts = jax.nn.softmax(jax.random.normal(ks[2], (K,)))
    a = jnp.abs(jax.random.normal(ks[3], (d,))) + 0.5
    out = ops.fused_aggregate(wt, deltas, wts, a, scale)
    expect = ref.fused_aggregate_ref(wt, deltas, wts, a, scale)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=tol, atol=tol)


def test_fused_aggregate_zero_weights_is_noop():
    """All-zero weights (every client sampled out) must return w^t exactly —
    the masking contract of the participation path."""
    wt = jax.random.normal(jax.random.PRNGKey(0), (777,))
    deltas = jax.random.normal(jax.random.PRNGKey(1), (6, 777))
    a = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (777,))) + 0.5
    out = ops.fused_aggregate(wt, deltas, jnp.zeros((6,)), a, 3.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(wt))


def test_scaled_aggregate_wrapper_matches_iterate_oracle():
    """The compat entry point (iterate-consuming) still honours the old
    semantics w^t + A ⊙ Σ wts (w_k − w^t)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    wt = jax.random.normal(ks[0], (513,))
    wks = jax.random.normal(ks[1], (7, 513))
    wts = jax.nn.softmax(jax.random.normal(ks[2], (7,)))
    a = jnp.abs(jax.random.normal(ks[3], (513,))) + 0.5
    out = ops.scaled_aggregate(wt, wks, wts, a)
    expect = ref.scaled_aggregate_ref(wt, wks, wts, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- #
# 2. engine dense-vs-fused parity across the full knob cross
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("weighting", ["nk", "uniform", "sum"])
@pytest.mark.parametrize("participation", [1.0, 0.5])
@pytest.mark.parametrize("server_scaling", ["none", "diag"])
def test_dense_vs_fused_engine_aggregation(small_problem, weighting,
                                           participation, server_scaling):
    """aggregator='pallas' (the delta-native fused path: the Pallas kernel
    on TPU, the identical fused jnp expression elsewhere) == the dense jnp
    reference for every weighting mode × participation × diag scaling, on
    the ragged real bucket layout."""
    prob = small_problem
    w = jax.random.normal(jax.random.PRNGKey(1), (prob.d,)) * 0.1
    rng = np.random.default_rng(1)
    deltas = [
        jnp.asarray(rng.standard_normal((b.num_clients, prob.d)), jnp.float32)
        for b in prob.buckets
    ]
    a_diag = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (prob.d,))) + 0.5
    key = jax.random.PRNGKey(3)
    kw = dict(weighting=weighting, participation=participation,
              server_scaling=server_scaling)
    dense = RoundEngine(prob, EngineConfig(**kw), a_diag=a_diag)
    fused = RoundEngine(prob, EngineConfig(aggregator="pallas", **kw),
                        a_diag=a_diag)
    out_d = dense.aggregate(w, deltas, key)
    out_f = fused.aggregate(w, deltas, key)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- #
# 3. the single participation draw
# --------------------------------------------------------------------- #


def test_participation_masks_single_draw_matches_per_bucket_chain(small_problem):
    """participation_masks(key) is bit-identical to the per-bucket
    fold_in(key, wi) -> fold_in(kb, 997) chain both consumers used to
    re-derive — one draw, same bits."""
    prob = small_problem
    eng = RoundEngine(prob, EngineConfig(participation=0.4))
    key = jax.random.PRNGKey(7)
    masks = eng.participation_masks(key)
    assert len(masks) == len(prob.buckets)
    wi = 0
    for m, b in zip(masks, prob.buckets):
        expect = eng.participation_mask(jax.random.fold_in(key, wi),
                                        b.num_clients)
        np.testing.assert_array_equal(np.asarray(m), np.asarray(expect))
        wi += b.num_clients


def test_participation_masks_none_under_full_participation(small_problem):
    assert RoundEngine(small_problem, EngineConfig()).participation_masks(
        jax.random.PRNGKey(0)) is None


def test_aggregate_with_explicit_masks_is_bit_identical(small_problem):
    """Passing the precomputed masks vs letting aggregate re-derive them
    must be the same bits (the dedup is a pure refactor)."""
    prob = small_problem
    eng = RoundEngine(prob, EngineConfig(participation=0.5))
    rng = np.random.default_rng(3)
    deltas = [
        jnp.asarray(rng.standard_normal((b.num_clients, prob.d)), jnp.float32)
        for b in prob.buckets
    ]
    w = jnp.zeros(prob.d)
    key = jax.random.PRNGKey(11)
    out_implicit = eng.aggregate(w, deltas, key)
    out_explicit = eng.aggregate(w, deltas, key,
                                 masks=eng.participation_masks(key))
    np.testing.assert_array_equal(np.asarray(out_implicit),
                                  np.asarray(out_explicit))


# --------------------------------------------------------------------- #
# 4. compiled round == reference round, bit for bit
# --------------------------------------------------------------------- #


@pytest.mark.parametrize("participation", [1.0, 0.5])
def test_compiled_round_pins_reference_fsvrg(tiny_problem, participation):
    """FSVRG.round (the compiled closure) == the eager reference
    RoundEngine.round over 3 rounds (the full-gradient prelude stays
    eager).  Tight tolerance on the iterate: the whole-round jit may
    re-associate the cross-bucket aggregation sum (single-bucket problems
    pin bit-for-bit; this fixture has several buckets)."""
    prob = tiny_problem
    solver = FSVRG(prob, FSVRGConfig(stepsize=1.0,
                                     participation=participation))
    state = solver.init()
    w_ref = jnp.zeros(prob.d)
    base = jax.random.PRNGKey(0)
    for r in range(3):
        kr = jax.random.fold_in(base, r)
        state = solver.round(state, kr)
        w_ref = solver._round_ref(w_ref, kr)
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_ref),
                                   rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("participation", [1.0, 0.5])
def test_compiled_round_pins_reference_cocoa(tiny_problem, participation):
    """CoCoA+.round (compiled, dual-state) == the eager
    RoundEngine.round_with_state reference — iterate at tight tolerance
    (cross-bucket sum association, as for FSVRG), dual blocks **bit for
    bit**: per-client state never crosses the aggregation, so the jit has
    nothing to re-associate — including the frozen-state masking under
    partial participation."""
    prob = tiny_problem
    solver = CoCoAPlus(prob, cfg=CoCoAConfig(participation=participation))
    state = solver.init()
    w_ref, alphas_ref = state.w, state.aux
    base = jax.random.PRNGKey(1)
    for r in range(2):
        kr = jax.random.fold_in(base, r)
        state = solver.round(state, kr)
        w_ref, alphas_ref = solver._round_ref(w_ref, alphas_ref, kr)
        np.testing.assert_allclose(np.asarray(state.w), np.asarray(w_ref),
                                   rtol=1e-5, atol=1e-8)
        for a_c, a_r in zip(state.aux, alphas_ref):
            np.testing.assert_array_equal(np.asarray(a_c), np.asarray(a_r))


def test_bucket_grouping_matches_quadratic_reference(small_dataset):
    """The single-pass bucket grouping in build_problem must produce exactly
    the groups the old O(K²) tail-rescan comprehension produced."""
    from repro.core import build_problem

    ds = small_dataset
    sizes = ds.client_sizes.astype(np.int64)
    order = np.argsort(np.ceil(np.log2(np.maximum(sizes, 1))).astype(np.int64),
                       kind="stable")
    expected = []
    i = 0
    while i < len(order):
        b = int(np.ceil(np.log2(max(sizes[order[i]], 1))))
        members = [k for k in order[i:]
                   if int(np.ceil(np.log2(max(sizes[k], 1)))) == b]
        i += len(members)
        expected.append([int(k) for k in members])

    prob = build_problem(ds)
    assert len(prob.buckets) == len(expected)
    for bucket, members in zip(prob.buckets, expected):
        np.testing.assert_array_equal(np.asarray(bucket.n_k),
                                      sizes[members].astype(np.int32))


def test_compiled_round_respects_fused_aggregator(tiny_problem):
    """A solver built with aggregator='pallas' routes its compiled round
    through the delta-native kernel and stays allclose to the dense build."""
    prob = tiny_problem
    dense = FSVRG(prob, FSVRGConfig(stepsize=1.0))
    fused = FSVRG(prob, FSVRGConfig(stepsize=1.0, aggregator="pallas"))
    key = jax.random.PRNGKey(2)
    sd = dense.round(dense.init(), key)
    sf = fused.round(fused.init(), key)
    np.testing.assert_allclose(np.asarray(sf.w), np.asarray(sd.w),
                               rtol=1e-5, atol=1e-5)
