"""Federated-LLM bridge (core/neural.py): FSVRG rounds on transformer
pytrees — convergence, vocab-occupancy scaling semantics, FedAvg mode.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import InputShape
from repro.core import neural
from repro.models import build_model, make_batch


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, InputShape("t", 64, 8, "train"), dtype=jnp.float32)
    return cfg, model, params, batch


def test_vocab_stats_semantics():
    vocab = 16
    # client 0 uses tokens {0,1}, client 1 uses {2,3} -> omega=1 for all, a=2
    tokens = jnp.array([[[0, 1, 0, 1]], [[2, 3, 2, 3]]])
    phi, omega, a = neural.vocab_stats(tokens, vocab)
    np.testing.assert_allclose(np.asarray(phi[:4]), 0.25)
    assert (np.asarray(omega[:4]) == 1).all()
    np.testing.assert_allclose(np.asarray(a[:4]), 2.0)   # C/omega = 2/1
    np.testing.assert_allclose(np.asarray(a[4:]), 1.0)   # unseen tokens

    s0 = neural.s_k_vocab(phi, tokens[0].reshape(-1), vocab)
    # client 0 sees tokens 0,1 with local freq 0.5 vs global 0.25 -> s=0.5
    np.testing.assert_allclose(np.asarray(s0[:2]), 0.5)
    np.testing.assert_allclose(np.asarray(s0[2:]), 1.0)


def test_fsvrg_round_decreases_loss(setup):
    cfg, model, params, batch = setup
    cb = neural.make_client_batches(batch, num_clients=4, local_steps=2)
    rnd = jax.jit(neural.make_fsvrg_round(model, neural.FedNeuralConfig(stepsize=0.5,
                                                                        local_steps=2)))
    p = params
    losses = [float(model.loss(p, batch)[0])]
    for _ in range(3):
        p, _ = rnd(p, cb)
        losses.append(float(model.loss(p, batch)[0]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_fedavg_mode_runs(setup):
    cfg, model, params, batch = setup
    cb = neural.make_client_batches(batch, num_clients=4, local_steps=2)
    rnd = jax.jit(neural.make_fsvrg_round(
        model, neural.FedNeuralConfig(stepsize=0.02, local_steps=2,
                                      algorithm="fedavg")))
    p, m = rnd(params, cb)
    assert float(model.loss(p, batch)[0]) < float(model.loss(params, batch)[0])


def test_fixed_point_at_zero_gradient(setup):
    """Neural property (A): if the full gradient and all per-batch gradients
    vanish, a round is a no-op.  We can't reach a true optimum cheaply, so
    check the algebra: with stepsize 0 the round must be the identity."""
    cfg, model, params, batch = setup
    cb = neural.make_client_batches(batch, num_clients=2, local_steps=1)
    rnd = jax.jit(neural.make_fsvrg_round(model, neural.FedNeuralConfig(stepsize=0.0)))
    p, _ = rnd(params, cb)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_make_client_batches_shapes(setup):
    cfg, model, params, batch = setup
    cb = neural.make_client_batches(batch, num_clients=4, local_steps=2)
    assert cb["tokens"].shape[:2] == (4, 2)
    assert cb["tokens"].shape[0] * cb["tokens"].shape[1] * cb["tokens"].shape[2] \
        == batch["tokens"].shape[0]


def test_optimizers_step():
    from repro.optim import adamw, momentum, sgd

    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    grads = jax.tree.map(jnp.ones_like, params)
    for opt in (sgd(0.1), momentum(0.1), adamw(0.1)):
        state = opt.init(params)
        p2, _ = opt.update(params, grads, state, jnp.zeros((), jnp.int32))
        assert float(p2["w"][0, 0]) < 1.0
