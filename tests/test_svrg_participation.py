"""Algorithm 1 (single-machine SVRG) behaviour + partial-participation FSVRG."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FSVRG, FSVRGConfig
from repro.core.svrg import run_svrg, svrg_epoch


def test_svrg_beats_gd_per_data_pass(small_problem):
    """§2.2: SVRG combines cheap iterations with fast convergence — at an
    equal number of full data passes it beats GD."""
    prob = small_problem.flat
    w0 = jnp.zeros(prob.num_features)
    # 6 SVRG epochs, m=n: each epoch = 2 passes (full grad + stochastic).
    # Alg. 1's h is the raw per-step size (~1/L), unlike FSVRG's h/n_k —
    # sweep small values per the paper's protocol.
    w_svrg = None
    best = np.inf
    for h in (0.03, 0.1, 0.3):
        w_h, hist = run_svrg(prob, w0, epochs=6, stepsize=h)
        if float(prob.loss(w_h)) < best:
            best, w_svrg = float(prob.loss(w_h)), w_h
    # GD with 12 passes (same data-touch budget), best of 3 stepsizes
    best_gd = np.inf
    for lr in (0.5, 2.0, 8.0):
        w = w0
        for _ in range(12):
            w = w - lr * prob.grad(w)
        best_gd = min(best_gd, float(prob.loss(w)))
    assert float(prob.loss(w_svrg)) < best_gd
    # monotone-ish: final better than first epoch
    assert hist[-1] < hist[0]


def test_svrg_fixed_point(small_problem):
    prob = small_problem.flat
    w = jnp.zeros(prob.num_features)
    for _ in range(3000):
        w = w - 2.0 * prob.grad(w)
    gn = float(jnp.linalg.norm(prob.grad(w)))
    h, m = 0.03, prob.n
    w2 = svrg_epoch(prob, w, jax.random.PRNGKey(0), stepsize=h, m=m)
    # at the optimum the VR terms cancel; drift is bounded by m·h·|∇f|
    assert float(jnp.linalg.norm(w2 - w)) < 5 * m * h * gn + 1e-6


@pytest.mark.parametrize("participation", [0.5, 0.25])
def test_partial_participation_still_converges(small_problem, participation):
    prob = small_problem
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))
    solver = FSVRG(prob, FSVRGConfig(stepsize=1.0, participation=participation))
    f8 = float(prob.flat.loss(solver.fit(8, seed=0).w))
    assert f8 < 0.93 * f0, (f8, f0)


def test_full_participation_unchanged(small_problem):
    """participation=1.0 must be bit-identical to the default path."""
    prob = small_problem
    w0 = jnp.zeros(prob.d)
    s1 = FSVRG(prob, FSVRGConfig(stepsize=1.0))
    s2 = FSVRG(prob, FSVRGConfig(stepsize=1.0, participation=1.0))
    w1 = s1.round(s1.init(w0), jax.random.PRNGKey(3)).w
    w2 = s2.round(s2.init(w0), jax.random.PRNGKey(3)).w
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
