"""Round-latency benchmark: the repo's measured perf trajectory.

The paper's premise is that *rounds* are the scarce resource (§1), so the
number this benchmark tracks is the server-side wall-clock latency of one
federated round, per algorithm × aggregation path × problem scale:

  * ``eager_dense``   — the reference round: a Python loop of per-bucket
                        dispatches plus the eager jnp weighted-sum
                        aggregation (``RoundEngine.round``, the pre-compile
                        hot path and the baseline every speedup is against).
  * ``compiled_dense``— the same round as one compiled dispatch
                        (``RoundEngine.compile``), dense aggregation.
  * ``compiled_fused``— the compiled round with the delta-native fused
                        aggregation (one HBM pass over the stacked deltas,
                        reweight + A epilogue folded in: the
                        ``fused_aggregate`` Pallas kernel on TPU, the
                        identical fused jnp expression elsewhere).

``--paper-k`` appends the paper-scale *client axis* entry: the §4
experiment's K = 10,000 clients (d and n_k shrunk so it fits CPU CI,
``configs.gplus_logreg.PAPER_K_CONFIG``), timed over

  * ``eager_dense``            — the unchunked reference round, which
                                 materializes every bucket's (Kb, d) delta
                                 stack: O(K·d) peak delta memory.
  * ``compiled_chunked_dense`` — the streamed round
                                 (``EngineConfig.client_chunk``): the client
                                 axis runs in chunks under one ``jax.jit``,
                                 O(client_chunk·d) peak delta memory.
  * ``compiled_chunked_fused`` — the streamed round accumulating through the
                                 delta-native ``fused_aggregate`` chunk
                                 entry.

``--participation-sweep`` appends the partial-participation family at the
same paper-scale config: for each participation p ∈ {1.0, 0.3, 0.1} it
times the **masked** streamed round (every client's pass runs; the
Bernoulli draw zeroes non-participants' weights) against the **cohort**
round (``EngineConfig.cohort``: only the sampled clients are gathered and
computed, capacity from ``cohort_capacity``).  At p=1.0 the cohort knob is
a compile-time no-op, so that row is the ≈1× sanity anchor; at the paper's
~10% participation the cohort path should win by roughly 1/p.

``--virtual`` appends the bounded-memory *virtual data* sweep: the client
axis pushed to the §1.2 "as many nodes as users" regime, K ∈ {10⁴, 10⁵,
10⁶} on ``configs.get_virtual_k_config`` — no dataset is ever
materialized; each scanned chunk's rows are regenerated inside the
compiled round (``EngineConfig.virtual_data``).  Alongside the round
latency it records the memory columns that make the claim checkable:

  * ``live_buffer_mb``     — Σ nbytes over ``jax.live_arrays()`` after the
                             timed rounds: every device buffer the process
                             retains.  The headline column — it must stay
                             at per-client *metadata* scale (a few B/client)
                             while ``est_materialized_mb`` grows ~50x per
                             K step.
  * ``est_materialized_mb``— what the same dataset's row arrays would
                             occupy if generated materialized.
  * ``rss_mb``             — psutil RSS after the entry's rounds.
  * ``peak_rss_mb``        — ``ru_maxrss``: the *process-lifetime* high
                             water mark, i.e. a monotone upper bound shared
                             by everything that ran before (compiles, other
                             entries); reported for context, not a per-K
                             signal.

Writes ``BENCH_round.json`` at the repo root — ≥ 2 problem scales × ≥ 3
algorithms, median/mean/min round latency per path and the
dense-vs-fused speedups, so every future PR has a trajectory to be judged
against.  ``--smoke`` is the CI guard: a tiny config that exercises every
path end-to-end (run by ``tests/run_tier1.sh`` with a scratch ``--json`` so
the committed trajectory file is not clobbered; ``--smoke --paper-k`` is the
budget-guarded large-K variant, ``--smoke --participation-sweep`` the
budget-guarded cohort variant, and ``--smoke --virtual`` the budget-guarded
K=10⁴ virtual variant — each skips the scale sweep).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import resource
import statistics
import time

import jax

try:
    import psutil
except ImportError:          # pragma: no cover - env-dependent
    psutil = None

from repro.configs import (get_logreg_config, get_paper_k_config,
                           get_virtual_k_config)
from repro.core import (build_problem, build_virtual_problem,
                        cohort_capacity, make_solver)
from repro.data.synthetic import generate, virtual_dataset

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_JSON = os.path.join(REPO_ROOT, "BENCH_round.json")

#: registry solvers benched by default — all stateless-w sparse solvers whose
#: round is a pure (w, key) -> w closure (dual-state solvers carry (Kb, m_pad)
#: blocks whose timing is dominated by the local SDCA scan, not the round
#: template this benchmark measures).
ALGOS = ("gd", "fedavg", "fsvrg", "dane")
PATHS = ("eager_dense", "compiled_dense", "compiled_fused")

#: the paper-scale entry's paths: unchunked reference vs the streamed round
PAPER_K_ALGOS = ("gd", "fedavg", "fsvrg")
PAPER_K_PATHS = ("eager_dense", "compiled_chunked_dense",
                 "compiled_chunked_fused")
PAPER_K_BUCKET_ROWS = 20_000

#: the participation-sweep family: masked streamed round vs cohort round at
#: the paper-scale config, per participation level
SWEEP_PARTICIPATIONS = (1.0, 0.3, 0.1)
SWEEP_PATHS = ("masked_chunked", "cohort_chunked")
SWEEP_ALGO = "fedavg"

#: the aggregator-guard overhead family at the paper-scale config: the
#: streamed round with and without the per-client clip guard, and the plain
#: round with and without coordinate-wise trimmed-mean (order stats need the
#: full delta stacks, so they have no streamed variant to compare)
GUARD_ALGO = "fedavg"
GUARD_PATHS = ("chunked_none", "chunked_clip",
               "plain_none", "plain_trimmed_mean")

#: the virtual-data client-axis sweep (ascending, so each K's numbers land
#: before the next, bigger one runs); gd+fedavg up to 10⁵, gd only at 10⁶
VIRTUAL_KS = (10_000, 100_000, 1_000_000)
VIRTUAL_ALGOS = ("gd", "fedavg")
VIRTUAL_GD_ONLY_ABOVE = 100_000
VIRTUAL_PATH = "compiled_virtual_chunked"


def _virtual_closures(algos, pv, chunk: int):
    """algo -> compiled virtual streamed round on the virtual problem (the
    solver factories detect ``problem.virtual`` and route their keyed chunk
    passes through ``EngineConfig.virtual_data``)."""
    return {algo: make_solver(algo, pv, client_chunk=chunk)._round_fast
            for algo in algos}


def _memory_columns():
    """(rss_mb, peak_rss_mb, live_buffer_mb) right now — see the module
    docstring for what each column can and cannot claim."""
    rss_mb = (psutil.Process().memory_info().rss / 2**20) if psutil else None
    # ru_maxrss is KB on Linux; process-lifetime monotone high-water mark
    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
    live_mb = sum(a.nbytes for a in jax.live_arrays()) / 2**20
    return rss_mb, peak_rss_mb, live_mb


def _est_materialized_mb(cfg) -> float:
    """What generate() would hold for this config: per row, nnz idx (i32) +
    nnz val (f32) + label (f32) + client id (i32), train + test."""
    row_bytes = cfg.nnz_per_example * 8 + 8
    return cfg.num_examples * row_bytes / 2**20


def _round_closures(algo: str, prob):
    """(eager_dense, compiled_dense, compiled_fused) round closures."""
    dense = make_solver(algo, prob)
    fused = make_solver(algo, prob, aggregator="pallas")
    return {
        "eager_dense": dense._round_ref,
        "compiled_dense": dense._round_fast,
        "compiled_fused": fused._round_fast,
    }


def _paper_k_closures(algo: str, prob, chunk: int):
    """Round closures for the large-K entry: the unchunked eager reference
    against the streamed (client_chunk) compiled round, dense and fused."""
    dense = make_solver(algo, prob)
    chunked = make_solver(algo, prob, client_chunk=chunk)
    fused = make_solver(algo, prob, client_chunk=chunk, aggregator="pallas")
    return {
        "eager_dense": dense._round_ref,
        "compiled_chunked_dense": chunked._round_fast,
        "compiled_chunked_fused": fused._round_fast,
    }


def _sweep_closures(algo: str, prob, chunk: int, participation: float):
    """(masked_chunked, cohort_chunked) compiled round closures at one
    participation level, plus the cohort capacity used.  Both paths stream
    with the same client_chunk; the only difference is whether the
    non-participants' passes run at all."""
    masked = make_solver(algo, prob, client_chunk=chunk,
                         participation=participation)
    cap = cohort_capacity(participation,
                          max(b.num_clients for b in prob.buckets)) \
        if participation < 1.0 else None
    kw = dict(client_chunk=chunk, participation=participation)
    if cap is not None:
        kw["cohort"] = cap
    cohort = make_solver(algo, prob, **kw)
    return {
        "masked_chunked": masked._round_fast,
        "cohort_chunked": cohort._round_fast,
    }, cap


def _guard_closures(algo: str, prob, chunk: int):
    """Guard-vs-none compiled round closures: the robust-aggregation cost
    is the *difference* within each (chunked, plain) pair."""
    return {
        "chunked_none": make_solver(algo, prob,
                                    client_chunk=chunk)._round_fast,
        "chunked_clip": make_solver(algo, prob, client_chunk=chunk,
                                    aggregator_guard="clip")._round_fast,
        "plain_none": make_solver(algo, prob)._round_fast,
        "plain_trimmed_mean": make_solver(
            algo, prob, aggregator_guard="trimmed_mean")._round_fast,
    }


def _time_rounds(closures, w0, rounds: int, repeats: int):
    """Per-round wall-clock samples per path (blocking each round).

    Paths are *interleaved at round granularity* — path A's round r runs
    back-to-back with path B's round r — so ambient machine load perturbs
    every path equally instead of biasing whichever path ran during a busy
    window.  Compilation happens in a warmup round outside the clock.
    """
    key = jax.random.PRNGKey(0)
    # every closure gets its own w0 buffer: compiled rounds donate their
    # input iterate on accelerator backends, so paths must never share one
    for fn in closures.values():
        jax.block_until_ready(fn(jax.numpy.array(w0),
                                 jax.random.fold_in(key, 0)))
    samples = {path: [] for path in closures}
    for _ in range(repeats):
        ws = {path: jax.numpy.array(w0) for path in closures}
        for r in range(rounds):
            kr = jax.random.fold_in(key, r)
            for path, fn in closures.items():
                t0 = time.perf_counter()
                w = fn(ws[path], kr)
                jax.block_until_ready(w)
                samples[path].append(time.perf_counter() - t0)
                ws[path] = w
    return samples


def _stats(samples):
    return {
        "median_s": statistics.median(samples),
        "mean_s": statistics.fmean(samples),
        "min_s": min(samples),
        "samples": len(samples),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--scales", default="0.002,0.005",
                    help="comma-separated problem scales (see "
                         "configs.gplus_logreg.scaled); the last one is the "
                         "'largest config' the speedup headline reports")
    ap.add_argument("--algos", default=",".join(ALGOS))
    ap.add_argument("--rounds", type=int, default=4,
                    help="timed rounds per repeat (after a compile warmup)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=DEFAULT_JSON)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: tiny config, 2 algorithms, 1 repeat")
    ap.add_argument("--paper-k", action="store_true",
                    help="append the K=10,000 paper-scale client-axis entry "
                         "(streamed rounds); with --smoke, run ONLY it at "
                         "reduced budget")
    ap.add_argument("--paper-chunk", type=int, default=512,
                    help="client_chunk for the --paper-k streamed rounds")
    ap.add_argument("--participation-sweep", action="store_true",
                    help="append the masked-vs-cohort family at the paper-k "
                         "config over --sweep-participations; with --smoke, "
                         "run ONLY it at reduced budget")
    ap.add_argument("--sweep-participations",
                    default=",".join(str(p) for p in SWEEP_PARTICIPATIONS))
    ap.add_argument("--guard-overhead", action="store_true",
                    help="append the aggregator-guard overhead family at "
                         "the paper-k config (guard vs none, streamed clip "
                         "and plain trimmed-mean); with --smoke, run ONLY "
                         "it at reduced budget")
    ap.add_argument("--virtual", action="store_true",
                    help="append the virtual-data client-axis sweep "
                         "(K up to 10^6, rows regenerated on demand); with "
                         "--smoke, run ONLY it at K=10^4")
    ap.add_argument("--virtual-ks",
                    default=",".join(str(k) for k in VIRTUAL_KS))
    ap.add_argument("--virtual-chunk", type=int, default=2048,
                    help="client_chunk for the --virtual streamed rounds")
    args = ap.parse_args(argv)

    if args.smoke:
        scales = [] if (args.paper_k or args.participation_sweep
                        or args.virtual or args.guard_overhead) else [0.001]
        algos = ["gd", "fedavg"]
        rounds, repeats = 2, 1
        pk_algos = ["gd", "fedavg"]
        sweep_ps = [0.1]     # budget guard: the headline level only
        virtual_ks = [10_000]
    else:
        scales = [float(s) for s in args.scales.split(",") if s]
        algos = [a.strip() for a in args.algos.split(",")]
        rounds, repeats = args.rounds, args.repeats
        pk_algos = list(PAPER_K_ALGOS)
        sweep_ps = [float(p) for p in args.sweep_participations.split(",")
                    if p]
        virtual_ks = sorted(int(k) for k in args.virtual_ks.split(",") if k)

    results = {
        "schema": 5,
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "seed": args.seed,
        "rounds_per_repeat": rounds,
        "repeats": repeats,
        "paths": list(PATHS),
        "configs": [],
    }

    print("scale,algo,path,median_s,mean_s,min_s")
    for scale in scales:
        cfg = get_logreg_config().scaled(scale)
        ds = generate(cfg, seed=args.seed)
        prob = build_problem(ds)
        entry = {
            "scale": scale,
            "clients": int(ds.num_clients),
            "examples": int(ds.num_examples),
            "features": int(ds.num_features),
            "buckets": len(prob.buckets),
            "algos": {},
        }
        for algo in algos:
            closures = _round_closures(algo, prob)
            w0 = jax.numpy.zeros(prob.d)
            all_samples = _time_rounds(closures, w0, rounds, repeats)
            rec = {}
            for path in PATHS:
                rec[path] = _stats(all_samples[path])
                print(f"{scale},{algo},{path},{rec[path]['median_s']:.5f},"
                      f"{rec[path]['mean_s']:.5f},{rec[path]['min_s']:.5f}")
            eager = rec["eager_dense"]["median_s"]
            rec["speedup_compiled_vs_eager"] = \
                eager / rec["compiled_dense"]["median_s"]
            rec["speedup_fused_vs_eager"] = \
                eager / rec["compiled_fused"]["median_s"]
            # Paired estimate: sample i of every path ran back-to-back under
            # the same machine load, so the median of per-round ratios is
            # far more noise-robust than the ratio of medians.
            rec["paired_speedup_fused_vs_eager"] = statistics.median(
                e / f for e, f in zip(all_samples["eager_dense"],
                                      all_samples["compiled_fused"]))
            entry["algos"][algo] = rec

        # One "round of everything": total median latency across the benched
        # algorithms, per path — the headline trajectory number.
        entry["total_median_s"] = {
            path: sum(rec[path]["median_s"] for rec in entry["algos"].values())
            for path in PATHS}
        results["configs"].append(entry)

    if scales:
        largest = results["configs"][-1]
        paired = {a: rec["paired_speedup_fused_vs_eager"]
                  for a, rec in largest["algos"].items()}
        # Headline speedup: geometric mean across algorithms of the *paired*
        # per-round estimates.  Summed raw medians let one compute-heavy
        # algorithm's ambient-load noise (±3% on a shared machine) swamp the
        # real per-algorithm wins; the paired ratios cancel that load, and
        # the geomean is the standard cross-benchmark summary.
        geomean = math.exp(
            statistics.fmean(math.log(s) for s in paired.values()))
        results["largest"] = {
            "scale": largest["scale"],
            "clients": largest["clients"],
            "median_round_latency_s": largest["total_median_s"],
            "per_algo_paired_speedup_fused_vs_eager": paired,
            "speedup_fused_vs_eager": geomean,
            "fused_beats_eager": geomean > 1.0,
        }
        print("# largest config (scale={scale}, K={clients}): total median "
              "round latency {median_round_latency_s}; paired per-algo "
              "{per_algo_paired_speedup_fused_vs_eager} -> fused-vs-eager "
              "speedup (geomean) {speedup_fused_vs_eager:.3f} "
              "(beats eager: {fused_beats_eager})"
              .format(**results["largest"]))

    pk_prob = None
    if args.paper_k or args.participation_sweep or args.guard_overhead:
        pk_cfg = get_paper_k_config()
        ds = generate(pk_cfg, seed=args.seed)
        pk_prob = build_problem(ds, max_bucket_rows=PAPER_K_BUCKET_ROWS)

    if args.paper_k:
        prob = pk_prob
        entry = {
            "scale": "paper-k",
            "clients": int(ds.num_clients),
            "examples": int(ds.num_examples),
            "features": int(ds.num_features),
            "buckets": len(prob.buckets),
            "client_chunk": args.paper_chunk,
            "max_bucket_rows": PAPER_K_BUCKET_ROWS,
            "paths": list(PAPER_K_PATHS),
            "algos": {},
        }
        for algo in pk_algos:
            closures = _paper_k_closures(algo, prob, args.paper_chunk)
            w0 = jax.numpy.zeros(prob.d)
            all_samples = _time_rounds(closures, w0, rounds, repeats)
            rec = {}
            for path in PAPER_K_PATHS:
                rec[path] = _stats(all_samples[path])
                print(f"paper-k,{algo},{path},{rec[path]['median_s']:.5f},"
                      f"{rec[path]['mean_s']:.5f},{rec[path]['min_s']:.5f}")
            rec["paired_speedup_chunked_vs_eager"] = statistics.median(
                e / c for e, c in zip(all_samples["eager_dense"],
                                      all_samples["compiled_chunked_dense"]))
            entry["algos"][algo] = rec
        entry["total_median_s"] = {
            path: sum(rec[path]["median_s"] for rec in entry["algos"].values())
            for path in PAPER_K_PATHS}
        results["configs"].append(entry)
        results["paper_k"] = {
            "clients": entry["clients"],
            "client_chunk": entry["client_chunk"],
            "median_round_latency_s": entry["total_median_s"],
            "per_algo_paired_speedup_chunked_vs_eager": {
                a: rec["paired_speedup_chunked_vs_eager"]
                for a, rec in entry["algos"].items()},
        }
        print("# paper-k (K={clients}, client_chunk={client_chunk}): total "
              "median round latency {median_round_latency_s}; paired "
              "chunked-vs-eager "
              "{per_algo_paired_speedup_chunked_vs_eager}"
              .format(**results["paper_k"]))

    if args.participation_sweep:
        prob = pk_prob
        entry = {
            "scale": "paper-k-participation-sweep",
            "clients": int(ds.num_clients),
            "features": int(ds.num_features),
            "buckets": len(prob.buckets),
            "client_chunk": args.paper_chunk,
            "max_bucket_rows": PAPER_K_BUCKET_ROWS,
            "algo": SWEEP_ALGO,
            "paths": list(SWEEP_PATHS),
            "participations": {},
        }
        for p in sweep_ps:
            closures, cap = _sweep_closures(SWEEP_ALGO, prob,
                                            args.paper_chunk, p)
            w0 = jax.numpy.zeros(prob.d)
            all_samples = _time_rounds(closures, w0, rounds, repeats)
            rec = {"cohort_capacity": cap}
            for path in SWEEP_PATHS:
                rec[path] = _stats(all_samples[path])
                print(f"sweep-p={p},{SWEEP_ALGO},{path},"
                      f"{rec[path]['median_s']:.5f},"
                      f"{rec[path]['mean_s']:.5f},{rec[path]['min_s']:.5f}")
            rec["paired_speedup_cohort_vs_masked"] = statistics.median(
                m / c for m, c in zip(all_samples["masked_chunked"],
                                      all_samples["cohort_chunked"]))
            entry["participations"][str(p)] = rec
        results["configs"].append(entry)
        summary = {
            "algo": SWEEP_ALGO,
            "clients": entry["clients"],
            "client_chunk": entry["client_chunk"],
            "per_participation_paired_speedup_cohort_vs_masked": {
                p_str: rec["paired_speedup_cohort_vs_masked"]
                for p_str, rec in entry["participations"].items()},
        }
        lowest = str(min(sweep_ps))
        if lowest in entry["participations"]:
            s_low = entry["participations"][lowest][
                "paired_speedup_cohort_vs_masked"]
            summary["lowest_participation"] = float(lowest)
            summary["speedup_cohort_vs_masked_at_lowest"] = s_low
            summary["cohort_beats_masked_2x_at_lowest"] = s_low >= 2.0
        results["participation_sweep"] = summary
        print("# participation sweep ({algo}, K={clients}): paired "
              "cohort-vs-masked "
              "{per_participation_paired_speedup_cohort_vs_masked}"
              .format(**summary))

    if args.guard_overhead:
        prob = pk_prob
        entry = {
            "scale": "paper-k-guard-overhead",
            "clients": int(ds.num_clients),
            "features": int(ds.num_features),
            "buckets": len(prob.buckets),
            "client_chunk": args.paper_chunk,
            "max_bucket_rows": PAPER_K_BUCKET_ROWS,
            "algo": GUARD_ALGO,
            "paths": list(GUARD_PATHS),
        }
        closures = _guard_closures(GUARD_ALGO, prob, args.paper_chunk)
        w0 = jax.numpy.zeros(prob.d)
        all_samples = _time_rounds(closures, w0, rounds, repeats)
        for path in GUARD_PATHS:
            entry[path] = _stats(all_samples[path])
            print(f"guard,{GUARD_ALGO},{path},{entry[path]['median_s']:.5f},"
                  f"{entry[path]['mean_s']:.5f},{entry[path]['min_s']:.5f}")
        # paired per-round ratios within each (guard, none) pair — ambient
        # load cancels, leaving the guard's own arithmetic
        entry["paired_overhead_clip_vs_none"] = statistics.median(
            c / n for c, n in zip(all_samples["chunked_clip"],
                                  all_samples["chunked_none"]))
        entry["paired_overhead_trimmed_vs_none"] = statistics.median(
            t / n for t, n in zip(all_samples["plain_trimmed_mean"],
                                  all_samples["plain_none"]))
        results["configs"].append(entry)
        results["guard_overhead"] = {
            "algo": GUARD_ALGO,
            "clients": entry["clients"],
            "client_chunk": entry["client_chunk"],
            "paired_overhead_clip_vs_none":
                entry["paired_overhead_clip_vs_none"],
            "paired_overhead_trimmed_vs_none":
                entry["paired_overhead_trimmed_vs_none"],
        }
        print("# guard overhead ({algo}, K={clients}): clip-vs-none "
              "{paired_overhead_clip_vs_none:.3f}x, trimmed-mean-vs-none "
              "{paired_overhead_trimmed_vs_none:.3f}x"
              .format(**results["guard_overhead"]))

    if args.virtual:
        entry = {
            "scale": "virtual-k-sweep",
            "client_chunk": args.virtual_chunk,
            "path": VIRTUAL_PATH,
            "ks": {},
        }
        for K in virtual_ks:
            vcfg = get_virtual_k_config(K)
            vds = virtual_dataset(vcfg, seed=args.seed)
            pv = build_virtual_problem(vds)
            # 10⁶ is the bounded-memory existence proof, not a latency
            # horse race: one timed gd round is the budget-sane payload
            v_algos = [a for a in VIRTUAL_ALGOS
                       if K <= VIRTUAL_GD_ONLY_ABOVE or a == "gd"]
            v_rounds = rounds if K <= VIRTUAL_GD_ONLY_ABOVE else 1
            v_repeats = repeats if K <= VIRTUAL_GD_ONLY_ABOVE else 1
            closures = _virtual_closures(v_algos, pv, args.virtual_chunk)
            w0 = jax.numpy.zeros(pv.d)
            all_samples = _time_rounds(closures, w0, v_rounds, v_repeats)
            rec = {
                "clients": int(vcfg.num_clients),
                "examples": int(vcfg.num_examples),
                "features": int(vcfg.num_features),
                "buckets": len(pv.buckets),
                "rounds_per_repeat": v_rounds,
                "repeats": v_repeats,
                "algos": {a: _stats(all_samples[a]) for a in v_algos},
            }
            del closures, pv, vds, all_samples, w0
            rss_mb, peak_rss_mb, live_mb = _memory_columns()
            rec["rss_mb"] = rss_mb
            rec["peak_rss_mb"] = peak_rss_mb
            rec["live_buffer_mb"] = live_mb
            rec["est_materialized_mb"] = _est_materialized_mb(vcfg)
            for a in v_algos:
                s = rec["algos"][a]
                print(f"virtual-k={K},{a},{VIRTUAL_PATH},"
                      f"{s['median_s']:.5f},{s['mean_s']:.5f},"
                      f"{s['min_s']:.5f}")
            print(f"# virtual-k={K}: live_buffer={live_mb:.1f}MB vs "
                  f"est_materialized={rec['est_materialized_mb']:.1f}MB "
                  f"(rss={rss_mb if rss_mb is None else round(rss_mb, 1)}MB, "
                  f"peak_rss={peak_rss_mb:.1f}MB)")
            entry["ks"][str(K)] = rec
        results["configs"].append(entry)
        largest_k = str(max(virtual_ks))
        big = entry["ks"][largest_k]
        results["virtual"] = {
            "client_chunk": args.virtual_chunk,
            "largest_k": int(largest_k),
            "largest_k_median_round_s": {
                a: s["median_s"] for a, s in big["algos"].items()},
            "largest_k_live_buffer_mb": big["live_buffer_mb"],
            "largest_k_est_materialized_mb": big["est_materialized_mb"],
            "bounded_memory": big["live_buffer_mb"]
            < 0.25 * big["est_materialized_mb"],
        }
        print("# virtual sweep: K={largest_k} round medians "
              "{largest_k_median_round_s}; live buffers "
              "{largest_k_live_buffer_mb:.1f}MB vs materialized-estimate "
              "{largest_k_est_materialized_mb:.1f}MB "
              "(bounded: {bounded_memory})".format(**results["virtual"]))

    with open(args.json, "w") as f:
        json.dump(results, f, indent=1)
        f.write("\n")
    print(f"# wrote {args.json}")
    return results


if __name__ == "__main__":
    main()
