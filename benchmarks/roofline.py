"""Roofline table renderer: reads the dry-run JSON dumps and prints the
§Roofline table (deliverable g).

    PYTHONPATH=src python -m benchmarks.roofline dryrun_singlepod.json [...]
"""
from __future__ import annotations

import json
import sys


def render(paths):
    rows = []
    for p in paths:
        with open(p) as f:
            data = json.load(f)
        rows += [r for r in data["results"] if "skipped" not in r]
        skipped = [r for r in data["results"] if "skipped" in r]
        failures = data.get("failures", [])
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':9s} "
           f"{'comp_ms':>9s} {'mem_ms':>9s} {'coll_ms':>9s} "
           f"{'bottleneck':>10s} {'useful':>7s} {'GB/chip':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in sorted(rows, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        bpc = r.get("bytes_per_chip") or {}
        gb = (bpc.get("temp") or 0) / 1e9
        print(f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:9s} "
              f"{r['t_compute_ms']:9.2f} {r['t_memory_ms']:9.2f} "
              f"{r['t_collective_ms']:9.2f} {r['bottleneck']:>10s} "
              f"{r['useful_flops_ratio']:7.3f} {gb:8.2f}")
    for r in skipped:
        print(f"{r['arch']:22s} {r['shape']:12s} SKIP: {r['skipped']}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_[:3], f_[3][:150])


if __name__ == "__main__":
    render(sys.argv[1:] or ["dryrun_singlepod.json"])
