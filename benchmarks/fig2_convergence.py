"""Fig. 2 reproduction: rounds of communication vs objective / test error.

Compares (as in the paper): OPT (offline optimum), GD (best stepsize),
CoCoA+, DANE, FSVRG, FSVRGR (same algorithm, randomly reshuffled data), plus
the FedAvg/local-SGD and one-shot baselines.  Every round-based curve is a
row in the data-driven ``CURVES`` table: the solver comes from the registry
(``make_solver``), the round loop and key schedule from the shared
:class:`repro.core.Trainer` (all derived from ``--seed``), and the
retrospective stepsize sweep from :func:`repro.core.sweep` — no
per-algorithm hand-rolled loops.  Adding an algorithm to the comparison is
one table row.

Scale is controlled by --scale (default CI-friendly 0.005 ≈ 50 clients; the
paper's full setting is scale=1.0: K=10,000, n≈2.2M, d=20,002).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (get_dane_config,
                           get_fedavg_config, get_fsvrg_config,
                           get_gd_config, get_logreg_config)
from repro.core import (Trainer, build_problem, build_test_problem,
                        make_solver, sweep)
from repro.core.baselines import majority_baseline_error, one_shot_average
from repro.data.synthetic import generate


@dataclasses.dataclass(frozen=True)
class Curve:
    """One comparison curve: a registry solver + its retrospective sweep."""

    solver: str                                  # registry name
    sweep_param: Optional[str] = None            # hyperparam swept (None: none)
    sweep: Tuple[float, ...] = ()
    reshuffle: bool = False                      # FSVRGR: same algo, shuffled data


def _curves():
    return {
        "fsvrg": Curve("fsvrg", "stepsize", get_fsvrg_config().stepsize_sweep),
        "fsvrgr": Curve("fsvrg", "stepsize", get_fsvrg_config().stepsize_sweep,
                        reshuffle=True),
        "gd": Curve("gd", "stepsize", get_gd_config().stepsize_sweep),
        "dane": Curve("dane", "local_lr", get_dane_config().local_lr_sweep),
        "cocoa": Curve("cocoa"),
        "fedavg": Curve("fedavg", "stepsize", get_fedavg_config().stepsize_sweep),
    }


ALGOS = ("fsvrg", "fsvrgr", "gd", "dane", "cocoa", "fedavg", "oneshot")


def optimum(prob, iters=6000, lr=2.0):
    w = jnp.zeros(prob.d)
    g = jax.jit(prob.flat.grad)
    best, best_f = w, float(prob.flat.loss(w))
    for i in range(iters):
        w = w - lr * g(w)
        if i % 500 == 499:
            f = float(prob.flat.loss(w))
            if f < best_f:
                best, best_f = w, f
    return best


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.005)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0,
                    help="drives the data generator AND every curve's "
                         "per-round key schedule (via the Trainer)")
    ap.add_argument("--opt-iters", type=int, default=6000,
                    help="GD iterations for the offline OPT reference "
                         "(lower it for smoke runs)")
    ap.add_argument("--json", default=None)
    ap.add_argument("--algo", default="all", choices=("all",) + ALGOS,
                    help="run a single comparison curve instead of all of them")
    ap.add_argument("--participation-model", default="none",
                    choices=("none", "bernoulli", "trace"),
                    help="run every curve under partial participation: "
                         "'bernoulli' uses --participation as the i.i.d. "
                         "rate, 'trace' a repro.fleet diurnal availability/"
                         "straggler trace (seeded from --seed)")
    ap.add_argument("--participation", type=float, default=0.3,
                    help="client participation rate for "
                         "--participation-model=bernoulli")
    ap.add_argument("--fault-model", default=None,
                    help="inject deterministic delta corruptions into every "
                         "curve, e.g. 'nan=0.01,sign=0.05,start=3' (knobs: "
                         "nan/sign/scale/replay rates, scale-factor, window, "
                         "start/stop rounds, seed) — repro.fleet.DeltaFaults; "
                         "unguarded NaN-poisoned candidates diverge and lose "
                         "their sweeps, so pair with --aggregator-guard")
    ap.add_argument("--aggregator-guard", default="none",
                    choices=("none", "clip", "trimmed_mean", "median"),
                    help="robust-aggregation guard installed in every "
                         "curve's engine (trimmed_mean/median reject the "
                         "cocoa curve: order-stat guards don't compose with "
                         "its sum-weighted dual aggregation)")
    args = ap.parse_args(argv)

    def want(name):
        return args.algo in ("all", name)

    # extra solver kwargs shared by every curve (merged into make_solver)
    fleet_kw = {}
    if args.participation_model == "bernoulli":
        fleet_kw = {"participation": args.participation}
    elif args.participation_model == "trace":
        from repro.fleet import FleetTrace, TraceParticipation
        trace = FleetTrace(seed=args.seed)
        fleet_kw = {"participation": trace.max_rate(),
                    "participation_model": TraceParticipation(trace)}
    if args.fault_model:
        from repro.fleet import DeltaFaults
        fleet_kw["fault_model"] = DeltaFaults.from_spec(args.fault_model)
    if args.aggregator_guard != "none":
        fleet_kw["aggregator_guard"] = args.aggregator_guard

    cfg = get_logreg_config().scaled(args.scale)
    ds = generate(cfg, seed=args.seed)
    prob = build_problem(ds)
    te = build_test_problem(ds)
    print(f"# K={ds.num_clients} n={ds.num_examples} d={ds.num_features} "
          f"n_k in [{ds.client_sizes.min()},{ds.client_sizes.max()}]")

    w_star = optimum(prob, iters=args.opt_iters)
    f_star = float(prob.flat.loss(w_star))
    err_star = float(te.error_rate(w_star))

    # naive prediction properties (§4.1 analogues)
    err_const = min(float((te.y == 1).mean()), float((te.y == -1).mean()))
    err_majority = majority_baseline_error(ds.y, ds.client_of, ds.test_y,
                                           ds.test_client_of)
    print(f"# OPT f*={f_star:.5f} err*={err_star:.4f} | "
          f"const-pred err={err_const:.4f} | per-author-majority err={err_majority:.4f}")

    results = {"opt": {"f": f_star, "err": err_star},
               "const_err": err_const, "majority_err": err_majority,
               "config": dataclasses.asdict(cfg)}

    # FSVRGR's reshuffled problem (built lazily, derived from --seed too)
    prob_r = None

    def reshuffled():
        nonlocal prob_r
        if prob_r is None:
            rng = np.random.default_rng(args.seed)
            perm = rng.permutation(ds.num_examples)
            ds_r = dataclasses.replace(ds, idx=ds.idx[perm], val=ds.val[perm],
                                       y=ds.y[perm])
            prob_r = build_problem(ds_r)
        return prob_r

    # ---- every round-based curve: one registry-driven sweep ---- #
    for name, c in _curves().items():
        if not want(name):
            continue
        problem = reshuffled() if c.reshuffle else prob

        def eval_w(w, problem=problem):
            return {"f": problem.flat.loss(w), "err": te.error_rate(w)}

        t0 = time.time()
        if c.sweep_param is not None:
            res, best = sweep(
                lambda v: make_solver(c.solver, problem,
                                      **{c.sweep_param: v, **fleet_kw}),
                c.sweep, rounds=args.rounds, seed=args.seed, eval_fn=eval_w)
            if res is None:
                print(f"{name}: every candidate in {c.sweep} diverged")
                continue
            swept = {c.sweep_param: best}
        else:
            res = Trainer(make_solver(c.solver, problem, **fleet_kw),
                          rounds=args.rounds,
                          seed=args.seed, eval_fn=eval_w).fit()
            swept = {}
        hist = res.history
        results[name] = {
            "solver": c.solver, "swept": swept, "hist": hist,
            # JSON-friendly hyperparams of the (best) run
            "hyperparams": {
                k: v for k, v in res.solver.hyperparams.items()
                if isinstance(v, (int, float, str, bool, type(None)))}}
        tag = ",".join(f"{k}={v}" for k, v in swept.items()) or "defaults"
        print(f"{name:7s} ({tag}): " + " ".join(
            f"r{r+1}={p['f']:.4f}"
            for r, p in list(enumerate(hist))[::max(1, args.rounds // 6)])
            + f"  err={hist[-1]['err']:.4f}  [{time.time()-t0:.0f}s]")

    # ---- one-shot averaging (not round-based: single communication) ---- #
    if want("oneshot"):
        key_os = jax.random.fold_in(jax.random.PRNGKey(args.seed), 10_000)
        w_os = one_shot_average(prob, jnp.zeros(prob.d), key_os,
                                stepsize=0.5, epochs=20)
        results["oneshot"] = {"f": float(prob.flat.loss(w_os)),
                              "err": float(te.error_rate(w_os))}
        print(f"oneshot: f={results['oneshot']['f']:.4f} "
              f"err={results['oneshot']['err']:.4f}")

    # rounds-to-within-10%-of-optimal-gap table
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))
    target = f_star + 0.1 * (f0 - f_star)
    print("\nname,rounds_to_10pct_gap,final_f,final_err")
    for name in ("fsvrg", "fsvrgr", "gd", "dane", "cocoa", "fedavg"):
        if name not in results:
            continue
        hist_n = results[name]["hist"]
        rto = next((r + 1 for r, p in enumerate(hist_n) if p["f"] <= target), None)
        print(f"{name},{rto},{hist_n[-1]['f']:.5f},{hist_n[-1]['err']:.4f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
