"""Fig. 2 reproduction: rounds of communication vs objective / test error.

Compares (as in the paper): OPT (offline optimum), GD (best stepsize),
CoCoA+, DANE, FSVRG, FSVRGR (same algorithm, randomly reshuffled data), plus
the FedAvg/local-SGD and one-shot baselines — every round-based curve runs
on the shared RoundEngine.  Scale is controlled by --scale (default
CI-friendly 0.005 ≈ 50 clients; the paper's full setting is scale=1.0:
K=10,000, n≈2.2M, d=20,002).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (get_cocoa_config, get_dane_config,
                           get_fedavg_config, get_logreg_config)
from repro.core import (DANE, DANEConfig, FSVRG, FSVRGConfig, FedAvg,
                        FedAvgConfig, build_problem, build_test_problem)
from repro.core.baselines import majority_baseline_error, one_shot_average
from repro.core.cocoa import CoCoAPlus
from repro.data.synthetic import generate

ALGOS = ("fsvrg", "fsvrgr", "gd", "dane", "cocoa", "fedavg", "oneshot")


def optimum(prob, iters=6000, lr=2.0):
    w = jnp.zeros(prob.d)
    g = jax.jit(prob.flat.grad)
    best, best_f = w, float(prob.flat.loss(w))
    for i in range(iters):
        w = w - lr * g(w)
        if i % 500 == 499:
            f = float(prob.flat.loss(w))
            if f < best_f:
                best, best_f = w, f
    return best


def sweep_stepsize(run_fn, prob, candidates, rounds):
    """Retrospectively pick the best stepsize (the paper's protocol)."""
    best_hist, best_f, best_h = None, np.inf, None
    for h in candidates:
        hist = run_fn(h, rounds)
        f = hist[-1]["f"]
        if np.isfinite(f) and f < best_f:
            best_f, best_hist, best_h = f, hist, h
    return best_hist, best_h


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.005)
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--opt-iters", type=int, default=6000,
                    help="GD iterations for the offline OPT reference "
                         "(lower it for smoke runs)")
    ap.add_argument("--json", default=None)
    ap.add_argument("--algo", default="all", choices=("all",) + ALGOS,
                    help="run a single comparison curve instead of all of them")
    args = ap.parse_args(argv)

    def want(name):
        return args.algo in ("all", name)

    cfg = get_logreg_config().scaled(args.scale)
    ds = generate(cfg, seed=args.seed)
    prob = build_problem(ds)
    te = build_test_problem(ds)
    print(f"# K={ds.num_clients} n={ds.num_examples} d={ds.num_features} "
          f"n_k in [{ds.client_sizes.min()},{ds.client_sizes.max()}]")

    w_star = optimum(prob, iters=args.opt_iters)
    f_star = float(prob.flat.loss(w_star))
    err_star = float(te.error_rate(w_star))

    # naive prediction properties (§4.1 analogues)
    err_const = min(float((te.y == 1).mean()), float((te.y == -1).mean()))
    err_majority = majority_baseline_error(ds.y, ds.client_of, ds.test_y,
                                           ds.test_client_of)
    print(f"# OPT f*={f_star:.5f} err*={err_star:.4f} | "
          f"const-pred err={err_const:.4f} | per-author-majority err={err_majority:.4f}")

    results = {"opt": {"f": f_star, "err": err_star},
               "const_err": err_const, "majority_err": err_majority,
               "config": dataclasses.asdict(cfg)}

    def eval_w(w):
        return {"f": float(prob.flat.loss(w)), "err": float(te.error_rate(w))}

    # ---- FSVRG ---- #
    if want("fsvrg"):
        def run_fsvrg(h, rounds, problem=prob):
            solver = FSVRG(problem, FSVRGConfig(stepsize=h))
            w = jnp.zeros(problem.d)
            hist = []
            for r in range(rounds):
                w = solver.round(w, jax.random.fold_in(jax.random.PRNGKey(1), r))
                hist.append(eval_w(w) if problem is prob else
                            {"f": float(problem.flat.loss(w)), "err": float("nan")})
            return hist

        t0 = time.time()
        hist, h_best = sweep_stepsize(run_fsvrg, prob, (0.3, 1.0, 3.0), args.rounds)
        results["fsvrg"] = {"h": h_best, "hist": hist}
        print(f"FSVRG   (h={h_best}): " + " ".join(
            f"r{r+1}={p['f']:.4f}" for r, p in list(enumerate(hist))[::max(1, args.rounds // 6)])
            + f"  err={hist[-1]['err']:.4f}  [{time.time()-t0:.0f}s]")

    # ---- FSVRGR: same algorithm, randomly reshuffled data ---- #
    if want("fsvrgr"):
        rng = np.random.default_rng(123)
        perm = rng.permutation(ds.num_examples)
        ds_r = dataclasses.replace(ds, idx=ds.idx[perm], val=ds.val[perm], y=ds.y[perm])
        prob_r = build_problem(ds_r)

        def run_fsvrgr(h, rounds):
            solver = FSVRG(prob_r, FSVRGConfig(stepsize=h))
            w = jnp.zeros(prob_r.d)
            hist = []
            for r in range(rounds):
                w = solver.round(w, jax.random.fold_in(jax.random.PRNGKey(1), r))
                hist.append({"f": float(prob_r.flat.loss(w)),
                             "err": float(te.error_rate(w))})
            return hist

        hist_r, h_r = sweep_stepsize(run_fsvrgr, prob_r, (0.3, 1.0, 3.0), args.rounds)
        results["fsvrgr"] = {"h": h_r, "hist": hist_r}
        print(f"FSVRGR  (h={h_r}): final f={hist_r[-1]['f']:.4f} err={hist_r[-1]['err']:.4f}")

    # ---- distributed GD ---- #
    if want("gd"):
        def run_gd_h(h, rounds):
            w = jnp.zeros(prob.d)
            g = jax.jit(prob.flat.grad)
            hist = []
            for r in range(rounds):
                w = w - h * g(w)
                hist.append(eval_w(w))
            return hist

        hist_gd, h_gd = sweep_stepsize(run_gd_h, prob, (0.5, 2.0, 8.0, 32.0), args.rounds)
        results["gd"] = {"h": h_gd, "hist": hist_gd}
        print(f"GD      (h={h_gd}): final f={hist_gd[-1]['f']:.4f} err={hist_gd[-1]['err']:.4f}")

    # ---- DANE (engine subsystem; η/µ from the config, local lr swept) ---- #
    if want("dane"):
        dcfg = get_dane_config()

        def run_dane(lr, rounds):
            solver = DANE(prob, DANEConfig(
                eta=dcfg.eta, mu=dcfg.mu, local_steps=dcfg.local_steps,
                local_lr=lr))
            w = jnp.zeros(prob.d)
            hist = []
            for r in range(rounds):
                w = solver.round(w, jax.random.fold_in(jax.random.PRNGKey(4), r))
                hist.append(eval_w(w))
            return hist

        hist_d, lr_d = sweep_stepsize(run_dane, prob, dcfg.local_lr_sweep,
                                      args.rounds)
        results["dane"] = {"local_lr": lr_d, "eta": dcfg.eta, "mu": dcfg.mu,
                           "hist": hist_d}
        print(f"DANE    (lr={lr_d},mu={dcfg.mu}): final f={hist_d[-1]['f']:.4f} "
              f"err={hist_d[-1]['err']:.4f}")

    # ---- CoCoA+ (engine subsystem; σ' from the config) ---- #
    if want("cocoa"):
        ccfg = get_cocoa_config()
        solver = CoCoAPlus(prob, sigma=ccfg.sigma)
        hist_c = []
        for r in range(args.rounds):
            solver.round(jax.random.PRNGKey(r))
            hist_c.append(eval_w(solver.w))
        results["cocoa"] = {"sigma": solver.sigma, "hist": hist_c}
        print(f"CoCoA+  (s'={solver.sigma:.0f}): final f={hist_c[-1]['f']:.4f} "
              f"err={hist_c[-1]['err']:.4f}")

    # ---- FedAvg (engine subsystem; E and sweep from the config entry) ---- #
    if want("fedavg"):
        facfg = get_fedavg_config()

        def run_fedavg(h, rounds):
            solver = FedAvg(prob, FedAvgConfig(
                stepsize=h, local_epochs=facfg.local_epochs,
                participation=facfg.participation))
            w = jnp.zeros(prob.d)
            hist = []
            for r in range(rounds):
                w = solver.round(w, jax.random.fold_in(jax.random.PRNGKey(2), r))
                hist.append(eval_w(w))
            return hist

        hist_fa, h_fa = sweep_stepsize(run_fedavg, prob, facfg.stepsize_sweep,
                                       args.rounds)
        results["fedavg"] = {"h": h_fa, "E": facfg.local_epochs, "hist": hist_fa}
        print(f"FedAvg  (h={h_fa},E={facfg.local_epochs}): "
              f"final f={hist_fa[-1]['f']:.4f} err={hist_fa[-1]['err']:.4f}")

    # ---- one-shot averaging ---- #
    if want("oneshot"):
        w_os = one_shot_average(prob, jnp.zeros(prob.d), jax.random.PRNGKey(3),
                                stepsize=0.5, epochs=20)
        results["oneshot"] = eval_w(w_os)
        print(f"OneShot: f={results['oneshot']['f']:.4f} err={results['oneshot']['err']:.4f}")

    # rounds-to-within-10%-of-optimal-gap table
    f0 = float(prob.flat.loss(jnp.zeros(prob.d)))
    target = f_star + 0.1 * (f0 - f_star)
    print("\nname,rounds_to_10pct_gap,final_f,final_err")
    for name in ("fsvrg", "fsvrgr", "gd", "dane", "cocoa", "fedavg"):
        if name not in results:
            continue
        hist_n = results[name]["hist"]
        rto = next((r + 1 for r, p in enumerate(hist_n) if p["f"] <= target), None)
        print(f"{name},{rto},{hist_n[-1]['f']:.5f},{hist_n[-1]['err']:.4f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    return results


if __name__ == "__main__":
    main()
