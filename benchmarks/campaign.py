"""Fleet campaign runner CLI — resumable paper-K runs under simulated fleets.

Runs a :class:`repro.fleet.CampaignSpec` (Fig.-2 solver cells × one
dataset × one participation model) to its round budget, checkpointing
every cell so that a ``kill -9`` at any instant costs at most
``checkpoint_every`` rounds: re-invoking the same command line resumes
from the newest atomic checkpoint and reproduces the uninterrupted run
bit-for-bit (final iterates AND the deterministic view of the JSONL
event stream).

    # the paper-K artifact run (K=10,000 clients, trace-driven fleet)
    python benchmarks/campaign.py --out runs/fig2_fleet --rounds 30 \
        --algos gd,fedavg,fsvrg --verify-resume --json CAMPAIGN_fig2.json

    # kill it mid-run, then just run it again — it resumes:
    python benchmarks/campaign.py --out runs/fig2_fleet --rounds 30 ...

    # CI smoke: 2 cells x 3 rounds at tiny scale, forced mid-run crash +
    # resume + bit-identity verification (exit 1 on any mismatch)
    python benchmarks/campaign.py --smoke --out /tmp/campaign_smoke

``--verify-resume`` runs the campaign twice — once uninterrupted, once
crashed via ``--stop-after``-style interruption and resumed — and
compares; it is the acceptance check for the resume machinery at full
scale.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys

import numpy as np

from repro.fleet import (CampaignSpec, DeltaFaults, EventLog, FleetTrace,
                         deterministic_view, run_campaign)


def _faults_from_args(args):
    """``--faults nan=0.01,sign=0.05,start=10,stop=12`` -> DeltaFaults
    (rate knobs by kind, plus the firing window / seed / magnitudes)."""
    if not args.faults:
        return None
    try:
        return DeltaFaults.from_spec(args.faults)
    except ValueError as e:
        raise SystemExit(f"--faults: {e}")


def _spec_from_args(args) -> CampaignSpec:
    trace = FleetTrace(seed=args.trace_seed, base=args.base,
                       amplitude=args.amplitude, period=args.period,
                       burst_prob=args.burst_prob, burst_frac=args.burst_frac,
                       straggler_rate=args.straggler_rate)
    return CampaignSpec(
        algos=tuple(args.algos.split(",")),
        rounds=args.rounds, seed=args.seed,
        scale=None if args.scale in (None, "paper") else float(args.scale),
        model=args.model, participation=args.participation, trace=trace,
        cohort=args.cohort, client_chunk=args.client_chunk,
        eval_every=args.eval_every, checkpoint_every=args.checkpoint_every,
        drift_every=args.drift_every, drift_w_scale=args.drift_w_scale,
        drift_resample=args.drift_resample,
        faults=_faults_from_args(args), guard=args.guard,
        guard_clip_norm=args.guard_clip_norm, guard_trim=args.guard_trim,
        max_rollbacks=args.max_rollbacks)


def _final_arrays(out_dir: str, algos) -> dict:
    """Each cell's checkpointed final iterate, loaded raw from disk."""
    out = {}
    for a in algos:
        ckpt = os.path.join(out_dir, "cells", a)
        with open(os.path.join(ckpt, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(ckpt, manifest["arrays_file"])) as z:
            out[a] = {k: z[k].copy() for k in z.files}
    return out


def verify_resume(spec: CampaignSpec, out_dir: str, stop_after: int,
                  verbose: bool = True) -> bool:
    """Uninterrupted vs crashed+resumed: deterministic event views and
    final checkpoint arrays must match bit-for-bit."""
    ref_dir = os.path.join(out_dir, "verify_ref")
    run_dir = os.path.join(out_dir, "verify_run")
    for d in (ref_dir, run_dir):
        shutil.rmtree(d, ignore_errors=True)
    run_campaign(spec, ref_dir, verbose=False)
    r = run_campaign(spec, run_dir, stop_after=stop_after, verbose=False)
    if not r.get("interrupted"):
        print(f"verify-resume: stop_after={stop_after} >= total rounds; "
              "nothing was interrupted", file=sys.stderr)
        return False
    run_campaign(spec, run_dir, verbose=False)

    ev_ref = [deterministic_view(e)
              for e in EventLog(os.path.join(ref_dir, "events.jsonl")).load()]
    ev_run = [deterministic_view(e)
              for e in EventLog(os.path.join(run_dir, "events.jsonl")).load()]
    ok = ev_ref == ev_run
    if verbose:
        print(f"verify-resume: events {'MATCH' if ok else 'MISMATCH'} "
              f"({len(ev_ref)} vs {len(ev_run)} rounds)")
    ref_w = _final_arrays(ref_dir, spec.algos)
    run_w = _final_arrays(run_dir, spec.algos)
    for a in spec.algos:
        same = (set(ref_w[a]) == set(run_w[a]) and
                all(np.array_equal(ref_w[a][k], run_w[a][k])
                    for k in ref_w[a]))
        ok = ok and same
        if verbose:
            print(f"verify-resume: {a} final state "
                  f"{'bit-identical' if same else 'MISMATCH'}")
    return ok


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="resumable fleet-simulation campaign over the Fig.-2 grid")
    ap.add_argument("--out", default="runs/campaign")
    ap.add_argument("--algos", default="gd,fedavg")
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scale", default="paper",
                    help="'paper' -> PAPER_K_CONFIG (K=10,000); a float "
                         "runs the scaled gplus config instead")
    ap.add_argument("--participation-model", dest="model", default="trace",
                    choices=("trace", "bernoulli", "full"))
    ap.add_argument("--participation", type=float, default=0.3,
                    help="Bernoulli rate (model=bernoulli)")
    # fleet trace knobs
    ap.add_argument("--trace-seed", type=int, default=0)
    ap.add_argument("--base", type=float, default=0.4)
    ap.add_argument("--amplitude", type=float, default=0.25)
    ap.add_argument("--period", type=float, default=24.0)
    ap.add_argument("--burst-prob", type=float, default=0.05)
    ap.add_argument("--burst-frac", type=float, default=0.3)
    ap.add_argument("--straggler-rate", type=float, default=0.02)
    # engine shape knobs
    ap.add_argument("--cohort", type=int, default=None)
    ap.add_argument("--client-chunk", type=int, default=None)
    # cadence
    ap.add_argument("--eval-every", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=5)
    # drift
    ap.add_argument("--drift-every", type=int, default=0)
    ap.add_argument("--drift-w-scale", type=float, default=1.0)
    ap.add_argument("--drift-resample", action="store_true")
    # fault injection + guard-rails
    ap.add_argument("--faults", default=None,
                    help="delta-corruption spec, e.g. "
                         "'nan=0.01,start=10,stop=12' "
                         "(knobs: nan/sign/scale/replay rates, "
                         "scale-factor, window, start/stop rounds, seed)")
    ap.add_argument("--guard", default="none",
                    choices=("none", "rollback", "clip", "trimmed_mean",
                             "median"),
                    help="divergence guard-rail; clip/trimmed_mean/median "
                         "also install the engine aggregator guard")
    ap.add_argument("--guard-clip-norm", type=float, default=None)
    ap.add_argument("--guard-trim", type=float, default=0.1)
    ap.add_argument("--max-rollbacks", type=int, default=3)
    # modes
    ap.add_argument("--stop-after", type=int, default=None,
                    help="abort this invocation after N rounds (crash "
                         "simulation; re-invoke to resume)")
    ap.add_argument("--verify-resume", action="store_true",
                    help="run twice (uninterrupted vs crashed+resumed) and "
                         "require bit-identity; exit 1 on mismatch")
    ap.add_argument("--smoke", action="store_true",
                    help="budget-guarded CI mode: tiny scale, 2 cells x 3 "
                         "rounds, forced mid-run resume + verification")
    ap.add_argument("--fault-smoke", action="store_true",
                    help="budget-guarded CI mode: tiny NaN-poisoned "
                         "campaign under the rollback rail; exit 1 unless "
                         ">= 1 rollback is recorded and the final iterate "
                         "converged")
    ap.add_argument("--json", default=None,
                    help="also write the summary (+ verification result) here")
    args = ap.parse_args(argv)

    if args.smoke:
        args.algos = "gd,fedavg"
        args.rounds = 3
        args.scale = 0.004
        args.eval_every = 2
        args.checkpoint_every = 1
    if args.fault_smoke:
        # one cell, a NaN-poisoning burst mid-run, rollback rail armed:
        # the guard must quarantine the poisoned round and still converge
        args.algos = "gd"
        args.rounds = 8
        args.scale = 0.004
        args.model = "full"
        args.checkpoint_every = 2
        args.faults = args.faults or "nan=0.4,seed=1,start=3,stop=4"
        if args.guard == "none":
            args.guard = "rollback"
    spec = _spec_from_args(args)

    if args.fault_smoke:
        shutil.rmtree(args.out, ignore_errors=True)
        summary = run_campaign(spec, args.out, verbose=False)
        cell = summary["cells"][spec.algos[0]]
        final_f = cell.get("final_f")
        ok = (cell["rollbacks"] >= 1 and final_f is not None
              and np.isfinite(final_f))
        print(f"fault-smoke: rollbacks={cell['rollbacks']} "
              f"faults={cell['faults_injected_total']} "
              f"final_f={final_f} -> {'PASS' if ok else 'FAIL'}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump({k: v for k, v in summary.items()
                           if k != "finals"}, f, indent=1, sort_keys=True)
        return 0 if ok else 1

    verified = None
    if args.smoke or args.verify_resume:
        # crash mid-way through the grid: after all of cell 1 plus one
        # round of cell 2 (exercises both the resume-into-a-cell and the
        # skip-completed-cell paths)
        stop = spec.rounds + 1 if len(spec.algos) > 1 else spec.rounds // 2 + 1
        verified = verify_resume(spec, args.out, stop_after=stop)
        if not verified:
            print("RESUME VERIFICATION FAILED", file=sys.stderr)
            return 1

    summary = run_campaign(spec, args.out, stop_after=args.stop_after)
    if summary.get("interrupted"):
        print(f"stopped after {summary['rounds_done']} rounds; re-invoke "
              f"with the same --out to resume")
        return 0

    for algo, cell in summary["cells"].items():
        line = (f"{algo:7s}: rounds={cell['rounds']} "
                f"realized/drawn={cell['realized_mean']:.1f}/"
                f"{cell['drawn_mean']:.1f} "
                f"stragglers={cell['straggler_total']} ")
        if cell.get("faults_injected_total") or cell.get("rollbacks"):
            line += (f"faults={cell['faults_injected_total']} "
                     f"rejected={cell['clients_rejected_total']} "
                     f"rollbacks={cell['rollbacks']} ")
        line += (f"final_f={cell.get('final_f', float('nan')):.5f} "
                 f"final_err={cell.get('final_err', float('nan')):.4f} "
                 f"[{cell['wall_total_s']:.0f}s]")
        print(line)
    if verified is not None:
        print(f"resume verification: {'PASS' if verified else 'FAIL'}")

    if args.json:
        payload = {k: v for k, v in summary.items() if k != "finals"}
        if verified is not None:
            payload["resume_verified"] = verified
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
