"""Benchmark harness — one entry per paper table/figure + framework benches.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows plus the Fig.-2 convergence
summary.  Roofline terms come from the dry-run JSON (see
benchmarks/roofline.py; the dry-run itself needs the 512-device env and is
run separately).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(fn, *args, reps=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_fig2(quick: bool):
    """Paper Fig. 2 (the paper's only figure-experiment)."""
    from benchmarks import fig2_convergence

    scale = 0.003 if quick else 0.01
    rounds = 15 if quick else 30
    res = fig2_convergence.main(["--scale", str(scale), "--rounds", str(rounds),
                                 "--json", "/root/repo/fig2_results.json"])
    f_fsvrg = res["fsvrg"]["hist"][-1]["f"]
    f_gd = res["gd"]["hist"][-1]["f"]
    f_cocoa = res["cocoa"]["hist"][-1]["f"]
    print(f"fig2_fsvrg_final_f,{f_fsvrg:.6f},opt={res['opt']['f']:.6f}")
    print(f"fig2_ordering_ok,{int(f_fsvrg < f_gd <= f_cocoa * 1.5)},fsvrg<gd(<~cocoa)")


def bench_kernels():
    """Kernel microbenchmarks (interpret mode on CPU — relative only)."""
    from repro.kernels import ops, ref

    d = 20_002  # the paper's dimensionality
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    w, s, gn, go, gb = [jax.random.normal(k, (d,)) for k in ks]
    us, _ = _timeit(lambda: ops.fsvrg_update(w, s, gn, go, gb, 0.1))
    print(f"kernel_fsvrg_update_d{d},{us:.1f},interpret")
    us_ref, _ = _timeit(lambda: ref.fsvrg_update_ref(w, s, gn, go, gb, 0.1))
    print(f"ref_fsvrg_update_d{d},{us_ref:.1f},jnp")

    us, _ = _timeit(lambda: ops.fedavg_update(w, gn, 0.1, 1e-4))
    print(f"kernel_fedavg_update_d{d},{us:.1f},interpret")
    us_ref, _ = _timeit(lambda: ref.fedavg_update_ref(w, gn, 0.1, 1e-4))
    print(f"ref_fedavg_update_d{d},{us_ref:.1f},jnp")

    K = 64
    wks = jax.random.normal(ks[1], (K, d))
    wts = jnp.full((K,), 1.0 / K)
    a = jnp.ones((d,))
    us, _ = _timeit(lambda: ops.scaled_aggregate(w, wks, wts, a))
    print(f"kernel_scaled_aggregate_K{K}_d{d},{us:.1f},interpret")
    us_ref, _ = _timeit(lambda: ref.scaled_aggregate_ref(w, wks, wts, a))
    print(f"ref_scaled_aggregate_K{K}_d{d},{us_ref:.1f},jnp")


def bench_round_cost(quick: bool):
    """Wall-clock of one FSVRG round vs one GD round vs one CoCoA+ round —
    the T_A side of the paper's efficiency paradigm (eq. 3/4)."""
    from repro.configs import get_logreg_config
    from repro.core import FSVRG, FSVRGConfig, build_problem
    from repro.core.cocoa import CoCoAPlus
    from repro.data.synthetic import generate

    cfg = get_logreg_config().scaled(0.002 if quick else 0.005)
    ds = generate(cfg, seed=0)
    prob = build_problem(ds)
    w = jnp.zeros(prob.d)

    solver = FSVRG(prob, FSVRGConfig(stepsize=1.0))
    st = solver.init(w)
    us, _ = _timeit(lambda: solver.round(st, jax.random.PRNGKey(0)).w, reps=3)
    print(f"fsvrg_round_K{ds.num_clients},{us:.0f},1 communication")

    g = jax.jit(prob.flat.grad)
    us, _ = _timeit(lambda: g(w), reps=3)
    print(f"gd_round_K{ds.num_clients},{us:.0f},1 communication")

    cc = CoCoAPlus(prob)
    st_cc = cc.init()
    us, _ = _timeit(lambda: cc.round(st_cc, jax.random.PRNGKey(0)).w, reps=3)
    print(f"cocoa_round_K{ds.num_clients},{us:.0f},1 communication")


def bench_neural_round(quick: bool):
    """Federated LM round on the reduced llama config (framework bench)."""
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.core import neural
    from repro.models import build_model, make_batch

    cfg = get_config("llama3-8b").reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, InputShape("t", 64, 8, "train"), dtype=jnp.float32)
    cb = neural.make_client_batches(batch, num_clients=4, local_steps=2)
    rnd = jax.jit(neural.make_fsvrg_round(model, neural.FedNeuralConfig(stepsize=0.5,
                                                                        local_steps=2)))
    us, _ = _timeit(lambda: rnd(params, cb)[0], reps=2, warmup=1)
    print(f"neural_fsvrg_round_reduced_llama3,{us:.0f},4 clients x 2 steps")


def bench_properties_table():
    """§3.1 properties as a one-round gap-closure table."""
    import sys
    sys.path.insert(0, "tests")
    from test_properties import _dense_problem_from_clients, _random_clients
    from repro.core import FSVRG, FSVRGConfig

    rng = np.random.default_rng(0)

    def gap_closure(prob):
        w_star = jnp.zeros(prob.d)
        for _ in range(2000):
            w_star = w_star - 0.5 * prob.flat.grad(w_star)
        f_star = float(prob.flat.loss(w_star))
        f0 = float(prob.flat.loss(jnp.zeros(prob.d)))
        # best stepsize retrospectively (the paper's protocol)
        def one_round_f(h):
            solver = FSVRG(prob, FSVRGConfig(stepsize=h))
            st = solver.round(solver.init(), jax.random.PRNGKey(0))
            return float(prob.flat.loss(st.w))

        f1 = min(one_round_f(h) for h in (1.0, 3.0, 10.0))
        return (f0 - f1) / max(f0 - f_star, 1e-12)

    p_b = _dense_problem_from_clients(_random_clients(rng, 1, 256, 16, 8), 16, lam=0.05)
    print(f"propB_one_round_gap_closure,{gap_closure(p_b):.3f},target>0.8")
    clients = []
    for k in range(4):
        pool = np.arange(k * 8, (k + 1) * 8)
        clients += _random_clients(rng, 1, 128, 32, 4, feature_pool=pool)
    p_c = _dense_problem_from_clients(clients, 32, lam=0.05)
    print(f"propC_one_round_gap_closure,{gap_closure(p_c):.3f},target>0.65")
    base = _random_clients(rng, 1, 128, 16, 8)[0]
    p_d = _dense_problem_from_clients([base] * 4, 16, lam=0.05)
    print(f"propD_one_round_gap_closure,{gap_closure(p_d):.3f},target>0.8")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    bench_kernels()
    bench_round_cost(args.quick)
    bench_properties_table()
    bench_neural_round(args.quick)
    bench_fig2(args.quick)


if __name__ == "__main__":
    main()
