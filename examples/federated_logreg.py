"""The paper's §4 experiment end-to-end, with every compared method and the
four FSVRG-modification ablations (§3.6.2).

Every run is a row in a data-driven table: the solver comes from the
registry (``make_solver(name, prob, **overrides)``), the round loop from
the shared Trainer (``solver.fit``) — no per-algorithm loops.

    PYTHONPATH=src python examples/federated_logreg.py --scale 0.01 --rounds 30
"""
import argparse

from repro.configs import get_logreg_config
from repro.core import build_problem, build_test_problem, make_solver
from repro.core.baselines import majority_baseline_error
from repro.data.synthetic import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.005)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--stepsize", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_logreg_config().scaled(args.scale)
    ds = generate(cfg, seed=args.seed)
    prob = build_problem(ds)
    te = build_test_problem(ds)
    print(f"K={ds.num_clients} n={ds.num_examples} d={ds.num_features}")

    # §4.1 naive prediction properties
    err_const = min(float((te.y == 1).mean()), float((te.y == -1).mean()))
    err_maj = majority_baseline_error(ds.y, ds.client_of, ds.test_y, ds.test_client_of)
    print(f"predict-constant err={err_const:.4f}  per-author-majority err={err_maj:.4f}")

    h = args.stepsize
    runs = (
        ("FSVRG (Algorithm 4, all mods)", "fsvrg", {"stepsize": h}),
        ("  − S_k gradient scaling", "fsvrg", {"stepsize": h, "use_S": False}),
        ("  − A aggregation scaling", "fsvrg", {"stepsize": h, "use_A": False}),
        ("  − local stepsize h/n_k", "fsvrg",
         {"stepsize": h, "use_local_stepsize": False}),
        ("  − n_k/n weighted aggregation", "fsvrg",
         {"stepsize": h, "use_weighted_agg": False}),
        ("naive FSVRG (Algorithm 3)", "svrg_naive",
         {"stepsize": h / 100, "naive_steps": 50}),
        ("GD", "gd", {"stepsize": 2.0}),
        ("FedAvg (registry defaults)", "fedavg", {}),
        ("DANE (registry defaults)", "dane", {}),
        ("CoCoA+ (sigma=K)", "cocoa", {}),
    )

    def evaluate(w):
        return {"f": prob.flat.loss(w), "err": te.error_rate(w)}

    for label, name, overrides in runs:
        res = make_solver(name, prob, **overrides).fit(
            args.rounds, seed=args.seed, eval_fn=evaluate)
        p = res.history[-1]
        print(f"{label:34s} f={p['f']:.5f} err={p['err']:.4f}")


if __name__ == "__main__":
    main()
