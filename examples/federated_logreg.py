"""The paper's §4 experiment end-to-end, with every compared method and the
four FSVRG-modification ablations (§3.6.2).

    PYTHONPATH=src python examples/federated_logreg.py --scale 0.01 --rounds 30
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import (get_cocoa_config, get_dane_config,
                           get_fedavg_config, get_logreg_config)
from repro.core import (DANE, DANEConfig, FSVRG, FSVRGConfig, FedAvg,
                        FedAvgConfig, build_problem, build_test_problem)
from repro.core.baselines import majority_baseline_error, run_gd
from repro.core.cocoa import CoCoAPlus
from repro.data.synthetic import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.005)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--stepsize", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = get_logreg_config().scaled(args.scale)
    ds = generate(cfg, seed=0)
    prob = build_problem(ds)
    te = build_test_problem(ds)
    print(f"K={ds.num_clients} n={ds.num_examples} d={ds.num_features}")

    # §4.1 naive prediction properties
    err_const = min(float((te.y == 1).mean()), float((te.y == -1).mean()))
    err_maj = majority_baseline_error(ds.y, ds.client_of, ds.test_y, ds.test_client_of)
    print(f"predict-constant err={err_const:.4f}  per-author-majority err={err_maj:.4f}")

    def run(cfg_fsvrg, label):
        w, _ = FSVRG(prob, cfg_fsvrg).run(jnp.zeros(prob.d), args.rounds, seed=0)
        print(f"{label:34s} f={float(prob.flat.loss(w)):.5f} "
              f"err={float(te.error_rate(w)):.4f}")
        return w

    h = args.stepsize
    run(FSVRGConfig(stepsize=h), "FSVRG (Algorithm 4, all mods)")
    run(FSVRGConfig(stepsize=h, use_S=False), "  − S_k gradient scaling")
    run(FSVRGConfig(stepsize=h, use_A=False), "  − A aggregation scaling")
    run(FSVRGConfig(stepsize=h, use_local_stepsize=False), "  − local stepsize h/n_k")
    run(FSVRGConfig(stepsize=h, use_weighted_agg=False), "  − n_k/n weighted aggregation")
    run(FSVRGConfig(stepsize=h / 100, naive=True, naive_steps=50),
        "naive FSVRG (Algorithm 3)")

    w_gd, _ = run_gd(prob, jnp.zeros(prob.d), args.rounds, 2.0)
    print(f"{'GD':34s} f={float(prob.flat.loss(w_gd)):.5f} "
          f"err={float(te.error_rate(w_gd)):.4f}")

    facfg = get_fedavg_config()
    w_fa, _ = FedAvg(prob, FedAvgConfig(stepsize=facfg.stepsize,
                                        local_epochs=facfg.local_epochs)).run(
        jnp.zeros(prob.d), args.rounds, seed=0)
    print(f"{'FedAvg (E=%d local SGD)' % facfg.local_epochs:34s} "
          f"f={float(prob.flat.loss(w_fa)):.5f} "
          f"err={float(te.error_rate(w_fa)):.4f}")

    dcfg = get_dane_config()
    w_da, _ = DANE(prob, DANEConfig(eta=dcfg.eta, mu=dcfg.mu,
                                    local_steps=dcfg.local_steps,
                                    local_lr=dcfg.local_lr)).run(
        jnp.zeros(prob.d), args.rounds, seed=0)
    print(f"{'DANE (mu=%g, GD local solver)' % dcfg.mu:34s} "
          f"f={float(prob.flat.loss(w_da)):.5f} "
          f"err={float(te.error_rate(w_da)):.4f}")

    cc = CoCoAPlus(prob, sigma=get_cocoa_config().sigma)
    for r in range(args.rounds):
        cc.round(jax.random.PRNGKey(r))
    print(f"{'CoCoA+ (sigma=K)':34s} f={float(prob.flat.loss(cc.w)):.5f} "
          f"err={float(te.error_rate(cc.w)):.4f}")


if __name__ == "__main__":
    main()
