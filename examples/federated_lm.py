"""End-to-end driver: federated training of a ~100M-parameter language model
with FSVRG rounds (the paper's technique as a first-class framework feature).

Clients are synthetic non-IID token streams — each client has a private
token distribution (the LM analogue of the paper's per-author vocabulary) —
and the round applies per-vocab-row S_k/A scaling exactly as Algorithm 4
prescribes for sparse features.

    PYTHONPATH=src python examples/federated_lm.py [--rounds 200] [--arch llama3-8b]

By default trains a ~100M reduced variant of the chosen architecture for a
few hundred rounds on CPU.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import neural
from repro.models import build_model


def synthetic_federated_tokens(rng, num_clients, batch_per_client, seq_len,
                               vocab, steps_per_client):
    """Each client samples from its own zipf-reweighted vocabulary slice."""
    out = []
    base = 1.0 / (np.arange(2, vocab) ** 1.05)
    for k in range(num_clients):
        own = rng.choice(np.arange(2, vocab), size=max(8, vocab // 50),
                         replace=False)
        p = base.copy()
        p[own - 2] *= 50.0                      # client-specific skew
        p = np.concatenate([[0.02, 0.02], p / p.sum() * 0.96])
        p = p / p.sum()
        toks = rng.choice(vocab, size=(steps_per_client, batch_per_client,
                                       seq_len + 1), p=p)
        out.append(toks)
    return np.stack(out)                        # (C, T, B_c, S+1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch-per-client", type=int, default=4)
    ap.add_argument("--stepsize", type=float, default=0.5)
    ap.add_argument("--eval-every", type=int, default=10)
    args = ap.parse_args(argv)

    # ~100M-class variant: reduced depth/width but real vocab structure
    cfg = get_config(args.arch).reduced()
    cfg = dataclasses.replace(cfg, name=cfg.name + "-100m", num_layers=4,
                              d_model=256, d_ff=1024, vocab_size=8192,
                              num_heads=4, num_kv_heads=2, head_dim=64)
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"C={args.clients} T={args.local_steps} seq={args.seq}")

    rng = np.random.default_rng(0)
    rnd = jax.jit(neural.make_fsvrg_round(
        model, neural.FedNeuralConfig(stepsize=args.stepsize,
                                      local_steps=args.local_steps)))

    held_out = None
    t0 = time.time()
    for r in range(args.rounds):
        toks = synthetic_federated_tokens(
            rng, args.clients, args.batch_per_client, args.seq,
            cfg.vocab_size, args.local_steps)
        cb = {
            "tokens": jnp.asarray(toks[:, :, :, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, :, :, 1:], jnp.int32),
            "mask": jnp.ones(toks[:, :, :, 1:].shape, jnp.float32),
        }
        if held_out is None:
            held_out = jax.tree.map(lambda x: x[0, 0], cb)   # client-0 batch
        params, metrics = rnd(params, cb)
        if (r + 1) % args.eval_every == 0 or r == 0:
            loss = float(model.loss(params, held_out)[0])
            print(f"round {r+1:4d}: held-out loss={loss:.4f} "
                  f"|∇f|={float(metrics['full_grad_norm']):.4f} "
                  f"({time.time()-t0:.0f}s)")

    final = float(model.loss(params, held_out)[0])
    print(f"done: final held-out loss {final:.4f} "
          f"(random-init would be ~{np.log(cfg.vocab_size):.2f})")
    return final


if __name__ == "__main__":
    main()
