"""Quickstart: federated optimization in 40 lines.

Generates a non-IID, unbalanced, sparse federated dataset (the paper's §4
setting, scaled down), runs FSVRG (Algorithm 4) for 10 rounds of
communication, and compares against distributed gradient descent.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_logreg_config
from repro.core import FSVRG, FSVRGConfig, build_problem, build_test_problem
from repro.core.baselines import run_gd
from repro.data.synthetic import generate

# 1. a federated dataset: K clients, power-law sizes, per-client skew
cfg = get_logreg_config().scaled(0.005)
ds = generate(cfg, seed=0)
print(f"K={ds.num_clients} clients, n={ds.num_examples} examples, "
      f"d={ds.num_features} features, n_k in "
      f"[{ds.client_sizes.min()}, {ds.client_sizes.max()}]")

# 2. the optimization problem (eq. 8): f(w) = sum_k (n_k/n) F_k(w)
prob = build_problem(ds)          # lambda = 1/n, the paper's choice
test = build_test_problem(ds)

# 3. Federated SVRG — one communication round per iteration
solver = FSVRG(prob, FSVRGConfig(stepsize=1.0))
w = jnp.zeros(prob.d)
for r in range(10):
    w = solver.round(w, jax.random.PRNGKey(r))
    print(f"round {r+1:2d}: objective={float(prob.flat.loss(w)):.5f} "
          f"test_error={float(test.error_rate(w)):.4f}")

# 4. baseline: distributed GD at the same communication budget
w_gd, _ = run_gd(prob, jnp.zeros(prob.d), rounds=10, stepsize=2.0)
print(f"\nFSVRG objective {float(prob.flat.loss(w)):.5f} vs "
      f"GD {float(prob.flat.loss(w_gd)):.5f} at 10 rounds each")
