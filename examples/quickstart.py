"""Quickstart: federated optimization in 40 lines.

Generates a non-IID, unbalanced, sparse federated dataset (the paper's §4
setting, scaled down), runs FSVRG (Algorithm 4) for 10 rounds of
communication through the shared Trainer driver, and compares against
distributed gradient descent — both constructed by name from the solver
registry.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_logreg_config
from repro.core import build_problem, build_test_problem, make_solver
from repro.data.synthetic import generate

# 1. a federated dataset: K clients, power-law sizes, per-client skew
cfg = get_logreg_config().scaled(0.005)
ds = generate(cfg, seed=0)
print(f"K={ds.num_clients} clients, n={ds.num_examples} examples, "
      f"d={ds.num_features} features, n_k in "
      f"[{ds.client_sizes.min()}, {ds.client_sizes.max()}]")

# 2. the optimization problem (eq. 8): f(w) = sum_k (n_k/n) F_k(w)
prob = build_problem(ds)          # lambda = 1/n, the paper's choice
test = build_test_problem(ds)


def evaluate(w):
    return {"f": prob.flat.loss(w), "err": test.error_rate(w)}


# 3. Federated SVRG — one communication round per iteration.  Any solver in
#    the registry works the same way: make_solver(name, prob).fit(rounds).
res = make_solver("fsvrg", prob, stepsize=1.0).fit(10, seed=0,
                                                   eval_fn=evaluate)
for r, p in enumerate(res.history):
    print(f"round {r+1:2d}: objective={p['f']:.5f} test_error={p['err']:.4f}")

# 4. baseline: distributed GD at the same communication budget
res_gd = make_solver("gd", prob, stepsize=2.0).fit(10, seed=0,
                                                   eval_fn=evaluate)
print(f"\nFSVRG objective {res.history[-1]['f']:.5f} vs "
      f"GD {res_gd.history[-1]['f']:.5f} at 10 rounds each")
