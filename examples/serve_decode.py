"""Serving example: batched prefill + autoregressive decode with KV caches,
for any assigned architecture (dense / SWA ring buffer / MoE / Mamba hybrid /
RWKV O(1) state / enc-dec).

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import InputShape
from repro.models import build_model, make_batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg, jnp.float32)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, InputShape("serve", args.prompt_len, args.batch,
                                       "prefill"), dtype=jnp.float32)

    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch)
    print(f"[{args.arch}] prefill {args.batch}x{args.prompt_len}: "
          f"{time.time()-t0:.2f}s")

    step = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits, -1)[:, None]
    generated = [tok]
    t0 = time.time()
    for t in range(args.tokens - 1):
        logits, cache = step(params, tok, cache)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits, -1)[:, None]
        generated.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(generated, axis=1)
    print(f"decoded {args.tokens} tokens/seq x {args.batch} seqs in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print("sample token ids:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
